"""Generate EXPERIMENTS.md tables from dry-run artifacts."""
import json, glob, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from benchmarks.roofline import roofline_row, load_cells, LEVERS

cells = load_cells()
rows = {t: roofline_row(t, c) for t, c in cells.items()}

def fmt(x):
    return f"{x:.3e}"

# ---- dry-run table (single + multi pod, baseline only)
print("## dryrun table")
print("| arch | shape | mesh | status | FLOPs/dev (HLO raw) | HBM bytes/dev | wire bytes/dev | temp bytes/dev | compile s |")
print("|---|---|---|---|---|---|---|---|---|")
for tag in sorted(cells):
    if "__opt" in tag or "__g1" in tag or "__r" in tag.split("__")[-1]:
        continue
    c = cells[tag]
    a, s, m = tag.split("__")[:3]
    if c["status"] != "ok":
        reason = c.get("reason", c.get("error", ""))[:60]
        print(f"| {a} | {s} | {m} | {c['status']}: {reason} | | | | | |")
        continue
    print(f"| {a} | {s} | {m} | ok | {fmt(c['flops_per_device'])} | "
          f"{fmt(c['bytes_accessed_per_device'])} | "
          f"{fmt(c['collectives_scaled']['wire_bytes'])} | "
          f"{fmt(c['memory']['temp_bytes'])} | {c['compile_sec']} |")

print()
print("## roofline table")
print("| arch | shape | mesh | compute s | memory s | collective s | dominant | roofline frac | MODEL_FLOPS | MODEL/HLOraw |")
print("|---|---|---|---|---|---|---|---|---|---|")
for tag in sorted(rows):
    if "__opt" in tag or "__g1" in tag:
        continue
    r = rows[tag]
    if r.get("status") != "ok":
        continue
    if not r["mesh"].startswith("16x16") or "opt" in r["mesh"] or "g1" in r["mesh"]:
        continue      # roofline table is single-pod per the brief
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
          f"{fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} | "
          f"{fmt(r['t_collective_s'])} | **{r['dominant']}** | "
          f"{r['roofline_fraction']:.3f} | {fmt(r['model_flops'])} | "
          f"{r['flops_ratio_raw']:.2f} |")

print()
print("## opt variants")
for tag in sorted(rows):
    if "__opt" not in tag and "__g1" not in tag:
        continue
    r = rows[tag]
    if r.get("status") != "ok":
        continue
    print(f"| {tag} | {fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} | "
          f"{fmt(r['t_collective_s'])} | {r['dominant']} | "
          f"{r['roofline_fraction']:.3f} |")
