"""Baseline vs --constrain optimized sweep: aggregate improvement table."""
import json, glob, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from benchmarks.roofline import roofline_row, load_cells

cells = load_cells()
print("| arch | shape | wire B/dev base | wire B/dev opt | wire gain | frac base | frac opt |")
print("|---|---|---|---|---|---|---|")
gains = []
for tag in sorted(cells):
    if not tag.endswith("__opt"):
        continue
    base_tag = tag[:-5]
    if base_tag not in cells:
        continue
    b, o = cells[base_tag], cells[tag]
    if b.get("status") != "ok" or o.get("status") != "ok":
        continue
    rb = roofline_row(base_tag, b)
    ro = roofline_row(tag, o)
    wb = b["collectives_scaled"]["wire_bytes"]
    wo = o["collectives_scaled"]["wire_bytes"]
    gain = wb / max(wo, 1)
    gains.append(gain)
    arch, shape = base_tag.split("__")[:2]
    print(f"| {arch} | {shape} | {wb:.2e} | {wo:.2e} | {gain:.1f}x "
          f"| {rb['roofline_fraction']:.3f} | {ro['roofline_fraction']:.3f} |")
if gains:
    import math
    geo = math.exp(sum(math.log(g) for g in gains) / len(gains))
    print(f"\ngeomean wire-byte gain over {len(gains)} cells: {geo:.2f}x")
