"""DIMACS/PACE reader edge cases + write/read round-trip (ISSUE 10).

``read_dimacs`` must survive what real instance files actually contain:
comments and blank lines anywhere, mixed ``e u v`` / bare edge lines,
node-weight lines, header-format variants, 0- vs 1-based numbering,
self-loops, duplicate edges, and vertex indices past the header's
``n``."""
import random
import tempfile

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import graph


def _edge_set(g):
    return {(u, v) for u in range(g.n) for v in range(u + 1, g.n)
            if g.adj[u][v]}


def _write(tmp_path, text, name="t.gr"):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


# ------------------------------------------------------------- round-trip

@pytest.mark.parametrize("name", sorted(graph.REGISTRY))
def test_registry_round_trip(name, tmp_path):
    g = graph.REGISTRY[name]()
    p = str(tmp_path / f"{name}.gr")
    graph.write_dimacs(g, p)
    back = graph.read_dimacs(p)
    assert back.n == g.n
    assert _edge_set(back) == _edge_set(g)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_gnp_round_trip(seed):
    rng = random.Random(seed)
    g = graph.gnp(rng.randint(1, 24), rng.choice([0.1, 0.3, 0.6]),
                  seed=seed)
    with tempfile.TemporaryDirectory() as d:
        p = f"{d}/g.gr"
        graph.write_dimacs(g, p)
        back = graph.read_dimacs(p)
    assert back.n == g.n and _edge_set(back) == _edge_set(g)


# ------------------------------------------------------- reader tolerance

def test_comments_and_blanks_anywhere(tmp_path):
    p = _write(tmp_path, "c header comment\n"
                         "\n"
                         "p tw 4 3\n"
                         "1 2\n"
                         "c mid-file comment\n"
                         "% percent comment\n"
                         "\n"
                         "2 3\n"
                         "3 4\n"
                         "c trailing\n")
    g = graph.read_dimacs(p)
    assert g.n == 4 and _edge_set(g) == {(0, 1), (1, 2), (2, 3)}


def test_mixed_e_and_bare_edge_lines_with_node_weights(tmp_path):
    p = _write(tmp_path, "p edge 4 3\n"
                         "n 1 10\n"
                         "e 1 2\n"
                         "3 4\n"
                         "e 2 3\n")
    g = graph.read_dimacs(p)
    assert g.n == 4 and _edge_set(g) == {(0, 1), (1, 2), (2, 3)}


def test_self_loops_dropped_duplicates_collapse(tmp_path):
    p = _write(tmp_path, "p tw 3 5\n"
                         "1 1\n"
                         "1 2\n"
                         "2 1\n"
                         "1 2\n"
                         "2 3\n")
    g = graph.read_dimacs(p)
    assert g.n == 3 and _edge_set(g) == {(0, 1), (1, 2)}


def test_zero_based_file_is_not_shifted(tmp_path):
    p = _write(tmp_path, "p tw 3 2\n0 1\n1 2\n")
    g = graph.read_dimacs(p)
    assert _edge_set(g) == {(0, 1), (1, 2)}


def test_one_based_file_shifts_down(tmp_path):
    p = _write(tmp_path, "p tw 3 2\n1 2\n2 3\n")
    g = graph.read_dimacs(p)
    assert _edge_set(g) == {(0, 1), (1, 2)}


def test_indices_past_header_grow_the_graph(tmp_path):
    p = _write(tmp_path, "p tw 2 2\n1 2\n2 5\n")
    g = graph.read_dimacs(p)
    assert g.n == 5 and _edge_set(g) == {(0, 1), (1, 4)}


def test_header_without_n_uses_edge_span(tmp_path):
    p = _write(tmp_path, "1 2\n2 3\n3 4\n")     # headerless PACE-ish
    g = graph.read_dimacs(p)
    assert g.n == 4 and len(_edge_set(g)) == 3


@pytest.mark.parametrize("header", ["p tw 3 1", "p edge 3 1", "p 3 1"])
def test_header_format_variants(header, tmp_path):
    g = graph.read_dimacs(_write(tmp_path, f"{header}\n1 2\n"))
    assert g.n == 3 and _edge_set(g) == {(0, 1)}


def test_isolated_vertices_survive_via_header_n(tmp_path):
    g = graph.read_dimacs(_write(tmp_path, "p tw 6 1\n1 2\n"))
    assert g.n == 6 and g.n_edges == 1


def test_malformed_header_and_negative_index_raise(tmp_path):
    with pytest.raises(ValueError, match="malformed p header"):
        graph.read_dimacs(_write(tmp_path, "p tw n m\n1 2\n"))
    with pytest.raises(ValueError, match="negative"):
        graph.read_dimacs(_write(tmp_path, "p tw 3 1\n-1 2\n"))


def test_empty_file_reads_as_empty_graph(tmp_path):
    g = graph.read_dimacs(_write(tmp_path, "c nothing here\n\n"))
    assert g.n == 0 and g.n_edges == 0
