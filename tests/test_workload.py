"""Workload generator: spec validation, trace determinism, the
duplicate/iso dials, and the generated-trace → serve_load replay path
(ISSUE 10 / DESIGN.md §16).

The generator's contract is *experiment-grade reproducibility*: a trace
is a pure function of its spec (same spec + seed → byte-identical
arrivals, in any process), every bad spec fails loudly at parse time,
and the duplicate provenance it records (``dup_of``/``iso``) is exactly
what the cache benchmarks key their assertions on."""
import dataclasses
import json
import os
import sys

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import canon, graph
from repro.workload import (Arrival, SpecError, SweepSpec, generate,
                            quick_spec, read_trace, write_trace)

# benchmarks/ is a repo-root namespace package (not on the src path the
# test runner installs) — the replay end of the pipeline lives there
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _dump(arrivals):
    return json.dumps([a.to_json() for a in arrivals], sort_keys=True)


# ------------------------------------------------------------ validation

@pytest.mark.parametrize("mutate,match", [
    (lambda d: d.update(bogus=1), "unknown spec field"),
    (lambda d: d.update(seed="x"), "seed must be an int"),
    (lambda d: d.update(requests=0), "requests must be an int >= 1"),
    (lambda d: d.update(arrival={"kind": "burst"}), "arrival.kind"),
    (lambda d: d.update(arrival={"kind": "poisson", "rate_hz": 0}),
     "rate_hz"),
    (lambda d: d.update(duplicate_rate=1.5), "duplicate_rate"),
    (lambda d: d.update(iso_rate=-0.1), "iso_rate"),
    (lambda d: d.update(sweep={"nodes": [8]}), "both nodes and p"),
    (lambda d: d.update(sweep={"nodes": [0], "p": [0.5]}),
     "nodes entries"),
    (lambda d: d.update(sweep={"nodes": [8], "p": [1.5]}), "p entries"),
    (lambda d: d.update(named={"names": ["not_a_graph"]}),
     "not in graph.REGISTRY"),
    (lambda d: d.update(knobs={"warp_speed": True}), "unknown knob"),
    (lambda d: d.update(knobs={"mode": []}), "empty choice list"),
])
def test_bad_specs_fail_at_parse_time(mutate, match):
    d = {"seed": 1, "requests": 4,
         "sweep": {"nodes": [8], "p": [0.5], "reps": 1}}
    mutate(d)
    with pytest.raises(SpecError, match=match):
        SweepSpec.parse(d)


def test_empty_spec_generates_nothing_and_says_so():
    with pytest.raises(SpecError, match="no instances"):
        SweepSpec.parse({"seed": 0})


def test_defaults_fill_in():
    spec = SweepSpec.parse({"named": {"names": ["petersen"], "reps": 3}})
    assert spec.requests == 3 and spec.arrival_kind == "uniform"
    assert spec.duplicate_rate == 0.0


# ----------------------------------------------------------- determinism

@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_trace_is_a_pure_function_of_the_spec(seed):
    spec = quick_spec(duplicate_rate=0.4, iso_rate=0.5, requests=12,
                      seed=seed)
    a, b = generate(spec), generate(spec)
    assert _dump(a) == _dump(b)
    other = generate(quick_spec(duplicate_rate=0.4, iso_rate=0.5,
                                requests=12, seed=seed + 1))
    assert _dump(a) != _dump(other)


def test_arrival_offsets_monotone_for_both_kinds():
    for arrival in ({"kind": "uniform", "gap_s": 0.01},
                    {"kind": "poisson", "rate_hz": 100.0}):
        spec = SweepSpec.parse({"seed": 3, "requests": 20,
                                "arrival": arrival,
                                "sweep": {"nodes": [8], "p": [0.3],
                                          "reps": 2}})
        ts = [a.t for a in generate(spec)]
        assert ts[0] == 0.0
        assert all(x <= y for x, y in zip(ts, ts[1:]))


def test_knob_draws_are_deterministic_and_in_range():
    spec = SweepSpec.parse({
        "seed": 5, "requests": 24,
        "named": {"names": ["petersen"], "reps": 1},
        "duplicate_rate": 0.3,
        "knobs": {"mode": ["sort", "bloom"], "reconstruct": False,
                  "seed": [0, 1, 2]}})
    a, b = generate(spec), generate(spec)
    assert _dump(a) == _dump(b)
    for arr in a:
        assert arr.knobs["mode"] in ("sort", "bloom")
        assert arr.knobs["reconstruct"] is False
        assert arr.knobs["seed"] in (0, 1, 2)
        if arr.dup_of is not None:      # duplicates replay root knobs
            assert arr.knobs == a[arr.dup_of].knobs


# ------------------------------------------------------ the two dials

def test_duplicate_dial_extremes():
    z = generate(quick_spec(duplicate_rate=0.0, requests=12, seed=2))
    assert all(a.dup_of is None for a in z)
    spec = SweepSpec.parse({"seed": 2, "requests": 12,
                            "named": {"names": ["petersen"]},
                            "duplicate_rate": 1.0})
    full = generate(spec)
    assert full[0].dup_of is None
    assert all(a.dup_of == 0 for a in full[1:])


def test_duplicates_reference_fresh_roots_with_identical_graphs():
    arrivals = generate(quick_spec(duplicate_rate=0.6, iso_rate=0.0,
                                   requests=24, seed=7))
    dups = [a for a in arrivals if a.dup_of is not None]
    assert dups
    for a in dups:
        root = arrivals[a.dup_of]
        assert root.dup_of is None and root.idx < a.idx
        assert not a.iso
        assert (a.n, a.edges) == (root.n, root.edges)


def test_iso_duplicates_are_isomorphic_but_byte_different():
    arrivals = generate(quick_spec(duplicate_rate=0.8, iso_rate=1.0,
                                   requests=24, seed=1))
    isos = [a for a in arrivals if a.iso]
    assert isos
    for a in isos:
        root = arrivals[a.dup_of]
        assert a.name.endswith("_iso") and a.n == root.n
        assert canon.graph_key(a.graph()) == canon.graph_key(root.graph())
    # at least one relabeling actually moved edges (n! >> 1 here)
    assert any(sorted(map(tuple, a.edges)) !=
               sorted(map(tuple, arrivals[a.dup_of].edges)) for a in isos)


def test_fresh_slots_recycle_the_base_pool():
    spec = SweepSpec.parse({"seed": 0, "requests": 7,
                            "named": {"names": ["petersen", "myciel3"]}})
    arrivals = generate(spec)
    assert all(a.dup_of is None for a in arrivals)
    names = sorted(a.name for a in arrivals)
    assert names.count("petersen") + names.count("myciel3") == 7


# --------------------------------------------------------------- traces

def test_trace_round_trip(tmp_path):
    spec = quick_spec(duplicate_rate=0.5, iso_rate=0.5, requests=10,
                      seed=4)
    arrivals = generate(spec)
    p = str(tmp_path / "t.jsonl")
    write_trace(p, arrivals, spec)
    back = read_trace(p)
    assert _dump(back) == _dump(arrivals)
    with open(p) as f:
        meta = json.loads(f.readline())["meta"]
    assert meta["arrivals"] == len(arrivals)
    # tuples come back as JSON lists; compare through one json pass
    want = json.loads(json.dumps(dataclasses.asdict(spec)))
    assert meta["spec"] == want


def test_trace_without_meta_line_still_replays(tmp_path):
    a = Arrival(idx=0, t=0.0, name="hand", n=3,
                edges=[[0, 1], [1, 2]])
    p = str(tmp_path / "bare.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps(a.to_json()) + "\n\n")
    back = read_trace(p)
    assert len(back) == 1 and back[0].graph().n_edges == 2


def test_cli_generates_a_replayable_trace(tmp_path):
    from repro.workload import generator
    out = str(tmp_path / "cli.jsonl")
    rc = generator.main(["--quick", "--requests", "8",
                         "--duplicate-rate", "0.5", "--seed", "3",
                         "--out", out])
    assert rc == 0
    back = read_trace(out)
    assert len(back) == 8
    assert rc == 0 and generator.main(
        ["--quick", "--requests", "0", "--out", out]) == 2  # bad spec


# ------------------------------------------------- end-to-end fast tier

def test_generated_trace_drives_serve_load():
    """The CI smoke in miniature: a quick-spec trace replayed closed-loop
    through the real server with the cache on — every duplicate hits
    (zero-dispatch asserted inside run_trace) and parity holds."""
    from benchmarks.serve_load import run_trace
    arrivals = generate(quick_spec(duplicate_rate=0.5, iso_rate=0.25,
                                   requests=10, seed=6))
    out = run_trace(arrivals, lanes=2, block=32, cache=16, closed=True)
    assert out["n"] == 10
    dups = {a.idx for a in arrivals if a.dup_of is not None}
    assert dups <= set(out["hit_idxs"])
    assert out["cache_stats"]["hits"] == len(out["hit_idxs"])
