"""Traffic shaping for the solve service (ISSUE 6 / DESIGN.md §12).

The SLO contract on top of §11's async pipeline: requests can be
cancelled mid-ladder (their in-flight verdicts discarded uncounted),
deadline-preempted into monotone anytime bounds, prioritised without
starving the base class, and shed with a ``retry_after`` hint when the
admission queue is bounded — while pipelined dispatch (depth > 1) keeps
the device busy across host syncs.  Throughout, every *surviving*
request's result stays bit-identical to sequential ``solver.solve``,
and the request lifecycle can no longer lose a request: admission
failures resolve with an ``error`` terminal event, event sinks are
invoked outside the scheduler lock, and duplicate rids are rejected.
"""
import threading
import time

import pytest

from repro.core import graph, solver
from repro.serve.slots import QueueFull, SlotPool
from repro.serve.twscheduler import TwScheduler

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)


class _Poisoned:
    """A graph-shaped object that explodes inside preprocessing."""
    n = 5
    name = "poisoned"
    adj = None


# ------------------------------------------------------ SlotPool mechanics

def test_slotpool_priority_classes_pop_most_urgent_first():
    pool = SlotPool(1)
    pool.submit("lo1"); pool.submit("lo2")
    pool.submit("hi", priority=3)
    assert pool.queue == ["hi", "lo1", "lo2"]
    assert pool._pop() == "hi"
    assert pool._pop() == "lo1"
    assert pool._pop() == "lo2"


def test_slotpool_weighted_fifo_never_starves_the_base_class():
    pool = SlotPool(1, prio_weight=2)
    for i in range(5):
        pool.submit(f"h{i}", priority=1)
    pool.submit("l0"); pool.submit("l1")
    order = [pool._pop() for _ in range(7)]
    # two preferential pops, then the base class is served once
    assert order == ["h0", "h1", "l0", "h2", "h3", "l1", "h4"]


def test_slotpool_bounded_queue_rejects_over_limit_submits():
    pool = SlotPool(1, max_queue=2)
    pool.submit("a"); pool.submit("b")
    with pytest.raises(QueueFull):
        pool.submit("c")
    assert pool.qsize == 2                     # the reject did not queue
    # admitted items free queue room
    pool.admit(lambda item: item)
    pool.submit("c")                           # fits now


def test_slotpool_discard_removes_a_queued_item():
    pool = SlotPool(1)
    pool.submit("a"); pool.submit("b", priority=1)
    assert pool.discard(lambda it: it == "b") == "b"
    assert pool.discard(lambda it: it == "b") is None
    assert pool.queue == ["a"]


# ----------------------------------------------------------- cancellation

def test_cancel_queued_request_never_runs(event_invariants):
    sched = TwScheduler(lanes=1, **FAST)
    keep = sched.submit(graph.petersen())
    evs = []
    drop = sched.submit(graph.myciel(3), on_event=evs.append)
    assert sched.cancel(drop)
    assert sched.status(drop) == {"state": "cancelled"}
    done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[keep].width, done[keep].expanded) == \
        (ref.width, ref.expanded)
    assert drop not in done
    assert event_invariants(evs, rid=drop)["event"] == "cancelled"


def test_cancel_running_request_frees_the_lane_and_keeps_parity(
        event_invariants):
    """Cancelling mid-flight discards the rid's in-flight verdicts
    uncounted; the surviving request stays bit-identical to its solo
    sequential solve."""
    sched = TwScheduler(lanes=2, **FAST)
    evs = []
    slow = sched.submit(graph.queen(6), on_event=evs.append)
    fast = sched.submit(graph.petersen())
    assert sched.launch()                      # both rungs now in flight
    assert sched.cancel(slow)
    assert sched.pool.free == 1                # the lane freed immediately
    done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[fast].width, done[fast].exact, done[fast].expanded,
            done[fast].per_k) == (ref.width, ref.exact, ref.expanded,
                                  ref.per_k)
    assert slow not in done
    assert sched.terminal[slow] == "cancelled"
    # the cancelled stream keeps the full contract (monotone bounds up
    # to the terminal event included)
    assert event_invariants(evs, rid=slow)["event"] == "cancelled"


def test_cancel_is_idempotent_and_safe_on_unknown_rids():
    sched = TwScheduler(lanes=1, **FAST)
    rid = sched.submit(graph.petersen())
    assert sched.cancel(rid)
    assert not sched.cancel(rid)               # already terminal
    assert not sched.cancel(999)               # never existed
    done = sched.run()
    assert done == {}


# --------------------------------------------------------------- deadlines

def test_deadline_preempts_mid_ladder_with_monotone_anytime_bounds(
        event_invariants):
    sched = TwScheduler(lanes=1, **FAST)
    evs = []
    rid = sched.submit(graph.queen(6), on_event=evs.append)
    assert sched.launch()
    # force the deadline into the past after the first round launched:
    # the next sync's deadline sweep must preempt the lane
    for _i, (req, _inst) in sched.pool.active():
        req.deadline = time.monotonic() - 1.0
    done = sched.run()
    res = done[rid]
    ref = solver.solve(graph.queen(6), **FAST)
    assert not res.exact
    assert res.lb <= ref.width <= res.ub       # genuine anytime bounds
    assert res.expanded < ref.expanded         # preempted: partial work
    assert sched.terminal[rid] == "timeout"
    assert sched.status(rid)["timed_out"] is True
    assert sched.pool.free == 1                # the lane was released
    last = event_invariants(evs, rid=rid)
    assert last["event"] == "done" and last["timed_out"] is True
    assert (last["lb"], last["ub"]) == (res.lb, res.ub)
    bounds = [(e["lb"], e["ub"]) for e in evs if "lb" in e]
    assert all(a[0] <= b[0] and a[1] >= b[1]
               for a, b in zip(bounds, bounds[1:]))


def test_deadline_expired_while_queued_resolves_without_a_lane():
    sched = TwScheduler(lanes=1, **FAST)
    rid = sched.submit(graph.queen(5), deadline_s=0.0)
    done = sched.run()
    res = done[rid]
    assert not res.exact and res.expanded == 0
    assert res.lb == 0 and res.ub == graph.queen(5).n - 1
    assert sched.terminal[rid] == "timeout"


def test_unhit_deadline_changes_nothing():
    sched = TwScheduler(lanes=1, **FAST)
    rid = sched.submit(graph.petersen(), deadline_s=3600.0)
    done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[rid].width, done[rid].exact, done[rid].expanded) == \
        (ref.width, ref.exact, ref.expanded)
    assert sched.terminal[rid] == "done"


# -------------------------------------------------------------- priorities

def test_high_priority_requests_jump_the_admission_queue():
    sched = TwScheduler(lanes=1, **FAST)
    lo = sched.submit(graph.myciel(3))
    hi = sched.submit(graph.petersen(), priority=5)
    order = []
    start = sched._start

    def spy(req):
        order.append(req.rid)
        return start(req)

    sched._start = spy
    done = sched.run()
    assert order[0] == hi and order[1] == lo
    for rid, g in ((lo, graph.myciel(3)), (hi, graph.petersen())):
        ref = solver.solve(g, **FAST)
        assert (done[rid].width, done[rid].expanded) == \
            (ref.width, ref.expanded)


# ------------------------------------------------------------ backpressure

def test_bounded_queue_rejects_with_a_retry_after_hint():
    sched = TwScheduler(lanes=1, max_queue=1, **FAST)
    rid = sched.submit(graph.petersen())
    with pytest.raises(QueueFull) as ei:
        sched.submit(graph.myciel(3))
    assert ei.value.retry_after is not None and ei.value.retry_after > 0
    # the shed submit left no trace: no rid burned, no progress entry
    assert sched._next_rid == rid + 1
    done = sched.run()
    assert set(done) == {rid}


# ------------------------------------------------------ pipelined dispatch

def test_pipeline_depth_2_matches_depth_1_with_fewer_idle_syncs():
    """Depth 2 keeps a second round in flight across each host sync
    (fewer idle host-sync gaps — the device had queued work); results and
    expanded accounting stay bit-identical to depth 1 and to sequential
    ``solver.solve``."""
    suite = [graph.queen(5), graph.myciel(3), graph.petersen()]
    refs = [solver.solve(g, **FAST) for g in suite]
    stats = {}
    for depth in (1, 2):
        sched = TwScheduler(lanes=3, pipeline=depth, **FAST)
        rids = [sched.submit(g) for g in suite]
        done = sched.run()
        for rid, ref in zip(rids, refs):
            assert (done[rid].width, done[rid].exact, done[rid].expanded,
                    done[rid].per_k) == (ref.width, ref.exact,
                                         ref.expanded, ref.per_k)
        stats[depth] = (sched.idle_syncs, sched.covered_syncs)
    assert stats[1][1] == 0                  # depth 1 never has cover
    assert stats[2][1] > 0                   # depth 2 does
    assert stats[2][0] < stats[1][0]         # ... so fewer idle gaps


def test_pipeline_guard_still_rejects_over_depth_launches():
    sched = TwScheduler(lanes=1, pipeline=2, **FAST)
    sched.submit(graph.queen(5))
    assert sched.launch() and sched.launch()
    with pytest.raises(RuntimeError, match="in flight"):
        sched.launch()
    sched.recover()


def test_pipeline_recover_after_failed_sync_keeps_parity():
    sched = TwScheduler(lanes=1, pipeline=2, **FAST)
    rid = sched.submit(graph.queen(5))
    assert sched.launch() and sched.launch()   # two rounds in flight
    no, handles, t0 = sched._rounds[0]
    handle, metas = handles[0]
    handles[0] = (None, metas)                 # .result() -> AttributeError
    with pytest.raises(AttributeError):
        sched.sync()
    sched.recover()
    assert not sched.in_flight
    done = sched.run()                         # re-packs from host state
    ref = solver.solve(graph.queen(5), **FAST)
    assert (done[rid].width, done[rid].exact, done[rid].expanded) == \
        (ref.width, ref.exact, ref.expanded)


# ----------------------------------------------------- lifecycle bugfixes

def test_poisoned_admission_is_isolated_and_emits_error(event_invariants):
    """An exception inside admission (preprocess/bounds/plan) must not
    lose the request or kill the queue: the request resolves with an
    ``error`` terminal event and everything behind it still runs."""
    sched = TwScheduler(lanes=1, **FAST)
    evs = []
    bad = sched.submit(_Poisoned(), on_event=evs.append)
    good = sched.submit(graph.petersen())
    done = sched.run()                         # must not raise or hang
    assert bad not in done
    assert sched.terminal[bad] == "error"
    assert "AttributeError" in sched.errors[bad]
    assert [e["event"] for e in evs] == ["admitted", "error"]
    assert event_invariants(evs, rid=bad)["event"] == "error"
    st = sched.status(bad)
    assert st["state"] == "error" and "AttributeError" in st["error"]
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[good].width, done[good].expanded) == \
        (ref.width, ref.expanded)


def test_event_sinks_run_outside_the_scheduler_lock():
    """A sink must never be invoked under ``_lock`` (a slow sink would
    stall every lane's dispatch): from inside the callback, another
    thread can take the scheduler lock immediately."""
    sched = TwScheduler(lanes=1, **FAST)
    lock_free = []

    def probe(ev):
        got = []

        def try_lock():
            ok = sched._lock.acquire(timeout=5)
            if ok:
                sched._lock.release()
            got.append(ok)

        t = threading.Thread(target=try_lock)
        t.start()
        t.join()
        lock_free.append(got[0])

    rid = sched.submit(graph.petersen(), on_event=probe)
    done = sched.run()
    assert lock_free and all(lock_free)
    assert done[rid].width == solver.solve(graph.petersen(), **FAST).width


def test_event_ordering_guarantees_survive_deferred_delivery(
        event_invariants):
    sched = TwScheduler(lanes=2, **FAST)
    evs = []
    rid = sched.submit(graph.queen(5), speculate=2, on_event=evs.append)
    sched.run()
    assert [e["seq"] for e in evs] == list(range(1, len(evs) + 1))
    assert evs[0]["event"] == "admitted"
    assert event_invariants(evs, rid=rid)["event"] == "done"
    ks = [e["k"] for e in evs if e["event"] == "rung_decided"]
    assert ks == sorted(ks) and ks


def test_duplicate_rid_is_rejected():
    sched = TwScheduler(lanes=1, **FAST)
    rid = sched.submit(graph.petersen())
    with pytest.raises(ValueError, match="already issued"):
        sched.submit(graph.myciel(3), rid=rid)
    fresh = sched.submit(graph.myciel(3), rid=rid + 7)   # gaps are fine
    assert fresh == rid + 7
    assert sched.submit(graph.myciel(3)) == fresh + 1


# ------------------------------------------------- the overload acceptance

def test_synthetic_overload_stream_degrades_gracefully():
    """The acceptance scenario: queue at its bound, mixed priorities, one
    deadline-bound and one cancelled request.  The service rejects with
    ``retry_after``, preempts and cancels correctly, and every surviving
    request's result is bit-identical to sequential ``solver.solve``."""
    sched = TwScheduler(lanes=2, max_queue=2, prio_weight=2, **FAST)
    surv_a = sched.submit(graph.petersen())              # takes a lane
    doomed = sched.submit(graph.queen(6))                # takes a lane
    assert sched.launch()                                # both in flight
    surv_b = sched.submit(graph.myciel(3), priority=1)   # queued, urgent
    victim = sched.submit(graph.queen(5))                # queue at limit
    with pytest.raises(QueueFull) as ei:                 # backpressure
        sched.submit(graph.myciel(4))
    assert ei.value.retry_after is not None and ei.value.retry_after > 0
    assert sched.cancel(victim)                          # cancel queued
    surv_c = sched.submit(graph.queen(5))                # room again
    for _i, (req, _inst) in sched.pool.active():         # deadline-bind
        if req.rid == doomed:
            req.deadline = time.monotonic() - 1.0
    done = sched.run()

    assert sched.terminal[victim] == "cancelled" and victim not in done
    assert sched.terminal[doomed] == "timeout"
    ref_doomed = solver.solve(graph.queen(6), **FAST)
    assert not done[doomed].exact
    assert done[doomed].lb <= ref_doomed.width <= done[doomed].ub
    for rid, g in ((surv_a, graph.petersen()), (surv_b, graph.myciel(3)),
                   (surv_c, graph.queen(5))):
        ref = solver.solve(g, **FAST)
        assert (done[rid].width, done[rid].exact, done[rid].expanded,
                done[rid].per_k) == (ref.width, ref.exact, ref.expanded,
                                     ref.per_k)
