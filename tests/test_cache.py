"""Content-addressed result cache: unit policy + differential parity
(ISSUE 10 / DESIGN.md §16).

The cache must be *invisible* in the result surface: for every golden
instance a warm resubmission returns exactly what the cold solve
returned — width, exactness, bounds, ``expanded``, ``per_k``, and
(when requested) a valid elimination order — while performing zero
device dispatches and resolving at submit time.  Failed work (cancel,
deadline, admission error) must never populate the cache, ``no_cache``
must bypass it in both directions, and the pool-scope cache counters
must reconcile exactly with the cache's own stats (§14)."""
import numpy as np
import pytest

import oracle
from repro.core import engine, graph, solver
from repro.core.telemetry import Tracker
from repro.serve import twscheduler
from repro.serve.cache import ResultCache
from repro.serve.client import TwClient
from repro.serve.twscheduler import TwScheduler
from repro.launch.twserved import TwServer

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)

GOLDEN = oracle.golden_cases()
# the golden tier includes myciel4/queen5_5 — same sizing as the exact
# golden sweep in test_core_solver
GFAST = dict(cap=1 << 16, block=1 << 9)


def _res(width=3, order=None, per_k=None, expanded=10):
    return solver.SolveResult(width=width, exact=True, lb=width,
                              ub=width, expanded=expanded, time_sec=0.0,
                              order=order, per_k=per_k)


def _surface(r):
    return (r.width, r.exact, r.lb, r.ub, r.expanded, r.per_k)


# --------------------------------------------------------- LRU+pin policy

def test_lru_evicts_oldest_and_lookup_refreshes_recency():
    c = ResultCache(entries=2)
    c.insert("a", _res(1)); c.insert("b", _res(2))
    assert c.lookup("a").width == 1      # refresh a: b is now oldest
    assert c.insert("c", _res(3)) == 1
    assert "b" not in c and "a" in c and "c" in c
    assert len(c) == 2


def test_pins_survive_eviction_and_may_exceed_capacity():
    c = ResultCache(entries=1)
    c.insert("a", _res(1))
    assert c.pin("a") and not c.pin("ghost")
    assert c.insert("b", _res(2)) == 0   # a pinned, b fresh: grows past 1
    assert len(c) == 2 and c.stats()["pinned"] == 1
    assert c.unpin("a")
    assert c.insert("d", _res(4)) == 2   # eviction resumes: a and b go
    assert len(c) == 1 and "d" in c


def test_need_order_misses_orderless_then_upgrade_never_downgrades():
    c = ResultCache(entries=4)
    c.insert("k", _res(3))
    assert c.lookup("k", need_order=True) is None        # counted a miss
    assert c.lookup("k").order is None                   # plain hit fine
    c.insert("k", _res(3, order=[2, 0, 1]))              # upgrade
    assert c.lookup("k", need_order=True).order == [2, 0, 1]
    c.insert("k", _res(3))                               # would downgrade
    assert c.peek("k").order == [2, 0, 1]                # refused


def test_hits_return_private_copies():
    c = ResultCache(entries=2)
    c.insert("k", _res(3, order=[0, 1, 2], per_k={"b": {"feasible": 1}}))
    r = c.lookup("k")
    r.order.append(99); r.per_k["x"] = 1
    clean = c.peek("k")
    assert clean.order == [0, 1, 2] and "x" not in clean.per_k


def test_stats_identities_and_validation():
    with pytest.raises(ValueError):
        ResultCache(entries=0)
    c = ResultCache(entries=2)
    c.insert("a", _res(1))
    c.lookup("a"); c.lookup("a"); c.lookup("nope")
    s = c.stats()
    assert s["hits"] + s["misses"] == 3
    assert s["hits"] == 2 and s["entries"] == len(c) == 1
    assert s["insertions"] - s["evictions"] == s["entries"]
    assert s["hit_rate"] == pytest.approx(2 / 3)


# ---------------------------------------------- golden differential parity

@pytest.mark.parametrize("name,gf,want", GOLDEN,
                         ids=[n for n, _, _ in GOLDEN])
def test_golden_warm_hit_is_bit_identical_and_dispatch_free(
        name, gf, want, event_invariants):
    """Cold solve then warm resubmission per golden instance: identical
    full surface, golden width, zero device work on the hit, and a
    contract-clean event stream with the ``cached`` flag."""
    g = gf()
    sched = TwScheduler(lanes=2, cache=ResultCache(32), **GFAST)
    cold_evs, warm_evs = [], []
    r0 = sched.submit(g, on_event=cold_evs.append)
    done = sched.run()
    cold = done[r0]
    assert cold.exact and cold.width == want

    engine.reset_counters()
    r1 = sched.submit(g, on_event=warm_evs.append)
    # a hit resolves entirely at submit — before any driver round
    assert sched.terminal[r1] == "done"
    warm = sched.run()[r1]
    assert dict(engine.COUNTERS).get("dispatches", 0) == 0
    assert dict(engine.COUNTERS).get("expanded", 0) == 0
    assert _surface(warm) == _surface(cold)

    t0 = event_invariants(cold_evs, rid=r0)
    t1 = event_invariants(warm_evs, rid=r1)
    assert t0["event"] == t1["event"] == "done"
    assert not cold_evs[0].get("cached")
    assert all(e.get("cached") for e in warm_evs)
    s = sched.cache_stats()
    assert s["enabled"] and s["hits"] == 1 and s["insertions"] == 1


@pytest.mark.parametrize("backend,mode,shards",
                         [("jax", "sort", 1), ("jax", "bloom", 1),
                          ("jax", "sort", 2), ("pallas", "sort", 1)])
def test_warm_parity_across_backend_mode_shards(backend, mode, shards):
    gs = [graph.petersen(), graph.myciel(3)]
    kw = dict(cap=1 << 12, block=BLOCK, mode=mode, backend=backend,
              m_bits=1 << 14, schedule="doubling")
    sched = TwScheduler(lanes=2, cache=ResultCache(16), **kw)
    cold_r = [sched.submit(g, shards=shards) for g in gs]
    cold = sched.run()
    warm_r = [sched.submit(g, shards=shards) for g in gs]
    warm = sched.run()
    for g, rc, rw in zip(gs, cold_r, warm_r):
        assert _surface(cold[rc]) == _surface(warm[rw]), \
            (g.name, backend, mode, shards)
    assert sched.cache_stats()["hits"] == len(gs)


def test_shards_do_not_split_the_key():
    """Sharding is bit-identical to unsharded (DESIGN.md §13), so it is
    deliberately outside the key: a sharded resubmission hits the
    unsharded entry."""
    g = graph.petersen()
    sched = TwScheduler(lanes=2, cache=ResultCache(8), **FAST)
    r0 = sched.submit(g)
    cold = sched.run()[r0]
    r1 = sched.submit(g, shards=2)
    assert sched.terminal[r1] == "done"
    assert _surface(sched.run()[r1]) == _surface(cold)


def test_iso_relabeled_hit_returns_a_valid_translated_order():
    """A relabeled duplicate hits the canonical entry; the cached order
    (stored in canonical space) is translated back into *its* labels and
    must certify the same width on the relabeled graph."""
    g = graph.petersen()
    rng = np.random.RandomState(9)
    h = g.relabel(rng.permutation(g.n))
    sched = TwScheduler(lanes=2, cache=ResultCache(8), **FAST)
    r0 = sched.submit(g, reconstruct=True)
    cold = sched.run()[r0]
    assert solver.order_width(g, cold.order) == cold.width

    r1 = sched.submit(h, reconstruct=True)
    assert sched.terminal[r1] == "done"          # canonical key: a hit
    warm = sched.run()[r1]
    assert warm.width == cold.width and warm.exact
    assert sorted(warm.order) == list(range(h.n))
    assert solver.order_width(h, warm.order) == cold.width


def test_reconstruct_miss_upgrades_the_entry():
    """An order-less entry misses a reconstruct submission; the re-solve
    upgrades the entry so the *next* reconstruct submission hits."""
    g = graph.petersen()
    sched = TwScheduler(lanes=2, cache=ResultCache(8), **FAST)
    sched.submit(g); sched.run()
    r1 = sched.submit(g, reconstruct=True)
    assert sched.terminal.get(r1) != "done"      # order needed: full solve
    warm = sched.run()[r1]
    assert solver.order_width(g, warm.order) == warm.width
    r2 = sched.submit(g, reconstruct=True)
    assert sched.terminal[r2] == "done"          # upgraded entry hits now
    assert sched.run()[r2].order == warm.order


def test_bloom_hits_identical_bytes_only():
    """mode="bloom" is Monte-Carlo and label-dependent: identical
    resubmission hits, a relabeling must NOT (it would alias a different
    ``expanded`` surface)."""
    g = graph.petersen()
    rng = np.random.RandomState(3)
    h = g.relabel(rng.permutation(g.n))
    kw = dict(cap=1 << 12, block=BLOCK, mode="bloom", m_bits=1 << 14)
    sched = TwScheduler(lanes=2, cache=ResultCache(8), **kw)
    r0 = sched.submit(g)
    sched.run()
    r1 = sched.submit(g)                         # same bytes: hit
    assert sched.terminal[r1] == "done"
    r2 = sched.submit(h)                         # relabeled: fresh solve
    assert sched.terminal.get(r2) != "done"
    done = sched.run()
    assert done[r2].width == done[r0].width      # widths still agree
    assert sched.cache_stats()["insertions"] == 2


# ------------------------------------------------------- negative caching

def test_cancelled_request_is_never_inserted():
    cache = ResultCache(8)
    sched = TwScheduler(lanes=1, cache=cache, **FAST)
    rid = sched.submit(graph.queen(5))
    assert sched.cancel(rid)
    sched.run()
    assert len(cache) == 0 and cache.stats()["insertions"] == 0


def test_deadline_timeout_is_never_inserted():
    cache = ResultCache(8)
    sched = TwScheduler(lanes=1, cache=cache, **FAST)
    rid = sched.submit(graph.queen(5), deadline_s=0.0)
    res = sched.run()[rid]
    assert sched.terminal[rid] == "timeout" and not res.exact
    assert len(cache) == 0 and cache.stats()["insertions"] == 0
    # and the poisoned bounds can't be served to a later submission
    r2 = sched.submit(graph.queen(5))
    assert sched.terminal.get(r2) != "done"
    assert sched.run()[r2].exact


def test_admission_error_is_never_inserted(monkeypatch):
    cache = ResultCache(8)
    sched = TwScheduler(lanes=1, cache=cache, **FAST)

    def boom(*a, **kw):
        raise RuntimeError("admission blew up")

    monkeypatch.setattr(twscheduler.batch, "InstanceState", boom)
    rid = sched.submit(graph.petersen())
    sched.run()
    assert sched.terminal[rid] == "error"
    assert len(cache) == 0 and cache.stats()["insertions"] == 0


def test_no_cache_bypasses_lookup_and_insert():
    cache = ResultCache(8)
    sched = TwScheduler(lanes=1, cache=cache, **FAST)
    g = graph.petersen()
    r0 = sched.submit(g, no_cache=True)          # no insert
    sched.run()
    assert len(cache) == 0
    r1 = sched.submit(g)
    cold = sched.run()[r1]
    r2 = sched.submit(g, no_cache=True)          # no lookup: fresh solve
    assert sched.terminal.get(r2) != "done"
    res = sched.run()[r2]
    s = cache.stats()
    assert s["hits"] == 0 and s["insertions"] == 1
    assert _surface(res) == _surface(cold) == _surface(sched.done[r0])


def test_heuristic_only_requests_skip_the_cache():
    cache = ResultCache(8)
    sched = TwScheduler(lanes=1, cache=cache, **FAST)
    rid = sched.submit(graph.petersen(), heuristic_only=True, seed=1)
    sched.run()
    assert rid in sched.done
    assert len(cache) == 0 and cache.stats()["misses"] == 0


# --------------------------------------------------- telemetry + the wire

def test_cache_counters_reconcile_with_cache_stats():
    """§14: pool-scope cache_{hits,misses,insertions,evictions} equal
    the cache's own stats after a mixed hit/miss stream."""
    cache = ResultCache(2)
    sched = TwScheduler(lanes=2, cache=cache, tracker=Tracker(), **FAST)
    gs = [graph.petersen(), graph.myciel(3), graph.grid(3, 4),
          graph.petersen()]
    for g in gs:
        sched.submit(g)
    sched.run()
    for g in gs[:2]:
        sched.submit(g)
    sched.run()
    pool = sched.metrics()["pool"]["counters"]
    s = cache.stats()
    for k in ("hits", "misses", "insertions", "evictions"):
        assert pool.get(f"cache_{k}", 0) == s[k], (k, pool, s)
    assert s["evictions"] > 0                    # capacity 2 really churned


def test_cache_over_the_wire():
    srv = TwServer(port=0, lanes=2, cap=1 << 12, block=BLOCK,
                   m_bits=1 << 14, cache=8)
    srv.start()
    try:
        c = TwClient(port=srv.port)
        rid = c.submit("petersen")
        cold = c.result(rid)
        s0 = c.cache_stats()
        assert s0["enabled"] and s0["insertions"] == 1

        rid2 = c.submit("petersen")
        evs = list(c.stream(rid2))
        assert evs and all(e.get("cached") for e in evs)
        warm = c.result(rid2)
        for f in ("width", "exact", "lb", "ub", "expanded", "per_k"):
            assert warm[f] == cold[f], f
        assert c.cache_stats()["hits"] == 1

        rid3 = c.submit("petersen", no_cache=True)
        bypass = c.result(rid3)
        assert bypass["width"] == cold["width"]
        s = c.cache_stats()
        assert s["hits"] == 1 and s["insertions"] == 1   # untouched
    finally:
        srv.close()


def test_cacheless_server_reports_disabled():
    sched = TwScheduler(lanes=1, **FAST)         # library default: off
    assert sched.cache_stats() == {"enabled": False}
    rid = sched.submit(graph.petersen())
    assert sched.terminal.get(rid) != "done"
    assert sched.run()[rid].exact
