"""Anytime heuristic bounds engine (DESIGN.md §15), oracle-verified.

Every claim the bounds engine makes is a certificate the suite can
check: an upper bound carries an elimination order whose host replay
(``solver.order_width``) must reproduce it, a lower bound must sit at or
below the exact treewidth (``tests/oracle.py``'s Held-Karp DP / the
golden-widths file).  The property tests pin the sandwich
``lb <= tw <= ub`` and replay-validity across random graphs and seeds;
the scheduler tests pin the monotone-tightening contract — heuristics
may shrink the exact ladder (skipped rungs), never change a verdict.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import oracle
from repro.core import batch, bounds, bounds_engine, graph, solver, telemetry
from repro.serve.twscheduler import TwScheduler

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)


# ----------------------------------------------------- oracle sandwich (host)

@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_bounds_sandwich_the_exact_treewidth(seed):
    """lb <= tw <= ub for quick_bounds and any number of improver
    rounds, against the exact python DP."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(4, 10))
    g = graph.gnp(n, float(rng.uniform(0.2, 0.7)), seed)
    tw = oracle.tw_oracle(g)
    lb, ub, order = bounds_engine.quick_bounds(g, seed=seed)
    assert lb <= tw <= ub
    assert solver.order_width(g, order) == ub
    imp = bounds_engine.improve(g, lb, ub, order, rounds=3, seed=seed)
    assert lb <= imp.lb <= tw <= imp.ub <= ub


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_heuristic_orders_replay_to_a_width_geq_tw(seed):
    """Every heuristic elimination order is a genuine certificate: the
    host replay of the order gives exactly the reported ub, which can
    never undercut the true treewidth."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(4, 10))
    g = graph.gnp(n, float(rng.uniform(0.2, 0.7)), seed)
    tw = oracle.tw_oracle(g)
    for strat in bounds_engine._UB_STRATEGIES:
        w, order = bounds.randomized_order(g, seed, strat)
        assert oracle.order_is_valid(g, order)
        assert solver.order_width(g, order) == w >= tw


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_contraction_lb_below_tw(seed):
    """Every contracted graph is a minor, so the sweep's bound is
    sound."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(3, 10))
    g = graph.gnp(n, float(rng.uniform(0.15, 0.7)), seed)
    assert bounds_engine.contraction_lb(g, seed) <= oracle.tw_oracle(g)


def test_improvers_are_deterministic_per_seed():
    g = graph.mcgee()
    a = bounds_engine.improve(g, rounds=4, seed=11)
    b = bounds_engine.improve(g, rounds=4, seed=11)
    assert (a.lb, a.ub, a.ub_order) == (b.lb, b.ub, b.ub_order)
    assert bounds.randomized_order(g, 5) == bounds.randomized_order(g, 5)
    assert bounds_engine.contraction_lb(g, 7) == \
        bounds_engine.contraction_lb(g, 7)
    assert bounds.upper_bound(g, seed=3, restarts=2) == \
        bounds.upper_bound(g, seed=3, restarts=2)
    assert bounds.lower_bound(g, seed=3) == bounds.lower_bound(g, seed=3)


def test_default_seed_reproduces_the_historical_deterministic_bounds():
    """seed=0, restarts=0 must be the exact pre-seeding behaviour: the
    rank tiebreak degenerates to the vertex index."""
    for g in [graph.petersen(), graph.myciel(3), graph.grid(4, 5)]:
        ub, order = bounds.upper_bound(g)
        w, o = bounds._elimination_ub(g, "min_degree")
        w2, o2 = bounds._elimination_ub(g, "min_fill")
        assert ub == min(w, w2)
        assert order in (o, o2)


# ------------------------------------------------ batched jax kernel parity

def test_vmapped_ub_kernel_widths_match_host_replay():
    """The one-dispatch pooled sweep returns (width, order) pairs whose
    host replay reproduces the width exactly — mixed sizes padded to a
    shared n, pad vertices filtered back out."""
    gs = [graph.petersen(), graph.myciel(3), graph.grid(4, 5)]
    tr = telemetry.Tracker()
    h = bounds_engine.ub_orders_async(gs, [3, 4, 5], tracker=tr)
    out = h.result()
    assert len(out) == len(gs)
    for g, (w, order) in zip(gs, out):
        assert oracle.order_is_valid(g, order)
        assert solver.order_width(g, order) == w
    c = tr.snapshot()["counters"]
    assert c["heur_dispatches"] == 1 and c["heur_lanes"] == len(gs)


def test_vmapped_ub_kernel_is_deterministic_and_seed_sensitive():
    g = graph.petersen()
    a = bounds_engine.ub_orders_async([g], [9]).result()
    b = bounds_engine.ub_orders_async([g], [9]).result()
    assert a == b
    outs = {tuple(bounds_engine.ub_orders_async([g], [s]).result()[0][1])
            for s in range(6)}
    assert len(outs) > 1          # distinct seeds explore distinct sweeps


def test_ub_orders_async_empty_pool_is_a_noop():
    assert bounds_engine.ub_orders_async([], []).result() == []


# --------------------------------------------- exact-instance bound clamping

PLAN_KW = dict(use_clique=True, use_paths=True, start_k=None)


def test_instance_improve_bounds_clamps_the_ladder_monotonically():
    g = graph.queen(5)                     # tw 18: a long ladder
    inst = batch.InstanceState(g, solver, use_preprocess=False,
                               plan_kw=dict(PLAN_KW))
    run = inst.run
    lb0, ub0 = inst.bounds()
    # a worse ub (no certificate needed to reject) and a worse lb: no-op
    out = inst.improve_bounds(lb=lb0 - 1, ub=ub0 + 1, ub_order=None)
    assert out == dict(lb_improved=False, ub_improved=False,
                       rungs_skipped=0, finished=False)
    assert inst.bounds() == (lb0, ub0)
    # an improved ub without its order certificate must be rejected
    out = inst.improve_bounds(ub=ub0 - 1, ub_order=None)
    assert not out["ub_improved"] and inst.bounds() == (lb0, ub0)
    # a genuine lb jump skips the refuted rungs: run.k snaps up
    k0 = run.k
    out = inst.improve_bounds(lb=k0 + 2)
    assert out["lb_improved"] and out["rungs_skipped"] == 2
    assert run.k == k0 + 2 and inst.bounds()[0] == k0 + 2


def test_instance_improve_bounds_ub_certificate_can_finish_the_run():
    g = graph.petersen()
    inst = batch.InstanceState(g, solver, use_preprocess=False,
                               plan_kw=dict(PLAN_KW))
    lb0, _ub0 = inst.bounds()
    # hand it a perfect certificate: an order of the exact width, with
    # lb pushed to meet it -> the run finishes without any DP rung
    r = solver.solve(g, reconstruct=True, use_preprocess=False, **FAST)
    out = inst.improve_bounds(lb=r.width, ub=r.width, ub_order=r.order)
    assert out["finished"] and inst.result is not None
    assert inst.result.width == r.width and inst.result.exact
    assert solver.order_width(g, inst.result.order) == r.width


# ------------------------------------------------- scheduler: exact parity

@pytest.mark.parametrize("heuristics", [0, 4])
def test_pool_with_improver_lanes_keeps_exact_verdicts(heuristics):
    """The acceptance criterion: with the bounds engine on, every final
    verdict (width, exact) is bit-identical to the sequential solver —
    the improvers may only shorten the ladder."""
    gs = [graph.petersen(), graph.myciel(3), graph.queen(4),
          graph.gnp(13, 0.3, 7)]
    sched = TwScheduler(lanes=4, heuristics=heuristics, **FAST)
    rids = [sched.submit(g) for g in gs]
    done = sched.run()
    for rid, g in zip(rids, gs):
        ref = solver.solve(g, **FAST)
        assert (done[rid].width, done[rid].exact) == \
            (ref.width, ref.exact), g.name
        if heuristics == 0:
            # engine off: not just verdicts — bit-identical accounting
            assert (done[rid].expanded, done[rid].per_k) == \
                (ref.expanded, ref.per_k), g.name


def test_improver_lanes_skip_exact_rungs_and_stream_bounds(
        event_invariants):
    """Forcing the full ladder (start_k=0) on petersen: the improver's
    randomized sweep finds the width-4 certificate before the ladder
    climbs there, so rungs are skipped, the `bounds` event fires, the
    telemetry reconciles — and the verdict is still exactly (4, True)."""
    evs = []
    sched = TwScheduler(lanes=1, pipeline=2, heuristics=8, **FAST)
    rid = sched.submit(graph.petersen(), start_k=0, on_event=evs.append)
    done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[rid].width, done[rid].exact) == (ref.width, ref.exact)

    base = TwScheduler(lanes=1, pipeline=2, **FAST)
    rid0 = base.submit(graph.petersen(), start_k=0)
    base.run()

    snap = sched.tracker.snapshot()["counters"]
    snap0 = base.tracker.snapshot()["counters"]
    assert snap["heur_ub_improvements"] >= 1
    assert snap["exact_rungs_skipped"] >= 1
    assert snap["rungs_decided"] < snap0["rungs_decided"]
    # the pool totals reconcile the per-request child scope (§14)
    req = sched.req_metrics[rid]["counters"]
    assert req["exact_rungs_skipped"] == snap["exact_rungs_skipped"]

    assert event_invariants(evs, rid=rid)["event"] == "done"
    assert any(e["event"] == "bounds" for e in evs)


def test_solver_heuristics_knob_plans_a_tighter_ladder():
    """solve(heuristics=N) applies the same improvers at plan time:
    same verdict, never more expanded states."""
    g = graph.petersen()
    a = solver.solve(g, start_k=0, **FAST)
    b = solver.solve(g, start_k=0, heuristics=8, **FAST)
    assert (a.width, a.exact) == (b.width, b.exact)
    assert b.expanded <= a.expanded
    c = solver.solve(g, start_k=0, heuristics=8, **FAST)
    assert (b.width, b.expanded, b.per_k) == (c.width, c.expanded, c.per_k)


# ------------------------------------------------ scheduler: heuristic-only

@pytest.mark.parametrize("name,spec",
                         sorted(oracle.golden_widths().items()),
                         ids=sorted(oracle.golden_widths()))
def test_heuristic_only_bounds_are_oracle_valid(name, spec,
                                                event_invariants):
    """Bounds-only serving on every golden instance — including the
    ``slow``-flagged ones the fast exact tier cannot finish: the stream
    obeys the event contract and the terminal bounds sandwich the known
    exact width, with ``exact == (lb == ub)``."""
    g = oracle.make_graph(name)
    evs = []
    sched = TwScheduler(lanes=2, **FAST)
    rid = sched.submit(g, heuristic_only=True, heuristics=6, seed=1,
                       on_event=evs.append)
    done = sched.run()
    res = done[rid]
    assert res.lb <= spec["tw"] <= res.ub, (name, res)
    assert res.exact == (res.lb == res.ub)
    assert res.width == res.ub
    assert res.order is not None
    assert oracle.order_is_valid(g, res.order)
    assert solver.order_width(g, res.order) <= res.ub
    term = event_invariants(evs, rid=rid)
    assert term["event"] == "done"
    assert (term["lb"], term["ub"]) == (res.lb, res.ub)
    assert not any(e["event"] in ("rung_started", "rung_decided")
                   for e in evs)                 # no exact rung ever ran


def test_heuristic_only_is_deterministic_per_seed():
    kw = dict(heuristic_only=True, heuristics=4, seed=5)
    outs = []
    for _ in range(2):
        sched = TwScheduler(lanes=1, **FAST)
        rid = sched.submit(graph.mcgee(), **kw)
        res = sched.run()[rid]
        outs.append((res.lb, res.ub, tuple(res.order)))
    assert outs[0] == outs[1]


def test_heuristic_only_mixes_with_exact_requests_in_one_pool():
    sched = TwScheduler(lanes=2, **FAST)
    r_h = sched.submit(graph.mcgee(), heuristic_only=True, heuristics=4)
    r_e = sched.submit(graph.petersen())
    done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[r_e].width, done[r_e].exact, done[r_e].expanded) == \
        (ref.width, ref.exact, ref.expanded)
    assert done[r_h].lb <= 7 <= done[r_h].ub     # mcgee tw = 7
    assert done[r_h].expanded == 0               # no DP work at all


def test_heuristic_only_rejects_sharding():
    sched = TwScheduler(lanes=2, **FAST)
    with pytest.raises(ValueError, match="heuristic_only"):
        sched.submit(graph.petersen(), heuristic_only=True, shards=2)
