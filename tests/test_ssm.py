"""Recurrent-block correctness: chunked training paths vs sequential refs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import ssm
from repro.models.params import init_params

CFG = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32, n_heads=4,
                  n_kv=4, d_ff=0, vocab=64,
                  ssm=SSMConfig(d_state=8, expand=2.0, chunk=8))


def test_mamba_chunked_matches_sequential():
    p = init_params(ssm.mamba_spec(CFG), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    got = ssm.mamba_block(p, x, CFG)
    want = ssm.mamba_ref(p, x, CFG)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_mamba_chunk_invariance(chunk):
    cfg = CFG.replace(ssm=SSMConfig(d_state=8, expand=2.0, chunk=chunk))
    p = init_params(ssm.mamba_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    base = ssm.mamba_ref(p, x, cfg)
    assert float(jnp.max(jnp.abs(ssm.mamba_block(p, x, cfg) - base))) < 1e-4


def test_mamba_nondivisible_length():
    p = init_params(ssm.mamba_spec(CFG), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 27, 32)) * 0.5
    got = ssm.mamba_block(p, x, CFG)
    want = ssm.mamba_ref(p, x, CFG)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


def test_mamba_decode_matches_train():
    p = init_params(ssm.mamba_spec(CFG), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32)) * 0.5
    full = ssm.mamba_block(p, x, CFG)
    st = None
    outs = []
    for t in range(12):
        if st is None:
            o, st = ssm.mamba_block(p, x[:, :1], CFG, return_state=True)
        else:
            o, st = ssm.mamba_decode(p, x[:, t:t + 1], CFG, st)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 1e-4


def test_mlstm_chunkwise_matches_sequential():
    b, s, h, hd = 2, 32, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (b, s, h)) * 2)
    li = jax.random.normal(ks[4], (b, s, h))
    got, _ = ssm.mlstm_inner(q, k, v, lf, li, chunk=8)
    want = ssm.mlstm_ref_inner(q, k, v, lf, li)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3


@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_mlstm_chunk_invariance(chunk):
    b, s, h, hd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (b, s, h)))
    li = jax.random.normal(ks[4], (b, s, h))
    want = ssm.mlstm_ref_inner(q, k, v, lf, li)
    got, _ = ssm.mlstm_inner(q, k, v, lf, li, chunk=chunk)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3


def test_mlstm_extreme_gates_stable():
    """Exponential input gates with large pre-activations must not NaN."""
    b, s, h, hd = 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (b, s, h)) * 10)
    li = jax.random.normal(ks[4], (b, s, h)) * 20   # exp(20) overflows naive
    got, _ = ssm.mlstm_inner(q, k, v, lf, li, chunk=4)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_slstm_decode_matches_scan():
    p = init_params(ssm.slstm_spec(CFG), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32)) * 0.5
    full = ssm.slstm_block(p, x, CFG)
    st = None
    outs = []
    for t in range(10):
        o, st = ssm.slstm_block(p, x[:, t:t + 1], CFG, state=st,
                                return_state=True)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 1e-4
