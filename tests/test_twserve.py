"""Treewidth solve service: scheduler parity, memory planning, slot pool.

The service contract (ISSUE 4 / DESIGN.md §10): N concurrent requests
through ``TwScheduler`` produce results bit-identical to per-request
``solver.solve`` — width, exactness, bounds, ``expanded``, ``per_k`` and
(when requested) the reconstructed elimination order — in strictly fewer
dispatches, with the pooled frontier buffers sized by
``batch.plan_capacity`` instead of the fixed worst-case cap.
"""
import warnings

import numpy as np
import pytest

from repro.core import backend as backend_lib
from repro.core import batch, bitset, engine, frontier, graph, solver
from repro.serve.slots import SlotPool
from repro.serve.twscheduler import TwScheduler

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)


def _request_stream():
    """Mixed sizes and depths so lanes genuinely interleave requests."""
    return [graph.petersen(), graph.myciel(3), graph.grid(3, 4),
            graph.gnp(12, 0.3, 7), graph.desargues(), graph.petersen()]


def _serve(gs, *, lanes=3, reconstruct=False, **kw):
    sched = TwScheduler(lanes=lanes, **kw)
    rids = [sched.submit(g, reconstruct=reconstruct) for g in gs]
    done = sched.run()
    return [done[r] for r in rids], sched


# ------------------------------------------------------------ result parity

def test_service_matches_sequential_solve_with_fewer_dispatches():
    """The acceptance criterion: full result-surface parity per request,
    and the whole stream in fewer dispatches than per-request solving."""
    gs = _request_stream()
    engine.reset_counters()
    seq = [solver.solve(g, **FAST) for g in gs]
    seq_c = dict(engine.COUNTERS)
    engine.reset_counters()
    srv, sched = _serve(gs, **FAST)
    srv_c = dict(engine.COUNTERS)
    for g, a, b in zip(gs, seq, srv):
        assert (a.width, a.exact, a.expanded, a.lb, a.ub, a.per_k) == \
            (b.width, b.exact, b.expanded, b.lb, b.ub, b.per_k), g.name
    assert srv_c["dispatches"] < seq_c["dispatches"]
    assert srv_c["host_syncs"] < seq_c["host_syncs"]
    assert sched.rounds == srv_c["dispatches"]


@pytest.mark.parametrize("backend,mode", [("jax", "sort"), ("jax", "bloom"),
                                          ("pallas", "sort")])
def test_service_backend_mode_matrix(backend, mode):
    """Parity across backend x dedup.  All instances here stay inside one
    32-vertex word, so even bloom (hash-sensitive to the padded word
    count, DESIGN.md §8/§10) is bit-identical to the solo runs."""
    gs = [graph.petersen(), graph.myciel(3), graph.grid(3, 4)]
    kw = dict(cap=1 << 12, block=BLOCK, mode=mode, backend=backend,
              m_bits=1 << 14, schedule="doubling")
    seq = [solver.solve(g, **kw) for g in gs]
    srv, _ = _serve(gs, lanes=2, **kw)
    for g, a, b in zip(gs, seq, srv):
        assert (a.width, a.exact, a.expanded, a.per_k) == \
            (b.width, b.exact, b.expanded, b.per_k), (g.name, backend, mode)


def test_service_reconstruction_parity():
    """reconstruct=True requests return the identical certified order the
    sequential solver produces (same host-level snapshots, same backtrack),
    with expanded parity — the certification replay is uncounted."""
    gs = [graph.petersen(), graph.queen(5)]
    seq = [solver.solve(g, reconstruct=True, **FAST) for g in gs]
    srv, _ = _serve(gs, lanes=2, reconstruct=True, **FAST)
    for g, a, b in zip(gs, seq, srv):
        assert a.order == b.order, g.name
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded), g.name
        assert solver.order_width(g, b.order) == b.width == a.width


def test_service_reconstruction_stitches_articulated_instances():
    """Reconstruction composes with preprocessing inside the service: an
    articulated instance is solved block-by-block in lanes and the block
    orders are stitched back to one certified global order."""
    adj = np.zeros((12, 12), dtype=bool)
    for u in range(5):
        for v in range(u + 1, 5):
            adj[u, v] = adj[v, u] = True
    for u in range(4, 9):
        for v in range(u + 1, 9):
            adj[u, v] = adj[v, u] = True
    adj[8, 9] = adj[9, 8] = adj[9, 10] = adj[10, 9] = True
    g = graph.Graph(12, adj, "barbell")
    ref = solver.solve(g, reconstruct=True, **FAST)
    srv, _ = _serve([g, graph.petersen()], lanes=2, reconstruct=True, **FAST)
    assert srv[0].order is not None
    assert sorted(srv[0].order) == list(range(g.n))
    assert srv[0].order == ref.order
    assert solver.order_width(g, srv[0].order) <= srv[0].width == ref.width


def test_more_requests_than_lanes_fifo_recycling():
    """Requests beyond the pool wait in FIFO order; finished lanes recycle
    to queued requests; everything completes with per-request parity."""
    gs = [graph.petersen(), graph.myciel(3), graph.grid(3, 4),
          graph.petersen(), graph.gnp(11, 0.35, 3), graph.myciel(3),
          graph.grid(2, 5)]
    srv, sched = _serve(gs, lanes=2, **FAST)
    assert len(srv) == len(gs)
    assert sorted(sched.done) == list(range(len(gs)))
    for g, b in zip(gs, srv):
        a = solver.solve(g, **FAST)
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded), g.name


def test_trivial_requests_never_occupy_a_lane():
    """Trivial instances (empty graph, singleton, clique: lb >= ub decides
    at plan time) finish at admission and are recycled straight through —
    a stream of only trivial requests issues zero dispatches."""
    empty = graph.Graph(0, np.zeros((0, 0), dtype=bool), "empty")
    single = graph.Graph(1, np.zeros((1, 1), dtype=bool), "single")
    gs = [empty, single, graph.complete(5)]
    engine.reset_counters()
    srv, sched = _serve(gs, lanes=2, **FAST)
    assert dict(engine.COUNTERS)["dispatches"] == 0
    assert sched.rounds == 0
    for g, b in zip(gs, srv):
        a = solver.solve(g, **FAST)
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded), g.name


def test_service_start_k_and_forced_inexactness():
    """Per-request start_k rides through admission planning, including the
    warn-and-return path (start_k >= ub finishes at admission)."""
    g = graph.petersen()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        seq = [solver.solve(g, use_preprocess=False, start_k=sk, **FAST)
               for sk in (1, 4, 50)]
        sched = TwScheduler(lanes=2, use_preprocess=False, **FAST)
        rids = [sched.submit(g, start_k=sk) for sk in (1, 4, 50)]
        done = sched.run()
    for sk, rid, a in zip((1, 4, 50), rids, seq):
        b = done[rid]
        assert (a.width, a.exact, a.expanded, a.lb, a.ub) == \
            (b.width, b.exact, b.expanded, b.lb, b.ub), sk


def test_service_validates_configuration_at_construction():
    with pytest.raises(backend_lib.BackendCapabilityError):
        TwScheduler(lanes=2, backend="pallas", schedule="while")
    with pytest.raises(backend_lib.BackendCapabilityError):
        TwScheduler(lanes=2, mode="nope")
    with pytest.raises(ValueError):
        TwScheduler(lanes=0)


# --------------------------------------------------------- memory planning

def test_plan_capacity_small_blocks_beat_fixed_footprint():
    """The acceptance criterion: for small blocks the planned batched
    footprint is strictly below the fixed-cap footprint — per lane and
    for the whole pool."""
    fixed = 1 << 18
    for n in (6, 10, 12, 14):
        cap = batch.plan_capacity(n, 1, lanes=8, block=1 << 11,
                                  cap_max=fixed)
        assert cap < fixed, n
        assert frontier.frontier_bytes(cap, 1, lanes=8) < \
            frontier.frontier_bytes(fixed, 1, lanes=8), n
    # n=10: 4096 rows instead of 2^18 — a 64x per-lane cut
    assert batch.plan_capacity(10, block=1 << 11, cap_max=fixed) == 4096


def test_plan_capacity_non_pow2_cap_max_is_a_ceiling():
    """An explicit cap_max must never be exceeded: non-power-of-two values
    round DOWN (100000 -> 65536), not up past the user's stated maximum."""
    assert batch.plan_capacity(25, cap_max=100_000) == 1 << 16
    assert batch.plan_capacity(25, cap_max=1 << 16) == 1 << 16


def test_scheduler_budget_survives_word_count_growth():
    """The budget outranks the cap ratchet: when a wider instance grows the
    padded word count, a cap ratcheted under W=1 must shrink so the pool
    stays within budget_bytes (lanes * cap * W * 4)."""
    budget = 2 * 1024 * 1 * 4            # exactly 2 lanes x 1024 rows x W=1
    sched = TwScheduler(lanes=2, block=BLOCK, budget_bytes=budget)
    sched.submit(graph.petersen())       # W=1 round: cap ratchets <= 1024
    sched.run()
    assert max(sched._cap_pad.values()) * 2 * 1 * 4 <= budget
    sched.submit(graph.grid(5, 8))       # one biconnected n=40 block -> W=2
    sched.run()
    w = bitset.n_words(sched._n_pad)
    assert w == 2
    assert max(sched._cap_pad.values()) * 2 * w * 4 <= budget
    assert sched.pool_bytes() <= budget


def test_plan_capacity_bounds_and_clamps():
    # power of two, floored at 32 and at the chunk block (chunk geometry
    # must match a fixed-cap run for bloom-mode bit-parity)
    assert batch.plan_capacity(1, block=32) == 32
    assert batch.plan_capacity(4, block=1 << 11) == 2048
    # large n clamps to cap_max exactly like the fixed default did
    assert batch.plan_capacity(25) == batch.DEFAULT_CAP
    assert batch.plan_capacity(64, cap_max=1 << 12) == 1 << 12
    # a budget bounds the whole pool: lanes * cap * W * 4 <= budget
    budget = 8 * 1024 * 4
    cap = batch.plan_capacity(14, 1, lanes=8, block=32,
                              budget_bytes=budget)
    assert cap * 8 * 4 <= budget
    # the budget floor never goes below the engine's smallest chunk
    assert batch.plan_capacity(14, 1, lanes=8, block=32,
                               budget_bytes=1) == 32


def test_plan_capacity_is_drop_free_for_small_blocks():
    """The parity guarantee behind auto-sizing: a planned cap never drops
    a state, so results (incl. exactness) match the fixed cap bit for
    bit.  gnp(13, .5) floods levels hard; the planned cap must hold."""
    for seed in (0, 1, 2):
        g = graph.gnp(13, 0.5, seed)
        a = solver.solve(g, cap=batch.DEFAULT_CAP, block=BLOCK)
        b = solver.solve(g, cap=None, block=BLOCK)
        assert (a.width, a.exact, a.expanded, a.per_k) == \
            (b.width, b.exact, b.expanded, b.per_k), seed
        assert a.exact     # nothing dropped at the planned cap either


def test_decide_lanes_auto_cap_parity():
    """decide_lanes(cap=None) plans from its largest lane and stays
    bit-identical to explicitly fixed-cap lanes."""
    gs = [graph.petersen(), graph.myciel(3), graph.grid(3, 4)]
    lanes = [batch.Lane(g, k) for g in gs for k in (2, 4)]
    kw = dict(block=BLOCK, mode="sort", use_mmw=False, m_bits=1 << 12,
              k_hashes=4, schedule="doubling")
    auto = batch.decide_lanes(lanes, cap=None, **kw)
    fixed = batch.decide_lanes(lanes, cap=1 << 12, **kw)
    for a, b in zip(auto, fixed):
        assert (a.feasible, a.inexact, a.expanded) == \
            (b.feasible, b.inexact, b.expanded)


def test_service_pool_bytes_reports_planned_footprint():
    gs = [graph.petersen(), graph.myciel(3)]
    srv, sched = _serve(gs, lanes=4, block=BLOCK)
    fixed_pool = frontier.frontier_bytes(batch.DEFAULT_CAP,
                                         bitset.n_words(32), lanes=4)
    assert 0 < sched.pool_bytes() < fixed_pool


def test_frontier_bytes_formula():
    assert frontier.frontier_bytes(1024, 1) == 4096
    assert frontier.frontier_bytes(1024, 2, lanes=8) == 8 * 1024 * 2 * 4


# -------------------------------------------------------------- slot pool

def test_slot_pool_fifo_admission_and_recycling():
    pool = SlotPool(2)
    for x in "abcd":
        pool.submit(x)
    got = pool.admit(lambda x: x.upper())
    assert got == [(0, "A"), (1, "B")]
    assert pool.active() == [(0, "A"), (1, "B")]
    pool.release(0)
    assert pool.admit(lambda x: x.upper()) == [(0, "C")]
    assert pool.busy
    pool.release(0)
    pool.release(1)
    assert pool.admit(lambda x: x.upper()) == [(0, "D")]
    pool.release(0)
    assert not pool.busy


def test_slot_pool_instant_finish_recycles_within_admission():
    """start() returning None (finished at admission) must not burn the
    slot: the same slot immediately tries the next queued item."""
    pool = SlotPool(1)
    for x in [0, 0, 3, 5]:
        pool.submit(x)
    started = pool.admit(lambda x: x if x else None)
    assert started == [(0, 3)]          # both zeros consumed, slot kept
    assert list(pool.queue) == [5]
    with pytest.raises(ValueError):
        SlotPool(0)
