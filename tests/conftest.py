"""Shared test plumbing.

Two pieces live here:

``check_event_stream`` — the serve-layer event-stream invariant
checker (also exposed as the ``event_invariants`` fixture).  Every test
that collects a request's event stream (async, traffic-shaping, shards,
bounds engine — including the cancel/deadline paths) funnels it through
the same checker, so the documented contract (``TwScheduler`` module
docstring, DESIGN.md §11/§12/§15) is asserted in one place: strictly
increasing ``seq``, monotone lb/ub, per-block ``rung_decided`` in
increasing k, one terminal event and it is last.

``hypothesis`` shim — ``hypothesis`` is a dev-only dependency
(requirements-dev.txt).  Some CI images don't carry it, and a missing
import must not take six whole test modules down with collection errors.  When the real package is absent we
install a minimal shim into ``sys.modules`` that covers exactly the API
surface our property tests use (``given``/``settings``/``strategies``
``integers|booleans|lists|sets|data``): examples are drawn from a
deterministic per-test RNG, so the tests still *run* — with less adversarial
example generation and no shrinking, but the same oracles.
"""
from __future__ import annotations

import hashlib
import inspect
import random
import sys
import types

import pytest

TERMINAL_EVENTS = ("done", "cancelled", "error")


def check_event_stream(events, rid=None):
    """Assert the scheduler's per-request event-stream contract.

    ``events`` is one request's stream as a list of event dicts (the
    ``on_event`` sink's captures, or a drained ``TwClient.stream``).
    Checks, per the documented guarantees:

      * all events carry the same ``rid`` (== ``rid`` when given);
      * ``seq`` is strictly increasing;
      * ``admitted`` appears at most once, and only as the first event;
      * ``lb`` never decreases, ``ub`` never increases, and ``lb <= ub``
        in every event carrying both (monotone anytime bounds — the
        heuristic improver lanes may only tighten);
      * within one block, ``rung_decided`` events arrive in strictly
        increasing ``k`` (ladder order; a heuristic lb jump may *skip*
        rungs, never reorder them);
      * exactly one terminal event (``done``/``cancelled``/``error``),
        and it is last;
      * an exact ``done`` has met bounds: ``lb == ub == width``.

    Returns the terminal event so callers can chain assertions."""
    assert events, "empty event stream"
    rids = {ev.get("rid") for ev in events}
    assert len(rids) == 1, f"stream mixes rids: {sorted(rids)}"
    if rid is not None:
        assert rids == {rid}

    seqs = [ev["seq"] for ev in events if "seq" in ev]
    assert seqs == sorted(set(seqs)), f"seq not strictly increasing: {seqs}"

    kinds = [ev["event"] for ev in events]
    assert kinds.count("admitted") <= 1
    if "admitted" in kinds:
        assert kinds[0] == "admitted", f"admitted not first: {kinds}"

    lb_prev, ub_prev = None, None
    per_block = {}
    for ev in events:
        lb, ub = ev.get("lb"), ev.get("ub")
        if lb is not None and ub is not None:
            assert lb <= ub, f"lb > ub in {ev}"
        if lb is not None:
            assert lb_prev is None or lb >= lb_prev, \
                f"lb regressed {lb_prev} -> {lb} in {ev}"
            lb_prev = lb
        if ub is not None:
            assert ub_prev is None or ub <= ub_prev, \
                f"ub regressed {ub_prev} -> {ub} in {ev}"
            ub_prev = ub
        if ev["event"] == "rung_decided":
            ks = per_block.setdefault(ev.get("block"), [])
            assert not ks or ev["k"] > ks[-1], \
                f"rung_decided out of k order for block {ev.get('block')}:" \
                f" {ks + [ev['k']]}"
            ks.append(ev["k"])

    terminals = [ev for ev in events if ev["event"] in TERMINAL_EVENTS]
    assert len(terminals) == 1, f"expected one terminal event: {kinds}"
    assert events[-1] is terminals[0], f"terminal event not last: {kinds}"
    term = terminals[0]
    if term["event"] == "done" and term.get("exact"):
        assert term["lb"] == term["ub"] == term["width"], term
    return term


@pytest.fixture
def event_invariants():
    """The shared event-stream invariant checker, as a fixture."""
    return check_event_stream


def _install_hypothesis_shim():
    class Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example_from(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5)

    def lists(elements, min_size=0, max_size=None):
        hi = min_size + 10 if max_size is None else max_size

        def draw(rng):
            size = rng.randint(min_size, hi)
            return [elements.example_from(rng) for _ in range(size)]

        return Strategy(draw)

    def sets(elements, min_size=0, max_size=None):
        hi = min_size + 10 if max_size is None else max_size

        def draw(rng):
            size = rng.randint(min_size, hi)
            out = set()
            for _ in range(8 * size + 8):
                if len(out) >= size:
                    break
                out.add(elements.example_from(rng))
            return out

        return Strategy(draw)

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example_from(self._rng)

    class _DataStrategy(Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    def data():
        return _DataStrategy()

    def settings(max_examples=20, deadline=None, **_kw):
        def mark(f):
            f._shim_settings = {"max_examples": max_examples}
            return f

        return mark

    class _Unsatisfied(Exception):
        """Raised by assume(); the example loop skips the draw like real
        hypothesis discards an unsatisfied example."""

    def assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    def given(*strategies, **kw_strategies):
        def wrap(f):
            n_examples = getattr(f, "_shim_settings",
                                 {"max_examples": 20})["max_examples"]
            # deterministic per-test seed: same examples every run
            seed = int(hashlib.sha256(
                f.__qualname__.encode()).hexdigest()[:8], 16)

            # like real hypothesis, strategies fill parameters from the
            # right; anything left of them (pytest parametrize args,
            # fixtures) stays in the visible signature
            sig = inspect.signature(f)
            params = list(sig.parameters.values())
            n_outer = len(params) - len(strategies) - len(kw_strategies)
            strat_names = [p.name for p in
                           params[n_outer:n_outer + len(strategies)]]

            def runner(*args, **kwargs):
                rng = random.Random(seed)
                for _ in range(n_examples):
                    ex_kw = dict(zip(strat_names,
                                     (s.example_from(rng)
                                      for s in strategies)))
                    for k, s in kw_strategies.items():
                        ex_kw[k] = s.example_from(rng)
                    try:
                        f(*args, **kwargs, **ex_kw)
                    except _Unsatisfied:
                        continue

            # NOT functools.wraps: pytest must only see the outer params or
            # it resolves the strategy parameters as fixtures
            runner.__signature__ = inspect.Signature(params[:n_outer])
            for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
                setattr(runner, attr, getattr(f, attr))
            return runner

        return wrap

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    st_mod = types.ModuleType("hypothesis.strategies")
    for name, fn in [("integers", integers), ("booleans", booleans),
                     ("lists", lists), ("sets", sets), ("data", data)]:
        setattr(st_mod, name, fn)
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:                                     # pragma: no cover - env dependent
    import hypothesis                    # noqa: F401
except ImportError:
    _install_hypothesis_shim()
