"""Shared test plumbing.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  Some CI
images don't carry it, and a missing import must not take six whole test
modules down with collection errors.  When the real package is absent we
install a minimal shim into ``sys.modules`` that covers exactly the API
surface our property tests use (``given``/``settings``/``strategies``
``integers|booleans|lists|sets|data``): examples are drawn from a
deterministic per-test RNG, so the tests still *run* — with less adversarial
example generation and no shrinking, but the same oracles.
"""
from __future__ import annotations

import hashlib
import inspect
import random
import sys
import types


def _install_hypothesis_shim():
    class Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example_from(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5)

    def lists(elements, min_size=0, max_size=None):
        hi = min_size + 10 if max_size is None else max_size

        def draw(rng):
            size = rng.randint(min_size, hi)
            return [elements.example_from(rng) for _ in range(size)]

        return Strategy(draw)

    def sets(elements, min_size=0, max_size=None):
        hi = min_size + 10 if max_size is None else max_size

        def draw(rng):
            size = rng.randint(min_size, hi)
            out = set()
            for _ in range(8 * size + 8):
                if len(out) >= size:
                    break
                out.add(elements.example_from(rng))
            return out

        return Strategy(draw)

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example_from(self._rng)

    class _DataStrategy(Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    def data():
        return _DataStrategy()

    def settings(max_examples=20, deadline=None, **_kw):
        def mark(f):
            f._shim_settings = {"max_examples": max_examples}
            return f

        return mark

    class _Unsatisfied(Exception):
        """Raised by assume(); the example loop skips the draw like real
        hypothesis discards an unsatisfied example."""

    def assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    def given(*strategies, **kw_strategies):
        def wrap(f):
            n_examples = getattr(f, "_shim_settings",
                                 {"max_examples": 20})["max_examples"]
            # deterministic per-test seed: same examples every run
            seed = int(hashlib.sha256(
                f.__qualname__.encode()).hexdigest()[:8], 16)

            # like real hypothesis, strategies fill parameters from the
            # right; anything left of them (pytest parametrize args,
            # fixtures) stays in the visible signature
            sig = inspect.signature(f)
            params = list(sig.parameters.values())
            n_outer = len(params) - len(strategies) - len(kw_strategies)
            strat_names = [p.name for p in
                           params[n_outer:n_outer + len(strategies)]]

            def runner(*args, **kwargs):
                rng = random.Random(seed)
                for _ in range(n_examples):
                    ex_kw = dict(zip(strat_names,
                                     (s.example_from(rng)
                                      for s in strategies)))
                    for k, s in kw_strategies.items():
                        ex_kw[k] = s.example_from(rng)
                    try:
                        f(*args, **kwargs, **ex_kw)
                    except _Unsatisfied:
                        continue

            # NOT functools.wraps: pytest must only see the outer params or
            # it resolves the strategy parameters as fixtures
            runner.__signature__ = inspect.Signature(params[:n_outer])
            for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
                setattr(runner, attr, getattr(f, attr))
            return runner

        return wrap

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    st_mod = types.ModuleType("hypothesis.strategies")
    for name, fn in [("integers", integers), ("booleans", booleans),
                     ("lists", lists), ("sets", sets), ("data", data)]:
        setattr(st_mod, name, fn)
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:                                     # pragma: no cover - env dependent
    import hypothesis                    # noqa: F401
except ImportError:
    _install_hypothesis_shim()
