"""Distributed solver tests.

Multi-device CPU requires XLA_FLAGS before jax initialises, so these run in
a subprocess (the main pytest process keeps its single device — smoke tests
and benches must see 1 device per the dry-run contract).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_distributed_matches_single_device():
    stdout = _run("""
        from repro.core import graph, distributed, solver
        mesh = distributed.make_solver_mesh()
        assert mesh.devices.size == 8
        for name, want in [("petersen", 4), ("myciel3", 5), ("queen5_5", 18)]:
            g = graph.REGISTRY[name]()
            r = distributed.solve_distributed(g, mesh, cap_local=1 << 12,
                                              block=1 << 6)
            s = solver.solve(g, cap=1 << 15, block=1 << 9)
            assert r.width == s.width == want, (name, r.width, s.width)
            assert r.exact and s.exact
            assert r.expanded == s.expanded, (name, r.expanded, s.expanded)
        print("MATCH-OK")
    """)
    assert "MATCH-OK" in stdout


def test_checkpoint_restart_and_elastic():
    stdout = _run("""
        import jax
        from repro.core import graph, distributed, bounds
        g = graph.queen(5)
        mesh8 = distributed.make_solver_mesh(jax.devices())
        clique = bounds.greedy_max_clique(g)
        ckpts = []
        feas, inexact, exp = distributed.decide_distributed(
            g, 18, clique, mesh8, cap_local=1 << 11, block=1 << 6,
            checkpoint_cb=lambda c: ckpts.append(c))
        assert feas
        mid = ckpts[len(ckpts) // 2]
        # crash-restart on the same mesh
        feas2, _, _ = distributed.decide_distributed(
            g, 18, clique, mesh8, cap_local=1 << 11, block=1 << 6, resume=mid)
        assert feas2
        # elastic restart on a smaller mesh (8 -> 4 devices)
        mesh4 = distributed.make_solver_mesh(jax.devices()[:4])
        feas3, _, _ = distributed.decide_distributed(
            g, 18, clique, mesh4, cap_local=1 << 12, block=1 << 6, resume=mid)
        assert feas3
        print("RESTART-OK")
    """)
    assert "RESTART-OK" in stdout


def test_overflow_marks_inexact_distributed():
    stdout = _run("""
        from repro.core import graph, distributed
        mesh = distributed.make_solver_mesh()
        g = graph.queen(5)
        r = distributed.solve_distributed(g, mesh, cap_local=32, block=32,
                                          use_preprocess=False,
                                          use_paths=False)
        assert (not r.exact) or r.width == 18
        print("OVERFLOW-OK", r.width, r.exact)
    """)
    assert "OVERFLOW-OK" in stdout


def test_mmw_distributed():
    stdout = _run("""
        from repro.core import graph, distributed
        mesh = distributed.make_solver_mesh()
        g = graph.petersen()
        a = distributed.solve_distributed(g, mesh, cap_local=1 << 11,
                                          block=1 << 6, use_mmw=True)
        b = distributed.solve_distributed(g, mesh, cap_local=1 << 11,
                                          block=1 << 6, use_mmw=False)
        assert a.width == b.width == 4
        assert a.expanded <= b.expanded
        print("MMW-OK")
    """)
    assert "MMW-OK" in stdout


def test_fused_engine_parity_distributed():
    """The device-resident (while_loop) distributed engine must agree with
    the host-driven level loop verdict-for-verdict, including expanded
    counts and the overflow/inexact flag."""
    stdout = _run("""
        from repro.core import bounds, distributed, graph
        mesh = distributed.make_solver_mesh()
        for name, cap_local in [("petersen", 1 << 11), ("myciel3", 1 << 11),
                                ("queen5_5", 1 << 8)]:   # queen: overflows
            g = graph.REGISTRY[name]()
            clique = bounds.greedy_max_clique(g)
            for k in range(max(1, len(clique) - 1), g.n - len(clique)):
                a = distributed.decide_distributed(
                    g, k, clique, mesh, cap_local=cap_local, block=1 << 6,
                    engine="host")
                b = distributed.decide_distributed(
                    g, k, clique, mesh, cap_local=cap_local, block=1 << 6,
                    engine="fused")
                assert a == b, (name, k, a, b)
                if a[0]:
                    break
        print("DIST-PARITY-OK")
    """)
    assert "DIST-PARITY-OK" in stdout


def test_simplicial_and_backend_distributed():
    """use_simplicial is honoured (not silently dropped) by the distributed
    solver, and the pallas backend matches jax bit-for-bit there too."""
    stdout = _run("""
        from repro.core import distributed, graph, solver
        mesh = distributed.make_solver_mesh()
        g = graph.random_tree(12, 5)
        # trees collapse to a single chain per level under simplicial
        # pruning, so the flag reaching the kernels shows up as a large
        # expanded-count reduction at k=1 (bounds short-circuit solve(),
        # hence decide at fixed k)
        kw = dict(cap_local=1 << 10, block=32)
        feas_p, _, exp_plain = distributed.decide_distributed(
            g, 1, [], mesh, **kw)
        feas_s, _, exp_simp = distributed.decide_distributed(
            g, 1, [], mesh, use_simplicial=True, **kw)
        assert feas_p and feas_s
        assert exp_simp < exp_plain, (exp_simp, exp_plain)
        single = solver.decide(g, 1, [], cap=1 << 12, block=32,
                               mode="sort", use_mmw=False, m_bits=1 << 10,
                               k_hashes=4, schedule="doubling",
                               use_simplicial=True)
        assert single.feasible and single.expanded == exp_simp
        feas_pal, _, exp_pal = distributed.decide_distributed(
            g, 1, [], mesh, use_simplicial=True, backend="pallas", **kw)
        assert feas_pal and exp_pal == exp_simp
        print("SIMPLICIAL-DIST-OK")
    """, devices=4)
    assert "SIMPLICIAL-DIST-OK" in stdout
