"""Simplicial-vertex pruning (the paper's §5 proposed rule, implemented
bit-parallel): correctness + branch-collapse reductions."""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitset, expand, graph, solver


def _is_simplicial_oracle(g, s, v):
    """v simplicial in the graph after eliminating S (python oracle)."""
    adjb = [list(map(bool, row)) for row in g.adj]
    q = [u for u in range(g.n) if u not in s and u != v
         and expand.degree_oracle(adjb, s | {u} - {u}, u) >= 0]  # noqa
    # neighbors of v in G_S:
    nbrs = []
    seen = [False] * g.n
    stack = [v]
    seen[v] = True
    while stack:
        u = stack.pop()
        for wv in range(g.n):
            if g.adj[u][wv] and not seen[wv]:
                seen[wv] = True
                if wv in s:
                    stack.append(wv)
                else:
                    nbrs.append(wv)
    # clique check among nbrs in G_S: a,b adjacent iff b reachable from a
    for i, a in enumerate(nbrs):
        reach_a = set()
        seen2 = [False] * g.n
        st = [a]
        seen2[a] = True
        while st:
            u = st.pop()
            for wv in range(g.n):
                if g.adj[u][wv] and not seen2[wv]:
                    seen2[wv] = True
                    if wv in s:
                        st.append(wv)
                    else:
                        reach_a.add(wv)
        for b in nbrs[i + 1:]:
            if b not in reach_a:
                return False
    return True


@pytest.mark.parametrize("seed", range(5))
def test_simplicial_mask_matches_oracle(seed):
    rng = random.Random(seed)
    n = rng.randint(4, 20)
    g = graph.gnp(n, rng.choice([0.2, 0.45]), seed)
    s = set(rng.sample(range(n), rng.randint(0, n // 2)))
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack([s], n))
    valid = jnp.asarray([True])
    allowed = bitset.full(n)
    _, feas, _, reach = expand.expand_block(
        adj, states, valid, jnp.int32(n), allowed, n)
    simp = np.asarray(expand.simplicial_mask(adj, states, reach, feas, n))[0]
    for v in range(n):
        if v in s:
            continue
        assert bool(simp[v]) == _is_simplicial_oracle(g, s, v), (v, s)


def test_collapse_keeps_single_candidate():
    feas = jnp.asarray([[True, True, True], [True, False, True]])
    simp = jnp.asarray([[False, True, True], [False, False, False]])
    out = np.asarray(expand.collapse_simplicial(feas, simp))
    assert out.tolist() == [[False, True, False], [True, False, True]]


@pytest.mark.parametrize("name,want", [("petersen", 4), ("myciel3", 5)])
def test_solver_simplicial_correct_and_prunes(name, want):
    g = graph.REGISTRY[name]()
    a = solver.solve(g, cap=1 << 14, block=1 << 8)
    b = solver.solve(g, cap=1 << 14, block=1 << 8, use_simplicial=True)
    assert a.width == b.width == want
    assert b.expanded <= a.expanded


def test_tree_collapses_greedily():
    """Trees are chordal-ish: every state has a simplicial leaf, so the
    search degenerates to a single path (massive reduction)."""
    g = graph.random_tree(14, 5)
    b = solver.solve(g, cap=1 << 12, block=1 << 6, use_simplicial=True,
                     use_preprocess=False, use_paths=False,
                     use_clique=False)
    assert b.width == 1
    # one chain of states per level at k=1: expanded ~ n per level bound
    assert b.expanded <= 3 * g.n
