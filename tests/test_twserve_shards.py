"""Sharded requests through the solve service (ISSUE 7 / DESIGN.md §13).

A request submitted with ``shards=S`` occupies S pool slots and has its
rungs decided by S-way sharded dispatches (``core.shard``), composing
with every traffic-shaping feature from DESIGN.md §12: S-slot admission
is head-of-line (a wide request is never starved by narrow ones),
cancel/deadline release the whole slot group, priorities still reorder
the queue, bounded queues still shed.  Throughout, every request's
result stays bit-identical to sequential ``solver.solve``.
"""
import time

import pytest

from repro.core import graph, solver
from repro.serve.slots import QueueFull, SlotPool
from repro.serve.twscheduler import TwScheduler

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)


# ------------------------------------------------ SlotPool multi-slot width

def test_slotpool_multislot_admission_occupies_a_group():
    pool = SlotPool(4, slots_of=lambda it: it[1])
    pool.submit(("wide", 3)); pool.submit(("a", 1)); pool.submit(("b", 1))
    adm = pool.admit(lambda it: it)
    # wide takes primary slot 0 + shadows 1,2; "a" lands in 3; "b" waits
    assert [i for i, _ in adm] == [0, 3]
    assert pool.free == 0
    assert [i for i, _ in pool.active()] == [0, 3]   # shadows not listed
    pool.release(0)                 # one release recycles the whole group
    assert pool.free == 3
    adm = pool.admit(lambda it: it)
    assert adm == [(0, ("b", 1))]


def test_slotpool_head_of_line_admission_never_starves_a_wide_item():
    pool = SlotPool(2, slots_of=lambda it: it[1])
    pool.submit(("n1", 1))
    assert pool.admit(lambda it: it) == [(0, ("n1", 1))]
    pool.submit(("wide", 2)); pool.submit(("n2", 1))
    # wide is head-of-line and does not fit: n2 must NOT overtake it,
    # else a stream of narrow submits starves the wide request forever
    assert pool.admit(lambda it: it) == []
    pool.release(0)
    assert pool.admit(lambda it: it) == [(0, ("wide", 2))]
    assert pool.free == 0 and pool.qsize == 1


# ------------------------------------------------------ scheduler admission

def test_sharded_submit_validates_against_pool_size():
    sched = TwScheduler(lanes=2, **FAST)
    with pytest.raises(ValueError):
        sched.submit(graph.petersen(), shards=3)
    with pytest.raises(ValueError):
        sched.submit(graph.petersen(), shards=0)


def test_sharded_request_occupies_shards_slots():
    sched = TwScheduler(lanes=4, **FAST)
    sched.submit(graph.queen(5), shards=3)
    nar = sched.submit(graph.petersen())
    assert sched.launch()
    assert sched.pool.free == 0          # 3 + 1 slots in flight
    assert len(sched.pool.active()) == 2
    done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[nar].width, done[nar].expanded) == (ref.width, ref.expanded)


def test_mixed_stream_parity_with_sharded_and_narrow_requests(
        event_invariants):
    gs = [(graph.petersen(), 4), (graph.myciel(3), 1), (graph.queen(4), 2)]
    sched = TwScheduler(lanes=4, **FAST)
    evs = []
    rids = [sched.submit(g, shards=s, on_event=evs.append) for g, s in gs]
    done = sched.run()
    for rid, (g, s) in zip(rids, gs):
        ref = solver.solve(g, **FAST)
        res = done[rid]
        assert (res.width, res.exact, res.expanded, res.per_k) == \
            (ref.width, ref.exact, ref.expanded, ref.per_k), (g.name, s)
    # every request saw a full monotone event stream ending in done
    # (the shared conftest contract, per rid)
    for rid in rids:
        mine = [e for e in evs if e["rid"] == rid]
        assert event_invariants(mine, rid=rid)["event"] == "done"


# -------------------------------------------------- cancel / deadline / prio

def test_cancel_sharded_request_frees_the_whole_slot_group(
        event_invariants):
    sched = TwScheduler(lanes=4, **FAST)
    evs = []
    wide = sched.submit(graph.queen(6), shards=4, on_event=evs.append)
    assert sched.launch()
    assert sched.pool.free == 0
    assert sched.cancel(wide)
    assert sched.pool.free == 4          # primary + shadows all recycled
    done = sched.run()
    assert wide not in done
    assert sched.terminal[wide] == "cancelled"
    assert event_invariants(evs, rid=wide)["event"] == "cancelled"


def test_deadline_preempts_a_sharded_request_with_anytime_bounds(
        event_invariants):
    sched = TwScheduler(lanes=4, **FAST)
    evs = []
    rid = sched.submit(graph.queen(6), shards=4, on_event=evs.append)
    assert sched.launch()
    for _i, (req, _inst) in sched.pool.active():
        req.deadline = time.monotonic() - 1.0
    done = sched.run()
    res = done[rid]
    ref = solver.solve(graph.queen(6), **FAST)
    assert not res.exact
    assert res.lb <= ref.width <= res.ub
    assert sched.terminal[rid] == "timeout"
    assert sched.pool.free == 4          # the whole group released
    term = event_invariants(evs, rid=rid)
    assert term["event"] == "done" and term["timed_out"] is True


def test_urgent_narrow_overtakes_a_queued_wide_request():
    sched = TwScheduler(lanes=2, **FAST)
    busy = sched.submit(graph.myciel(3))          # holds one slot first
    wide = sched.submit(graph.queen(4), shards=2)  # must wait for both
    hi = sched.submit(graph.petersen(), priority=5)
    order = []
    start = sched._start

    def spy(req):
        order.append(req.rid)
        return start(req)

    sched._start = spy
    done = sched.run()
    # priority reorders ahead of the wide item (it is not head-of-line
    # for *more urgent* classes), but the wide request still completes
    assert order == [hi, busy, wide]
    for rid, g in ((busy, graph.myciel(3)), (wide, graph.queen(4)),
                   (hi, graph.petersen())):
        ref = solver.solve(g, **FAST)
        assert (done[rid].width, done[rid].expanded) == \
            (ref.width, ref.expanded)


def test_bounded_queue_sheds_sharded_submits_too():
    sched = TwScheduler(lanes=2, max_queue=1, **FAST)
    sched.submit(graph.petersen(), shards=2)
    with pytest.raises(QueueFull) as ei:
        sched.submit(graph.myciel(3), shards=2)
    assert ei.value.retry_after is not None


# ----------------------------------------------------- scale-out regression

def test_sharded_heavy_request_finishes_in_fewer_rounds():
    """The acceptance scenario at test scale: the same heavy request
    finishes in strictly fewer scheduler rounds with ``shards=4`` (4-way
    rung dispatches + a 4-rung ladder window from its slot entitlement)
    than with ``shards=1``, while concurrent small requests still
    complete — and both runs stay bit-identical to sequential solve."""
    heavy = graph.myciel(4)
    smalls = [graph.myciel(3), graph.petersen()]
    ref_h = solver.solve(heavy, block=1 << 10)
    ref_s = [solver.solve(g, block=1 << 10) for g in smalls]
    done_round = {}
    for s in (1, 4):
        sched = TwScheduler(lanes=4, block=1 << 10)
        evs = []
        rid_h = sched.submit(heavy, shards=s, on_event=evs.append)
        rids = [sched.submit(g) for g in smalls]
        done = sched.run()
        done_round[s] = next(e["rounds"] for e in evs
                             if e["event"] == "done")
        rh = done[rid_h]
        assert (rh.width, rh.exact, rh.expanded, rh.per_k) == \
            (ref_h.width, ref_h.exact, ref_h.expanded, ref_h.per_k)
        for rid, ref in zip(rids, ref_s):
            assert (done[rid].width, done[rid].expanded) == \
                (ref.width, ref.expanded)
    assert done_round[4] < done_round[1], done_round
