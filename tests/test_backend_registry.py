"""Backend registry: dispatch, capability table, fail-fast validation.

The contract under test (ISSUE 2): every wavefront op resolves through one
registry; unsupported op/backend/flag combinations raise
``BackendCapabilityError`` at entry — at ``get_op``, ``validate``,
``solver.decide``/``solve`` and the CLI — never a bare TypeError deep
inside a jit.
"""
import warnings

import pytest

from repro.core import backend as backend_lib
from repro.core import graph, solver
from repro.core.backend import BackendCapabilityError


# ------------------------------------------------------------------ get_op

def test_every_registered_op_resolves_to_a_callable():
    for op, backends in backend_lib.capability_table().items():
        for b in backends:
            assert callable(backend_lib.get_op(op, b)), (op, b)


def test_unknown_backend_rejected():
    with pytest.raises(BackendCapabilityError, match="unknown backend"):
        backend_lib.get_op("wavefront_expand", "cuda")


def test_unknown_op_rejected_with_op_listing():
    with pytest.raises(BackendCapabilityError, match="wavefront_expand"):
        backend_lib.get_op("warp_speed", "jax")


def test_missing_impl_names_available_backends():
    # simplicial_mask exists standalone only in jax (the pallas form is
    # fused inside wavefront_expand)
    with pytest.raises(BackendCapabilityError, match="jax"):
        backend_lib.get_op("simplicial_mask", "pallas")


def test_capability_table_shape():
    table = backend_lib.capability_table()
    assert table["wavefront_expand"] == ("jax", "pallas")
    assert table["sort_dedup"] == ("jax", "pallas")
    assert table["bloom_query_insert"] == ("jax", "pallas")
    assert table["simplicial_mask"] == ("jax",)


# ---------------------------------------------------------------- validate

def test_validate_accepts_full_pallas_feature_set():
    backend_lib.validate("pallas", mode="bloom", schedule="doubling",
                         use_mmw=True, use_simplicial=True, m_bits=1 << 14)


@pytest.mark.parametrize("schedule", ["while", "linear", "matmul"])
def test_pallas_rejects_jax_only_schedules(schedule):
    with pytest.raises(BackendCapabilityError, match="doubling"):
        backend_lib.validate("pallas", schedule=schedule)


def test_pallas_bloom_requires_word_aligned_filter():
    with pytest.raises(BackendCapabilityError, match="multiple of 32"):
        backend_lib.validate("pallas", mode="bloom", m_bits=(1 << 14) + 1)
    # jax byte-per-bit filter has no such constraint
    backend_lib.validate("jax", mode="bloom", m_bits=(1 << 14) + 1)


def test_validate_rejects_unknown_mode_and_backend():
    with pytest.raises(BackendCapabilityError, match="mode"):
        backend_lib.validate("jax", mode="hashset")
    with pytest.raises(BackendCapabilityError, match="backend"):
        backend_lib.validate("tpu-native")


# ------------------------------------------------- entry-point enforcement

def test_solver_entry_points_fail_fast():
    g = graph.petersen()
    kw = dict(cap=1 << 8, block=32, mode="sort", use_mmw=False,
              m_bits=1 << 10, k_hashes=4)
    with pytest.raises(BackendCapabilityError):
        solver.decide(g, 3, [], schedule="while", backend="pallas", **kw)
    with pytest.raises(BackendCapabilityError):
        solver.solve(g, cap=1 << 8, block=32, backend="pallas",
                     schedule="linear")
    with pytest.raises(BackendCapabilityError):
        solver.solve(g, cap=1 << 8, block=32, backend="opencl")


def test_solve_schedule_default_is_backend_aware():
    """schedule=None resolves per backend, so the pallas default just works
    instead of tripping over the jax-only 'while' schedule."""
    g = graph.petersen()
    a = solver.solve(g, cap=1 << 10, block=32, backend="jax")
    b = solver.solve(g, cap=1 << 10, block=32, backend="pallas")
    assert a.width == b.width == 4


def test_deprecated_impl_alias_still_routes():
    g = graph.petersen()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with pytest.raises(DeprecationWarning):
            solver.solve(g, cap=1 << 10, block=32, impl="jax")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = solver.solve(g, cap=1 << 10, block=32, impl="jax")
    assert res.width == 4


def test_cli_reports_capability_error(capsys):
    from repro.launch import solve as cli
    rc = cli.main(["--graph", "petersen", "--backend", "pallas",
                   "--schedule", "while"])
    assert rc == 2
    assert "unsupported configuration" in capsys.readouterr().err
