"""Pallas bloom kernel vs sequential python reference."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bloom as bloom_core
from repro.kernels.bloom import bloom_insert, bloom_ref, make_filter_words


def _case(b, w, seed, dup_frac=0.3):
    rng = np.random.RandomState(seed)
    states = rng.randint(0, 2**31, size=(b, w)).astype(np.uint32)
    # inject duplicates
    for i in range(b):
        if rng.rand() < dup_frac and i > 0:
            states[i] = states[rng.randint(i)]
    valid = rng.rand(b) < 0.9
    return states, valid


@pytest.mark.parametrize("w", [1, 2, 4, 8])
def test_word_width_sweep(w):
    m_bits = 1 << 12
    states, valid = _case(12, w, seed=w)
    filt0 = np.zeros((m_bits // 32,), dtype=np.uint32)
    want_new, want_filt = bloom_ref(filt0, states, valid, m_bits, 17)
    got_new, got_filt = bloom_insert(jnp.asarray(filt0), jnp.asarray(states),
                                     jnp.asarray(valid), m_bits=m_bits,
                                     block=4)
    assert np.array_equal(np.asarray(got_new), want_new)
    assert np.array_equal(np.asarray(got_filt), want_filt)


@pytest.mark.parametrize("block", [1, 4, 16])
def test_block_sweep_sequential_semantics(block):
    """Duplicates later in the batch must see earlier inserts regardless of
    how the batch is tiled across grid steps."""
    m_bits = 1 << 14
    states, _ = _case(16, 2, seed=3, dup_frac=0.0)
    states[8:] = states[:8]         # second half duplicates first half
    valid = np.ones(16, dtype=bool)
    filt0 = np.zeros((m_bits // 32,), dtype=np.uint32)
    got_new, _ = bloom_insert(jnp.asarray(filt0), jnp.asarray(states),
                              jnp.asarray(valid), m_bits=m_bits, block=block)
    got_new = np.asarray(got_new)
    assert got_new[:8].all() and not got_new[8:].any()


def test_matches_core_bloom_queries():
    """Kernel-inserted filter must agree with the pure-JAX probe positions."""
    m_bits = 1 << 13
    states, valid = _case(20, 2, seed=9, dup_frac=0.0)
    filt0 = make_filter_words(m_bits)
    _, filt = bloom_insert(filt0, jnp.asarray(states), jnp.asarray(valid),
                           m_bits=m_bits, block=4)
    filt = np.asarray(filt)
    idx = np.asarray(bloom_core.probe_indices(jnp.asarray(states), m_bits))
    for i in range(20):
        present = all((int(filt[int(j) >> 5]) >> (int(j) & 31)) & 1
                      for j in idx[i])
        assert present == bool(valid[i])


def test_kernel_no_false_negatives_property():
    rng = np.random.RandomState(1)
    m_bits = 1 << 15
    filt = make_filter_words(m_bits)
    states = rng.randint(0, 2**31, size=(64, 3)).astype(np.uint32)
    valid = jnp.ones((64,), bool)
    _, filt = bloom_insert(filt, jnp.asarray(states), valid,
                           m_bits=m_bits, block=16)
    again, _ = bloom_insert(filt, jnp.asarray(states), valid,
                            m_bits=m_bits, block=16)
    assert not bool(jnp.any(again))
