"""Canonical-labeling + cache-key properties (DESIGN.md §16).

The result cache is only sound if the canonical key is a *complete*
isomorphism invariant: equal for every relabeling, distinct for every
non-isomorphic pair, and stable across processes.  These tests pin each
leg — including the Shrikhande-vs-rook pair that 1-WL refinement alone
cannot separate (the individualization search must)."""
import os
import random
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import canon, graph


def _shuffled(g, seed):
    rng = np.random.RandomState(seed)
    return g.relabel(rng.permutation(g.n))


# ------------------------------------------------------ canonical form

@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_canonical_form_perm_invariant(seed):
    """Every relabeling of a random graph canonicalizes to the same
    bytes, and the returned perm really maps onto the canonical graph."""
    rng = random.Random(seed)
    n = rng.randint(2, 14)
    g = graph.gnp(n, rng.choice([0.2, 0.4, 0.6]), seed=seed)
    b0, p0 = canon.canonical_form(g)
    cg = g.relabel(np.array(p0))
    assert canon._canon_bytes(n, canon._adj_masks(cg),
                              list(range(n))) == b0
    for k in range(3):
        b1, p1 = canon.canonical_form(_shuffled(g, seed * 7 + k))
        assert b1 == b0


@pytest.mark.parametrize("name", ["petersen", "myciel3", "desargues",
                                  "queen5_5", "grid6x6"])
def test_named_instances_perm_invariant(name):
    g = graph.REGISTRY[name]()
    b0, _ = canon.canonical_form(g)
    for k in range(2):
        b1, _ = canon.canonical_form(_shuffled(g, 100 + k))
        assert b1 == b0


def test_perm_reconstructs_adjacency():
    """canonical bytes pack exactly the relabeled adjacency, row v =
    bitset over canonical columns (little-endian)."""
    g = graph.petersen()
    b, perm = canon.canonical_form(g)
    cg = g.relabel(np.array(perm))
    row_bytes = (g.n + 7) // 8
    for i in range(g.n):
        row = int.from_bytes(b[i * row_bytes:(i + 1) * row_bytes],
                             "little")
        mask = sum(1 << j for j in np.nonzero(cg.adj[i])[0])
        assert row == mask


def _cyc_edges(n, off=0):
    return [(off + i, off + (i + 1) % n) for i in range(n)]


def test_non_iso_same_degree_sequence():
    """C6 vs 2xC3: identical degree sequence (all-2), different graphs —
    the key must separate them."""
    c6 = graph.from_edges(6, _cyc_edges(6), "C6")
    c33 = graph.from_edges(6, _cyc_edges(3) + _cyc_edges(3, 3), "2C3")
    assert canon.canonical_form(c6)[0] != canon.canonical_form(c33)[0]
    assert canon.graph_key(c6) != canon.graph_key(c33)


def _rook4x4():
    """4x4 rook's graph: (a,b)~(c,d) iff same row or same column."""
    def vid(a, b):
        return 4 * a + b
    edges = []
    for a in range(4):
        for b in range(4):
            for c in range(4):
                for d in range(4):
                    if (a, b) < (c, d) and (a == c or b == d):
                        edges.append((vid(a, b), vid(c, d)))
    return graph.from_edges(16, edges, "rook4x4")


def _shrikhande():
    """Shrikhande graph on Z4 x Z4: (a,b)~(c,d) iff the difference is in
    {±(1,0), ±(0,1), ±(1,1)}.  Same SRG(16,6,2,2) parameters as the 4x4
    rook's graph but NOT isomorphic — 1-WL cannot tell them apart, the
    individualization search must."""
    def vid(a, b):
        return 4 * a + b
    diffs = {(1, 0), (3, 0), (0, 1), (0, 3), (1, 1), (3, 3)}
    edges = []
    for a in range(4):
        for b in range(4):
            for c in range(4):
                for d in range(4):
                    if vid(a, b) < vid(c, d) and \
                            ((a - c) % 4, (b - d) % 4) in diffs:
                        edges.append((vid(a, b), vid(c, d)))
    return graph.from_edges(16, edges, "shrikhande")


def test_non_iso_beyond_1wl():
    """Shrikhande vs 4x4 rook: strongly regular with identical
    parameters, so color refinement alone yields one color class for
    both.  The full search still separates them."""
    rook, shri = _rook4x4(), _shrikhande()
    # same SRG parameters: both 6-regular on 16 vertices
    assert sorted(rook.degrees()) == sorted(shri.degrees())
    # 1-WL sees a single equitable class on each
    for g in (rook, shri):
        masks = canon._adj_masks(g)
        assert len(set(canon._refine(g.n, masks, [0] * g.n))) == 1
    assert canon.canonical_form(rook)[0] != canon.canonical_form(shri)[0]
    # and each is still perm-invariant despite the huge automorphism group
    assert canon.canonical_form(_shuffled(shri, 3))[0] == \
        canon.canonical_form(shri)[0]


def test_golden_n20_pairwise_distinct():
    gs = [graph.grid(4, 5), graph.desargues(), graph.random_tree(20, 7)]
    keys = [canon.graph_key(g) for g in gs]
    assert len(set(keys)) == 3


def test_empty_and_tiny():
    b0, p0 = canon.canonical_form(graph.from_edges(0, [], "empty"))
    assert b0 == b"" and p0 == ()
    b1, p1 = canon.canonical_form(graph.from_edges(1, [], "one"))
    assert p1 == (0,)


# ------------------------------------------------------ cache keys

def test_cache_key_canonical_vs_raw():
    """canonical=True keys hit across relabelings; canonical=False
    (bloom) keys are deliberately label-dependent."""
    g = graph.petersen()
    h = _shuffled(g, 5)
    cfg = {"mode": "sort", "cap": 1 << 12}
    assert canon.cache_key(g, cfg)[0] == canon.cache_key(h, cfg)[0]
    kg = canon.cache_key(g, cfg, canonical=False)
    kh = canon.cache_key(h, cfg, canonical=False)
    assert kg[0] != kh[0]
    assert kg[1] == tuple(range(g.n))        # identity perm for raw keys


def test_cache_key_config_separation():
    """Any one-knob change must address a different entry."""
    g = graph.myciel(3)
    base = {"mode": "sort", "cap": 1 << 12, "use_mmw": True, "seed": 0}
    k0 = canon.cache_key(g, base)[0]
    for knob, v in [("mode", "bloom"), ("cap", 1 << 13),
                    ("use_mmw", False), ("seed", 1)]:
        assert canon.cache_key(g, dict(base, **{knob: v}))[0] != k0
    # graph-only key differs from config-carrying key domains
    assert canon.graph_key(g) != k0


def test_config_blob_order_independent():
    a = canon.config_blob({"a": 1, "b": "x", "c": None})
    b = canon.config_blob({"c": None, "b": "x", "a": 1})
    assert a == b


def test_render_value_rejects_non_primitives():
    for bad in ({"a": 1}, object(), {1, 2}, b"bytes"):
        with pytest.raises(TypeError):
            canon.config_blob({"k": bad})


def test_keys_stable_across_processes():
    """Digests must not depend on PYTHONHASHSEED — run the key
    computation in two subprocesses with different hash seeds."""
    prog = ("from repro.core import canon, graph;"
            "g = graph.petersen();"
            "print(canon.graph_key(g));"
            "print(canon.cache_key(g, {'mode': 'sort', 'cap': 4096})[0])")
    outs = []
    for hs in ("0", "1"):
        env = dict(os.environ, PYTHONHASHSEED=hs,
                   PYTHONPATH="src" + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        r = subprocess.run([sys.executable, "-c", prog], env=env,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))),
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    # and they match this process too
    g = graph.petersen()
    want = canon.graph_key(g) + "\n" + \
        canon.cache_key(g, {"mode": "sort", "cap": 4096})[0] + "\n"
    assert outs[0] == want
