"""Serving: engine greedy decode, continuous batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import Model
from repro.serve.engine import Engine
from repro.serve.scheduler import Request, Scheduler

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv=2, d_ff=64, vocab=100,
                  vocab_pad_multiple=64, attn_chunk=16)


@pytest.fixture(scope="module")
def setup():
    m = Model(CFG)
    p = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, batch=4, cache_len=64)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 100), np.int32)
    return m, p, eng, prompts


def test_greedy_matches_full_forward(setup):
    """Greedy generation via cache == argmax over repeated full forwards."""
    m, p, eng, prompts = setup
    gen = np.asarray(eng.generate_greedy(p, jnp.asarray(prompts), max_new=5))
    seqs = prompts.copy()
    for t in range(5):
        logits, _, _ = m.apply(p, jnp.asarray(seqs))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        assert np.array_equal(nxt, gen[:, t]), t
        seqs = np.concatenate([seqs, nxt[:, None]], axis=1)


def test_scheduler_matches_engine(setup):
    m, p, eng, prompts = setup
    gen = np.asarray(eng.generate_greedy(p, jnp.asarray(prompts), max_new=6))
    sched = Scheduler(eng, p)
    for r in range(4):
        sched.submit(Request(rid=r, prompt=prompts[r], max_tokens=6))
    done = sched.run()
    for r in range(4):
        assert np.array_equal(np.asarray(done[r].output), gen[r])


def test_more_requests_than_slots(setup):
    m, p, eng, prompts = setup
    sched = Scheduler(eng, p)
    for r in range(9):
        plen = 4 + r % 5
        sched.submit(Request(rid=r, prompt=prompts[r % 4][:plen],
                             max_tokens=3 + r % 3))
    done = sched.run()
    assert sorted(done) == list(range(9))
    for r, req in done.items():
        assert len(req.output) == 3 + r % 3


def test_eos_releases_slot(setup):
    m, p, eng, prompts = setup
    # find what the model generates, then use its first token as EOS
    gen = np.asarray(eng.generate_greedy(p, jnp.asarray(prompts), max_new=1))
    eos = int(gen[0, 0])
    sched = Scheduler(eng, p)
    sched.submit(Request(rid=0, prompt=prompts[0], max_tokens=50,
                         eos_id=eos))
    done = sched.run()
    assert len(done[0].output) < 50


def test_ssm_arch_serves():
    cfg = ModelConfig(name="tx", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv=4, d_ff=0, vocab=100,
                      vocab_pad_multiple=64,
                      block_pattern=(("mlstm",), ("slstm",)),
                      ssm=SSMConfig(d_state=8, expand=1.0, chunk=4))
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, batch=2, cache_len=32)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 100), np.int32)
    out = eng.generate_greedy(p, jnp.asarray(prompts), max_new=4)
    assert out.shape == (2, 4)
