"""Sharded solve tests (``repro.core.shard``, intra-request scale-out).

Parity is the whole contract: splitting one rung's frontier across S
shards (owner-hash routing + single-writer dedup + work donation) must
leave the verdict, the ``expanded`` count, and the per-rung ladder trace
bit-identical to the single-lane fused engine — on every axis of the
support matrix, and under forced donation skew.  The multi-device mesh
variant needs XLA_FLAGS set before jax initialises, so it runs in a
subprocess like ``test_distributed_tw``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core import bloom, engine, graph, shard, solver  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BLOCK = 1 << 6


def _run(script: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def _parity(ref, res, ctx):
    assert (res.width, res.exact, res.expanded, res.per_k) == \
        (ref.width, ref.exact, ref.expanded, ref.per_k), (ctx, res, ref)


# ------------------------------------------------------------ unit helpers

def test_route_states_partitions_losslessly():
    rng = np.random.default_rng(7)
    m, w, s, cap = 64, 2, 4, 64
    rows = jnp.asarray(rng.integers(0, 2**32, size=(m, w), dtype=np.uint32))
    valid = jnp.asarray(rng.random(m) < 0.8)
    recv, counts, dropped = shard.route_states(rows, valid, s, cap)
    recv, counts = np.asarray(recv), np.asarray(counts)
    assert int(dropped) == 0
    assert int(counts.sum()) == int(np.asarray(valid).sum())
    live = sorted(map(tuple, np.asarray(rows)[np.asarray(valid)]))
    got = sorted(tuple(recv[d, i]) for d in range(s)
                 for i in range(counts[d]))
    assert got == live
    owner = np.asarray(bloom.murmur3_words(rows, bloom.SEED1)) % s
    owner_of = {tuple(r): int(o) for r, o in zip(np.asarray(rows), owner)}
    for d in range(s):
        bucket = [tuple(recv[d, i]) for i in range(counts[d])]
        # each owner's bucket arrives sorted and owned by d
        assert bucket == sorted(bucket)
        assert all(owner_of[row] == d for row in bucket)


def test_donation_plan_triggers_on_skew_only():
    skewed = jnp.asarray([10, 0, 0, 0], jnp.int32)
    targets, trig, moved = shard.donation_plan(skewed, 1.5)
    assert bool(trig) and int(moved) == 10 - int(np.asarray(targets)[0])
    assert int(jnp.sum(targets)) == 10
    balanced = jnp.asarray([5, 5, 6, 5], jnp.int32)
    _, trig, _ = shard.donation_plan(balanced, 1.5)
    assert not bool(trig)
    empty = jnp.asarray([0, 0, 0, 0], jnp.int32)
    _, trig, _ = shard.donation_plan(empty, 1.5)
    assert not bool(trig)


# --------------------------------------------------------- parity matrix

# (backend, mode, use_mmw, use_simplicial) — the shard-supported surface
CFGS = [
    ("jax", "sort", False, False),
    ("jax", "bloom", False, False),
    ("jax", "sort", True, False),
    ("jax", "sort", False, True),
    ("pallas", "sort", False, False),
]


@pytest.mark.parametrize("backend,mode,mmw,simp", CFGS)
def test_sharded_solve_bit_parity_matrix(backend, mode, mmw, simp):
    g = graph.REGISTRY["petersen"]()
    kw = dict(block=BLOCK, backend=backend, mode=mode, use_mmw=mmw,
              use_simplicial=simp)
    ref = solver.solve(g, engine="fused", **kw)
    assert ref.width == 4
    for s in (2, 3):
        res = solver.solve(g, shards=s, **kw)
        _parity(ref, res, (backend, mode, mmw, simp, s))


def test_sharded_solve_parity_across_instances():
    for name, want in [("myciel3", 5), ("queen5_5", 18)]:
        g = graph.REGISTRY[name]()
        ref = solver.solve(g, block=BLOCK)
        assert ref.width == want
        for s in (2, 4):
            _parity(ref, solver.solve(g, shards=s, block=BLOCK), (name, s))


def test_forced_skew_donation_triggers_and_preserves_parity():
    g = graph.REGISTRY["myciel3"]()
    ref = solver.solve(g, block=BLOCK)
    engine.reset_counters()
    # ratio <= 1.0 rebalances every level: the donation path runs hot
    res = solver.solve(g, shards=4, block=BLOCK, donate_ratio=1.0)
    assert engine.COUNTERS["shard_donations"] > 0
    assert engine.COUNTERS["shard_donated_rows"] > 0
    _parity(ref, res, "forced-skew")


# ------------------------------------------------------------ exact alias

def test_shards1_and_lanes1_are_exact_aliases():
    g = graph.REGISTRY["petersen"]()
    engine.reset_counters()
    ref = solver.solve(g, block=BLOCK)
    c_ref = dict(engine.COUNTERS)
    for kw in ({"shards": 1}, {"lanes": 1}):
        engine.reset_counters()
        res = solver.solve(g, block=BLOCK, **kw)
        # not just equal results: the identical engine path — same
        # dispatch/sync/shard counter trace as the plain call
        assert dict(engine.COUNTERS) == c_ref, (kw, engine.COUNTERS, c_ref)
        _parity(ref, res, kw)
        assert res.order == ref.order and res.lb == ref.lb \
            and res.ub == ref.ub


def test_shards_reject_unsupported_combos():
    from repro.core import backend as backend_lib
    g = graph.REGISTRY["petersen"]()
    with pytest.raises(backend_lib.BackendCapabilityError):
        solver.solve(g, shards=0, block=BLOCK)


# ---------------------------------------------------------------- mesh

def test_mesh_sharded_rung_matches_single_lane():
    stdout = _run("""
        import jax
        from repro.core import bounds, distributed, graph, shard, solver
        mesh = distributed.make_solver_mesh()
        assert mesh.devices.size == 8
        g = graph.REGISTRY["petersen"]()
        clique = bounds.greedy_max_clique(g)
        for k in (3, 4):
            ref = solver.decide(g, k, clique, cap=1 << 12, block=1 << 6,
                                mode="sort", use_mmw=False,
                                m_bits=1 << 24, k_hashes=17,
                                schedule="while")
            res = shard.decide_sharded(g, k, clique, shards=8, mesh=mesh,
                                       cap=1 << 9, block=1 << 6)
            assert res.feasible == ref.feasible, (k, res, ref)
            assert not res.inexact
            assert res.expanded == ref.expanded, (k, res, ref)
        print("MESH-RUNG-OK")
    """)
    assert "MESH-RUNG-OK" in stdout


def test_vmapped_shards_match_under_forced_devices():
    # the CI job runs this file under 8 forced host devices; the vmapped
    # (mesh-free) shard path must be device-count independent
    stdout = _run("""
        from repro.core import graph, solver
        g = graph.REGISTRY["myciel3"]()
        ref = solver.solve(g, block=1 << 6)
        res = solver.solve(g, shards=4, block=1 << 6)
        assert (res.width, res.exact, res.expanded, res.per_k) == \\
            (ref.width, ref.exact, ref.expanded, ref.per_k), (res, ref)
        print("VMAP-8DEV-OK")
    """)
    assert "VMAP-8DEV-OK" in stdout
