"""Fault tolerance: checkpoint/restart, crash injection + supervisor,
elastic restore, async checkpointing."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import Model
from repro.train import step as step_lib
from repro.train.checkpoint import CheckpointManager

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv=2, d_ff=64, vocab=128,
                  vocab_pad_multiple=64)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    m = Model(CFG)
    tcfg = TrainConfig()
    state = step_lib.init_state(m, jax.random.PRNGKey(0), tcfg)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(state, s, blocking=True)
    assert mgr.all_steps() == [3, 4]        # gc keeps last 2
    restored, step = mgr.restore(jax.eval_shape(lambda: state))
    assert step == 4
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path):
    m = Model(CFG)
    state = step_lib.init_state(m, jax.random.PRNGKey(0), TrainConfig())
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(state, 7, blocking=False)      # background thread
    mgr.wait()
    assert mgr.latest_step() == 7


def test_crash_restart_supervisor(tmp_path):
    """Inject a crash at step 30; supervisor restarts; the run resumes from
    the step-20 checkpoint and finishes all 50 steps."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable, "-m", "repro.launch.supervisor",
           "--max-restarts", "2", "--",
           sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-0.6b", "--reduced", "--steps", "50",
           "--batch", "2", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "20",
           "--crash-at-step", "30"]
    # fault injection is one-shot (a marker file in the ckpt dir records
    # that the crash already fired), so the restarted run resumes from the
    # step-20 checkpoint and completes.
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "injected crash at step 30" in out.stdout
    assert "resumed from step" in out.stdout
    assert "[train] done" in out.stdout


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints are logical arrays: restoring onto different shardings
    (device counts) must reproduce identical values."""
    m = Model(CFG)
    tcfg = TrainConfig()
    state = step_lib.init_state(m, jax.random.PRNGKey(1), tcfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(state, 5, blocking=True)
    # restore without shardings (single device) — values equal
    restored, _ = mgr.restore(jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_train_resume_is_exact(tmp_path):
    """50 straight steps == 30 steps + checkpoint + resume + 20 steps."""
    from repro.data.synthetic import SyntheticLM
    m = Model(CFG)
    tcfg = TrainConfig(learning_rate=1e-3)
    fn = jax.jit(step_lib.build_train_step(m, tcfg))
    data = SyntheticLM(vocab=128, seq_len=32, global_batch=4, seed=9)

    def run(state, lo, hi):
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, _ = fn(state, batch)
        return state

    s_straight = run(step_lib.init_state(m, jax.random.PRNGKey(2), tcfg),
                     0, 25)
    s_mid = run(step_lib.init_state(m, jax.random.PRNGKey(2), tcfg), 0, 15)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(s_mid, 15, blocking=True)
    s_resumed, step = mgr.restore(jax.eval_shape(lambda: s_mid))
    s_resumed = run(s_resumed, step, 25)
    for a, b in zip(jax.tree.leaves(s_straight),
                    jax.tree.leaves(s_resumed)):
        assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
