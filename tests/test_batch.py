"""Multi-lane engine parity + regression tests for the solver bugfixes.

The batched engine must be a pure scheduling transform: every lane's
verdict, inexactness and expansion count is pinned bit-for-bit to the
sequential ``decide``/``solve`` loop it replaces, across the backend ×
dedup mode × pruning matrix (pallas runs in interpret mode on CPU).  The
suite driver must additionally do it in *fewer* dispatches — that is the
acceptance criterion, asserted here via ``engine.COUNTERS``.

Also pins the two user-facing bugfixes that ride along:
  * ``solve(reconstruct=True, use_preprocess=True)`` used to silently
    return ``order=None`` (the preprocess loop hardcoded
    ``reconstruct=False``);
  * ``solve_block`` with ``start_k >= ub`` used to overwrite the genuine
    lower bound and report ``exact=True`` with zero search.
"""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import backend as backend_lib
from repro.core import batch, engine, graph, preprocess, solver

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)

CONFIGS = [
    dict(mode="sort", use_mmw=False, use_simplicial=False),
    dict(mode="bloom", use_mmw=False, use_simplicial=False),
    dict(mode="sort", use_mmw=True, use_simplicial=False),
    dict(mode="sort", use_mmw=False, use_simplicial=True),
]
CONFIG_IDS = ["sort", "bloom", "sort+mmw", "sort+simplicial"]

DECIDE_KW = dict(cap=1 << 10, block=BLOCK, m_bits=1 << 12, k_hashes=4,
                 schedule="doubling")


# ------------------------------------------------------------ decide_batch

@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("cfg", CONFIGS, ids=CONFIG_IDS)
def test_decide_batch_matches_sequential_decide(cfg, backend):
    """Speculative lanes are bit-identical to the sequential k-ladder for
    every backend x mode x pruning combo (lanes share the true n, so no
    padding caveats apply)."""
    g = graph.petersen()
    ks = list(range(2, 6))
    lanes = batch.decide_batch(g, ks, [], backend=backend, **DECIDE_KW,
                               **cfg)
    for k, lane in zip(ks, lanes):
        ref = solver.decide(g, k, [], engine="fused", backend=backend,
                            **DECIDE_KW, **cfg)
        assert (lane.feasible, lane.inexact, lane.expanded) == \
            (ref.feasible, ref.inexact, ref.expanded), (backend, cfg, k)


@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_decide_batch_random_graphs_with_clique(seed):
    """Random graphs, random k-windows, a clique skip set, and a cap small
    enough that overflow accounting is exercised per lane."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(8, 13))
    g = graph.gnp(n, float(rng.uniform(0.2, 0.55)), seed)
    from repro.core import bounds
    clique = bounds.greedy_max_clique(g)
    k0 = int(rng.randint(1, max(2, n - 3)))
    ks = list(range(k0, min(k0 + 4, n - 1)))
    if not ks:
        return
    kw = dict(cap=512, block=BLOCK, m_bits=1 << 12, k_hashes=4,
              schedule="doubling", mode="sort", use_mmw=False,
              use_simplicial=False)
    lanes = batch.decide_batch(g, ks, clique, **kw)
    for k, lane in zip(ks, lanes):
        ref = solver.decide(g, k, clique, engine="fused", **kw)
        assert (lane.feasible, lane.inexact, lane.expanded) == \
            (ref.feasible, ref.inexact, ref.expanded), (seed, k)


def test_decide_lanes_cross_n_padding():
    """Lanes of different true n padded to a common n_max: verdicts and
    expansion counts still match the unpadded sequential runs (sort mode:
    zero-padded words keep the dedup order bit-identical)."""
    gs = [graph.petersen(), graph.myciel(3), graph.grid(3, 4)]
    lanes = [batch.Lane(g, k) for g in gs for k in (2, 4)]
    kw = dict(cap=512, block=BLOCK, mode="sort", use_mmw=False,
              m_bits=1 << 12, k_hashes=4, schedule="doubling")
    out = batch.decide_lanes(lanes, n_pad=32, lane_pad=8, **kw)
    assert len(out) == len(lanes)
    for lane, res in zip(lanes, out):
        ref = solver.decide(lane.g, lane.k, [], engine="fused", **kw)
        assert (res.feasible, res.inexact, res.expanded) == \
            (ref.feasible, ref.inexact, ref.expanded), (lane.g.name, lane.k)


def test_decide_lanes_trivial_target_matches_decide_early_return():
    """k+1 >= n lanes are trivially feasible with zero expansion, exactly
    like solver.decide's target<=0 early return."""
    g = graph.petersen()
    out = batch.decide_lanes([batch.Lane(g, g.n - 1), batch.Lane(g, 3)],
                             cap=256, block=BLOCK, mode="sort",
                             use_mmw=False, m_bits=1, k_hashes=1,
                             schedule="doubling")
    ref = solver.decide(g, g.n - 1, [], engine="fused", cap=256,
                        block=BLOCK, mode="sort", use_mmw=False, m_bits=1,
                        k_hashes=1, schedule="doubling")
    assert (out[0].feasible, out[0].inexact, out[0].expanded) == \
        (ref.feasible, ref.inexact, ref.expanded) == (True, False, 0)


def test_lanes_capability_validation():
    with pytest.raises(backend_lib.BackendCapabilityError):
        backend_lib.validate("jax", lanes=0)
    with pytest.raises(backend_lib.BackendCapabilityError):
        solver.solve(graph.petersen(), lanes=0, **FAST)
    # both shipped backends are vmap-safe; a non-member must be rejected
    # before tracing
    old = backend_lib.BATCHED_BACKENDS
    backend_lib.BATCHED_BACKENDS = ("jax",)
    try:
        with pytest.raises(backend_lib.BackendCapabilityError):
            backend_lib.validate("pallas", lanes=2)
    finally:
        backend_lib.BATCHED_BACKENDS = old


# ------------------------------------------------------------- solve lanes

def test_solve_speculative_lanes_agreement():
    """solve(lanes=L) is bit-identical to solve() in result AND ladder
    accounting, for several L."""
    for g in [graph.petersen(), graph.myciel(3), graph.gnp(12, 0.35, 3)]:
        ref = solver.solve(g, **FAST)
        for lanes in (2, 3, 8):
            got = solver.solve(g, lanes=lanes, **FAST)
            assert (got.width, got.exact, got.expanded, got.lb, got.ub,
                    got.per_k) == \
                (ref.width, ref.exact, ref.expanded, ref.lb, ref.ub,
                 ref.per_k), (g.name, lanes)


def test_solve_speculative_fewer_dispatches():
    """Speculation's point: the myciel4 ladder (k=6..10 after bounds) runs
    in fewer dispatches at lanes=4 than sequentially."""
    g = graph.myciel(4)
    engine.reset_counters()
    ref = solver.solve(g, **FAST)
    seq = dict(engine.COUNTERS)
    engine.reset_counters()
    got = solver.solve(g, lanes=4, **FAST)
    bat = dict(engine.COUNTERS)
    assert (got.width, got.exact, got.expanded) == \
        (ref.width, ref.exact, ref.expanded)
    assert bat["dispatches"] < seq["dispatches"]
    assert bat["host_syncs"] < seq["host_syncs"]


# -------------------------------------------------------------- solve_many

SUITE = ["petersen", "myciel3", "queen5_5", "desargues"]


def _suite_graphs():
    return [graph.REGISTRY[k]() for k in SUITE]


def test_solve_many_matches_sequential_solve_with_fewer_dispatches():
    """The acceptance criterion: identical widths/exactness (and here the
    full result surface) to sequential solve, in fewer total dispatches."""
    gs = _suite_graphs()
    engine.reset_counters()
    seq = [solver.solve(g, **FAST) for g in gs]
    seq_c = dict(engine.COUNTERS)
    engine.reset_counters()
    man = batch.solve_many(gs, **FAST)
    bat_c = dict(engine.COUNTERS)
    for g, a, b in zip(gs, seq, man):
        assert (a.width, a.exact, a.expanded, a.lb, a.ub, a.per_k) == \
            (b.width, b.exact, b.expanded, b.lb, b.ub, b.per_k), g.name
    assert bat_c["dispatches"] < seq_c["dispatches"]
    assert bat_c["host_syncs"] < seq_c["host_syncs"]


@pytest.mark.parametrize("backend,mode", [("jax", "sort"), ("jax", "bloom"),
                                          ("pallas", "sort")])
def test_solve_many_backend_mode_matrix(backend, mode):
    """Width/exactness parity per backend x mode.  bloom keeps every lane
    at one shared W here (all suite members are < 32 vertices), so even
    the hash-sensitive mode stays bit-identical."""
    gs = [graph.petersen(), graph.myciel(3), graph.desargues()]
    kw = dict(cap=1 << 12, block=BLOCK, mode=mode, backend=backend,
              m_bits=1 << 14, schedule="doubling")
    seq = [solver.solve(g, **kw) for g in gs]
    man = batch.solve_many(gs, **kw)
    for g, a, b in zip(gs, seq, man):
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded), (g.name, backend, mode)


def test_solve_many_pruning_rules_verdict_parity():
    """MMW/simplicial enabled: padded lanes may expand a superset (the
    padding-weakened-MMW caveat) but widths and exactness must match."""
    gs = [graph.petersen(), graph.myciel(3)]
    kw = dict(cap=1 << 12, block=BLOCK, use_mmw=True, use_simplicial=True)
    seq = [solver.solve(g, **kw) for g in gs]
    man = batch.solve_many(gs, **kw)
    for g, a, b in zip(gs, seq, man):
        assert (a.width, a.exact) == (b.width, b.exact), g.name
        assert b.expanded >= a.expanded, g.name


def test_solve_many_edge_instances():
    """Empty / single-vertex / disconnected inputs keep solve()'s shapes."""
    import numpy as _np
    empty = graph.Graph(0, _np.zeros((0, 0), dtype=bool), "empty")
    single = graph.Graph(1, _np.zeros((1, 1), dtype=bool), "single")
    disc_adj = _np.zeros((11, 11), dtype=bool)
    disc_adj[:5, :5] = graph.complete(5).adj
    disc_adj[5:, 5:] = graph.cycle(6).adj
    disc = graph.Graph(11, disc_adj, "disc")
    gs = [empty, single, disc, graph.petersen()]
    seq = [solver.solve(g, **FAST) for g in gs]
    man = batch.solve_many(gs, **FAST)
    for g, a, b in zip(gs, seq, man):
        assert (a.width, a.exact, a.expanded, a.per_k) == \
            (b.width, b.exact, b.expanded, b.per_k), g.name


def test_solve_many_no_preprocess_and_speculate():
    gs = [graph.petersen(), graph.gnp(12, 0.3, 11)]
    seq = [solver.solve(g, use_preprocess=False, **FAST) for g in gs]
    for spec in (1, 3):
        man = batch.solve_many(gs, use_preprocess=False, speculate=spec,
                               **FAST)
        for g, a, b in zip(gs, seq, man):
            assert (a.width, a.exact, a.expanded, a.lb, a.ub, a.per_k) == \
                (b.width, b.exact, b.expanded, b.lb, b.ub, b.per_k), \
                (g.name, spec)


# ------------------------------------------- bugfix 1: reconstruct + pre

def _articulated_graph():
    """Two K5s sharing an articulation vertex, a bridge, a pendant path:
    exercises top-level reduction, block splitting, empty bridge blocks
    and per-block reduction in one instance."""
    adj = np.zeros((12, 12), dtype=bool)
    for u in range(5):
        for v in range(u + 1, 5):
            adj[u, v] = adj[v, u] = True
    for u in range(4, 9):
        for v in range(u + 1, 9):
            adj[u, v] = adj[v, u] = True
    adj[8, 9] = adj[9, 8] = True
    adj[9, 10] = adj[10, 9] = True
    adj[10, 11] = adj[11, 10] = True
    return graph.Graph(12, adj, "barbell")


def test_reconstruct_with_preprocess_returns_certified_order():
    """Regression: used to silently return order=None (preprocess loop
    hardcoded reconstruct=False)."""
    for g in [graph.petersen(), _articulated_graph(), graph.grid(3, 5),
              graph.gnp(14, 0.25, 51)]:
        r = solver.solve(g, reconstruct=True, use_preprocess=True, **FAST)
        assert r.order is not None, g.name
        assert sorted(r.order) == list(range(g.n)), g.name
        assert solver.order_width(g, r.order) <= r.width, g.name
        if r.exact:
            assert solver.order_width(g, r.order) == r.width, g.name


@given(st.integers(0, 5000))
@settings(max_examples=8, deadline=None)
def test_reconstruct_preprocess_property(seed):
    """Random sparse graphs (rich articulation structure): stitched order
    is a permutation certifying the computed width."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(6, 15))
    g = graph.gnp(n, float(rng.uniform(0.12, 0.3)), seed)
    r = solver.solve(g, reconstruct=True, use_preprocess=True, **FAST)
    assert r.order is not None and sorted(r.order) == list(range(n))
    assert solver.order_width(g, r.order) <= r.width


def test_stitch_block_orders_handles_empty_bridge_blocks():
    """A bridge block fully reduces away; its endpoints must still land in
    the stitched order via the block-cut forest (the old code dropped
    empty blocks entirely)."""
    g = _articulated_graph()
    pre = preprocess.preprocess(g)
    covered = set(pre.removed)
    for b in pre.blocks:
        covered.update(b.vertices)
    assert covered == set(range(g.n))
    order = preprocess.stitch_block_orders(
        pre, [list(range(b.g.n)) for b in pre.blocks])
    assert sorted(order) == list(range(g.n))


def test_reconstruction_agrees_with_and_without_preprocess():
    g = graph.queen(5)
    a = solver.solve(g, reconstruct=True, use_preprocess=False, **FAST)
    b = solver.solve(g, reconstruct=True, use_preprocess=True, **FAST)
    assert a.width == b.width == 18
    assert solver.order_width(g, a.order) == 18
    assert solver.order_width(g, b.order) == 18


# --------------------------------------------------- bugfix 2: start_k

def test_start_k_at_or_above_ub_is_not_exact():
    """Regression: start_k >= ub used to hit the lb >= ub early return and
    claim exact=True with zero search."""
    g = graph.petersen()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = solver.solve(g, use_preprocess=False, start_k=50, **FAST)
    assert r.expanded == 0
    assert not r.exact                       # nothing was proven
    assert r.width == r.ub                   # heuristic ub passed through
    assert r.lb <= 4                         # genuine bound, not start_k
    assert any("start_k" in str(x.message) for x in w)


def test_start_k_forced_above_lb_feasible_immediately_is_inexact():
    """tw(petersen)=4: starting at 4 finds it feasible at once, but
    nothing proved tw > 3, so exact must be False."""
    g = graph.petersen()
    r = solver.solve(g, use_preprocess=False, start_k=4, **FAST)
    assert r.width == 4 and not r.exact


def test_start_k_forced_but_ladder_proves_exactness():
    """Starting above lb but below tw: the infeasible rung below the
    answer restores the proof, so exact stays True."""
    g = graph.torus_grid(4, 4)   # genuine lb 4 < tw 6
    ref = solver.solve(g, use_preprocess=False, **FAST)
    assert ref.exact and ref.width == 6 and ref.lb == 4
    r = solver.solve(g, use_preprocess=False, start_k=5, **FAST)
    assert r.width == 6 and r.exact
    assert r.lb == 4             # reported lb is the genuine bound


def test_start_k_below_lb_keeps_exactness():
    g = graph.petersen()
    r = solver.solve(g, use_preprocess=False, start_k=1, **FAST)
    assert r.width == 4 and r.exact


def test_start_k_speculative_lanes_agree():
    g = graph.petersen()
    for sk in (1, 4, 50):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            a = solver.solve(g, use_preprocess=False, start_k=sk, **FAST)
            b = solver.solve(g, use_preprocess=False, start_k=sk, lanes=4,
                             **FAST)
        assert (a.width, a.exact, a.expanded, a.lb, a.ub) == \
            (b.width, b.exact, b.expanded, b.lb, b.ub), sk
