"""Persistent ``twserved`` front end: start / submit / stream / shutdown.

Runs the real TCP server in-process on an ephemeral port (one driver
thread owning JAX, stdlib socketserver threads per connection) and
drives it through ``repro.serve.client.TwClient`` — plus one raw-socket
test speaking the JSON-lines protocol by hand (the ``nc`` path from the
README cookbook).
"""
import json
import socket

import pytest

from repro.core import graph, solver
from repro.launch.twserved import TwServer
from repro.serve.client import TwClient, TwServerError

BLOCK = 32
POOL = dict(lanes=2, cap=1 << 12, block=BLOCK, m_bits=1 << 14)


@pytest.fixture()
def server():
    srv = TwServer(port=0, **POOL)       # port 0: ephemeral
    srv.start()
    yield srv
    srv.close()


def test_submit_stream_result_roundtrip(server):
    c = TwClient(port=server.port)
    assert c.ping()
    rid = c.submit("petersen")
    evs = list(c.stream(rid))
    assert evs[0]["event"] == "admitted"
    assert evs[-1]["event"] == "done"
    ks = [e["k"] for e in evs if e["event"] == "rung_decided"]
    assert ks == sorted(ks) and ks
    bounds = [(e["lb"], e["ub"]) for e in evs if "lb" in e]
    assert all(a[0] <= b[0] and a[1] >= b[1]
               for a, b in zip(bounds, bounds[1:]))

    res = c.result(rid)
    ref = solver.solve(graph.petersen(), cap=1 << 12, block=BLOCK)
    assert (res["width"], res["exact"], res["expanded"]) == \
        (ref.width, ref.exact, ref.expanded)
    st = c.status(rid)
    assert st["state"] == "done" and st["width"] == ref.width
    # a finished request's stream replays its full history
    assert [e["seq"] for e in c.stream(rid)] == [e["seq"] for e in evs]


def test_submit_wire_graph_with_per_request_knobs(server):
    c = TwClient(port=server.port)
    g = graph.myciel(3)
    rid = c.submit(g, mode="bloom", speculate=2)     # Graph over the wire
    res = c.result(rid)
    ref = solver.solve(g, cap=1 << 12, block=BLOCK, mode="bloom",
                       m_bits=1 << 14)
    assert (res["width"], res["exact"]) == (ref.width, ref.exact)
    rid2 = c.submit(g, reconstruct=True)
    res2 = c.result(rid2)
    assert res2["order"] is not None
    assert solver.order_width(g, res2["order"]) == res2["width"]


def test_invalid_submits_fail_per_request_and_pool_survives(server):
    c = TwClient(port=server.port)
    with pytest.raises(TwServerError, match="unknown graph"):
        c.submit("nope")
    with pytest.raises(TwServerError):
        c.submit("petersen", mode="nope")            # BackendCapabilityError
    with pytest.raises(TwServerError, match="unknown rid"):
        c.result(999)
    rid = c.submit("petersen")                       # pool still serving
    ref = solver.solve(graph.petersen(), cap=1 << 12, block=BLOCK)
    assert c.result(rid)["width"] == ref.width


def test_raw_json_lines_socket(server):
    """The nc-equivalent: one JSON line in, JSON lines out."""
    with socket.create_connection(("127.0.0.1", server.port)) as s:
        s.sendall(b'{"op": "submit", "n": 4, "edges": '
                  b'[[0,1],[1,2],[2,3],[3,0]], "name": "c4"}\n')
        resp = json.loads(s.makefile("r").readline())
    assert resp["ok"]
    rid = resp["rid"]
    with socket.create_connection(("127.0.0.1", server.port)) as s:
        s.sendall(json.dumps({"op": "result", "rid": rid}).encode() + b"\n")
        res = json.loads(s.makefile("r").readline())
    assert res["ok"] and res["result"]["width"] == 2   # tw(C4) = 2


def test_result_eviction_bounds_server_memory():
    """keep_results caps what a long-lived server retains: the oldest
    finished requests are evicted and answer as unknown."""
    import time
    srv = TwServer(port=0, keep_results=2, **POOL)
    srv.start()
    try:
        c = TwClient(port=srv.port)
        rids = []
        for _ in range(4):
            rid = c.submit("myciel3")
            c.result(rid)                   # finish before the next one
            rids.append(rid)
        deadline = time.time() + 10         # driver evicts on its next tick
        while time.time() < deadline and len(srv.sched.done) > 2:
            time.sleep(0.1)
        assert sorted(srv.sched.done) == rids[-2:]
        assert c.status(rids[0])["state"] == "unknown"
        with pytest.raises(TwServerError, match="unknown rid"):
            c.result(rids[0])
        st = c.status(rids[-1])
        assert st["state"] == "done"
    finally:
        srv.close()


def test_shutdown_drains_and_exits():
    srv = TwServer(port=0, **POOL)
    srv.start()
    c = TwClient(port=srv.port)
    rid = c.submit("petersen")
    c.shutdown()
    srv._driver.join(timeout=120)
    assert not srv._driver.is_alive()
    assert rid in srv.sched.done        # admitted work drained before exit
    srv.close()
