"""Persistent ``twserved`` front end: start / submit / stream / shutdown.

Runs the real TCP server in-process on an ephemeral port (one driver
thread owning JAX, stdlib socketserver threads per connection) and
drives it through ``repro.serve.client.TwClient`` — plus one raw-socket
test speaking the JSON-lines protocol by hand (the ``nc`` path from the
README cookbook).
"""
import json
import socket

import pytest

from repro.core import graph, solver
from repro.launch.twserved import TwServer
from repro.serve.client import TwClient, TwServerError

BLOCK = 32
POOL = dict(lanes=2, cap=1 << 12, block=BLOCK, m_bits=1 << 14)


@pytest.fixture()
def server():
    srv = TwServer(port=0, **POOL)       # port 0: ephemeral
    srv.start()
    yield srv
    srv.close()


def test_submit_stream_result_roundtrip(server):
    c = TwClient(port=server.port)
    assert c.ping()
    rid = c.submit("petersen")
    evs = list(c.stream(rid))
    assert evs[0]["event"] == "admitted"
    assert evs[-1]["event"] == "done"
    ks = [e["k"] for e in evs if e["event"] == "rung_decided"]
    assert ks == sorted(ks) and ks
    bounds = [(e["lb"], e["ub"]) for e in evs if "lb" in e]
    assert all(a[0] <= b[0] and a[1] >= b[1]
               for a, b in zip(bounds, bounds[1:]))

    res = c.result(rid)
    ref = solver.solve(graph.petersen(), cap=1 << 12, block=BLOCK)
    assert (res["width"], res["exact"], res["expanded"]) == \
        (ref.width, ref.exact, ref.expanded)
    st = c.status(rid)
    assert st["state"] == "done" and st["width"] == ref.width
    # a finished request's stream replays its full history
    assert [e["seq"] for e in c.stream(rid)] == [e["seq"] for e in evs]


def test_submit_wire_graph_with_per_request_knobs(server):
    c = TwClient(port=server.port)
    g = graph.myciel(3)
    rid = c.submit(g, mode="bloom", speculate=2)     # Graph over the wire
    res = c.result(rid)
    ref = solver.solve(g, cap=1 << 12, block=BLOCK, mode="bloom",
                       m_bits=1 << 14)
    assert (res["width"], res["exact"]) == (ref.width, ref.exact)
    rid2 = c.submit(g, reconstruct=True)
    res2 = c.result(rid2)
    assert res2["order"] is not None
    assert solver.order_width(g, res2["order"]) == res2["width"]


def test_invalid_submits_fail_per_request_and_pool_survives(server):
    c = TwClient(port=server.port)
    with pytest.raises(TwServerError, match="unknown graph"):
        c.submit("nope")
    with pytest.raises(TwServerError):
        c.submit("petersen", mode="nope")            # BackendCapabilityError
    with pytest.raises(TwServerError, match="unknown rid"):
        c.result(999)
    rid = c.submit("petersen")                       # pool still serving
    ref = solver.solve(graph.petersen(), cap=1 << 12, block=BLOCK)
    assert c.result(rid)["width"] == ref.width


def test_raw_json_lines_socket(server):
    """The nc-equivalent: one JSON line in, JSON lines out."""
    with socket.create_connection(("127.0.0.1", server.port)) as s:
        s.sendall(b'{"op": "submit", "n": 4, "edges": '
                  b'[[0,1],[1,2],[2,3],[3,0]], "name": "c4"}\n')
        resp = json.loads(s.makefile("r").readline())
    assert resp["ok"]
    rid = resp["rid"]
    with socket.create_connection(("127.0.0.1", server.port)) as s:
        s.sendall(json.dumps({"op": "result", "rid": rid}).encode() + b"\n")
        res = json.loads(s.makefile("r").readline())
    assert res["ok"] and res["result"]["width"] == 2   # tw(C4) = 2


def test_result_eviction_bounds_server_memory():
    """keep_results caps what a long-lived server retains: the oldest
    finished requests are evicted and answer as unknown."""
    import time
    srv = TwServer(port=0, keep_results=2, **POOL)
    srv.start()
    try:
        c = TwClient(port=srv.port)
        rids = []
        for _ in range(4):
            rid = c.submit("myciel3")
            c.result(rid)                   # finish before the next one
            rids.append(rid)
        deadline = time.time() + 10         # driver evicts on its next tick
        while time.time() < deadline and len(srv.sched.done) > 2:
            time.sleep(0.1)
        assert sorted(srv.sched.done) == rids[-2:]
        assert c.status(rids[0])["state"] == "unknown"
        with pytest.raises(TwServerError, match="unknown rid"):
            c.result(rids[0])
        st = c.status(rids[-1])
        assert st["state"] == "done"
    finally:
        srv.close()


def test_shutdown_drains_and_exits():
    srv = TwServer(port=0, **POOL)
    srv.start()
    c = TwClient(port=srv.port)
    rid = c.submit("petersen")
    c.shutdown()
    srv._driver.join(timeout=120)
    assert not srv._driver.is_alive()
    assert rid in srv.sched.done        # admitted work drained before exit
    srv.close()


# --------------------------------------------- traffic shaping over the wire

def test_cancel_over_the_wire(server):
    c = TwClient(port=server.port)
    rid = c.submit("queen6_6")
    assert c.cancel(rid) is True
    assert c.cancel(rid) is False                  # idempotent
    evs = list(c.stream(rid))
    assert evs[-1]["event"] == "cancelled"
    with pytest.raises(TwServerError, match="cancelled"):
        c.result(rid)
    assert c.status(rid)["state"] == "cancelled"
    other = c.submit("petersen")                   # pool keeps serving
    ref = solver.solve(graph.petersen(), cap=1 << 12, block=BLOCK)
    assert c.result(other)["width"] == ref.width


def test_deadline_and_priority_knobs_ride_the_submit_line(server):
    c = TwClient(port=server.port)
    # an unhit deadline and a priority class change nothing about the result
    rid = c.submit("petersen", priority=1, deadline_s=3600.0)
    res = c.result(rid)
    ref = solver.solve(graph.petersen(), cap=1 << 12, block=BLOCK)
    assert (res["width"], res["exact"], res["expanded"]) == \
        (ref.width, ref.exact, ref.expanded)
    assert "timed_out" not in res
    # an already-expired deadline resolves with anytime bounds, flagged
    rid2 = c.submit("queen5_5", deadline_s=0.0)
    res2 = c.result(rid2)
    assert res2["timed_out"] is True and res2["exact"] is False
    assert res2["lb"] <= res2["ub"] == res2["width"]
    evs = list(c.stream(rid2))
    assert evs[-1]["event"] == "done" and evs[-1]["timed_out"] is True


def test_backpressure_rejects_with_retry_after():
    """With the driver not yet running, submits pile into the admission
    queue; past --max-queue the server sheds them with a retry_after
    hint instead of queuing unboundedly."""
    import threading

    srv = TwServer(port=0, max_queue=1, **POOL)
    acceptor = threading.Thread(target=srv._tcp.serve_forever, daemon=True)
    acceptor.start()                 # acceptor only: nothing drains the queue
    try:
        c = TwClient(port=srv.port)
        c.submit("petersen")         # fills the bounded queue
        with pytest.raises(TwServerError, match="queue full") as ei:
            c.submit("myciel3")
        assert ei.value.retry_after is not None and ei.value.retry_after > 0
        # raw wire shape: ok false + error + retry_after
        with socket.create_connection(("127.0.0.1", srv.port)) as s:
            s.sendall(b'{"op": "submit", "graph": "myciel3"}\n')
            resp = json.loads(s.makefile("r").readline())
        assert resp["ok"] is False and resp["retry_after"] > 0
    finally:
        srv._tcp.shutdown()
        srv._tcp.server_close()


def test_server_never_passes_rids_so_they_never_collide(server):
    c = TwClient(port=server.port)
    rids = [c.submit("myciel3") for _ in range(3)]
    assert rids == sorted(set(rids))               # fresh, strictly increasing


def test_eviction_skips_logs_with_blocked_readers():
    """A ``result`` reader blocked on a still-running rid must receive the
    finished result even when eviction pressure passes keep_results while
    it waits (the log is registered busy, so _evict skips it)."""
    import threading

    srv = TwServer(port=0, keep_results=1, **POOL)
    srv.start()
    try:
        c = TwClient(port=srv.port)
        slow = c.submit("queen6_6")
        got = {}

        def read_result():
            got["res"] = c.result(slow)

        t = threading.Thread(target=read_result)
        t.start()                    # blocks in iter_events on the slow rid
        for _ in range(3):           # eviction pressure while it waits
            c.result(c.submit("myciel3"))
        t.join(timeout=120)
        assert not t.is_alive()
        ref = solver.solve(graph.queen(6), cap=1 << 12, block=BLOCK)
        assert (got["res"]["width"], got["res"]["exact"]) == \
            (ref.width, ref.exact)
    finally:
        srv.close()


def test_evict_unit_semantics_unclosed_and_busy_logs_survive():
    """White-box pin of the eviction rules: only terminal rids whose logs
    are closed and reader-free are dropped."""
    from repro.launch.twserved import _EventLog

    srv = TwServer(port=0, keep_results=1, **POOL)   # driver not started
    try:
        sched = srv.sched
        for rid, state in ((0, "done"), (1, "done"), (2, "done")):
            sched.terminal[rid] = state
            sched.done[rid] = object()
            log = _EventLog()
            log.push({"event": "done"})              # closed
            srv._logs[rid] = log
        srv._logs[1].acquire()                       # a blocked reader
        srv._logs[2].closed = False                  # terminal not delivered
        srv._evict()
        assert 0 not in sched.done                   # evictable: dropped
        assert 1 in sched.done and 2 in sched.done   # busy/unclosed: kept
    finally:
        srv._tcp.server_close()


def test_wire_responses_coerce_numpy_payloads():
    """A result carrying numpy/jax scalars or arrays (order, per_k) must
    serialize instead of dying in json.dumps."""
    import dataclasses

    import numpy as np

    srv = TwServer(port=0, **POOL)
    srv.start()
    try:
        c = TwClient(port=srv.port)
        rid = c.submit("petersen")
        res = c.result(rid)                          # finished and logged
        poisoned = dataclasses.replace(
            srv.sched.done[rid], width=np.int64(res["width"]),
            order=np.array([3, 1, 2]),
            per_k={"g": {"expanded": np.int32(7)}})
        srv.sched.done[rid] = poisoned
        res2 = c.result(rid)
        assert res2["width"] == res["width"]
        assert res2["order"] == [3, 1, 2]
        assert res2["per_k"]["g"]["expanded"] == 7
    finally:
        srv.close()
