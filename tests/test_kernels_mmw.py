"""Pallas MMW kernel vs the validated core implementation (which is itself
checked against the python contraction oracle in test_core_mmw.py)."""
import random

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitset, components, graph
from repro.kernels.mmw import mmw_bounds, mmw_bounds_ref


def _case(n, n_states, seed, p=0.3):
    rng = random.Random(seed)
    g = graph.gnp(n, p, seed)
    adj = jnp.asarray(g.packed())
    ss = [set(rng.sample(range(n), rng.randint(0, n // 2)))
          for _ in range(n_states)]
    states = jnp.asarray(bitset.np_pack(ss, n))
    _, reach = jax.vmap(
        lambda s: components.eliminated_degrees(adj, s, n))(states)
    return reach, states


@pytest.mark.parametrize("n", [5, 16, 31, 33, 48, 64])
def test_shape_sweep(n):
    reach, states = _case(n, 6, seed=n)
    got = np.asarray(mmw_bounds(reach, states, jnp.int32(1000), n=n,
                                block=2))
    want = np.asarray(mmw_bounds_ref(reach, states, jnp.int32(1000), n))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("block", [1, 3, 8])
def test_block_sweep_and_padding(block):
    n = 20
    reach, states = _case(n, 7, seed=3)        # 7 pads to block multiples
    got = np.asarray(mmw_bounds(reach, states, jnp.int32(1000), n=n,
                                block=block))
    want = np.asarray(mmw_bounds_ref(reach, states, jnp.int32(1000), n))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("k", [0, 2, 5])
def test_early_freeze_matches_core(k):
    """Both implementations freeze the bound once it exceeds k."""
    n = 24
    reach, states = _case(n, 8, seed=9, p=0.5)
    got = np.asarray(mmw_bounds(reach, states, jnp.int32(k), n=n, block=4))
    want = np.asarray(mmw_bounds_ref(reach, states, jnp.int32(k), n))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("density", [0.05, 0.6, 0.95])
def test_density_sweep(density):
    n = 30
    reach, states = _case(n, 4, seed=11, p=density)
    got = np.asarray(mmw_bounds(reach, states, jnp.int32(1000), n=n,
                                block=4))
    want = np.asarray(mmw_bounds_ref(reach, states, jnp.int32(1000), n))
    assert np.array_equal(got, want)
