"""Exact reference oracles shared across the test suite.

Centralizes what used to be per-file ad-hoc references:

  * ``tw_oracle`` — the exact Held-Karp python DP over vertex subsets
    (previously inlined in ``test_engine_parity.py``), usable up to
    n ~ 12;
  * ``golden_widths.json`` — known exact treewidths for the small
    Table-1 / named instances (previously the ``KNOWN`` list inlined in
    ``test_core_solver.py``), each entry optionally flagged ``slow``
    when the fast exact tier cannot finish it — the heuristic-only
    serving tests use exactly those as oracle targets;
  * ``order_is_valid`` — elimination-order certificate sanity.

Every consumer asserts against the same numbers, so a golden update is
one file, not a grep.
"""
import json
import pathlib

from repro.core import expand, graph

_GOLDEN_PATH = pathlib.Path(__file__).with_name("golden_widths.json")

# name -> zero-argument Graph factory for the golden instances that are
# not registry one-liners (parameterized families)
FACTORIES = {
    "path10": lambda: graph.path(10),
    "cycle12": lambda: graph.cycle(12),
    "complete7": lambda: graph.complete(7),
    "bipartite4_6": lambda: graph.complete_bipartite(4, 6),
    "star9": lambda: graph.star(9),
    "grid4x5": lambda: graph.grid(4, 5),
    "grid3x7": lambda: graph.grid(3, 7),
    "grid5x5": lambda: graph.grid(5, 5),
    "tree20_7": lambda: graph.random_tree(20, 7),
}


def golden_widths() -> dict:
    """name -> {"tw": int, "slow": bool} from the golden file."""
    raw = json.loads(_GOLDEN_PATH.read_text())
    return {name: {"tw": int(spec["tw"]), "slow": bool(spec.get("slow"))}
            for name, spec in raw.items() if not name.startswith("_")}


def make_graph(name: str):
    """Instantiate a golden instance by name (factory or registry)."""
    if name in FACTORIES:
        return FACTORIES[name]()
    return graph.REGISTRY[name]()


def golden_cases(slow=False):
    """[(name, factory, tw)] for golden instances; ``slow`` selects the
    heavy tier (fast exact runs should keep the default)."""
    return [(name, (lambda n=name: make_graph(n)), spec["tw"])
            for name, spec in golden_widths().items()
            if spec["slow"] == slow]


def tw_oracle(g) -> int:
    """Exact Held-Karp treewidth by python DP over subsets (n <= 12)."""
    n = g.n
    adjb = [list(map(bool, row)) for row in g.adj]
    full = (1 << n) - 1
    f = {0: -1}
    for s in range(1, full + 1):
        best = n
        members = [v for v in range(n) if s >> v & 1]
        sset = set(members)
        for v in members:
            prev = f[s & ~(1 << v)]
            d = expand.degree_oracle(adjb, sset - {v}, v)
            best = min(best, max(prev, d))
        f[s] = best
    return f[full]


def order_is_valid(g, order) -> bool:
    """Is ``order`` a permutation of g's vertices?"""
    return sorted(order) == list(range(g.n))
