"""Pallas expansion kernel vs pure-jnp ref vs the paper's DFS oracle."""
import random

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitset, expand, graph
from repro.kernels.expand import expand_degrees, expand_ref


def _random_case(n, n_states, seed, p=0.3):
    rng = random.Random(seed)
    g = graph.gnp(n, p, seed)
    ss = [set(rng.sample(range(n), rng.randint(0, n - 1)))
          for _ in range(n_states)]
    return g, ss


@pytest.mark.parametrize("n", [3, 17, 31, 32, 33, 48, 64, 96])
def test_kernel_matches_ref_shape_sweep(n):
    g, ss = _random_case(n, 6, seed=n)
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack(ss, n))
    got = np.asarray(expand_degrees(adj, states, n=n, block=2))
    want = np.asarray(expand_ref(adj, states, n))
    for b, s in enumerate(ss):
        for v in range(n):
            if v not in s:
                assert got[b, v] == want[b, v]


@pytest.mark.parametrize("block", [1, 2, 8, 16])
def test_block_size_sweep(block):
    n = 24
    g, ss = _random_case(n, 16, seed=7)
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack(ss, n))
    got = np.asarray(expand_degrees(adj, states, n=n, block=block))
    want = np.asarray(expand_ref(adj, states, n))
    mask = ~np.asarray([[v in s for v in range(n)] for s in ss])
    assert np.array_equal(got[mask], want[mask])


def test_kernel_matches_dfs_oracle():
    n = 20
    g, ss = _random_case(n, 5, seed=3, p=0.4)
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack(ss, n))
    got = np.asarray(expand_degrees(adj, states, n=n, block=5))
    adjb = [list(map(bool, row)) for row in g.adj]
    for b, s in enumerate(ss):
        for v in range(n):
            if v not in s:
                assert got[b, v] == expand.degree_oracle(adjb, s, v)


def test_padding_is_stripped():
    n = 10
    g, ss = _random_case(n, 3, seed=5)
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack(ss, n))
    out = expand_degrees(adj, states, n=n, block=16)   # 3 -> padded to 16
    assert out.shape == (3, n)


@pytest.mark.parametrize("density", [0.05, 0.5, 0.95])
def test_density_sweep(density):
    n = 40
    g, ss = _random_case(n, 4, seed=11, p=density)
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack(ss, n))
    got = np.asarray(expand_degrees(adj, states, n=n, block=4))
    want = np.asarray(expand_ref(adj, states, n))
    mask = ~np.asarray([[v in s for v in range(n)] for s in ss])
    assert np.array_equal(got[mask], want[mask])
