"""Sharding rules + HLO accounting units (single-device safe: specs only)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules
from repro.utils import hlo, hlo2


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (enough for spec_for)."""
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible everywhere
    assert rules.spec_for((1024, 3072), ("embed", "mlp"), mesh) == \
        P("data", "model")
    # 25 heads don't divide 16 -> replicated on that dim
    assert rules.spec_for((1600, 25, 64), ("embed", "heads", None), mesh) == \
        P("data", None, None)
    # odd vocab falls back
    assert rules.spec_for((49155, 64), ("vocab", "embed"), mesh) == \
        P(None, "data")


def test_spec_no_axis_reuse():
    mesh = FakeMesh({"data": 16, "model": 16})
    # both dims want 'model': only the first gets it
    spec = rules.spec_for((32, 64), ("heads", "mlp"), mesh)
    assert spec == P("model", None)


def test_layers_axis_never_sharded():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = rules.spec_for((48, 1024, 3072), ("layers", "embed", "mlp"), mesh)
    assert spec == P(None, "data", "model")


def test_shape_bytes_parsing():
    assert hlo2._shape_bytes("bf16[256,1024]") == 256 * 1024 * 2
    assert hlo2._shape_bytes("f32[16]") == 64
    assert hlo2._shape_bytes("(f32[4,4], bf16[2])") == 64 + 4
    assert hlo2._shape_bytes("pred[8]") == 8


def test_collective_bytes_scaled_synthetic():
    text = """\
%body_a (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[128,2] all-reduce(%x), replica_groups={}, to_apply=%add
}

%cond_a (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %ag = f32[64] all-gather(%p0), dimensions={0}
  %w = (s32[], f32[4]) while(%t), condition=%cond_a, body=%body_a, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    out = hlo2.collective_bytes_scaled(text)
    assert out["all-gather"] == 64 * 4
    assert out["all-reduce"] == 128 * 2 * 4 * 7      # x trip count
    # wire factor: AR counts 2x
    assert out["wire_bytes"] == 64 * 4 + 128 * 2 * 4 * 7 * 2


def test_collective_bytes_raw():
    text = "%r = bf16[10] all-gather(%x)\n%s = f32[4] all-reduce(%y)\n"
    out = hlo.collective_bytes(text)
    assert out["all-gather"] == 20
    assert out["all-reduce"] == 16


def test_batch_sharding_fallback_small_batch():
    # with a fake 16-way dp mesh, batch=1 must fall back to replication
    mesh = FakeMesh({"data": 16, "model": 16})
    dp = rules.dp_axes(mesh)
    assert dp == ("data",)
    assert rules._mesh_size(mesh, dp) == 16
    # the divisibility predicate used by batch_sharding:
    assert 1 % 16 != 0 and 256 % 16 == 0
