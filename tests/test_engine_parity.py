"""Engine and backend parity: host vs fused, jax vs pallas, bit for bit.

The fused engine must be a pure performance transform: same frontiers, same
verdicts, same drop accounting, bit for bit.  Both engines are driven with
the same pinned ``block`` so their chunk partitioning — and therefore their
dedup and overflow behaviour — is identical; any divergence is a bug in the
while_loop fusion, not legitimate nondeterminism.

The same contract holds across the backend axis (ISSUE 2): the fused
pallas wavefront kernel dispatched by ``backend="pallas"`` must reproduce
the jax reference composition exactly, for every engine × dedup mode ×
pruning flag — pinned here as a backend × engine matrix on interpret-mode
pallas, with registry capability errors for the combinations that are
genuinely unsupported.

Also pins the engine's contract: O(1) dispatches/host syncs per decide, and
end-to-end ``solve`` agreement with a pure-python Held-Karp treewidth
oracle on random graphs.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

import oracle
from repro.core import backend as backend_lib
from repro.core import bitset, engine, frontier as frontier_lib
from repro.core import graph, solver

BLOCK = 32          # pinned: host run_level adapts within [32, block], so 32
                    # forces identical chunking in both engines

CONFIGS = [
    dict(mode="sort", use_mmw=False, use_simplicial=False),
    dict(mode="bloom", use_mmw=False, use_simplicial=False),
    dict(mode="sort", use_mmw=True, use_simplicial=False),
    dict(mode="sort", use_mmw=False, use_simplicial=True),
]
CONFIG_IDS = ["sort", "bloom", "sort+mmw", "sort+simplicial"]


def _devify(g):
    adj = jnp.asarray(g.packed())
    allowed = jnp.asarray(np.asarray(bitset.full(g.n)))
    return adj, allowed


def _host_levels(adj, allowed, k, levels, *, n, cap, **kw):
    """Drive solver.run_level like decide's host loop; return the final
    frontier plus accumulated (expanded, dropped)."""
    w = adj.shape[-1]
    fr = frontier_lib.empty_frontier(cap, w)
    expanded = dropped = 0
    for _ in range(levels):
        fr, stats = solver.run_level(adj, fr, k, allowed, n=n, cap=cap,
                                     block=BLOCK, **kw)
        expanded += stats.expanded
        dropped += stats.dropped
        if int(fr.count) == 0:
            break
    return fr, expanded, dropped


@pytest.mark.parametrize("cfg", CONFIGS, ids=CONFIG_IDS)
@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_frontier_parity_random_graphs(cfg, seed):
    """Level-by-level frontier buffers match bit for bit (incl. overflow:
    cap=512 is small enough that denser draws drop states)."""
    rng = np.random.RandomState(seed)
    n, cap = 12, 512
    g = graph.gnp(n, float(rng.uniform(0.15, 0.55)), seed)
    k = int(rng.randint(1, n - 2))
    target = n - (k + 1)
    if target <= 0:
        return
    adj, allowed = _devify(g)
    kw = dict(n=n, cap=cap, m_bits=1 << 12, k_hashes=4,
              schedule="doubling", backend="jax", **cfg)

    fr_h, exp_h, drop_h = _host_levels(adj, allowed, k, target, **kw)
    feas_f, inexact_f, exp_f, fr_f = engine.fused_decide(
        adj, allowed, k, target, block=BLOCK, **kw)

    assert exp_f == exp_h
    assert inexact_f == (drop_h > 0)
    assert int(fr_f.dropped) == int(drop_h)
    assert int(fr_f.count) == int(fr_h.count)
    assert feas_f == (int(fr_h.count) > 0)
    np.testing.assert_array_equal(np.asarray(fr_f.states),
                                  np.asarray(fr_h.states))


@pytest.mark.parametrize("cfg", CONFIGS, ids=CONFIG_IDS)
def test_decide_parity_named_graphs(cfg):
    """decide() verdicts agree engine-to-engine across k on real instances."""
    for g in [graph.petersen(), graph.myciel(3)]:
        for k in range(1, 7):
            kw = dict(cap=1 << 12, block=BLOCK, m_bits=1 << 14, k_hashes=4,
                      schedule="doubling", **cfg)
            a = solver.decide(g, k, [], engine="host", **kw)
            b = solver.decide(g, k, [], engine="fused", **kw)
            assert (a.feasible, a.inexact, a.expanded) == \
                (b.feasible, b.inexact, b.expanded), (g.name, k, a, b)


def test_fused_decide_is_one_dispatch_one_sync():
    """The acceptance criterion: O(1) host transfers per k, independent of
    the number of levels and chunks."""
    g = graph.queen(5)          # 18 levels of chunked expansion per decide
    engine.reset_counters()
    solver.decide(g, 17, [], cap=1 << 14, block=BLOCK, mode="sort",
                  use_mmw=False, m_bits=1, k_hashes=1,
                  schedule="doubling", engine="fused")
    assert engine.COUNTERS["dispatches"] == 1
    assert engine.COUNTERS["host_syncs"] == 1

    engine.reset_counters()
    solver.decide(g, 17, [], cap=1 << 14, block=BLOCK, mode="sort",
                  use_mmw=False, m_bits=1, k_hashes=1,
                  schedule="doubling", engine="host")
    # host loop: a dispatch per chunk and several syncs per level — both
    # grow with the instance instead of staying O(1)
    assert engine.COUNTERS["dispatches"] > 10
    assert engine.COUNTERS["host_syncs"] > 10


# ---------------------------------------------------- backend x engine matrix

BACKENDS = ["jax", "pallas"]


@pytest.mark.parametrize("mode", ["sort", "bloom"])
@pytest.mark.parametrize("eng", ["host", "fused"])
def test_backend_engine_matrix_decide_parity(eng, mode):
    """jax vs pallas (interpret mode), per engine and dedup mode, with both
    pruning rules enabled: identical verdict / inexact / expanded across k."""
    g = graph.petersen()
    results = {}
    for backend in BACKENDS:
        kw = dict(cap=1 << 10, block=BLOCK, mode=mode, m_bits=1 << 12,
                  k_hashes=4, schedule="doubling", use_mmw=True,
                  use_simplicial=True, backend=backend)
        results[backend] = [solver.decide(g, k, [], engine=eng, **kw)
                            for k in range(2, 6)]
    for k, (a, b) in enumerate(zip(results["jax"], results["pallas"])):
        assert (a.feasible, a.inexact, a.expanded) == \
            (b.feasible, b.inexact, b.expanded), (eng, mode, k + 2, a, b)


@pytest.mark.parametrize("cfg", CONFIGS, ids=CONFIG_IDS)
def test_backend_frontier_bit_parity(cfg):
    """Final frontier buffers identical between backends, per dedup/prune
    config — the fused pallas kernel is a pure performance transform."""
    for seed in (0, 1):
        n, cap = 10, 256
        g = graph.gnp(n, 0.35, seed)
        k = 3
        target = n - (k + 1)
        adj, allowed = _devify(g)
        out = {}
        for backend in BACKENDS:
            out[backend] = engine.fused_decide(
                adj, allowed, k, target, n=n, cap=cap, block=BLOCK,
                m_bits=1 << 12, k_hashes=4, schedule="doubling",
                backend=backend, **cfg)
        (feas_j, inex_j, exp_j, fr_j) = out["jax"]
        (feas_p, inex_p, exp_p, fr_p) = out["pallas"]
        assert (feas_j, inex_j, exp_j) == (feas_p, inex_p, exp_p)
        assert int(fr_j.count) == int(fr_p.count)
        assert int(fr_j.dropped) == int(fr_p.dropped)
        np.testing.assert_array_equal(np.asarray(fr_j.states),
                                      np.asarray(fr_p.states))


def test_unsupported_backend_combos_fail_at_dispatch():
    """The registry rejects genuinely unsupported combos with a capability
    error at entry — not a TypeError mid-jit (the old impl= failure mode)."""
    g = graph.petersen()
    kw = dict(cap=1 << 8, block=BLOCK, mode="sort", use_mmw=False,
              m_bits=1 << 10, k_hashes=4)
    with pytest.raises(backend_lib.BackendCapabilityError):
        solver.decide(g, 3, [], schedule="while", backend="pallas", **kw)
    with pytest.raises(backend_lib.BackendCapabilityError):
        solver.decide(g, 3, [], schedule="doubling", backend="rocm", **kw)
    with pytest.raises(backend_lib.BackendCapabilityError):
        engine.fused_decide(*_devify(g), 3, 5, n=g.n, cap=1 << 8,
                            block=BLOCK, mode="bloom", use_mmw=False,
                            m_bits=100, k_hashes=4, schedule="doubling",
                            backend="pallas")


def test_solve_matches_python_oracle():
    """End-to-end fused solve() against the exact python DP
    (``tests/oracle.py``, shared with the bounds-engine invariants)."""
    for seed in range(5):
        rng = np.random.RandomState(100 + seed)
        g = graph.gnp(8, float(rng.uniform(0.2, 0.6)), 100 + seed)
        want = oracle.tw_oracle(g)
        got = solver.solve(g, cap=1 << 12, block=BLOCK, engine="fused")
        assert got.exact and got.width == want, (seed, want, got)


def test_solve_engine_agreement_end_to_end():
    """Full solve(): width/exact/expanded identical between engines."""
    cases = [graph.petersen(), graph.myciel(3), graph.grid(3, 5),
             graph.gnp(13, 0.3, 7)]
    for g in cases:
        solve_kw = dict(cap=1 << 13, block=BLOCK)
        a = solver.solve(g, engine="host", **solve_kw)
        b = solver.solve(g, engine="fused", **solve_kw)
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded), (g.name, a, b)


@pytest.mark.parametrize("cfg", CONFIGS, ids=CONFIG_IDS)
def test_lane_engine_frontier_bit_parity(cfg):
    """The multi-lane engine (ISSUE 3) is a pure scheduling transform of
    the fused engine: per-lane final frontier buffers — states, counts,
    drop accounting — are bit-identical to running each (k) alone."""
    from repro.core import batch, frontier as fr_lib

    g = graph.gnp(11, 0.35, 5)
    n, cap = g.n, 512
    adj, allowed = _devify(g)
    ks = [2, 3, 4, 5]
    b = len(ks)
    kw = dict(n=n, cap=cap, block=BLOCK, m_bits=1 << 12, k_hashes=4,
              schedule="doubling", backend="jax", **cfg)
    adj_b = jnp.broadcast_to(adj, (b,) + adj.shape)
    al_b = jnp.broadcast_to(allowed, (b,) + allowed.shape)
    fr_b = fr_lib.lane_frontiers(b, cap, adj.shape[-1])
    out_fr, _lvl, exp_b, drop_b = batch._lanes_decide(
        adj_b, al_b, jnp.asarray(ks, jnp.int32),
        jnp.asarray([n - (k + 1) for k in ks], jnp.int32), fr_b, **kw)
    for i, k in enumerate(ks):
        feas, inexact, exp, fr_ref = engine.fused_decide(
            adj, allowed, k, n - (k + 1), **kw)
        assert exp == int(exp_b[i])
        assert inexact == (int(drop_b[i]) > 0)
        assert feas == (int(out_fr.count[i]) > 0)
        np.testing.assert_array_equal(np.asarray(out_fr.states[i]),
                                      np.asarray(fr_ref.states))
        np.testing.assert_array_equal(
            fr_lib.lane_to_host(out_fr, i),
            np.asarray(fr_ref.states[:int(fr_ref.count)]))


def test_solve_many_dispatch_reduction_quick_suite():
    """Acceptance criterion (ISSUE 3): solve_many over the quick suite
    matches sequential solve widths/exactness with fewer dispatches."""
    from repro.core import batch
    gs = [graph.REGISTRY[k]() for k in
          ("myciel3", "petersen", "desargues")]
    kw = dict(cap=1 << 12, block=BLOCK)
    engine.reset_counters()
    seq = [solver.solve(g, **kw) for g in gs]
    seq_c = dict(engine.COUNTERS)
    engine.reset_counters()
    man = batch.solve_many(gs, **kw)
    bat_c = dict(engine.COUNTERS)
    for a, b in zip(seq, man):
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded)
    assert bat_c["dispatches"] < seq_c["dispatches"]


def test_keep_levels_forces_host_engine():
    """Reconstruction path still works when the fused engine is requested:
    keep_levels falls back to the host loop and returns snapshots."""
    g = graph.petersen()
    res = solver.solve(g, cap=1 << 13, block=BLOCK, use_preprocess=False,
                      reconstruct=True, engine="fused")
    assert res.order is not None
    assert solver.order_width(g, res.order) == res.width == 4
