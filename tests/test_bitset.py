import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitset


@given(st.integers(1, 100), st.data())
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(n, data):
    bits = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    arr = jnp.asarray(np.array(bits, dtype=bool))
    packed = bitset.pack(arr, n)
    assert packed.shape == (bitset.n_words(n),)
    back = bitset.unpack(packed, n)
    assert np.array_equal(np.asarray(back), np.array(bits))


@given(st.integers(1, 100), st.data())
@settings(max_examples=50, deadline=None)
def test_popcount(n, data):
    s = data.draw(st.sets(st.integers(0, n - 1)))
    packed = jnp.asarray(bitset.np_pack([s], n)[0])
    assert int(bitset.popcount(packed)) == len(s)


def test_onehot_get_set_clear():
    n = 70
    w = bitset.n_words(n)
    for i in [0, 31, 32, 63, 64, 69]:
        oh = bitset.onehot(i, w)
        assert int(bitset.popcount(oh)) == 1
        assert bool(bitset.get_bit(oh, i))
        assert not bool(bitset.get_bit(oh, (i + 1) % n))
        z = bitset.clear_bit(oh, i)
        assert int(bitset.popcount(z)) == 0
        assert int(bitset.popcount(bitset.set_bit(z, i))) == 1


def test_full():
    for n in [1, 31, 32, 33, 64, 65, 100]:
        f = bitset.full(n)
        assert int(bitset.popcount(f)) == n


@given(st.integers(2, 64), st.data())
@settings(max_examples=30, deadline=None)
def test_or_matmul_matches_numpy(n, data):
    rng = np.random.RandomState(data.draw(st.integers(0, 10000)))
    rows_bool = rng.rand(n, n) < 0.3
    masks_bool = rng.rand(5, n) < 0.3
    rows = jnp.asarray(bitset.np_pack([set(np.nonzero(r)[0]) for r in rows_bool], n))
    masks = jnp.asarray(bitset.np_pack([set(np.nonzero(r)[0]) for r in masks_bool], n))
    out = bitset.or_matmul(masks, rows, n)
    want = (masks_bool.astype(int) @ rows_bool.astype(int)) > 0
    got = np.asarray(bitset.unpack(out, n))
    assert np.array_equal(got, want)


def test_np_pack_unpack():
    s = {0, 5, 33, 63}
    p = bitset.np_pack([s], 64)[0]
    assert bitset.np_unpack(p, 64) == s
