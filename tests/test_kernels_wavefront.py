"""Fused wavefront kernel vs the jax backend composition vs the DFS oracle.

The kernel's contract is bit-for-bit equality with
``repro.core.expand.wavefront_expand`` (the registered jax implementation)
for every pruning-flag combination — that is what makes ``backend="pallas"``
a pure performance transform of ``backend="jax"``.
"""
import random

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitset, expand, graph
from repro.kernels.wavefront import wavefront_expand, wavefront_ref


def _case(n, n_states, seed, p=0.3):
    rng = random.Random(seed)
    g = graph.gnp(n, p, seed)
    ss = [set(rng.sample(range(n), rng.randint(0, max(0, n // 2))))
          for _ in range(n_states)]
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack(ss, n))
    valid = jnp.ones((n_states,), dtype=bool)
    allowed = bitset.full(n)
    return g, ss, adj, states, valid, allowed


def _both(adj, states, valid, k, allowed, n, **flags):
    got = wavefront_expand(adj, states, valid, jnp.int32(k), allowed,
                           n=n, block=2, **flags)
    want = wavefront_ref(adj, states, valid, jnp.int32(k), allowed,
                         n=n, **flags)
    return [np.asarray(x) for x in got], [np.asarray(x) for x in want]


@pytest.mark.parametrize("n", [3, 17, 31, 32, 33, 48])
def test_matches_ref_shape_sweep(n):
    _, _, adj, states, valid, allowed = _case(n, 6, seed=n)
    (gc, gf), (wc, wf) = _both(adj, states, valid, n // 2, allowed, n)
    np.testing.assert_array_equal(gc, wc)
    np.testing.assert_array_equal(gf, wf)


@pytest.mark.parametrize("use_mmw,use_simplicial",
                         [(True, False), (False, True), (True, True)])
def test_pruning_flags_match_ref(use_mmw, use_simplicial):
    n = 20
    _, _, adj, states, valid, allowed = _case(n, 8, seed=5, p=0.35)
    for k in (2, 4, 8):
        (gc, gf), (wc, wf) = _both(adj, states, valid, k, allowed, n,
                                   use_mmw=use_mmw,
                                   use_simplicial=use_simplicial)
        np.testing.assert_array_equal(gc, wc)
        np.testing.assert_array_equal(gf, wf)


@pytest.mark.parametrize("block", [1, 2, 8])
def test_block_sweep_and_padding(block):
    n = 16
    _, _, adj, states, valid, allowed = _case(n, 5, seed=7)   # 5 pads
    got = wavefront_expand(adj, states, valid, jnp.int32(4), allowed,
                           n=n, block=block)
    want = wavefront_ref(adj, states, valid, jnp.int32(4), allowed, n=n)
    assert got[0].shape == (5, n, bitset.n_words(n))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_feasibility_matches_dfs_oracle():
    n = 14
    g, ss, adj, states, valid, allowed = _case(n, 5, seed=3, p=0.4)
    k = 4
    _, feas = wavefront_expand(adj, states, valid, jnp.int32(k), allowed,
                               n=n, block=5)
    feas = np.asarray(feas)
    adjb = [list(map(bool, row)) for row in g.adj]
    for b, s in enumerate(ss):
        for v in range(n):
            want = (v not in s) and expand.degree_oracle(adjb, s, v) <= k
            assert bool(feas[b, v]) == want, (b, v, s)


def test_invalid_rows_are_infeasible():
    n = 12
    _, _, adj, states, _, allowed = _case(n, 4, seed=9)
    valid = jnp.asarray([True, False, True, False])
    _, feas = wavefront_expand(adj, states, valid, jnp.int32(6), allowed,
                               n=n, block=2)
    feas = np.asarray(feas)
    assert not feas[1].any() and not feas[3].any()
    assert feas[0].any() or feas[2].any()


def test_non_doubling_schedule_rejected():
    n = 8
    _, _, adj, states, valid, allowed = _case(n, 2, seed=1)
    with pytest.raises(ValueError, match="doubling"):
        wavefront_expand(adj, states, valid, jnp.int32(3), allowed,
                         n=n, schedule="while")
