"""The scoped telemetry layer (DESIGN.md §14): tracker tree semantics,
sinks, thread safety, and the deprecated ``engine.COUNTERS`` view.

The contract under test: counts and timings **write through** to every
ancestor atomically (a child scope's counters sum into its parents by
construction), plain gauges stay on their own scope, ``gauge_max``
ratchets the whole ancestor chain, ``NullTracker`` is a true no-op, and
the legacy ``COUNTERS`` mapping is a frozen read-only window over the
process root — the shape ~30 pre-telemetry tests assert against.
"""
import io
import json
import threading

import pytest

from repro.core import engine, graph, solver, telemetry
from repro.core.telemetry import (InMemorySink, JsonlSink, NullTracker,
                                  StdoutSink, Tracker)


# --------------------------------------------------------- tree semantics

def test_count_writes_through_to_every_ancestor():
    root = Tracker()
    pool = root.child("pool")
    req = pool.child("req0")
    req.count(expanded=3)
    req.count(expanded=2, rungs=1)
    for tr in (req, pool, root):
        assert tr["expanded"] == 5
        assert tr["rungs"] == 1


def test_sibling_scopes_sum_into_parent():
    root = Tracker()
    a, b = root.child("a"), root.child("b")
    a.count(x=2)
    b.count(x=5)
    assert a["x"] == 2 and b["x"] == 5
    assert root["x"] == 7


def test_gauge_stays_on_its_scope():
    root = Tracker()
    child = root.child("c")
    child.gauge("depth", 4)
    assert child["depth"] == 4
    assert root["depth"] == 0     # last-value gauges do not roll up


def test_gauge_max_ratchets_self_and_ancestors():
    root = Tracker()
    a, b = root.child("a"), root.child("b")
    a.gauge_max("peak", 10)
    b.gauge_max("peak", 7)
    a.gauge_max("peak", 3)        # lower: no change anywhere
    assert a["peak"] == 10 and b["peak"] == 7
    assert root["peak"] == 10     # parent peak = max over children


def test_timing_accumulates_and_rolls_up():
    root = Tracker()
    child = root.child("c")
    child.timing("span", 0.5)
    with child.time_block("span"):
        pass
    for tr in (child, root):
        t = tr.snapshot()["timings"]["span"]
        assert t["calls"] == 2
        assert t["total_s"] >= 0.5
        assert t["max_s"] >= 0.5


def test_child_is_idempotent_per_name():
    root = Tracker()
    assert root.child("x") is root.child("x")
    assert root.child("x") is not root.child("y")


def test_drop_child_keeps_contributions_in_ancestors():
    root = Tracker()
    req = root.child("req0")
    req.count(expanded=9)
    root.drop_child("req0")
    assert root["expanded"] == 9
    assert "req0" not in root.snapshot()["children"]
    # the name can be reused by a fresh scope
    again = root.child("req0")
    assert again is not req
    assert again["expanded"] == 0


def test_snapshot_shape_and_children_toggle():
    root = Tracker()
    root.child("c").count(n=1)
    root.gauge("g", 2)
    snap = root.snapshot()
    assert snap["counters"] == {"n": 1}
    assert snap["gauges"] == {"g": 2}
    assert snap["children"]["c"]["counters"] == {"n": 1}
    assert "children" not in root.snapshot(children=False)
    # plain JSON all the way down (the wire/metrics-op requirement)
    json.dumps(snap)


def test_reset_zeroes_tree_but_keeps_structure():
    root = Tracker()
    c = root.child("c")
    c.count(n=3)
    root.gauge("g", 1)
    root.reset()
    assert root["n"] == 0 and root["g"] == 0 and c["n"] == 0
    assert root.child("c") is c


# ------------------------------------------------------------------ sinks

def test_inmemory_sink_sees_descendant_records_in_order():
    sink = InMemorySink()
    root = Tracker(sinks=[sink])
    req = root.child("pool").child("req0")
    req.count(expanded=2)
    req.gauge("depth", 1)
    req.gauge_max("peak", 5)
    req.timing("span", 0.1)
    kinds = [r["kind"] for r in sink.records]
    assert kinds == ["count", "gauge", "gauge_max", "time"]
    assert all(r["scope"] == "pool/req0" for r in sink.records)
    assert sink.records[0]["counters"] == {"expanded": 2}
    sink.clear()
    assert sink.records == []


def test_sink_attached_mid_tree_sees_only_its_subtree():
    root_sink, pool_sink = InMemorySink(), InMemorySink()
    root = Tracker(sinks=[root_sink])
    pool = root.child("pool")
    pool.add_sink(pool_sink)
    pool.child("req0").count(n=1)
    root.child("other").count(n=1)
    assert len(root_sink.records) == 2
    assert len(pool_sink.records) == 1    # only the pool subtree


def test_jsonl_sink_appends_parseable_lines():
    buf = io.StringIO()
    root = Tracker(sinks=[JsonlSink(buf)])
    root.count(a=1)
    root.count(a=2)
    lines = [json.loads(s) for s in buf.getvalue().splitlines()]
    assert [r["counters"]["a"] for r in lines] == [1, 2]
    assert all("ts" in r and "scope" in r for r in lines)


def test_jsonl_sink_file_roundtrip(tmp_path):
    path = tmp_path / "metrics.jsonl"
    sink = JsonlSink(path)
    root = Tracker(sinks=[sink])
    root.count(a=1)
    root.gauge("g", 3)
    sink.close()
    records = [json.loads(s) for s in path.read_text().splitlines()]
    assert [r["kind"] for r in records] == ["count", "gauge"]


def test_stdout_sink_formats_each_kind():
    buf = io.StringIO()
    root = Tracker(sinks=[StdoutSink(buf)])
    root.count(a=1)
    root.gauge("g", 2)
    root.timing("t", 0.25)
    out = buf.getvalue().splitlines()
    assert len(out) == 3
    assert all(line.startswith("[telemetry]") for line in out)


# ---------------------------------------------------------- thread safety

def test_concurrent_counts_from_threads_land_exactly():
    """The satellite regression for the twserved race: many threads
    hammering ``count`` on distinct child scopes (plus the root) must
    produce exact totals — no lost updates."""
    root = Tracker()
    n_threads, n_iters = 8, 500
    barrier = threading.Barrier(n_threads)

    def hammer(i):
        child = root.child(f"t{i}")
        barrier.wait()
        for _ in range(n_iters):
            child.count(hits=1)
            root.count(direct=1)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert root["hits"] == n_threads * n_iters
    assert root["direct"] == n_threads * n_iters
    for i in range(n_threads):
        assert root.child(f"t{i}")["hits"] == n_iters


def test_concurrent_gauge_max_keeps_true_peak():
    root = Tracker()
    vals = list(range(1, 201))

    def hammer(chunk):
        child = root.child(f"c{chunk[0]}")
        for v in chunk:
            child.gauge_max("peak", v)

    chunks = [vals[i::4] for i in range(4)]
    threads = [threading.Thread(target=hammer, args=(c,)) for c in chunks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert root["peak"] == 200


# ------------------------------------------------- legacy COUNTERS window

def test_counters_view_is_read_only():
    with pytest.raises(TypeError):
        engine.COUNTERS["dispatches"] = 1


def test_counters_view_is_frozen_to_legacy_keys():
    engine.reset_counters()
    assert set(engine.COUNTERS) == set(telemetry.LEGACY_KEYS)
    assert len(engine.COUNTERS) == len(telemetry.LEGACY_KEYS)
    with pytest.raises(KeyError):
        engine.COUNTERS["lane_expanded"]
    # new counters landing in the root never widen the legacy window
    telemetry.root().count(lane_expanded=7)
    assert "lane_expanded" not in dict(engine.COUNTERS)
    engine.reset_counters()


def test_counters_view_reads_the_root_tracker():
    engine.reset_counters()
    assert all(v == 0 for v in engine.COUNTERS.values())
    telemetry.root().count(dispatches=2, host_syncs=1)
    telemetry.root().gauge_max("shard_peak_occupancy", 5)
    c = dict(engine.COUNTERS)
    assert c["dispatches"] == 2
    assert c["host_syncs"] == 1
    assert c["shard_peak_occupancy"] == 5   # gauge read-through
    engine.reset_counters()
    assert all(v == 0 for v in engine.COUNTERS.values())


def test_engine_count_shim_still_feeds_the_root():
    engine.reset_counters()
    engine.count(dispatches=1)
    engine.count(host_syncs=2)
    assert engine.COUNTERS["dispatches"] == 1
    assert engine.COUNTERS["host_syncs"] == 2
    engine.reset_counters()


# -------------------------------------------------- NullTracker + opt-out

def test_null_tracker_is_inert():
    n = telemetry.NULL
    assert isinstance(n, NullTracker)
    assert n.child("x") is n
    n.count(a=1)
    n.gauge("g", 2)
    n.gauge_max("m", 3)
    n.timing("t", 0.1)
    with n.time_block("t"):
        pass
    assert n["a"] == 0 and n.counters() == {}
    assert n.snapshot()["counters"] == {}


def test_null_tracker_leaves_solo_solve_counters_unchanged():
    """The overhead opt-out: a solo fused ``solve`` routed through
    ``NULL`` must leave the process-global dispatch accounting exactly
    as it found it, while the default (root) path still counts."""
    g = graph.petersen()
    engine.reset_counters()
    res_null = solver.solve(g, cap=1 << 12, block=32,
                            tracker=telemetry.NULL)
    assert all(v == 0 for v in engine.COUNTERS.values())

    res_root = solver.solve(g, cap=1 << 12, block=32)
    assert engine.COUNTERS["dispatches"] > 0
    assert (res_null.width, res_null.exact, res_null.expanded) == \
        (res_root.width, res_root.exact, res_root.expanded)
    engine.reset_counters()


def test_detached_tracker_isolates_a_measurement():
    """The benchmark idiom: a fresh ``Tracker()`` given to ``solve``
    captures that run's counters without touching the root."""
    g = graph.petersen()
    engine.reset_counters()
    tr = Tracker()
    res = solver.solve(g, cap=1 << 12, block=32, tracker=tr)
    assert res.width == 4
    assert tr["dispatches"] > 0
    assert tr["expanded"] == res.expanded
    assert all(v == 0 for v in engine.COUNTERS.values())
