import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import oracle
from repro.core import bounds, graph, preprocess, solver

FAST = dict(cap=1 << 16, block=1 << 9)

# the shared golden-widths file (tests/golden_widths.json via tests/oracle.py)
# is the single source of truth for known exact treewidths
KNOWN = oracle.golden_cases()
HEAVY = oracle.golden_widths()


@pytest.mark.parametrize("name,gf,want", KNOWN, ids=[c[0] for c in KNOWN])
def test_known_treewidth(name, gf, want):
    g = gf()
    r = solver.solve(g, **FAST)
    assert r.exact and r.width == want, (g.name, r)


@pytest.mark.slow
def test_grid5x5_heavy():
    """Grids are state-heavy (cf. the paper's 8x6 torus at 2.1e9 states)."""
    r = solver.solve(graph.grid(5, 5), cap=1 << 19, block=1 << 11)
    assert r.exact and r.width == HEAVY["grid5x5"]["tw"]


def test_mcgee_overflow_semantics():
    """With a small list cap the run overflows: the found width is still the
    true value here (paper: myciel5 found exactly despite overflow), but the
    result must be flagged inexact."""
    r = solver.solve(graph.mcgee(), cap=1 << 16, block=1 << 9)
    assert r.width == HEAVY["mcgee"]["tw"] and not r.exact


@pytest.mark.slow
def test_mcgee_exact():
    r = solver.solve(graph.mcgee(), cap=1 << 22, block=1 << 12)
    assert r.exact and r.width == HEAVY["mcgee"]["tw"]


def test_relabel_invariance():
    rng = np.random.RandomState(3)
    g = graph.queen(5)
    base = solver.solve(g, **FAST).width
    for _ in range(2):
        perm = rng.permutation(g.n)
        assert solver.solve(g.relabel(perm), **FAST).width == base


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_property_partial_ktree_bound(seed):
    """Random partial k-trees have tw <= k; solver must respect that."""
    rng = random.Random(seed)
    k = rng.randint(1, 4)
    n = rng.randint(k + 2, 16)
    g = graph.random_partial_ktree(n, k, drop=0.3, seed=seed)
    r = solver.solve(g, **FAST)
    assert r.exact and r.width <= k


def test_bloom_mode_agrees():
    for name in ["petersen", "myciel3", "queen5_5"]:
        g = graph.REGISTRY.get(name, lambda: graph.petersen())()
        a = solver.solve(g, mode="sort", **FAST)
        b = solver.solve(g, mode="bloom", m_bits=1 << 22, **FAST)
        assert a.width == b.width


def test_disconnected_graph():
    # union of a clique and a cycle: tw = max(4, 2)
    a = graph.complete(5)
    b = graph.cycle(6)
    n = a.n + b.n
    adj = np.zeros((n, n), dtype=bool)
    adj[:5, :5] = a.adj
    adj[5:, 5:] = b.adj
    g = graph.Graph(n, adj, "disc")
    r = solver.solve(g, **FAST)
    assert r.exact and r.width == 4


def test_overflow_marks_inexact():
    g = graph.queen(5)
    r = solver.solve(g, cap=64, block=32, use_preprocess=False, use_paths=False)
    # tiny capacity must either still find the right answer or mark inexact
    assert (not r.exact) or r.width == 18


def test_reconstruction_order_is_valid():
    g = graph.petersen()
    r = solver.solve(g, use_preprocess=False, reconstruct=True, **FAST)
    assert r.order is not None and len(r.order) == g.n
    assert sorted(r.order) == list(range(g.n))
    assert solver.order_width(g, r.order) == r.width == 4


def test_reconstruction_queen5():
    g = graph.queen(5)
    r = solver.solve(g, use_preprocess=False, reconstruct=True, **FAST)
    assert solver.order_width(g, r.order) == 18


def test_preprocess_block_safety():
    """tw computed via block decomposition == tw of the raw graph."""
    rng = random.Random(11)
    for seed in range(3):
        g = graph.gnp(14, 0.25, 50 + seed)
        a = solver.solve(g, use_preprocess=True, **FAST)
        b = solver.solve(g, use_preprocess=False, **FAST)
        assert a.width == b.width, (seed, a.width, b.width)


def test_schedules_agree():
    g = graph.myciel(3)
    widths = {s: solver.solve(g, schedule=s, **FAST).width
              for s in ("doubling", "while", "linear")}
    assert set(widths.values()) == {5}


def test_expanded_counts_deterministic():
    g = graph.petersen()
    a = solver.solve(g, **FAST)
    b = solver.solve(g, **FAST)
    assert a.expanded == b.expanded


def test_upper_bound_heuristics():
    g = graph.grid(6, 6)
    ub, order = bounds.upper_bound(g)
    assert ub >= 6
    assert solver.order_width(g, order) == ub
    assert bounds.lower_bound(g) <= 6
