import random

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitset, components, graph, mmw, solver


def _jax_mmw(g, s, k=1000):
    adj = jnp.asarray(g.packed())
    sw = jnp.asarray(bitset.np_pack([s], g.n)[0])
    _, reach = components.eliminated_degrees(adj, sw, g.n)
    return int(mmw.mmw_bound(reach, sw, jnp.int32(k), g.n))


@pytest.mark.parametrize("seed", range(6))
def test_matches_oracle(seed):
    rng = random.Random(seed)
    n = rng.randint(3, 24)
    g = graph.gnp(n, rng.choice([0.15, 0.3, 0.5]), seed)
    s = set(rng.sample(range(n), rng.randint(0, n // 2)))
    got = _jax_mmw(g, s)
    want = mmw.mmw_oracle(g.adj, s)
    assert got == want, (seed, n, s, got, want)


def test_known_graphs():
    assert _jax_mmw(graph.complete(6), set()) == 5
    assert _jax_mmw(graph.cycle(8), set()) == 2
    assert _jax_mmw(graph.path(8), set()) == 1


@pytest.mark.parametrize("seed", range(5))
def test_mmw_is_lower_bound(seed):
    """MMW(G) <= tw(G): the heuristic must never prune a true solution."""
    rng = random.Random(100 + seed)
    n = rng.randint(4, 14)
    g = graph.gnp(n, 0.4, seed)
    lb = _jax_mmw(g, set())
    tw = solver.solve(g, cap=1 << 12, block=1 << 6).width
    assert lb <= tw, (g.name, lb, tw)


def test_early_exit_prunes():
    # with tiny k the while loop exits as soon as lb > k; bound still valid
    g = graph.complete(8)
    got = _jax_mmw(g, set(), k=2)
    assert got >= 3   # early exit: >k, not necessarily the full bound


def test_solver_mmw_equivalent_results():
    for name in ["petersen", "mcgee", "grid6x6"]:
        g = graph.REGISTRY[name]()
        a = solver.solve(g, cap=1 << 14, block=1 << 8, use_mmw=False)
        b = solver.solve(g, cap=1 << 14, block=1 << 8, use_mmw=True)
        assert a.width == b.width
        assert b.expanded <= a.expanded   # MMW can only prune
