"""Per-architecture smoke tests: reduced configs of the same family, one
forward + one train step on CPU, asserting shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — launch/dryrun.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config, reduced
from repro.models import Model
from repro.train import step as step_lib

BATCH, SEQ = 2, 32


def _front(cfg, batch):
    out = {}
    if cfg.frontend == "audio":
        out["enc_embeds"] = jnp.ones((batch, cfg.encoder_len, cfg.d_model),
                                     jnp.float32) * 0.01
    if cfg.frontend == "vision":
        out["prefix_embeds"] = jnp.ones(
            (batch, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.01
    return out


@pytest.fixture(scope="module")
def rkey():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, rkey):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(rkey)
    toks = jax.random.randint(rkey, (BATCH, SEQ), 0, cfg.vocab)
    logits, _, aux = model.apply(params, toks, **_front(cfg, BATCH))
    assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rkey):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    # warmup 0: the lr ramp starts at 0, and a single-step smoke test needs
    # a non-zero update to observe parameter movement
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10)
    state = step_lib.init_state(model, rkey, tcfg)
    step_fn = jax.jit(step_lib.build_train_step(model, tcfg))
    toks = jax.random.randint(rkey, (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": toks,
             "targets": jnp.roll(toks, -1, axis=1),
             "mask": jnp.ones((BATCH, SEQ), jnp.float32)}
    batch.update(_front(cfg, BATCH))
    new_state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    assert int(new_state["step"]) == 1
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert changed, arch


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "hymba-1.5b", "xlstm-1.3b",
                                  "whisper-small", "granite-moe-1b-a400m"])
def test_decode_smoke(arch, rkey):
    """Prefill + 4 decode steps with finite logits for representative archs
    of each cache kind (KV / window+SSM / pure-state / cross / MoE)."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(rkey)
    toks = jax.random.randint(rkey, (BATCH, SEQ), 0, cfg.vocab)
    cache = model.init_cache(BATCH, SEQ + 8)
    kw = _front(cfg, BATCH)
    logits, cache, _ = model.apply(params, toks, mode="prefill", cache=cache,
                                   **kw)
    pos = jnp.full((BATCH,), SEQ, jnp.int32)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(4):
        step_logits, cache, _ = model.apply(params, tok, mode="decode",
                                            cache=cache, pos=pos)
        assert bool(jnp.all(jnp.isfinite(step_logits))), arch
        tok = jnp.argmax(step_logits, axis=-1).astype(jnp.int32)[:, None] \
            if step_logits.ndim == 2 else jnp.argmax(
                step_logits, axis=-1).astype(jnp.int32)
        tok = tok.reshape(BATCH, 1)
        pos = pos + 1


def test_reduced_preserves_family():
    for arch in ARCH_IDS:
        full = get_config(arch)
        red = reduced(full)
        assert red.family == full.family
        assert red.block_pattern == full.block_pattern
        assert (red.moe is None) == (full.moe is None)
        assert (red.ssm is None) == (full.ssm is None)
        assert red.cross_attention == full.cross_attention
