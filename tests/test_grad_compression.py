"""int8 gradient compression (DP all-reduce) — quality + wire-savings.

Runs in a subprocess with 8 forced devices (pure-DP mesh: params replicated
across DP for the compression path; FSDP composition is documented future
work in DESIGN.md §4)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, devices=8, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout[-2000:] + "\n" + out.stderr[-3000:]
    return out.stdout


def test_quantize_roundtrip_accuracy():
    import jax
    import jax.numpy as jnp
    from repro.train.step import quantize_int8
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
    q, scale = quantize_int8(g)
    rec = q.astype(jnp.float32) * scale
    rel = float(jnp.linalg.norm(rec - g) / jnp.linalg.norm(g))
    assert rel < 0.01                      # <1% relative error per tensor


def test_compressed_psum_matches_mean_grad():
    stdout = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train.step import compressed_psum

        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 0.02

        def local(xs):
            return compressed_psum(xs, ("data",))

        f = jax.jit(shard_map(local, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_rep=False))
        got = np.asarray(f(x))[0]              # every shard returns the mean
        want = np.asarray(jnp.mean(x, axis=0))
        rel = np.linalg.norm(got - want) / np.linalg.norm(want)
        print("REL", rel)
        assert rel < 0.05, rel
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in stdout


def test_compressed_training_still_learns():
    stdout = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, TrainConfig
        from repro.data.synthetic import SyntheticLM
        from repro.models import Model
        from repro.optim import optimizers as opt_lib
        from repro.train import step as step_lib

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv=2, d_ff=64, vocab=128,
                          vocab_pad_multiple=64)
        tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=0,
                           total_steps=40)
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        model = Model(cfg)
        grads_fn = jax.jit(step_lib.build_compressed_grads(model, tcfg,
                                                           mesh))
        params = model.init(jax.random.PRNGKey(0))
        opt = opt_lib.adamw_init(params)
        data = SyntheticLM(vocab=128, seq_len=32, global_batch=8, seed=4)
        losses = []
        for i in range(30):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            g, m = grads_fn(params, b)
            g, _ = opt_lib.clip_by_global_norm(g, 1.0)
            params, opt = opt_lib.adamw_update(
                g, opt, params, lr=1e-2)
            losses.append(float(m["loss"]))
        print("LOSSES", losses[0], losses[-1])
        assert losses[-1] < losses[0] - 0.3
        print("LEARNS-OK")
    """)
    assert "LEARNS-OK" in stdout
