"""Training substrate: learning, accumulation equivalence, optimizers,
schedules, data determinism."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models import Model
from repro.optim import optimizers as opt_lib
from repro.train import step as step_lib

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=256,
                  vocab_pad_multiple=64, attn_chunk=32)


def _batch(data, i):
    return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}


def test_loss_decreases():
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=60)
    m = Model(CFG)
    state = step_lib.init_state(m, jax.random.PRNGKey(0), tcfg)
    fn = jax.jit(step_lib.build_train_step(m, tcfg))
    data = SyntheticLM(vocab=256, seq_len=64, global_batch=8, seed=1)
    losses = []
    for i in range(30):
        state, metrics = fn(state, _batch(data, i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert losses[-1] < math.log(256)      # beats uniform


def test_grad_accumulation_equivalence():
    m = Model(CFG)
    data = SyntheticLM(vocab=256, seq_len=32, global_batch=8, seed=2)
    b = _batch(data, 0)
    outs = []
    for micro in (0, 2, 4):
        tcfg = TrainConfig(learning_rate=1e-2, microbatch=micro)
        st = step_lib.init_state(m, jax.random.PRNGKey(0), tcfg)
        st, _ = jax.jit(step_lib.build_train_step(m, tcfg))(st, b)
        outs.append(jax.tree.leaves(st["params"]))
    for leaves in outs[1:]:
        for a, c in zip(outs[0], leaves):
            assert float(jnp.max(jnp.abs(a - c))) < 1e-4


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_learn(opt):
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=2, total_steps=40,
                       optimizer=opt)
    m = Model(CFG)
    state = step_lib.init_state(m, jax.random.PRNGKey(0), tcfg)
    fn = jax.jit(step_lib.build_train_step(m, tcfg))
    data = SyntheticLM(vocab=256, seq_len=32, global_batch=8, seed=3)
    first = last = None
    for i in range(25):
        state, metrics = fn(state, _batch(data, i))
        first = first if first is not None else float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.2, (opt, first, last)


def test_adafactor_state_is_factored():
    m = Model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    st = opt_lib.adafactor_init(params)
    pbytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    vbytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves(st["v"]))
    assert vbytes < 0.25 * pbytes          # factored stats are tiny


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((2, 2)) * 10.0}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert abs(float(opt_lib.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_warmup_cosine_schedule():
    lr = opt_lib.warmup_cosine(jnp.asarray(0), peak=1.0, warmup=10, total=100)
    assert float(lr) == 0.0
    lr = opt_lib.warmup_cosine(jnp.asarray(10), peak=1.0, warmup=10,
                               total=100)
    assert abs(float(lr) - 1.0) < 1e-6
    lr_end = opt_lib.warmup_cosine(jnp.asarray(100), peak=1.0, warmup=10,
                                   total=100)
    assert float(lr_end) < 0.11


def test_data_determinism_and_sharded_slices():
    d = SyntheticLM(vocab=64, seq_len=16, global_batch=8, seed=5)
    a = d.batch_at(3)
    b = d.batch_at(3)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # per-host slicing reassembles to the global batch
    s0 = d.batch_at(3, batch=4, batch_offset=0)
    s1 = d.batch_at(3, batch=4, batch_offset=4)
    assert np.array_equal(np.concatenate([s0["tokens"], s1["tokens"]]),
                          a["tokens"])


def test_data_is_learnable_structure():
    """targets follow the affine rule ~(1-p_noise) of the time."""
    d = SyntheticLM(vocab=64, seq_len=128, global_batch=4, seed=6)
    b = d.batch_at(0)
    pred = (d.a * b["tokens"] + d.c) % d.vocab
    agreement = (pred == b["targets"]).mean()
    assert 0.7 < agreement <= 1.0
