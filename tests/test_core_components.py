"""eliminated_degrees (the TPU-native Q(S,v) computation) vs the paper's DFS."""
import random

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitset, components, expand, graph


def _check_graph_state(g, s):
    adj = jnp.asarray(g.packed())
    sw = jnp.asarray(bitset.np_pack([s], g.n)[0])
    adjb = [list(map(bool, row)) for row in g.adj]
    for schedule in ("doubling", "while", "linear"):
        degs, _ = components.eliminated_degrees(adj, sw, g.n, schedule=schedule)
        for v in range(g.n):
            if v in s:
                continue
            assert int(degs[v]) == expand.degree_oracle(adjb, s, v), (
                schedule, v, s)


@pytest.mark.parametrize("seed", range(8))
def test_random_gnp(seed):
    rng = random.Random(seed)
    n = rng.randint(2, 48)
    g = graph.gnp(n, rng.choice([0.08, 0.25, 0.5, 0.9]), seed)
    s = set(rng.sample(range(n), rng.randint(0, n - 1)))
    _check_graph_state(g, s)


def test_empty_s_is_plain_degree():
    g = graph.queen(4)
    adj = jnp.asarray(g.packed())
    sw = jnp.zeros((g.w,), dtype=jnp.uint32)
    degs, _ = components.eliminated_degrees(adj, sw, g.n)
    assert np.array_equal(np.asarray(degs), g.degrees())


def test_word_boundary_graphs():
    # n crossing 32/64 boundaries exercises multi-word packing
    for n in (31, 32, 33, 63, 64, 65):
        g = graph.cycle(n)
        s = {1, 2, 3, n - 2}
        _check_graph_state(g, s)


def test_path_through_s_chain():
    # 0-1-2-3-4 path: eliminating {1,2,3} makes 0 adjacent to 4
    g = graph.path(5)
    adj = jnp.asarray(g.packed())
    sw = jnp.asarray(bitset.np_pack([{1, 2, 3}], 5)[0])
    degs, _ = components.eliminated_degrees(adj, sw, 5)
    assert int(degs[0]) == 1 and int(degs[4]) == 1


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_property_degrees_match_oracle(seed):
    rng = random.Random(seed)
    n = rng.randint(2, 34)
    g = graph.gnp(n, rng.random(), seed % 7919)
    s = set(rng.sample(range(n), rng.randint(0, n - 1)))
    adj = jnp.asarray(g.packed())
    sw = jnp.asarray(bitset.np_pack([s], n)[0])
    degs, _ = components.eliminated_degrees(adj, sw, n)
    adjb = [list(map(bool, row)) for row in g.adj]
    vs = [v for v in range(n) if v not in s]
    v = rng.choice(vs)
    assert int(degs[v]) == expand.degree_oracle(adjb, s, v)


def test_reach_reused_by_expand_block():
    g = graph.grid(4, 4)
    adj = jnp.asarray(g.packed())
    states = jnp.asarray(bitset.np_pack([set(), {0, 1}, {5}], g.n))
    valid = jnp.asarray([True, True, True])
    allowed = bitset.full(g.n)
    children, feas, degs, reach = expand.expand_block(
        adj, states, valid, jnp.int32(3), allowed, g.n)
    assert children.shape == (3, g.n, g.w)
    assert feas.shape == (3, g.n)
    # child bitsets contain the parent plus exactly one vertex
    pc = np.asarray(children)
    for b in range(3):
        for v in range(g.n):
            got = bitset.np_unpack(pc[b, v], g.n)
            want = bitset.np_unpack(np.asarray(states[b]), g.n) | {v}
            assert got == want
