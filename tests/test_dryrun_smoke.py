"""Dry-run machinery smoke test (subprocess: needs forced host devices).

The full 512-device sweep lives in artifacts/ (launch/dryrun.py); this test
proves the lowering path end-to-end on a small forced mesh so CI catches
sharding regressions quickly.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, devices=16, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout[-2000:] + "\n" + out.stderr[-4000:]
    return out.stdout


def test_reduced_cells_lower_on_4x4_mesh():
    stdout = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced, TrainConfig
        from repro.models import Model
        from repro.sharding import rules as rules_lib
        from repro.train import step as step_lib
        from repro.utils import compat

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        for arch in ["qwen3-0.6b", "granite-moe-1b-a400m", "xlstm-1.3b",
                     "hymba-1.5b", "whisper-small"]:
            cfg = reduced(get_config(arch)).replace(
                d_model=64, n_heads=4, n_kv=2, d_ff=128)
            model = Model(cfg)
            tcfg = TrainConfig()
            state_abs = step_lib.abstract_state(model, tcfg)
            state_sh = step_lib.state_shardings(model, tcfg, mesh)
            specs = {
                "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                "mask": jax.ShapeDtypeStruct((8, 64), jnp.float32),
            }
            if cfg.frontend == "audio":
                specs["enc_embeds"] = jax.ShapeDtypeStruct(
                    (8, cfg.encoder_len, cfg.d_model), jnp.float32)
            if cfg.frontend == "vision":
                specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                    (8, cfg.frontend_len, cfg.d_model), jnp.float32)
            bsh = rules_lib.batch_shardings_for(specs, mesh)
            fn = step_lib.build_train_step(model, tcfg)
            lowered = jax.jit(fn, in_shardings=(state_sh, bsh),
                              out_shardings=(state_sh, None)).lower(
                                  state_abs, specs)
            compiled = lowered.compile()
            cost = compat.cost_analysis_dict(compiled)
            assert cost.get("flops", 0) > 0, arch
            print("LOWERED", arch)
        print("DRYRUN-SMOKE-OK")
    """)
    assert "DRYRUN-SMOKE-OK" in stdout


def test_production_mesh_shapes():
    stdout = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.devices.shape == (16, 16)
        assert m1.axis_names == ("data", "model")
        m2 = make_production_mesh(multi_pod=True)
        assert m2.devices.shape == (2, 16, 16)
        assert m2.axis_names == ("pod", "data", "model")
        print("MESH-OK")
    """, devices=512)
    assert "MESH-OK" in stdout


def test_artifacts_exist_and_wellformed():
    """The committed sweep must cover all 40 cells x 2 meshes."""
    adir = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(adir):
        pytest.skip("no artifacts directory (sweep not run)")
    import glob
    base = [p for p in glob.glob(os.path.join(adir, "*.json"))
            if "__opt" not in p and "__g1" not in p and "__r" not in
            os.path.basename(p).split("__")[-1]]
    cells = {}
    for p in base:
        with open(p) as f:
            cells[os.path.basename(p)] = json.load(f)
    meshes = {"16x16", "2x16x16"}
    seen = {m: 0 for m in meshes}
    for name, c in cells.items():
        mesh = name[:-5].split("__")[2]
        if mesh in meshes:
            seen[mesh] += 1
            assert c["status"] in ("ok", "skipped"), (name, c["status"])
    for m, n in seen.items():
        assert n == 40, (m, n)
