"""Async streaming solve service (ISSUE 5 / DESIGN.md §11).

The contract on top of §10's: dispatches launch without blocking
(``decide_lanes_async`` / ``DispatchHandle``), admission and planning of
newly arrived requests overlap the in-flight device work (they are
packed into the *next* dispatch, never waiting for an idle pool),
per-request knob overrides coexist in one pool via config-group
sub-dispatches, and every request can stream per-rung events whose
running lb/ub are monotone and whose ordering is pinned — all while
results stay bit-identical to sequential ``solver.solve``.
"""
import pytest

from repro.core import backend as backend_lib
from repro.core import batch, engine, graph, solver
from repro.serve.twscheduler import TwScheduler

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)
LANE_KW = dict(block=BLOCK, mode="sort", use_mmw=False, m_bits=1 << 12,
               k_hashes=4, schedule="while")


# ------------------------------------------------------- dispatch handles

def test_decide_lanes_async_matches_blocking_and_defers_the_sync():
    """The async launch counts its dispatch immediately but no host sync
    until ``result()``; verdicts are identical to the blocking call and
    cached on the handle."""
    lanes = [batch.Lane(graph.petersen(), k) for k in (2, 3, 4)]
    engine.reset_counters()
    h = batch.decide_lanes_async(lanes, **FAST, mode="sort", use_mmw=False,
                                 m_bits=1 << 12, k_hashes=4,
                                 schedule="while")
    c = dict(engine.COUNTERS)
    assert c["dispatches"] == 1 and c["host_syncs"] == 0
    res = h.result()
    assert engine.COUNTERS["host_syncs"] == 1
    assert h.result() is res                       # cached
    assert engine.COUNTERS["host_syncs"] == 1      # ... without a resync
    blocking = batch.decide_lanes(lanes, **FAST, mode="sort",
                                  use_mmw=False, m_bits=1 << 12,
                                  k_hashes=4, schedule="while")
    for a, b in zip(res, blocking):
        assert (a.feasible, a.inexact, a.expanded) == \
            (b.feasible, b.inexact, b.expanded)


def test_decide_lanes_async_empty_is_a_noop():
    engine.reset_counters()
    assert batch.decide_lanes_async([], **LANE_KW).result() == []
    assert all(v == 0 for v in engine.COUNTERS.values()), engine.COUNTERS


def test_fused_decide_launch_handle_parity():
    """engine.fused_decide == fused_decide_launch().result(), bit for bit,
    and the handle reports ready after the sync."""
    import jax.numpy as jnp
    from repro.core import bitset
    g = graph.petersen()
    adj = jnp.asarray(g.packed())
    allowed = jnp.asarray(bitset.np_allowed(g.n, []))
    kw = dict(n=g.n, cap=1 << 10, block=BLOCK, mode="sort", use_mmw=False,
              m_bits=1 << 12, k_hashes=4, schedule="while")
    h = engine.fused_decide_launch(adj, allowed, 3, g.n - 4, **kw)
    feas, inex, exp, fr = h.result()
    assert h.ready()
    feas2, inex2, exp2, fr2 = engine.fused_decide(adj, allowed, 3,
                                                  g.n - 4, **kw)
    assert (feas, inex, exp) == (feas2, inex2, exp2)
    assert int(fr.count) == int(fr2.count)
    assert (fr.states == fr2.states).all()


# ------------------------------------------------------------- streaming

def _collect(sched, gs, **per_req):
    events = {}
    rids = []
    for g in gs:
        evs = []
        rid = sched.submit(g, on_event=evs.append, **per_req)
        events[rid] = evs
        rids.append(rid)
    done = sched.run()
    return rids, events, done


def test_event_stream_order_and_monotone_bounds(event_invariants):
    """Per request: seq strictly increases, a block's rung_decided ks
    arrive in increasing order, lb never decreases, ub never increases,
    lb <= ub throughout, and the final done event is last and consistent
    with the result (lb meets ub at the width when exact) — the shared
    ``conftest.check_event_stream`` contract."""
    sched = TwScheduler(lanes=2, **FAST)
    rids, events, done = _collect(sched, [graph.petersen(), graph.queen(5)])
    for rid in rids:
        evs = events[rid]
        assert evs[0]["event"] == "admitted"
        d = event_invariants(evs, rid=rid)
        r = done[rid]
        assert d["event"] == "done"
        assert (d["width"], d["exact"], d["expanded"]) == \
            (r.width, r.exact, r.expanded)
        assert d["ub"] == r.width
        if r.exact:
            assert d["lb"] == r.width


def test_streamed_per_k_deltas_reassemble_the_result_per_k():
    """The rung_decided deltas are the per_k dict: reassembling them per
    block reproduces the result's per_k (and the sequential solver's)."""
    g = graph.queen(5)
    sched = TwScheduler(lanes=1, **FAST)
    (rid,), events, done = _collect(sched, [g])
    got = {}
    for e in events[rid]:
        if e["event"] == "rung_decided":
            got.setdefault(e["block"], {})[e["k"]] = {
                "feasible": e["feasible"], "inexact": e["inexact"],
                "expanded": e["expanded"]}
    res = done[rid]
    searched = {blk: pk for blk, pk in res.per_k.items() if pk}
    assert got == searched
    seq = solver.solve(g, **FAST)
    assert res.per_k == seq.per_k


def test_broken_event_sink_does_not_break_the_solve():
    def sink(ev):
        raise RuntimeError("boom")
    sched = TwScheduler(lanes=1, **FAST)
    with pytest.warns(UserWarning, match="event sink"):
        rid = sched.submit(graph.petersen(), on_event=sink)
        done = sched.run()
    ref = solver.solve(graph.petersen(), **FAST)
    assert done[rid].width == ref.width


# -------------------------------------------------- per-request overrides

def test_mixed_per_request_configs_in_one_pool_match_solo_solves():
    """One pool, four configs: pool-default sort, a bloom request, an MMW
    request, an explicit-cap request.  Each result matches its own
    sequential solve; incompatible configs ran as sub-pool dispatches
    (more dispatches than steps)."""
    pool_kw = dict(block=BLOCK, m_bits=1 << 14, cap=1 << 12)
    reqs = [
        (graph.petersen(), {}),
        (graph.myciel(3), {"mode": "bloom"}),       # one word: bit parity
        (graph.grid(3, 4), {"use_mmw": True}),
        (graph.desargues(), {"cap": 1 << 11}),
    ]
    sched = TwScheduler(lanes=4, **pool_kw)
    engine.reset_counters()
    rids = [sched.submit(g, **kw) for g, kw in reqs]
    done = sched.run()
    c = dict(engine.COUNTERS)
    # >= 2 config groups coexisted, so some step issued several dispatches
    assert c["dispatches"] > sched.rounds
    for rid, (g, kw) in zip(rids, reqs):
        solo_kw = dict(pool_kw)
        solo_kw["cap"] = kw.get("cap", solo_kw["cap"])
        if "mode" in kw:
            solo_kw["mode"] = kw["mode"]
        a = solver.solve(g, use_mmw=kw.get("use_mmw", False), **solo_kw)
        b = done[rid]
        assert (a.width, a.exact, a.lb, a.ub) == \
            (b.width, b.exact, b.lb, b.ub), g.name
        if not kw.get("use_mmw"):
            # bit parity; under MMW padding rows may change expanded
            # (documented §8/§10 caveat), verdicts never
            assert (a.expanded, a.per_k) == (b.expanded, b.per_k), g.name


def test_per_request_speculate_keeps_parity_in_fewer_rounds():
    g = graph.queen(5)
    seq = solver.solve(g, **FAST)
    rungs = sum(len(pk) for pk in seq.per_k.values())
    assert rungs > 1, "need a multi-rung ladder for this test"
    one = TwScheduler(lanes=4, **FAST)
    r1 = one.submit(g)
    spec = TwScheduler(lanes=4, **FAST)
    r4 = spec.submit(g, speculate=4)
    a, b = one.run()[r1], spec.run()[r4]
    for res in (a, b):
        assert (res.width, res.exact, res.expanded, res.per_k) == \
            (seq.width, seq.exact, seq.expanded, seq.per_k)
    assert spec.rounds < one.rounds


def test_budget_splits_across_a_steps_concurrent_dispatches():
    """All of a step's dispatches are device-resident before any sync,
    so a pool budget must bound their SUM: two config groups in one
    step each get half the budget."""
    from repro.core import bitset
    budget = 2 * 1024 * 1 * 4 * 2        # two groups of lanes=2 x 1024 x W=1
    sched = TwScheduler(lanes=2, block=BLOCK, budget_bytes=budget)
    r0 = sched.submit(graph.petersen())
    r1 = sched.submit(graph.myciel(3), use_mmw=True)   # second group
    assert sched.launch()
    assert sched.inflight_dispatches == 2   # one dispatch per config group
    w = bitset.n_words(sched._n_pad)
    resident = sum(cap * 2 * w * 4 for cap in sched._cap_pad.values())
    assert resident <= budget
    sched.sync()
    done = sched.run()
    # a binding budget may reintroduce drops (documented §10): results
    # stay correct-as-upper-bounds and every request completes
    assert set(done) == {r0, r1}
    assert done[r0].width >= solver.solve(graph.petersen(),
                                          block=BLOCK).width
    assert done[r1].width >= solver.solve(graph.myciel(3), use_mmw=True,
                                          block=BLOCK).width


def test_recover_after_failed_step_keeps_serving():
    """recover() clears in-flight state after a raising step; the rungs
    re-pack from unchanged host state and results stay correct."""
    sched = TwScheduler(lanes=2, **FAST)
    rid = sched.submit(graph.petersen())
    assert sched.launch()
    # simulate a sync-side failure: poison the handle, then recover
    no, handles, t0 = sched._rounds[0]
    handle, metas = handles[0]
    handles[0] = (None, metas)                  # .result() -> AttributeError
    with pytest.raises(AttributeError):
        sched.sync()
    sched.recover()
    assert not sched.in_flight
    done = sched.run()                           # re-packs the same rung
    ref = solver.solve(graph.petersen(), **FAST)
    assert (done[rid].width, done[rid].exact) == (ref.width, ref.exact)


def test_per_request_capability_error_is_per_request():
    """A bad override fails its submit alone; the pool keeps serving."""
    sched = TwScheduler(lanes=2, **FAST)
    with pytest.raises(backend_lib.BackendCapabilityError):
        sched.submit(graph.petersen(), mode="nope")
    with pytest.raises(ValueError):
        sched.submit(graph.petersen(), cap=100)      # not a clean geometry
    rid = sched.submit(graph.petersen())
    ref = solver.solve(graph.petersen(), **FAST)
    assert sched.run()[rid].width == ref.width


# ------------------------------------------------------- overlap pipeline

def test_late_arrival_is_admitted_during_an_inflight_dispatch():
    """The acceptance criterion: submit while a dispatch is in flight;
    the request takes a free slot *before* the verdict sync
    (COUNTERS-asserted: zero host syncs between launch and admission)
    and its first rung rides the very next dispatch."""
    sched = TwScheduler(lanes=2, **FAST)
    r0 = sched.submit(graph.queen(5))
    engine.reset_counters()
    assert sched.launch()
    assert sched.in_flight
    launch_c = dict(engine.COUNTERS)
    assert launch_c["host_syncs"] == 0      # verdict not read yet

    evs = []
    r1 = sched.submit(graph.petersen(), on_event=evs.append)
    sched.poll_admissions()                 # overlap bookkeeping
    # admitted into a free slot while round 1 is still un-synced
    assert engine.COUNTERS["host_syncs"] == 0
    assert sched.in_flight
    assert any(req.rid == r1 for _i, (req, _s) in sched.pool.active())
    admitted = [e for e in evs if e["event"] == "admitted"]
    assert admitted and admitted[0]["round"] == 2

    sched.sync()
    done = sched.run()
    first_rung = next(e for e in evs if e["event"] == "rung_started")
    assert first_rung["round"] == 2         # the very next dispatch
    for rid, g in ((r0, graph.queen(5)), (r1, graph.petersen())):
        a = solver.solve(g, **FAST)
        b = done[rid]
        assert (a.width, a.exact, a.expanded, a.per_k) == \
            (b.width, b.exact, b.expanded, b.per_k), g.name


def test_overlap_beats_blocking_two_phase_round_count():
    """Step-count evidence: a late burst overlapped into a draining pool
    completes in fewer scheduler rounds than the blocking pattern (drain
    to idle, then serve the burst)."""
    early, late = [graph.queen(5)], [graph.petersen(), graph.myciel(3)]

    blocking = TwScheduler(lanes=4, **FAST)
    for g in early:
        blocking.submit(g)
    blocking.run()                           # wait for pool idle ...
    for g in late:
        blocking.submit(g)
    blocking.run()                           # ... then serve the burst

    overlap = TwScheduler(lanes=4, **FAST)
    rids = [overlap.submit(g) for g in early]
    assert overlap.launch()
    rids += [overlap.submit(g) for g in late]   # lands mid-flight
    overlap.poll_admissions()
    overlap.sync()
    done = overlap.run()

    assert overlap.rounds < blocking.rounds
    for rid, g in zip(rids, early + late):
        a = solver.solve(g, **FAST)
        b = done[rid]
        assert (a.width, a.exact, a.expanded) == \
            (b.width, b.exact, b.expanded), g.name


def test_launch_twice_without_sync_is_an_error():
    sched = TwScheduler(lanes=1, **FAST)
    sched.submit(graph.petersen())
    assert sched.launch()
    with pytest.raises(RuntimeError, match="in flight"):
        sched.launch()
    sched.sync()
    sched.run()


def test_status_snapshots():
    sched = TwScheduler(lanes=1, **FAST)
    r0 = sched.submit(graph.queen(5))
    r1 = sched.submit(graph.petersen())
    assert sched.status(r0)["state"] == "queued"
    sched.launch()
    assert sched.status(r0)["state"] == "running"
    assert sched.status(r1)["state"] == "queued"   # pool full: 1 lane
    assert sched.status(999)["state"] == "unknown"
    sched.sync()
    sched.run()
    st = sched.status(r0)
    assert st["state"] == "done" and st["width"] == sched.done[r0].width
