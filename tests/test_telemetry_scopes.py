"""Tracker scoping through the solve service: per-request child scopes
sum exactly into the pool scope (the write-through invariant), across
the full traffic-shaping matrix — plain streams, cancellation, deadline
preemption, and sharded scale-out — and over the wire via the twserved
``metrics`` op.

The reconciliation keys are the rung-attributed counters (``expanded``,
``rungs_decided``, ``rung_overflows``): they are only ever counted
through a request's ``InstanceState.feed`` (or its sharded dispatches),
so the sum over request snapshots must equal the pool totals exactly —
discarded verdicts (cancelled / preempted / overshot rungs) are counted
in neither.
"""
import pytest

from repro.core import graph, telemetry
from repro.core.telemetry import Tracker
from repro.serve.twscheduler import TwScheduler

BLOCK = 32
FAST = dict(cap=1 << 12, block=BLOCK)

RECON_KEYS = ("expanded", "rungs_decided", "rung_overflows")


def _reconcile(m):
    """Assert the per-request snapshots sum exactly into the pool scope
    on every rung-attributed counter."""
    pool = m["pool"]["counters"]
    for key in RECON_KEYS:
        total = sum(s["counters"].get(key, 0)
                    for s in m["requests"].values())
        assert total == pool.get(key, 0), \
            (key, total, pool.get(key, 0), m)


def test_request_scopes_sum_to_pool_totals():
    sched = TwScheduler(lanes=2, tracker=Tracker(), **FAST)
    rids = [sched.submit(g) for g in (graph.petersen(), graph.myciel(3),
                                      graph.petersen())]
    done = sched.run()
    assert set(done) == set(rids)
    m = sched.metrics()
    assert set(m["requests"]) == set(rids)
    assert m["pool"]["counters"]["expanded"] > 0
    _reconcile(m)
    # each terminal snapshot carries the rounds-per-request gauge and the
    # submit->done latency timing
    for snap in m["requests"].values():
        assert "rounds" in snap["gauges"]
        assert snap["timings"]["request_s"]["calls"] == 1


def test_done_event_metrics_match_retained_snapshot():
    events = []
    sched = TwScheduler(lanes=2, tracker=Tracker(), **FAST)
    rid = sched.submit(graph.petersen(), on_event=events.append)
    sched.run()
    done_ev = next(e for e in events if e["event"] == "done")
    assert done_ev["metrics"] == sched.req_metrics[rid]
    assert done_ev["metrics"]["counters"]["expanded"] == \
        sched.done[rid].expanded


def test_cancelled_requests_reconcile():
    # one request cancelled while queued (lanes=1 serialises admission),
    # one cancelled mid-flight, one surviving
    sched = TwScheduler(lanes=1, tracker=Tracker(), **FAST)
    evs = {}

    def sub(g):
        lst = []
        rid = sched.submit(g, on_event=lst.append)
        evs[rid] = lst
        return rid

    r_live = sub(graph.petersen())
    r_fly = sub(graph.petersen())
    r_queued = sub(graph.myciel(3))
    assert sched.launch()              # r_live's rung goes in flight
    assert sched.cancel(r_queued)      # dropped from the queue
    sched.sync()
    # r_fly is admitted by now (lanes=1: as soon as r_live finishes) or
    # still queued; cancel it wherever it is
    sched.cancel(r_fly)
    sched.run()
    assert sched.terminal[r_queued] == "cancelled"
    assert sched.terminal[r_fly] == "cancelled"
    assert sched.terminal[r_live] == "done"
    m = sched.metrics()
    _reconcile(m)
    # cancelled requests still report their terminal per-request metrics
    for rid in (r_queued, r_fly):
        assert rid in m["requests"]
        cancel_ev = next(e for e in evs[rid] if e["event"] == "cancelled")
        assert cancel_ev["metrics"] == sched.req_metrics[rid]
    # the queued cancel never ran a rung
    assert m["requests"][r_queued]["counters"].get("rungs_decided", 0) == 0


def test_deadline_preempted_requests_reconcile():
    events = []
    sched = TwScheduler(lanes=2, tracker=Tracker(), **FAST)
    r_dead = sched.submit(graph.myciel(3), deadline_s=0.0,
                          on_event=events.append)
    r_live = sched.submit(graph.petersen())
    done = sched.run()
    assert sched.terminal[r_dead] == "timeout"
    assert sched.terminal[r_live] == "done"
    assert not done[r_dead].exact
    m = sched.metrics()
    _reconcile(m)
    ev = next(e for e in events if e["event"] == "done")
    assert ev["timed_out"] is True
    assert ev["metrics"] == sched.req_metrics[r_dead]


def test_sharded_request_reconciles_and_attributes_dispatches():
    sched = TwScheduler(lanes=4, tracker=Tracker(), **FAST)
    r_shard = sched.submit(graph.myciel(3), shards=2)
    r_small = sched.submit(graph.petersen())
    done = sched.run()
    assert done[r_shard].exact and done[r_small].exact
    m = sched.metrics()
    _reconcile(m)
    # a sharded dispatch serves exactly one request, so its dispatch
    # count is attributed to that request's scope (shared vmapped
    # dispatches stay pool-level: the small request's scope counts none)
    shard_snap = m["requests"][r_shard]
    assert shard_snap["counters"].get("dispatches", 0) > 0
    assert m["requests"][r_small]["counters"].get("dispatches", 0) == 0
    assert m["pool"]["counters"]["dispatches"] >= \
        shard_snap["counters"]["dispatches"]


def test_pool_scope_isolated_per_scheduler():
    """Two schedulers in one process must not merge counters — each
    default pool tracker is a uniquely-scoped child of the root."""
    a = TwScheduler(lanes=1, **FAST)
    b = TwScheduler(lanes=1, **FAST)
    assert a.tracker is not b.tracker
    assert a.tracker.scope != b.tracker.scope
    ra = a.submit(graph.petersen())
    a.run()
    assert a.metrics()["pool"]["counters"]["expanded"] > 0
    assert b.metrics()["pool"]["counters"].get("expanded", 0) == 0
    assert ra in a.metrics()["requests"]
    assert a.metrics()["requests"] and not b.metrics()["requests"]


def test_metrics_rid_filter():
    sched = TwScheduler(lanes=2, tracker=Tracker(), **FAST)
    r0 = sched.submit(graph.petersen())
    r1 = sched.submit(graph.petersen())
    sched.run()
    m = sched.metrics(rid=r0)
    assert set(m["requests"]) == {r0}
    assert sched.metrics(rid=10_000)["requests"] == {}
    assert set(sched.metrics()["requests"]) == {r0, r1}


def test_metrics_op_over_the_wire():
    """The twserved ``metrics`` op returns the scheduler snapshot as
    plain JSON, reconciling over the wire (rids stringify in JSON)."""
    twserved = pytest.importorskip("repro.launch.twserved")
    from repro.serve.client import TwClient

    srv = twserved.TwServer(port=0, lanes=2, **FAST)
    srv.start()
    try:
        c = TwClient(port=srv.port)
        rid = c.submit("petersen")
        r_cancel = c.submit("myciel4", priority=-1)
        res = c.result(rid)
        c.cancel(r_cancel)
        m = c.metrics()
        pool = m["pool"]["counters"]
        for key in RECON_KEYS:
            total = sum(s["counters"].get(key, 0)
                        for s in m["requests"].values())
            assert total == pool.get(key, 0), (key, m)
        snap = m["requests"][str(rid)]
        assert snap["counters"]["expanded"] == res["expanded"]
        only = c.metrics(rid=rid)["requests"]
        assert set(only) == {str(rid)}
    finally:
        c.shutdown()
        srv.serve_until_shutdown()
