"""Attention equivalences + MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import attention as A
from repro.models import moe
from repro.models.params import init_params


def _qkv(seed, b=2, s=64, h=4, kh=2, hd=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
@pytest.mark.parametrize("window", [None, 12])
def test_chunked_equals_full(chunk, window):
    q, k, v = _qkv(0)
    a = A.full_attention(q, k, v, causal=True, window=window)
    b = A.chunked_attention(q, k, v, causal=True, chunk=chunk, window=window)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_gqa_grouping_matches_repeated_heads():
    """GQA via grouped einsum == explicitly repeating kv heads."""
    q, k, v = _qkv(1, h=8, kh=2)
    a = A.full_attention(q, k, v, causal=True)
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    b = A.full_attention(q, k_rep, v_rep, causal=True)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_decode_matches_full_last_position():
    q, k, v = _qkv(2)
    pos = jnp.asarray([63, 63])
    d = A.decode_attention(q[:, -1:], k, v, pos)
    f = A.full_attention(q, k, v, causal=True)[:, -1:]
    assert float(jnp.max(jnp.abs(d - f))) < 2e-5


def test_decode_per_slot_positions():
    """Different pos per slot must mask independently."""
    q, k, v = _qkv(3)
    positions = [10, 40]
    q_dec = jnp.stack([q[b, p] for b, p in enumerate(positions)])[:, None]
    d = A.decode_attention(q_dec, k, v, jnp.asarray(positions))
    for b, p in enumerate(positions):
        f = A.full_attention(q[b:b + 1, p:p + 1], k[b:b + 1, :p + 1],
                             v[b:b + 1, :p + 1], causal=True, q_offset=p)
        assert float(jnp.max(jnp.abs(d[b] - f[0]))) < 2e-5


def test_ring_buffer_window_decode():
    q, k, v = _qkv(4)
    win = 16
    b = q.shape[0]
    kr = jnp.zeros((b, win) + k.shape[2:])
    vr = jnp.zeros((b, win) + v.shape[2:])
    for t in range(64):
        kr, vr = A.update_window_cache(kr, vr, k[:, t:t + 1], v[:, t:t + 1],
                                       jnp.full((b,), t))
    d = A.decode_window_attention(q[:, -1:], kr, vr,
                                  jnp.full((b,), 63), win)
    f = A.full_attention(q, k, v, causal=True, window=win)[:, -1:]
    assert float(jnp.max(jnp.abs(d - f))) < 2e-5


# ------------------------------------------------------------------- MoE

def _moe_cfg(e=8, k=2, cap=4.0, shared=False):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4, n_kv=2,
        d_ff=64, vocab=128,
        moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=64,
                      capacity_factor=cap, shared_expert=shared))


@pytest.mark.parametrize("e,k", [(4, 1), (8, 2), (16, 4)])
def test_moe_matches_dense_reference(e, k):
    cfg = _moe_cfg(e, k)
    p = init_params(moe.moe_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.moe_block(p, x, cfg)
    yr = moe.moe_ref(p, x, cfg)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-4
    assert float(aux) > 0


def test_moe_shared_expert():
    cfg = _moe_cfg(4, 1, shared=True)
    p = init_params(moe.moe_spec(cfg), jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 32))
    y, _ = moe.moe_block(p, x, cfg)
    yr = moe.moe_ref(p, x, cfg)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-4


def test_moe_capacity_drops_degrade_gracefully():
    """With tiny capacity, output must stay finite (dropped tokens pass
    through the residual path as zeros, the Switch behaviour)."""
    cfg = _moe_cfg(4, 2, cap=0.25)
    p = init_params(moe.moe_spec(cfg), jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 32))
    y, _ = moe.moe_block(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_moe_router_load_balance_loss_bounds(seed):
    """Aux loss >= 1 with equality iff perfectly balanced (Switch lemma)."""
    cfg = _moe_cfg(4, 1, cap=8.0)
    p = init_params(moe.moe_spec(cfg), jax.random.PRNGKey(seed % 97))
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 32, 32))
    _, aux = moe.moe_block(p, x, cfg)
    # aux = lb_loss + z_loss; lb part >= 1 for top-1 routing
    assert float(aux) > 0.9
