import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bloom


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=8),
       st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_murmur_matches_reference(words, seed):
    arr = jnp.asarray(np.array(words, dtype=np.uint32))
    got = int(bloom.murmur3_words(arr, np.uint32(seed)))
    want = bloom.murmur3_ref(words, seed)
    assert got == want


def test_murmur_batched():
    rng = np.random.RandomState(0)
    batch = rng.randint(0, 2**32, size=(17, 2), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(bloom.murmur3_words(jnp.asarray(batch), bloom.SEED1))
    for i in range(17):
        assert int(got[i]) == bloom.murmur3_ref(batch[i], int(bloom.SEED1))


def test_no_false_negatives():
    """Inserted elements are always reported present (Bloom invariant)."""
    rng = np.random.RandomState(1)
    m = 1 << 16
    filt = bloom.make_filter(m)
    words = jnp.asarray(rng.randint(0, 2**31, size=(500, 2)).astype(np.uint32))
    valid = jnp.ones((500,), dtype=bool)
    new, filt = bloom.query_and_insert(filt, words, valid, m)
    assert bool(jnp.all(new))          # empty filter: everything is new
    new2, _ = bloom.query_and_insert(filt, words, valid, m)
    assert not bool(jnp.any(new2))     # all present now


def test_false_positive_rate_reasonable():
    """With m/n >= 24 and k=17 the paper expects ~1e-5 fp; test <= 1e-2."""
    rng = np.random.RandomState(2)
    n_elems = 2000
    m = n_elems * 24
    filt = bloom.make_filter(m)
    a = jnp.asarray(rng.randint(0, 2**31, size=(n_elems, 2)).astype(np.uint32))
    _, filt = bloom.query_and_insert(filt, a, jnp.ones((n_elems,), bool), m)
    b = jnp.asarray(rng.randint(0, 2**31, size=(20000, 2)).astype(np.uint32))
    idx = bloom.probe_indices(b, m)
    fp = float(jnp.mean(bloom.query(filt, idx)))
    assert fp <= 1e-2, fp


def test_invalid_entries_not_inserted():
    m = 1 << 12
    filt = bloom.make_filter(m)
    words = jnp.asarray(np.array([[1, 2], [3, 4]], dtype=np.uint32))
    valid = jnp.asarray([True, False])
    _, filt = bloom.query_and_insert(filt, words, valid, m)
    idx = bloom.probe_indices(words, m)
    present = np.asarray(bloom.query(filt, idx))
    assert present[0] and not present[1]


def test_probe_indices_spread():
    words = jnp.asarray(np.array([[123, 456]], dtype=np.uint32))
    idx = np.asarray(bloom.probe_indices(words, 1 << 20, 17))[0]
    assert len(set(idx.tolist())) == 17          # distinct probes w.h.p.
    assert idx.min() >= 0 and idx.max() < (1 << 20)
