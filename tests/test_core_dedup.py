import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dedup


def _np_unique_rows(rows, valid):
    live = rows[valid]
    return np.unique(live, axis=0) if len(live) else live


@given(st.integers(0, 10000), st.integers(1, 200), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_dedup_matches_numpy_unique(seed, m, w):
    rng = np.random.RandomState(seed)
    # small value range to force duplicates
    rows = rng.randint(0, 4, size=(m, w)).astype(np.uint32)
    valid = rng.rand(m) < 0.8
    cap = m + 4
    buf, count, dropped = dedup.dedup_compact(
        jnp.asarray(rows), jnp.asarray(valid), cap)
    want = _np_unique_rows(rows, valid)
    count = int(count)
    assert int(dropped) == 0
    assert count == len(want)
    got = np.asarray(buf)[:count]
    assert np.array_equal(np.sort(got, axis=0), np.sort(want, axis=0)) or \
        np.array_equal(got[np.lexsort(got.T[::-1])], want[np.lexsort(want.T[::-1])])


def test_overflow_drops_and_counts():
    rows = jnp.asarray(np.arange(40, dtype=np.uint32).reshape(20, 2))
    valid = jnp.ones((20,), dtype=bool)
    buf, count, dropped = dedup.dedup_compact(rows, valid, 8)
    assert int(count) == 8 and int(dropped) == 12


def test_all_invalid():
    rows = jnp.asarray(np.zeros((10, 2), dtype=np.uint32))
    valid = jnp.zeros((10,), dtype=bool)
    buf, count, dropped = dedup.dedup_compact(rows, valid, 16)
    assert int(count) == 0 and int(dropped) == 0


def test_duplicates_across_validity():
    rows = np.array([[1, 0], [1, 0], [2, 0], [2, 0], [3, 0]], dtype=np.uint32)
    valid = np.array([True, True, True, False, True])
    buf, count, dropped = dedup.dedup_compact(
        jnp.asarray(rows), jnp.asarray(valid), 8)
    assert int(count) == 3   # {1,2,3}
    got = set(map(tuple, np.asarray(buf)[:3].tolist()))
    assert got == {(1, 0), (2, 0), (3, 0)}
