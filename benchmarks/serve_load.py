"""Open-loop load driver for the persistent solve service (twserved).

The serve/shard benches measure closed-loop throughput (submit
everything, drain); a *service* is judged under open-loop load — requests
arrive on a fixed schedule whether or not the pool has caught up, and the
number that matters is the submit→done latency distribution, tails
included.  This driver replays a fixed arrival trace (a deterministic
interleave of Table-1 instances at a constant inter-arrival gap — no
randomness, so runs are comparable across PRs) against an **embedded**
``TwServer`` over its real TCP wire, then reads each request's
submit→done latency from the service's own telemetry: the per-request
scope snapshots returned by the ``metrics`` wire op carry a
``request_s`` timing stamped at the terminal event, and ``admission_s``
(queue wait) splits out the shaping delay.

Printed per run: p50/p95/p99 submit→done latency, mean admission wait,
pool-level dispatch/sync totals — and every result is parity-asserted
against a sequential ``solver.solve`` of the same instance, so the
driver doubles as an end-to-end wire correctness check.

    python -m benchmarks.serve_load               # fast trace (16 reqs)
    python -m benchmarks.serve_load --quick       # CI-sized (8 reqs)
    python -m benchmarks.serve_load --jsonl serve_load_metrics.jsonl

``--jsonl PATH`` streams the service's raw telemetry mutation log
(``telemetry.JsonlSink`` attached to the pool scope) for offline
analysis; CI uploads it as an artifact.
"""
from __future__ import annotations

import time

from repro.core import solver
from repro.launch.twserved import TwServer
from repro.serve.client import TwClient

from .common import Timer, emit, get_instance

# deterministic arrival traces: (instance key, arrival offset seconds)
_MIX = ["myciel3", "petersen", "desargues", "petersen"]
TRACE = [(_MIX[i % len(_MIX)], 0.10 * i) for i in range(16)]
TRACE_QUICK = [(_MIX[i % len(_MIX)], 0.05 * i) for i in range(8)]


def _pct(xs, q):
    """Nearest-rank percentile of a non-empty list."""
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(round(q / 100.0 * (len(ys) - 1))))]


def run(quick: bool = False, lanes: int = 4, block: int = 1 << 10,
        jsonl_path: str = None):
    trace = TRACE_QUICK if quick else TRACE
    keys = sorted({k for k, _t in trace})
    refs = {k: solver.solve(get_instance(k), block=block) for k in keys}

    srv = TwServer(port=0, lanes=lanes, block=block,
                   metrics_jsonl=jsonl_path)
    srv.start()
    c = TwClient(port=srv.port)
    try:
        # open-loop replay: submit at each arrival offset regardless of
        # how far the pool has fallen behind
        rids = []
        t0 = time.monotonic()
        for key, offset in trace:
            lag = t0 + offset - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            rids.append((key, c.submit(key)))
        with Timer() as t_drain:
            results = {rid: c.result(rid) for _k, rid in rids}

        # parity: the wire + scheduler are pure transport/scheduling
        for key, rid in rids:
            ref, res = refs[key], results[rid]
            assert (ref.width, ref.exact, ref.expanded) == \
                (res["width"], res["exact"], res["expanded"]), \
                (key, rid, res, ref)

        # latency percentiles from the service's own metrics snapshots
        m = c.metrics()
        snaps = {int(r): s for r, s in m["requests"].items()}
        lat = [snaps[rid]["timings"]["request_s"]["total_s"]
               for _k, rid in rids]
        adm = [snaps[rid]["timings"]["admission_s"]["total_s"]
               for _k, rid in rids if "admission_s" in snaps[rid]["timings"]]
        pool = m["pool"]["counters"]
    finally:
        srv.close()

    p50, p95, p99 = _pct(lat, 50), _pct(lat, 95), _pct(lat, 99)
    wall = time.monotonic() - t0
    print(f"serve_load: {len(trace)} requests over {trace[-1][1]:.2f}s "
          f"arrivals, {lanes} lanes", flush=True)
    print(f"  submit->done latency  p50={p50 * 1e3:.1f}ms  "
          f"p95={p95 * 1e3:.1f}ms  p99={p99 * 1e3:.1f}ms", flush=True)
    print(f"  admission wait mean   "
          f"{(sum(adm) / max(len(adm), 1)) * 1e3:.1f}ms", flush=True)
    print(f"  pool totals           dispatches={int(pool['dispatches'])} "
          f"host_syncs={int(pool['host_syncs'])} "
          f"reqs_done={int(pool.get('reqs_done', 0))}", flush=True)
    print(f"  wall {wall:.2f}s (drain {t_drain.seconds:.2f}s); "
          f"parity=exact", flush=True)
    emit("serve_load/latency", p50,
         f"p50_s={p50:.4f};p95_s={p95:.4f};p99_s={p99:.4f};"
         f"n={len(trace)};lanes={lanes};"
         f"dispatches={int(pool['dispatches'])};parity=exact")
    if jsonl_path:
        print(f"-> wrote {jsonl_path}", flush=True)
    return dict(p50_s=p50, p95_s=p95, p99_s=p99, n=len(trace),
                lanes=lanes, wall_s=wall,
                dispatches=int(pool["dispatches"]),
                host_syncs=int(pool["host_syncs"]))


if __name__ == "__main__":
    import sys
    jsonl_path = None
    if "--jsonl" in sys.argv:
        jsonl_path = sys.argv[sys.argv.index("--jsonl") + 1]
    lanes = 4
    if "--lanes" in sys.argv:
        lanes = int(sys.argv[sys.argv.index("--lanes") + 1])
    run(quick="--quick" in sys.argv, lanes=lanes, jsonl_path=jsonl_path)
