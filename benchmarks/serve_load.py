"""Open-loop load driver for the persistent solve service (twserved).

The serve/shard benches measure closed-loop throughput (submit
everything, drain); a *service* is judged under open-loop load — requests
arrive on a fixed schedule whether or not the pool has caught up, and the
number that matters is the submit→done latency distribution, tails
included.  This driver replays a fixed arrival trace (a deterministic
interleave of Table-1 instances at a constant inter-arrival gap — no
randomness, so runs are comparable across PRs) against an **embedded**
``TwServer`` over its real TCP wire, then reads each request's
submit→done latency from the service's own telemetry: the per-request
scope snapshots returned by the ``metrics`` wire op carry a
``request_s`` timing stamped at the terminal event, and ``admission_s``
(queue wait) splits out the shaping delay.

Printed per run: p50/p95/p99 submit→done latency, mean admission wait,
pool-level dispatch/sync totals — and every result is parity-asserted
against a sequential ``solver.solve`` of the same instance, so the
driver doubles as an end-to-end wire correctness check.

    python -m benchmarks.serve_load               # fast trace (16 reqs)
    python -m benchmarks.serve_load --quick       # CI-sized (8 reqs)
    python -m benchmarks.serve_load --jsonl serve_load_metrics.jsonl
    python -m benchmarks.serve_load --trace wl_trace.jsonl --cache 64

``--jsonl PATH`` streams the service's raw telemetry mutation log
(``telemetry.JsonlSink`` attached to the pool scope) for offline
analysis; CI uploads it as an artifact.

``--trace PATH`` replays a generated workload trace
(``python -m repro.workload`` JSONL, DESIGN.md §16) instead of the
built-in interleave: each arrival's graph + knobs are submitted at its
recorded offset, parity is asserted per arrival against a deduped set
of reference solves (relabeled duplicates check the verdict surface —
the solve is label-invariant, the plan heuristics' tie-breaks are not),
and with ``--cache N`` the server runs its content-addressed result
cache — duplicate arrivals resolve at submit and the driver asserts
their per-request telemetry shows **zero device dispatches**.
"""
from __future__ import annotations

import time

from repro.core import solver
from repro.core.canon import graph_key
from repro.launch.twserved import TwServer
from repro.serve.client import TwClient
from repro.workload import read_trace

from .common import Timer, emit, get_instance

# deterministic arrival traces: (instance key, arrival offset seconds)
_MIX = ["myciel3", "petersen", "desargues", "petersen"]
TRACE = [(_MIX[i % len(_MIX)], 0.10 * i) for i in range(16)]
TRACE_QUICK = [(_MIX[i % len(_MIX)], 0.05 * i) for i in range(8)]


def _pct(xs, q):
    """Nearest-rank percentile of a non-empty list."""
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(round(q / 100.0 * (len(ys) - 1))))]


def run(quick: bool = False, lanes: int = 4, block: int = 1 << 10,
        jsonl_path: str = None):
    trace = TRACE_QUICK if quick else TRACE
    keys = sorted({k for k, _t in trace})
    refs = {k: solver.solve(get_instance(k), block=block) for k in keys}

    srv = TwServer(port=0, lanes=lanes, block=block,
                   metrics_jsonl=jsonl_path)
    srv.start()
    c = TwClient(port=srv.port)
    try:
        # open-loop replay: submit at each arrival offset regardless of
        # how far the pool has fallen behind
        rids = []
        t0 = time.monotonic()
        for key, offset in trace:
            lag = t0 + offset - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            rids.append((key, c.submit(key)))
        with Timer() as t_drain:
            results = {rid: c.result(rid) for _k, rid in rids}

        # parity: the wire + scheduler are pure transport/scheduling
        for key, rid in rids:
            ref, res = refs[key], results[rid]
            assert (ref.width, ref.exact, ref.expanded) == \
                (res["width"], res["exact"], res["expanded"]), \
                (key, rid, res, ref)

        # latency percentiles from the service's own metrics snapshots
        m = c.metrics()
        snaps = {int(r): s for r, s in m["requests"].items()}
        lat = [snaps[rid]["timings"]["request_s"]["total_s"]
               for _k, rid in rids]
        adm = [snaps[rid]["timings"]["admission_s"]["total_s"]
               for _k, rid in rids if "admission_s" in snaps[rid]["timings"]]
        pool = m["pool"]["counters"]
    finally:
        srv.close()

    p50, p95, p99 = _pct(lat, 50), _pct(lat, 95), _pct(lat, 99)
    wall = time.monotonic() - t0
    print(f"serve_load: {len(trace)} requests over {trace[-1][1]:.2f}s "
          f"arrivals, {lanes} lanes", flush=True)
    print(f"  submit->done latency  p50={p50 * 1e3:.1f}ms  "
          f"p95={p95 * 1e3:.1f}ms  p99={p99 * 1e3:.1f}ms", flush=True)
    print(f"  admission wait mean   "
          f"{(sum(adm) / max(len(adm), 1)) * 1e3:.1f}ms", flush=True)
    print(f"  pool totals           dispatches={int(pool['dispatches'])} "
          f"host_syncs={int(pool['host_syncs'])} "
          f"reqs_done={int(pool.get('reqs_done', 0))}", flush=True)
    print(f"  wall {wall:.2f}s (drain {t_drain.seconds:.2f}s); "
          f"parity=exact", flush=True)
    emit("serve_load/latency", p50,
         f"p50_s={p50:.4f};p95_s={p95:.4f};p99_s={p99:.4f};"
         f"n={len(trace)};lanes={lanes};"
         f"dispatches={int(pool['dispatches'])};parity=exact")
    if jsonl_path:
        print(f"-> wrote {jsonl_path}", flush=True)
    return dict(p50_s=p50, p95_s=p95, p99_s=p99, n=len(trace),
                lanes=lanes, wall_s=wall,
                dispatches=int(pool["dispatches"]),
                host_syncs=int(pool["host_syncs"]))


# result-relevant knob subset: what makes two arrivals need distinct
# reference solves (scheduling knobs — shards/speculate/priority — are
# bit-identical paths and share one reference)
_REF_KNOBS = ("mode", "use_mmw", "use_simplicial", "start_k",
              "heuristics", "seed")


def run_trace(arrivals, lanes: int = 4, block: int = 1 << 10,
              cache: int = 256, jsonl_path: str = None,
              closed: bool = False):
    """Replay a generated workload trace (``repro.workload`` arrivals)
    against an embedded server over the real wire.

    Open-loop, like ``run``; additionally exercises and checks the
    result cache: every arrival's verdict is parity-asserted against a
    reference ``solver.solve`` deduped by (canonical graph, result-
    relevant knobs) — relabeled duplicates (``iso``) check
    ``width``/``exact`` (the verdict is label-invariant; the plan
    heuristics' greedy tie-breaks and therefore ``expanded`` are not) —
    and when the cache is on, every rid whose telemetry shows a cache
    hit is asserted to have performed **zero device dispatches**.

    ``closed=True`` switches to closed-loop replay — each arrival waits
    for its result before the next submits (offsets ignored).  Under a
    closed loop every duplicate arrives *after* its root finished, so
    with the cache on the hit count deterministically equals the
    duplicate count — what ``benchmarks/cache_effect.py`` and the CI
    smoke assert."""
    assert arrivals, "empty trace"
    refs = {}
    for a in arrivals:
        g = a.graph()
        key = (graph_key(g),
               tuple((k, a.knobs.get(k)) for k in _REF_KNOBS))
        if key not in refs:
            kn = {k: a.knobs[k] for k in _REF_KNOBS if k in a.knobs}
            refs[key] = solver.solve(g, block=block, **kn)
        a._ref = refs[key]      # noqa: SLF001 — driver-local annotation

    srv = TwServer(port=0, lanes=lanes, block=block, cache=cache,
                   metrics_jsonl=jsonl_path)
    srv.start()
    c = TwClient(port=srv.port)
    try:
        rids = []
        results = {}
        t0 = time.monotonic()
        for a in arrivals:
            if not closed:
                lag = t0 + a.t - time.monotonic()
                if lag > 0:
                    time.sleep(lag)
            rid = c.submit(a.graph(), **a.knobs)
            rids.append((a, rid))
            if closed:
                results[rid] = c.result(rid)
        with Timer() as t_drain:
            for _a, rid in rids:
                if rid not in results:
                    results[rid] = c.result(rid)

        for a, rid in rids:
            ref, res = a._ref, results[rid]
            assert (ref.width, ref.exact) == (res["width"], res["exact"]), \
                (a.idx, a.name, rid, res, ref)
            if not a.iso:
                assert ref.expanded == res["expanded"], \
                    (a.idx, a.name, rid, res, ref)

        m = c.metrics()
        snaps = {int(r): s for r, s in m["requests"].items()}
        lat = [snaps[rid]["timings"]["request_s"]["total_s"]
               for _a, rid in rids]
        hit_lat, miss_lat, hits = [], [], 0
        hit_idxs = []
        for a, rid in rids:
            cnt = snaps[rid]["counters"]
            if cnt.get("cache_hits"):
                hits += 1
                hit_idxs.append(a.idx)
                hit_lat.append(snaps[rid]["timings"]["request_s"]
                               ["total_s"])
                # the headline guarantee: a warm hit never touches the
                # device — its request scope saw no dispatch and
                # expanded no state
                assert not cnt.get("dispatches") and \
                    not cnt.get("expanded"), (rid, cnt)
            else:
                miss_lat.append(snaps[rid]["timings"]["request_s"]
                                ["total_s"])
        pool = m["pool"]["counters"]
        cstats = c.cache_stats()
    finally:
        srv.close()

    p50, p95, p99 = _pct(lat, 50), _pct(lat, 95), _pct(lat, 99)
    wall = time.monotonic() - t0
    dups = sum(1 for a in arrivals if a.dup_of is not None)
    print(f"serve_load[trace]: {len(arrivals)} arrivals "
          f"({dups} duplicates) over {arrivals[-1].t:.2f}s, "
          f"{lanes} lanes, cache={cache}", flush=True)
    print(f"  submit->done latency  p50={p50 * 1e3:.1f}ms  "
          f"p95={p95 * 1e3:.1f}ms  p99={p99 * 1e3:.1f}ms", flush=True)
    if hit_lat:
        print(f"  warm hits {hits}: p50={_pct(hit_lat, 50) * 1e3:.2f}ms "
              f"(cold p50={_pct(miss_lat, 50) * 1e3:.1f}ms); "
              f"zero-dispatch asserted", flush=True)
    print(f"  pool totals           dispatches={int(pool['dispatches'])} "
          f"reqs_done={int(pool.get('reqs_done', 0))} "
          f"cache_hits={int(pool.get('cache_hits', 0))}", flush=True)
    print(f"  wall {wall:.2f}s (drain {t_drain.seconds:.2f}s); "
          f"parity=exact", flush=True)
    emit("serve_load/trace", p50,
         f"p50_s={p50:.4f};p95_s={p95:.4f};p99_s={p99:.4f};"
         f"n={len(arrivals)};dups={dups};hits={hits};cache={cache};"
         f"dispatches={int(pool['dispatches'])};parity=exact")
    return dict(p50_s=p50, p95_s=p95, p99_s=p99, n=len(arrivals),
                dups=dups, hits=hits, cache_entries=cache,
                lanes=lanes, wall_s=wall, closed=closed,
                hit_p50_s=_pct(hit_lat, 50) if hit_lat else None,
                miss_p50_s=_pct(miss_lat, 50) if miss_lat else None,
                dispatches=int(pool["dispatches"]),
                cache_stats=cstats, hit_idxs=hit_idxs,
                results={a.idx: results[rid] for a, rid in rids})


if __name__ == "__main__":
    import sys
    jsonl_path = None
    if "--jsonl" in sys.argv:
        jsonl_path = sys.argv[sys.argv.index("--jsonl") + 1]
    lanes = 4
    if "--lanes" in sys.argv:
        lanes = int(sys.argv[sys.argv.index("--lanes") + 1])
    if "--trace" in sys.argv:
        trace_path = sys.argv[sys.argv.index("--trace") + 1]
        cache = 256
        if "--cache" in sys.argv:
            cache = int(sys.argv[sys.argv.index("--cache") + 1])
        run_trace(read_trace(trace_path), lanes=lanes, cache=cache,
                  jsonl_path=jsonl_path, closed="--closed" in sys.argv)
    else:
        run(quick="--quick" in sys.argv, lanes=lanes,
            jsonl_path=jsonl_path)
