"""Beyond-paper: simplicial-vertex pruning (the rule the paper's §5 poses
as future work).  States explored with/without branch collapsing."""
from __future__ import annotations

from repro.core import solver

from .common import Timer, emit, get_instance

INSTANCES = ["petersen", "myciel3", "queen5_5", "desargues"]


def run():
    for key in INSTANCES:
        g = get_instance(key)
        res = {}
        for simp in (False, True):
            with Timer() as t:
                r = solver.solve(g, cap=1 << 16, block=1 << 9,
                                 use_simplicial=simp)
            res[simp] = (r, t.seconds)
            emit(f"simplicial/{key}/{'on' if simp else 'off'}", t.seconds,
                 f"tw={r.width};exp={r.expanded}")
        r0, _ = res[False]
        r1, _ = res[True]
        assert r0.width == r1.width
        assert r1.expanded <= r0.expanded


if __name__ == "__main__":
    run()
