"""Lane-batching throughput bench: sequential solve loop vs solve_many.

ISSUE 3's motivation quantified: the sequential suite issues one fused
dispatch per (instance, k) and the device idles between them; the
multi-lane engine (``repro.core.batch``) packs the unfinished instances'
current deepening rungs into shared dispatches.  For the suite this bench
reports wall-clock, dispatch and host-sync counts for

  * ``sequential`` — ``[solver.solve(g) for g in suite]``
  * ``lanes=L``    — ``batch.solve_many(suite, lanes=L)``
  * ``spec=S``     — per-instance speculative deepening
                     (``solver.solve(g, lanes=S)``), the single-instance
                     counterpart

and asserts width/exactness parity between all of them (expanded parity
too — the default config has no padded-MMW caveat).  On CPU absolute
times measure XLA's CPU backend; the dispatch/sync reductions are the
portable signal (as with engine_sync, wall-clock becomes meaningful on
real TPU hardware).

    python -m benchmarks.batch_throughput              # fast suite
    python -m benchmarks.batch_throughput --quick      # CI-sized
    python -m benchmarks.batch_throughput --full
    python -m benchmarks.batch_throughput --lanes 16
"""
from __future__ import annotations

from repro.core import batch, engine as engine_lib, solver

from .common import SUITE_FAST, SUITE_FULL, Timer, emit, get_instance

SUITE_QUICK = [("myciel3", 5), ("petersen", 4), ("desargues", 6)]


def run(full: bool = False, quick: bool = False, lanes: int = 8,
        speculate: int = 4, cap: int = 1 << 18, block: int = 1 << 10):
    suite = SUITE_FULL if full else (SUITE_QUICK if quick else SUITE_FAST)
    keys = [k for k, _ in suite]
    gs = [get_instance(k) for k in keys]
    kw = dict(cap=cap, block=block)

    header = (f"{'mode':<16} {'time_s':>8} {'dispatches':>10} "
              f"{'host_syncs':>10} {'states':>10}")
    print(header, flush=True)
    rows = {}

    engine_lib.reset_counters()
    with Timer() as t_seq:
        seq = [solver.solve(g, **kw) for g in gs]
    rows["sequential"] = (t_seq.seconds, dict(engine_lib.COUNTERS), seq)

    engine_lib.reset_counters()
    with Timer() as t_spec:
        spec = [solver.solve(g, lanes=speculate, **kw) for g in gs]
    rows[f"spec={speculate}"] = (t_spec.seconds, dict(engine_lib.COUNTERS),
                                 spec)

    engine_lib.reset_counters()
    with Timer() as t_many:
        many = batch.solve_many(gs, lanes=lanes, **kw)
    rows[f"lanes={lanes}"] = (t_many.seconds, dict(engine_lib.COUNTERS),
                              many)

    for mode, (secs, c, results) in rows.items():
        states = sum(r.expanded for r in results)
        print(f"{mode:<16} {secs:>8.2f} {c['dispatches']:>10} "
              f"{c['host_syncs']:>10} {states:>10}", flush=True)
        emit(f"batch_throughput/{mode}", secs,
             f"dispatches={c['dispatches']};host_syncs={c['host_syncs']};"
             f"states={states}")

    # parity across every mode: the batching axes are pure scheduling
    for mode in list(rows)[1:]:
        for key, a, b in zip(keys, seq, rows[mode][2]):
            assert (a.width, a.exact, a.expanded) == \
                (b.width, b.exact, b.expanded), (mode, key, a, b)

    (ts, cs, _), (tm, cm, _) = rows["sequential"], rows[f"lanes={lanes}"]
    d_ratio = cs["dispatches"] / max(cm["dispatches"], 1)
    print(f"-> solve_many: {d_ratio:.1f}x fewer dispatches, "
          f"{ts / max(tm, 1e-9):.2f}x wall-clock", flush=True)
    emit("batch_throughput/summary", tm,
         f"dispatch_reduction={d_ratio:.2f}x;"
         f"speedup={ts / max(tm, 1e-9):.2f}x")
    return rows


if __name__ == "__main__":
    import sys
    lanes = 8
    if "--lanes" in sys.argv:
        lanes = int(sys.argv[sys.argv.index("--lanes") + 1])
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        lanes=lanes)
