"""Table 4/5 analogue: minor-min-width pruning on/off.

The paper found MMW prunes few states (graphs explored have weak MMW
bounds) while costing 2-3x runtime; this benchmark reproduces that
trade-off measurement on the generatable suite."""
from __future__ import annotations

from repro.core import solver

from .common import Timer, emit, get_instance

INSTANCES = ["petersen", "myciel3", "queen5_5", "queen6_6", "desargues"]


def run():
    for key in INSTANCES:
        g = get_instance(key)
        res = {}
        for mmw in (False, True):
            with Timer() as t:
                r = solver.solve(g, cap=1 << 16, block=1 << 9, use_mmw=mmw)
            res[mmw] = (r, t.seconds)
            emit(f"table4/{key}/{'mmw' if mmw else 'none'}", t.seconds,
                 f"tw={r.width};exp={r.expanded}")
        r0, t0 = res[False]
        r1, t1 = res[True]
        assert r0.width == r1.width
        assert r1.expanded <= r0.expanded       # MMW can only prune
        emit(f"table4/{key}/summary", t1,
             f"prune_ratio={r1.expanded / max(r0.expanded, 1):.3f};"
             f"slowdown={t1 / max(t0, 1e-9):.2f}")


if __name__ == "__main__":
    run()
