"""Host-sync microbench: engine (host vs fused) x backend (jax vs pallas)
x lanes (sequential vs speculative deepening) x shards (scale-out).

The paper's §3 design point is that the Held-Karp frontier never leaves the
GPU; the cost of not doing that is kernel-dispatch serialisation.  This
bench quantifies it on the Table 1 instances: for each graph it runs the
full iterative-deepening solve under each engine x backend combination and
reports wall-clock, jitted-program dispatches, and blocking device→host
transfers (counted by a per-measurement ``repro.core.telemetry.Tracker``
— a detached scope, so concurrent process-global accounting never leaks
into a row).

The backend column tracks the fused pallas wavefront kernel against the
jax reference composition from day one (ISSUE 2).  The lanes column
(ISSUE 3) runs the fused engine through speculative deepening
(``solver.solve(lanes=4)`` -> ``core.batch``): one multi-lane dispatch
per ladder window instead of one per k; ``benchmarks/batch_throughput.py``
covers the cross-instance ``solve_many`` axis.  The shards column
(ISSUE 7) runs the fused engine through intra-request scale-out
(``solver.solve(shards=2)`` -> ``core.shard``): the frontier split
across vmapped shard lanes with work donation — the shard-health
counters (donations, donated rows, idle shard-steps, peak occupancy)
land in the same tracker scope.  On CPU the pallas rows run in
interpret mode, so their absolute times measure the interpreter, not
the kernel — the dispatch/sync counts and the bit-for-bit width/
expanded parity asserts are what carry; wall-clock becomes meaningful on
real TPU hardware.

    python -m benchmarks.engine_sync             # fast suite
    python -m benchmarks.engine_sync --quick     # CI-sized suite
    python -m benchmarks.engine_sync --full
    python -m benchmarks.engine_sync --no-pallas # jax rows only
"""
from __future__ import annotations

from repro.core import solver, telemetry

from .common import SUITE_FAST, SUITE_FULL, Timer, emit, get_instance

SUITE_QUICK = [("myciel3", 5), ("petersen", 4), ("desargues", 6)]

# (backend, engine, lanes, shards) rows per instance; host/pallas adds
# nothing the others don't already cover (host-loop overhead is
# backend-independent).  The lanes=4 row runs the same fused engine
# through the multi-lane speculative-deepening path (core.batch); the
# shards=2 row through the sharded scale-out path (core.shard) — both
# extra columns of the dispatch/sync accounting, and both must stay
# bit-identical to the sequential fused row.
COMBOS = [("jax", "host", 1, 1), ("jax", "fused", 1, 1),
          ("jax", "fused", 4, 1), ("jax", "fused", 1, 2),
          ("pallas", "fused", 1, 1)]

SHARD_KEYS = ("shard_donations", "shard_donated_rows",
              "shard_idle_steps", "shard_peak_occupancy")


def run(full: bool = False, quick: bool = False, pallas: bool = True,
        cap: int = 1 << 18, block: int = 1 << 10):
    suite = SUITE_FULL if full else (SUITE_QUICK if quick else SUITE_FAST)
    combos = [c for c in COMBOS if pallas or c[0] != "pallas"]
    rows = []
    header = (f"{'instance':<12} {'backend':<7} {'engine':<6} {'lanes':>5} "
              f"{'shards':>6} {'tw':>3} {'time_s':>8} {'dispatches':>10} "
              f"{'host_syncs':>10}")
    print(header, flush=True)
    for key, want in suite:
        g = get_instance(key)
        per_combo = {}
        for backend, engine, lanes, shards in combos:
            # fresh detached tracker per measurement: isolates this run's
            # counters from the process-global accounting
            tr = telemetry.Tracker()
            with Timer() as t:
                res = solver.solve(g, cap=cap, block=block, engine=engine,
                                   backend=backend, schedule="doubling",
                                   lanes=lanes, shards=shards, tracker=tr)
            c = {k: int(tr[k]) for k in telemetry.LEGACY_KEYS}
            ok = (want is None) or (res.width == want)
            per_combo[(backend, engine, lanes, shards)] = \
                (res, c, t.seconds, ok)
            rows.append((key, backend, engine, lanes, shards, res.width,
                         t.seconds, c["dispatches"], c["host_syncs"], ok))
            print(f"{key:<12} {backend:<7} {engine:<6} {lanes:>5} "
                  f"{shards:>6} {res.width:>3} {t.seconds:>8.2f} "
                  f"{c['dispatches']:>10} {c['host_syncs']:>10}",
                  flush=True)
            emit(f"engine_sync/{key}/{backend}/{engine}/lanes{lanes}"
                 f"/shards{shards}",
                 t.seconds,
                 f"tw={res.width};dispatches={c['dispatches']};"
                 f"host_syncs={c['host_syncs']};expected_ok={ok}")
        # parity across every combo: same width, same states expanded
        # (speculative lanes discard rungs above the first feasible one
        # and shards repartition without re-expanding, so even the
        # lanes=4 and shards=2 rows must match exactly)
        base, *rest = [per_combo[c][0] for c in combos]
        for r in rest:
            assert r.width == base.width, (key, r.width, base.width)
            assert r.expanded == base.expanded, \
                (key, r.expanded, base.expanded)
        (rh, ch, th, _) = per_combo[("jax", "host", 1, 1)]
        (rf, cf, tf, _) = per_combo[("jax", "fused", 1, 1)]
        speedup = th / max(tf, 1e-9)
        sync_ratio = ch["host_syncs"] / max(cf["host_syncs"], 1)
        emit(f"engine_sync/{key}/summary", tf,
             f"speedup={speedup:.2f}x;sync_reduction={sync_ratio:.0f}x")
        print(f"{key:<12} -> fused speedup {speedup:.2f}x, "
              f"{ch['host_syncs']} -> {cf['host_syncs']} syncs "
              f"({sync_ratio:.0f}x fewer)", flush=True)
        (rb, cb, tb, _) = per_combo[("jax", "fused", 4, 1)]
        emit(f"engine_sync/{key}/batch_summary", tb,
             f"fused_dispatches={cf['dispatches']};"
             f"lanes4_dispatches={cb['dispatches']};parity=exact")
        (rs, cs, ts, _) = per_combo[("jax", "fused", 1, 2)]
        shard_health = ";".join(f"{k}={cs[k]}" for k in SHARD_KEYS)
        emit(f"engine_sync/{key}/shard_summary", ts,
             f"seq_s={tf:.3f};shards2_s={ts:.3f};{shard_health};"
             f"parity=exact")
        print(f"{key:<12} -> shards=2: "
              f"{cs['shard_donations']} donations "
              f"({cs['shard_donated_rows']} rows), "
              f"{cs['shard_idle_steps']} idle shard-steps, "
              f"peak occupancy {cs['shard_peak_occupancy']}", flush=True)
        if ("pallas", "fused", 1, 1) in per_combo:
            (rp, cp, tp, _) = per_combo[("pallas", "fused", 1, 1)]
            emit(f"engine_sync/{key}/backend_summary", tp,
                 f"jax_fused_s={tf:.3f};pallas_fused_s={tp:.3f};"
                 f"parity=exact")
    return rows


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        pallas="--no-pallas" not in sys.argv)
