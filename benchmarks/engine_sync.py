"""Host-sync microbench: fused (device-resident) vs host-loop engine.

The paper's §3 design point is that the Held-Karp frontier never leaves the
GPU; the cost of not doing that is kernel-dispatch serialisation.  This
bench quantifies it on the Table 1 instances: for each graph it runs the
full iterative-deepening solve under both engines and reports wall-clock,
jitted-program dispatches, and blocking device→host transfers (counted by
``repro.core.engine.COUNTERS``).

    python -m benchmarks.engine_sync            # fast suite
    python -m benchmarks.engine_sync --full
"""
from __future__ import annotations

from repro.core import engine as engine_lib
from repro.core import solver

from .common import SUITE_FAST, SUITE_FULL, Timer, emit, get_instance


def run(full: bool = False, cap: int = 1 << 18, block: int = 1 << 10):
    suite = SUITE_FULL if full else SUITE_FAST
    rows = []
    header = (f"{'instance':<12} {'engine':<6} {'tw':>3} {'time_s':>8} "
              f"{'dispatches':>10} {'host_syncs':>10}")
    print(header, flush=True)
    for key, want in suite:
        g = get_instance(key)
        per_engine = {}
        for engine in ("host", "fused"):
            engine_lib.reset_counters()
            with Timer() as t:
                res = solver.solve(g, cap=cap, block=block, engine=engine)
            c = dict(engine_lib.COUNTERS)
            ok = (want is None) or (res.width == want)
            per_engine[engine] = (res, c, t.seconds, ok)
            rows.append((key, engine, res.width, t.seconds,
                         c["dispatches"], c["host_syncs"], ok))
            print(f"{key:<12} {engine:<6} {res.width:>3} {t.seconds:>8.2f} "
                  f"{c['dispatches']:>10} {c['host_syncs']:>10}", flush=True)
            emit(f"engine_sync/{key}/{engine}", t.seconds,
                 f"tw={res.width};dispatches={c['dispatches']};"
                 f"host_syncs={c['host_syncs']};expected_ok={ok}")
        (rh, ch, th, _), (rf, cf, tf, _) = (per_engine["host"],
                                            per_engine["fused"])
        assert rh.width == rf.width, (key, rh.width, rf.width)
        assert rh.expanded == rf.expanded, (key, rh.expanded, rf.expanded)
        speedup = th / max(tf, 1e-9)
        sync_ratio = ch["host_syncs"] / max(cf["host_syncs"], 1)
        emit(f"engine_sync/{key}/summary", tf,
             f"speedup={speedup:.2f}x;sync_reduction={sync_ratio:.0f}x")
        print(f"{key:<12} -> speedup {speedup:.2f}x, "
              f"{ch['host_syncs']} -> {cf['host_syncs']} syncs "
              f"({sync_ratio:.0f}x fewer)", flush=True)
    return rows


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
