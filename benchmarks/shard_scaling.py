"""Intra-request scale-out bench: sequential solve vs sharded solve.

ISSUE 7's perf trajectory: for each of the heavier Table 1 instances,
run the fused-engine ladder sequentially (``solver.solve``) and then
with the frontier split across S vmapped shard lanes
(``solver.solve(shards=S)`` -> ``core.shard``: owner-hash routing +
per-level work donation).  Every sharded run is asserted bit-identical
to the sequential baseline — width, exactness, states expanded, and the
per-rung feasibility trace — so the table measures pure partitioning
cost/benefit, never a search-quality trade.

On CPU the vmapped shard lanes execute serially, so wall-clock speedup
is flat-to-negative here; the numbers that carry are the shard-health
counters (donations, donated rows, idle shard-steps, peak per-shard
occupancy — a per-measurement ``telemetry.Tracker``) showing the rebalancer
keeping the lanes busy.  Wall-clock becomes meaningful on real
accelerators where the lanes map onto hardware parallelism.

    python -m benchmarks.shard_scaling                # fast suite
    python -m benchmarks.shard_scaling --quick        # CI-sized suite
    python -m benchmarks.shard_scaling --full
    python -m benchmarks.shard_scaling --json BENCH_shard.json

``--json PATH`` additionally writes the machine-readable records so CI
can archive the trajectory next to ``BENCH_serve.json``.
"""
from __future__ import annotations

from repro.core import solver, telemetry

from .common import Timer, emit, get_instance

# Heavier Table 1 instances: sharding targets the requests whose rungs
# dominate a pool, not the toys.
SUITE = [("myciel4", 10), ("queen5_5", 18)]
SUITE_QUICK = [("myciel3", 5), ("petersen", 4)]
SUITE_FULL = SUITE + [("queen6_6", 25), ("dyck", 7)]

SHARDS = (2, 4)

SHARD_KEYS = ("shard_donations", "shard_donated_rows",
              "shard_idle_steps", "shard_peak_occupancy")


def run(full: bool = False, quick: bool = False, block: int = 1 << 10,
        json_path: str = None):
    suite = SUITE_FULL if full else (SUITE_QUICK if quick else SUITE)
    records = []
    header = (f"{'instance':<12} {'shards':>6} {'tw':>3} {'time_s':>8} "
              f"{'speedup':>8} {'donations':>9} {'don_rows':>8} "
              f"{'idle':>6} {'peak_occ':>8}")
    print(header, flush=True)
    for key, want in suite:
        g = get_instance(key)
        tr0 = telemetry.Tracker()
        with Timer() as t0:
            ref = solver.solve(g, block=block, tracker=tr0)
        c0 = {k: int(tr0[k]) for k in telemetry.LEGACY_KEYS}
        assert want is None or ref.width == want, (key, ref.width, want)
        print(f"{key:<12} {1:>6} {ref.width:>3} {t0.seconds:>8.2f} "
              f"{'1.00':>8} {'-':>9} {'-':>8} {'-':>6} {'-':>8}",
              flush=True)
        emit(f"shard_scaling/{key}/shards1", t0.seconds,
             f"tw={ref.width};dispatches={c0['dispatches']}")
        records.append(dict(instance=key, shards=1, tw=ref.width,
                            wall_s=t0.seconds, speedup=1.0,
                            dispatches=c0["dispatches"]))
        for s in SHARDS:
            tr = telemetry.Tracker()
            with Timer() as t:
                res = solver.solve(g, block=block, shards=s, tracker=tr)
            c = {k: int(tr[k]) for k in telemetry.LEGACY_KEYS}
            # bit-for-bit parity with the sequential ladder: sharding
            # repartitions the frontier, it never re-expands or prunes
            # differently
            assert (res.width, res.exact, res.expanded, res.per_k) == \
                (ref.width, ref.exact, ref.expanded, ref.per_k), \
                (key, s, res, ref)
            speedup = t0.seconds / max(t.seconds, 1e-9)
            health = ";".join(f"{k}={c[k]}" for k in SHARD_KEYS)
            print(f"{key:<12} {s:>6} {res.width:>3} {t.seconds:>8.2f} "
                  f"{speedup:>8.2f} {c['shard_donations']:>9} "
                  f"{c['shard_donated_rows']:>8} "
                  f"{c['shard_idle_steps']:>6} "
                  f"{c['shard_peak_occupancy']:>8}", flush=True)
            emit(f"shard_scaling/{key}/shards{s}", t.seconds,
                 f"tw={res.width};speedup={speedup:.2f}x;{health};"
                 f"parity=exact")
            records.append(dict(
                instance=key, shards=s, tw=res.width, wall_s=t.seconds,
                speedup=speedup, dispatches=c["dispatches"],
                **{k: c[k] for k in SHARD_KEYS}))
    if json_path:
        import json as json_lib
        with open(json_path, "w") as f:
            json_lib.dump({"bench": "shard_scaling",
                           "shards": [1, *SHARDS],
                           "records": records}, f, indent=2)
        print(f"-> wrote {json_path}", flush=True)
    return records


if __name__ == "__main__":
    import sys
    json_path = None
    if "--json" in sys.argv:
        json_path = sys.argv[sys.argv.index("--json") + 1]
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        json_path=json_path)
