"""Anytime bounds-engine quality bench (DESIGN.md §15).

Two measurements per Table-1 instance:

  * **exact-rung reduction** — the same forced full ladder
    (``start_k=0``, so every instance climbs from rung 0) served with the
    improver lanes off vs on.  The verdict must be identical (heuristics
    only ever tighten); the payoff is fewer decided Held-Karp rungs —
    a tightened lb skips refuted rungs, a width-matching elimination
    order certifies the top of the ladder without running it.
  * **ub-lb gap vs time** — bounds-only serving (``heuristic_only``):
    the monotone trajectory of (t, lb, ub) from the request's ``bounds``
    events, its final gap, and whether the improvers closed it
    (``exact = (lb == ub)``).

The run asserts what CI needs: every clamped verdict matches its
baseline, every heuristic bound sandwiches the known exact width, and at
least one instance finishes with strictly fewer exact rungs.

    python -m benchmarks.bounds_quality                # fast suite
    python -m benchmarks.bounds_quality --quick        # CI-sized suite
    python -m benchmarks.bounds_quality --full
    python -m benchmarks.bounds_quality --json BENCH_bounds.json
"""
from __future__ import annotations

import time

from repro.core import telemetry
from repro.serve.twscheduler import TwScheduler

from .common import Timer, emit, get_instance

# (key, exact tw) — the forced-full-ladder clamp runs; modest ladders so
# the fast tier stays CI-sized
SUITE = [("petersen", 4), ("myciel3", 5), ("desargues", 6)]
SUITE_QUICK = [("petersen", 4), ("myciel3", 5)]
SUITE_FULL = SUITE + [("queen5_5", 18)]

# (key, exact tw) — bounds-only serving targets: graphs whose exact
# ladder is out of the fast tier's reach are exactly where the gap
# trajectory matters
HSUITE = [("mcgee", 7), ("dyck", 7)]
HSUITE_QUICK = [("mcgee", 7)]
HSUITE_FULL = HSUITE + [("grid6x6", 6), ("queen6_6", 25)]

ROUNDS = 8          # improver budget per request
FAST = dict(cap=1 << 12, block=32)


def _ladder(key, want, *, heuristics):
    g = get_instance(key)
    tr = telemetry.Tracker()
    sched = TwScheduler(lanes=1, pipeline=2, heuristics=heuristics,
                        tracker=tr, **FAST)
    rid = sched.submit(g, start_k=0)
    with Timer() as t:
        res = sched.run()[rid]
    c = tr.snapshot()["counters"]
    assert res.width == want, (key, res.width, want)
    return res, t.seconds, c


def run(full: bool = False, quick: bool = False, json_path: str = None):
    suite = SUITE_FULL if full else (SUITE_QUICK if quick else SUITE)
    hsuite = HSUITE_FULL if full else (HSUITE_QUICK if quick else HSUITE)
    records = []

    print(f"{'instance':<12} {'tw':>3} {'rungs_off':>9} {'rungs_on':>8} "
          f"{'skipped':>7} {'ub_moves':>8} {'lb_moves':>8} {'wall_on_s':>9}",
          flush=True)
    for key, want in suite:
        ref, t_off, c_off = _ladder(key, want, heuristics=0)
        res, t_on, c_on = _ladder(key, want, heuristics=ROUNDS)
        # parity: the bounds engine may only tighten, never change
        assert (res.width, res.exact) == (ref.width, ref.exact), (key, res)
        rungs_off = int(c_off.get("rungs_decided", 0))
        rungs_on = int(c_on.get("rungs_decided", 0))
        assert rungs_on <= rungs_off, (key, rungs_on, rungs_off)
        rec = dict(instance=key, mode="exact_clamp", tw=res.width,
                   exact=res.exact, rungs_off=rungs_off,
                   rungs_on=rungs_on,
                   rungs_skipped=int(c_on.get("exact_rungs_skipped", 0)),
                   heur_ub_improvements=int(
                       c_on.get("heur_ub_improvements", 0)),
                   heur_lb_improvements=int(
                       c_on.get("heur_lb_improvements", 0)),
                   wall_off_s=t_off, wall_on_s=t_on)
        records.append(rec)
        print(f"{key:<12} {res.width:>3} {rungs_off:>9} {rungs_on:>8} "
              f"{rec['rungs_skipped']:>7} "
              f"{rec['heur_ub_improvements']:>8} "
              f"{rec['heur_lb_improvements']:>8} {t_on:>9.2f}", flush=True)
        emit(f"bounds_quality/{key}/clamp", t_on,
             f"tw={res.width};rungs={rungs_off}->{rungs_on};"
             f"skipped={rec['rungs_skipped']}")
    clamped = [r for r in records if r["rungs_on"] < r["rungs_off"]]
    assert clamped, "no instance finished with strictly fewer exact rungs"
    print(f"-> {len(clamped)}/{len(records)} instances decided strictly "
          f"fewer exact rungs with the bounds engine on", flush=True)

    print(f"\n{'instance':<12} {'tw':>3} {'lb':>3} {'ub':>3} {'gap':>4} "
          f"{'exact':>5} {'moves':>5} {'wall_s':>7}", flush=True)
    for key, want in hsuite:
        g = get_instance(key)
        sched = TwScheduler(lanes=1, **FAST)
        traj = []
        t0 = time.time()
        rid = sched.submit(g, heuristic_only=True, heuristics=ROUNDS,
                           seed=1,
                           on_event=lambda ev: traj.append(
                               (time.time() - t0, ev.get("lb"),
                                ev.get("ub")))
                           if ev.get("event") == "bounds" else None)
        with Timer() as t:
            res = sched.run()[rid]
        # the heuristic bounds must sandwich the known exact width
        assert res.lb <= want <= res.ub, (key, res.lb, res.ub, want)
        assert res.exact == (res.lb == res.ub)
        rec = dict(instance=key, mode="heuristic_only", tw=want,
                   lb=res.lb, ub=res.ub, gap=res.ub - res.lb,
                   exact=res.exact, wall_s=t.seconds,
                   trajectory=[dict(t_s=round(ts, 4), lb=lb, ub=ub)
                               for ts, lb, ub in traj])
        records.append(rec)
        print(f"{key:<12} {want:>3} {res.lb:>3} {res.ub:>3} "
              f"{rec['gap']:>4} {str(res.exact):>5} {len(traj):>5} "
              f"{t.seconds:>7.2f}", flush=True)
        emit(f"bounds_quality/{key}/heuristic_only", t.seconds,
             f"tw={want};lb={res.lb};ub={res.ub};gap={rec['gap']}")

    if json_path:
        import json as json_lib
        with open(json_path, "w") as f:
            json_lib.dump({"bench": "bounds_quality", "rounds": ROUNDS,
                           "records": records}, f, indent=2)
        print(f"-> wrote {json_path}", flush=True)
    return records


if __name__ == "__main__":
    import sys
    json_path = None
    if "--json" in sys.argv:
        json_path = sys.argv[sys.argv.index("--json") + 1]
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        json_path=json_path)
