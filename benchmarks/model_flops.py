"""Analytic MODEL_FLOPS per (arch x shape) — the 'useful work' reference.

Conventions (stated so the roofline ratio is interpretable):
  * matmul x@W costs 2*m*n*k flops;
  * dense-train step = 3x forward (backward ~ 2x forward);
  * attention forward = 4*B*S*T*H*hd (QK^T + PV), x0.5 when causal over the
    full square (only the lower triangle is useful);
  * MoE counts top_k routed experts + shared expert (active params);
  * mamba state path = ~8 flops per (token, d_inner, d_state) element
    (discretise, decay, update, readout);
  * mLSTM = projections + intra-chunk C^2 attention + hd^2 state update per
    chunk; sLSTM = 4 gate matmuls (d x d per-head block) per token.

XLA's cost_analysis undercounts while-loop bodies (counted once, see
EXPERIMENTS.md §Methodology), so MODEL_FLOPS here is the denominator-of-
record for the compute roofline term.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model
from repro.models.params import count_params, map_spec
from repro.models import ssm as ssm_lib


def _expert_params(cfg) -> int:
    if cfg.moe is None:
        return 0
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = sum(1 for grp in cfg.block_pattern for k in grp
                      if k == "moe") * cfg.n_reps
    return n_moe_layers * m.n_experts * per_expert


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (dense count minus inactive experts)."""
    total = count_params(Model(cfg).spec)
    if cfg.moe is None:
        return total
    m = cfg.moe
    inactive = _expert_params(cfg) * (1 - m.top_k / m.n_experts)
    return int(total - inactive)


def _attn_layers(cfg) -> int:
    return sum(1 for grp in cfg.block_pattern for k in grp
               if k in ("attn", "hymba")) * cfg.n_reps


def _ssm_layers(cfg, kind) -> int:
    names = {"mamba": ("mamba", "hymba"), "mlstm": ("mlstm",),
             "slstm": ("slstm",)}[kind]
    return sum(1 for grp in cfg.block_pattern for k in grp
               if k in names) * cfg.n_reps


def forward_flops(cfg: ModelConfig, batch: int, seq: int,
                  ctx: int | None = None, decode: bool = False) -> float:
    """Forward flops for `batch` sequences of `seq` new tokens (ctx = KV
    context length for decode)."""
    t = batch * seq
    n_act = active_params(cfg)
    flops = 2.0 * n_act * t                      # all linear layers

    la = _attn_layers(cfg)
    h, hd = cfg.n_heads, cfg.hd
    if decode:
        kv_len = ctx if ctx is not None else seq
        if cfg.sliding_window:
            kv_len = min(kv_len, cfg.sliding_window)
        flops += 4.0 * batch * seq * kv_len * h * hd * la
    else:
        kv = seq if cfg.sliding_window is None else min(seq,
                                                        cfg.sliding_window)
        flops += 0.5 * 4.0 * batch * seq * kv * h * hd * la
    if cfg.cross_attention:
        flops += 4.0 * batch * seq * cfg.encoder_len * h * hd * cfg.n_layers
        # encoder self-attention (bidirectional, full square)
        flops += 4.0 * batch * cfg.encoder_len ** 2 * h * hd \
            * cfg.encoder_layers

    if cfg.ssm is not None:
        di, _, ds, _ = ssm_lib.mamba_dims(cfg)
        lm = _ssm_layers(cfg, "mamba")
        flops += 8.0 * t * di * ds * lm
        lml = _ssm_layers(cfg, "mlstm")
        if lml:
            dim, hh, hdm = ssm_lib.mlstm_dims(cfg)
            c = cfg.ssm.chunk if not decode else 1
            flops += lml * (4.0 * t * c * dim          # intra-chunk attn
                            + 4.0 * t * hdm * dim)     # state update/read
        lsl = _ssm_layers(cfg, "slstm")
        if lsl:
            hd2 = cfg.d_model // cfg.n_heads
            flops += lsl * t * cfg.n_heads * (2.0 * hd2 * 4 * hd2)
    return flops


def model_flops(arch_or_cfg, shape: ShapeConfig) -> float:
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) \
        else get_config(arch_or_cfg)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend == "vision":
            s = s  # prefix embeds consume part of the budget; keep S total
        return 3.0 * forward_flops(cfg, b, s)
    if shape.kind == "prefill":
        return forward_flops(cfg, b, s)
    # decode: one new token against ctx = seq_len
    return forward_flops(cfg, b, 1, ctx=s, decode=True)
