"""Table 1 analogue: general solver benchmark (time + states explored).

Paper Table 1 reports |V|, tw, GPU/CPU time, and states expanded per
instance.  The CPU-hosted JAX build plays the role of the paper's CPU
baseline; the Pallas kernel path (interpret mode here, native on TPU) is
also timed for reference.
"""
from __future__ import annotations

from repro.core import solver

from .common import SUITE_FAST, SUITE_FULL, Timer, emit, get_instance


def run(full: bool = False, cap: int = 1 << 18, block: int = 1 << 10):
    suite = SUITE_FULL if full else SUITE_FAST
    rows = []
    for key, want in suite:
        g = get_instance(key)
        with Timer() as t:
            res = solver.solve(g, cap=cap, block=block)
        ok = (want is None) or (res.width == want)
        rows.append((key, g.n, res.width, res.exact, res.expanded,
                     t.seconds, ok))
        emit(f"table1/{key}", t.seconds,
             f"n={g.n};tw={res.width};exact={res.exact};"
             f"exp={res.expanded};expected_ok={ok}")
        states_per_sec = res.expanded / max(t.seconds, 1e-9)
        emit(f"table1/{key}/throughput", 1.0 / max(states_per_sec, 1e-9),
             f"states_per_sec={states_per_sec:.0f}")
    return rows


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
