"""Table 1 analogue: general solver benchmark (time + states explored).

Paper Table 1 reports |V|, tw, GPU/CPU time, and states expanded per
instance.  The CPU-hosted JAX build plays the role of the paper's CPU
baseline; the Pallas kernel path (interpret mode here, native on TPU) is
also timed for reference.

    python -m benchmarks.table1_general                 # fast suite
    python -m benchmarks.table1_general --quick         # CI-sized suite
    python -m benchmarks.table1_general --full
    python -m benchmarks.table1_general --json BENCH_solver.json

``--json PATH`` writes the machine-readable per-instance records —
wall-clock, jitted-program dispatches and host syncs (from a detached
per-measurement ``telemetry.Tracker``), states expanded — so CI can
archive the solver-side perf trajectory next to ``BENCH_serve.json``
and ``BENCH_shard.json``.
"""
from __future__ import annotations

from repro.core import solver, telemetry

from .common import SUITE_FAST, SUITE_FULL, Timer, emit, get_instance

SUITE_QUICK = [("myciel3", 5), ("petersen", 4), ("desargues", 6)]


def run(full: bool = False, quick: bool = False, cap: int = 1 << 18,
        block: int = 1 << 10, json_path: str = None):
    suite = SUITE_FULL if full else (SUITE_QUICK if quick else SUITE_FAST)
    rows, records = [], []
    for key, want in suite:
        g = get_instance(key)
        tr = telemetry.Tracker()
        with Timer() as t:
            res = solver.solve(g, cap=cap, block=block, tracker=tr)
        ok = (want is None) or (res.width == want)
        rows.append((key, g.n, res.width, res.exact, res.expanded,
                     t.seconds, ok))
        emit(f"table1/{key}", t.seconds,
             f"n={g.n};tw={res.width};exact={res.exact};"
             f"exp={res.expanded};expected_ok={ok};"
             f"dispatches={int(tr['dispatches'])};"
             f"host_syncs={int(tr['host_syncs'])}")
        states_per_sec = res.expanded / max(t.seconds, 1e-9)
        emit(f"table1/{key}/throughput", 1.0 / max(states_per_sec, 1e-9),
             f"states_per_sec={states_per_sec:.0f}")
        records.append(dict(
            instance=key, n=int(g.n), tw=int(res.width),
            exact=bool(res.exact), expanded=int(res.expanded),
            wall_s=t.seconds, states_per_sec=states_per_sec,
            dispatches=int(tr["dispatches"]),
            host_syncs=int(tr["host_syncs"]), expected_ok=bool(ok)))
    if json_path:
        import json as json_lib
        with open(json_path, "w") as f:
            json_lib.dump({"bench": "table1_general",
                           "suite": [k for k, _w in suite],
                           "records": records}, f, indent=2)
        print(f"-> wrote {json_path}", flush=True)
    return rows


if __name__ == "__main__":
    import sys
    json_path = None
    if "--json" in sys.argv:
        json_path = sys.argv[sys.argv.index("--json") + 1]
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        json_path=json_path)
