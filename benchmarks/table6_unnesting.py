"""Table 6 analogue: loop-scheduling sweep.

The paper's "loop unnesting" flattens three nested loops into a state
machine to trade branch divergence against bookkeeping.  The TPU analogue
is the component-closure fixpoint schedule:
  doubling : static ceil(log2 n) trip count   — (inf,inf): no divergence,
             some wasted converged iterations;
  while    : data-dependent early exit        — (1,1): minimal work, but a
             batched while runs until the LAST lane converges (the SIMD
             form of waiting on the slowest thread);
  linear   : one-hop per iteration            — the paper's per-level BFS.
The paper found the unmodified nested loop fastest; doubling is our
analogous default and the sweep verifies the same ordering holds.
"""
from __future__ import annotations

from repro.core import solver

from .common import Timer, emit, get_instance

INSTANCES = ["queen5_5", "queen6_6", "petersen", "myciel3"]
SCHEDULES = ["doubling", "while", "linear"]


def run():
    for key in INSTANCES:
        g = get_instance(key)
        widths = set()
        for sched in SCHEDULES:
            with Timer() as t:
                r = solver.solve(g, cap=1 << 16, block=1 << 9,
                                 schedule=sched)
            widths.add(r.width)
            emit(f"table6/{key}/{sched}", t.seconds,
                 f"tw={r.width};exp={r.expanded}")
        assert len(widths) == 1


if __name__ == "__main__":
    run()
