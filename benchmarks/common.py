"""Shared benchmark plumbing: CSV emission + suites."""
from __future__ import annotations

import time

from repro.core import graph

# Paper Table 1 instance families that are generatable offline.  Exact PACE
# protein/BN files are not redistributable (DESIGN.md §7); names refer to
# the construction.  Tuples: (key, expected tw or None, heavy?)
SUITE_FAST = [
    ("myciel3", 5), ("myciel4", 10), ("queen5_5", 18), ("queen6_6", 25),
    ("petersen", 4), ("desargues", 6),
]
SUITE_FULL = SUITE_FAST + [
    ("mcgee", 7), ("queen7_7", 35), ("dyck", 7), ("grid6x6", 6),
]


def get_instance(key):
    return graph.REGISTRY.get(key, lambda: None)() or {
        "petersen": graph.petersen, "desargues": graph.desargues,
    }[key]()


def emit(name: str, seconds: float, derived: str = ""):
    """run.py contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
