"""Table 2/3 analogue: work-size x memory-placement sweep.

Paper Table 2 sweeps the OpenCL work-group size (threads per SM) and global
vs shared memory.  The TPU analogues:
  * work size  -> expansion block size (states per jit'd chunk / per Pallas
    grid step);
  * global (G) vs shared (S) memory -> plain-XLA expansion ("jax", compiler-
    managed HBM streaming) vs the Pallas kernel with explicit VMEM tiling
    ("pallas"; interpret-mode on CPU, so absolute times are not meaningful
    on this host — the sweep structure is what carries to hardware).
"""
from __future__ import annotations

from repro.core import solver

from .common import Timer, emit, get_instance

INSTANCES = ["queen5_5", "queen6_6", "myciel3"]
BLOCKS = [128, 256, 512, 1024, 2048]


def run(pallas: bool = False):
    backends = ["jax", "pallas"] if pallas else ["jax"]
    for key in INSTANCES:
        g = get_instance(key)
        base = None
        for backend in backends:
            for block in BLOCKS:
                with Timer() as t:
                    res = solver.solve(g, cap=1 << 16, block=block,
                                       backend=backend)
                tag = "S" if backend == "pallas" else "G"
                base = base or res.width
                assert res.width == base
                emit(f"table2/{key}/{tag}/W={block}", t.seconds,
                     f"tw={res.width};exp={res.expanded}")


if __name__ == "__main__":
    import sys
    run(pallas="--pallas" in sys.argv)
