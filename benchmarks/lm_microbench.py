"""LM substrate microbenchmarks: reduced-config train & decode step wall
time per architecture (CPU-hosted; relative costs only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, TrainConfig, get_config, reduced
from repro.models import Model
from repro.train import step as step_lib

from .common import Timer, emit


def _front(cfg, batch):
    out = {}
    if cfg.frontend == "audio":
        out["enc_embeds"] = jnp.zeros((batch, cfg.encoder_len, cfg.d_model))
    if cfg.frontend == "vision":
        out["prefix_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model))
    return out


def run(iters: int = 3):
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        model = Model(cfg)
        tcfg = TrainConfig()
        state = step_lib.init_state(model, jax.random.PRNGKey(0), tcfg)
        fn = jax.jit(step_lib.build_train_step(model, tcfg))
        toks = jnp.zeros((2, 32), jnp.int32)
        batch = {"tokens": toks, "targets": toks,
                 "mask": jnp.ones((2, 32), jnp.float32)}
        batch.update(_front(cfg, 2))
        state, _ = fn(state, batch)       # compile
        with Timer() as t:
            for _ in range(iters):
                state, m = fn(state, batch)
            jax.block_until_ready(m["loss"])
        emit(f"lm/{arch}/train_step", t.seconds / iters,
             f"params={model.n_params()}")


if __name__ == "__main__":
    run()
