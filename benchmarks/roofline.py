"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh):
  compute term    = MODEL_FLOPS / (chips * 197e12 bf16 FLOP/s)
  memory term     = HBM bytes moved / (chips * 819e9 B/s)
  collective term = wire bytes / (chips * 50e9 B/s per ICI link)

Sources: MODEL_FLOPS analytic (benchmarks/model_flops.py — cost_analysis
undercounts loop bodies, see §Methodology in EXPERIMENTS.md); memory bytes
from the loop-UNDER-counted cost_analysis 'bytes accessed' reported raw,
plus an analytic floor (params + KV/state traffic); collective bytes from
the loop-aware HLO parse (utils/hlo2.py), already per-device.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.models import Model
from repro.models.params import count_params

from .model_flops import model_flops, active_params

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "dryrun")


def _param_bytes(cfg) -> int:
    return count_params(Model(cfg).spec) * 2      # bf16


def analytic_memory_bytes(arch: str, shape_name: str, n_devices: int) -> float:
    """Per-device HBM floor: weights streamed once (+grad/opt traffic for
    train), plus cache/activation traffic."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pb = _param_bytes(cfg)
    act_bytes_per_tok = cfg.d_model * 2 * cfg.n_layers * 6   # rough
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
    if shape.kind == "train":
        # fwd read + bwd read + grad write + opt read/write (fp32 m,v)
        traffic = pb * 3 + count_params(Model(cfg).spec) * (4 * 4) \
            + toks * act_bytes_per_tok * 2
    elif shape.kind == "prefill":
        traffic = pb + toks * act_bytes_per_tok \
            + 2 * toks * cfg.n_kv * cfg.hd * 2 * cfg.n_layers
    else:
        kv_len = shape.seq_len if cfg.sliding_window is None else \
            min(shape.seq_len, cfg.sliding_window)
        if not cfg.sub_quadratic():
            cache = (2 * shape.global_batch * kv_len * cfg.n_kv * cfg.hd
                     * 2 * cfg.n_layers)
        else:
            cache = shape.global_batch * cfg.d_model * 64 * cfg.n_layers
        traffic = pb * min(1.0, shape.global_batch) + cache
    return traffic / n_devices


def load_cells(artifact_dir: str = ARTIFACT_DIR):
    cells = {}
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        tag = os.path.basename(path)[:-5]
        with open(path) as f:
            cells[tag] = json.load(f)
    return cells


def roofline_row(tag: str, cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return {"tag": tag, "status": cell.get("status"),
                "reason": cell.get("reason", cell.get("error", ""))[:110]}
    parts = tag.split("__")
    arch, shape_name, mesh = parts[0], parts[1], "__".join(parts[2:])
    n_dev = cell["n_devices"]
    mf = model_flops(arch, SHAPES[shape_name])
    t_compute = mf / (n_dev * PEAK_FLOPS)

    mem_cost = cell.get("bytes_accessed_per_device", 0.0)
    mem_analytic = analytic_memory_bytes(arch, shape_name, n_dev)
    mem_bytes = max(mem_cost, mem_analytic)
    t_memory = mem_bytes / HBM_BW

    wire = cell.get("collectives_scaled", {}).get("wire_bytes", 0.0)
    t_coll = wire / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    hlo_flops = cell.get("flops_per_device", 0.0) * n_dev
    return {
        "tag": tag, "status": "ok", "arch": arch, "shape": shape_name,
        "mesh": mesh, "n_devices": n_dev,
        "model_flops": mf,
        "hlo_flops_raw": hlo_flops,
        "flops_ratio_raw": mf / hlo_flops if hlo_flops > 0 else float("nan"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_time_bound_s": total,
        "roofline_fraction": t_compute / total if total > 0 else 0.0,
        "mem_bytes_per_dev": mem_bytes,
        "wire_bytes_per_dev": wire,
    }


LEVERS = {
    "compute": "already compute-bound: raise MFU via larger per-core tiles "
               "/ fewer recompute passes",
    "memory": "cut HBM traffic: fuse/remat less, shrink optimizer state, "
              "bf16 cache, better layout",
    "collective": "cut wire bytes: reshard to kill the dominant gather/"
                  "reduce, overlap collectives with compute, int8 grads",
}


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | MODEL/HLOraw |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['flops_ratio_raw']:.2f} |\n")
    return "".join(out)


def main():
    cells = load_cells()
    rows = [roofline_row(t, c) for t, c in cells.items()]
    rows = [r for r in rows if r]
    print("tag,t_compute,t_memory,t_collective,dominant,roofline_frac")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['tag']},{r.get('status')},{r.get('reason','')}")
            continue
        print(f"{r['tag']},{r['t_compute_s']:.4e},{r['t_memory_s']:.4e},"
              f"{r['t_collective_s']:.4e},{r['dominant']},"
              f"{r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
