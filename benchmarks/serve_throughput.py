"""Solve-service throughput bench: per-request solving vs the
continuous-batching lane scheduler, blocking vs async overlap
(``repro.serve.twscheduler``).

ISSUE 4's motivation quantified, extended with ISSUE 5's overlap
pipeline: a service answering one solve request at a time issues one
fused dispatch per (request, block, k) and the device idles between
them; the lane scheduler packs every in-flight request's current
deepening rung into shared multi-lane dispatches and right-sizes the
pooled frontier buffers with ``batch.plan_capacity``; the async
scheduler additionally admits requests arriving *mid-flight* into the
very next dispatch instead of waiting for an idle pool.  This bench
pushes a mixed Table-1 instance stream through

  * ``sequential`` — ``[solver.solve(g) for g in stream]`` (per-request)
  * ``service=L``  — ``TwScheduler(lanes=L)``, blocking drain
  * ``async=L``    — the same stream with its second half arriving while
    the first dispatch is in flight, vs the blocking two-phase pattern
    (drain to idle, then serve the burst)
  * ``pipeline=D`` — ISSUE 6's pipelined dispatch: depth 2 launches the
    next round's projected rungs before the previous round syncs, so
    each host sync finds the device already covered by queued work
    (``covered_syncs`` vs ``idle_syncs``) — parity asserted against
    depth 1 and the sequential baseline
  * ``shards=S``   — ISSUE 7's intra-request scale-out: one heavy
    request submitted with ``shards=4`` (its rungs decided by 4-way
    sharded dispatches with work donation, its ladder climbing 4 rungs
    per round from its 4-slot entitlement) finishes in measurably fewer
    scheduler rounds than the same request with ``shards=1``, while the
    concurrent small requests keep completing — parity asserted for
    every request in both runs

and reports requests/sec, dispatch/host-sync/round counts and the pooled
frontier footprint, asserting full result parity (width/exactness/
expanded — the default config carries no padding caveat) including the
per_k reassembled from the streamed ``rung_decided`` events, plus the
dispatch reduction and the mid-flight-admission round evidence.  On CPU
absolute times measure XLA's CPU backend; the dispatch/round reduction
is the portable signal (wall-clock becomes meaningful on real TPU
hardware, as with engine_sync).

    python -m benchmarks.serve_throughput              # fast stream
    python -m benchmarks.serve_throughput --quick      # CI-sized
    python -m benchmarks.serve_throughput --full
    python -m benchmarks.serve_throughput --lanes 16
    python -m benchmarks.serve_throughput --json BENCH_serve.json

``--json PATH`` additionally writes the machine-readable record (one
entry per mode: wall-clock, req/s, dispatch/host-sync/round counts,
idle vs covered syncs, pool bytes) so CI can archive the perf
trajectory across PRs.
"""
from __future__ import annotations

from repro.core import batch, solver, telemetry
from repro.core import bitset, frontier
from repro.serve.twscheduler import TwScheduler


def _counters(tr) -> dict:
    """The legacy-shaped counter dict for one measurement's tracker."""
    return {k: int(tr[k]) for k in telemetry.LEGACY_KEYS}

from .common import Timer, emit, get_instance

# the acceptance stream: 8 mixed Table-1 instances (small and mid blocks
# interleaved so lanes genuinely overlap requests of different depths)
STREAM = ["myciel3", "petersen", "queen5_5", "desargues",
          "myciel4", "petersen", "myciel3", "queen5_5"]
# CI-sized: small blocks only (plan_capacity stays well under DEFAULT_CAP,
# so the footprint cut is visible) and a 4-lane pool — the vmapped lane
# program compiles slowly on CPU (ROADMAP: TPU-vs-CPU compile note)
STREAM_QUICK = ["myciel3", "petersen", "myciel3", "petersen",
                "myciel3", "petersen", "myciel3", "petersen"]
STREAM_FULL = STREAM + ["queen6_6", "mcgee", "dyck", "myciel4"]


def run(full: bool = False, quick: bool = False, lanes: int = 8,
        block: int = 1 << 10, json_path: str = None):
    keys = STREAM_FULL if full else (STREAM_QUICK if quick else STREAM)
    gs = [get_instance(k) for k in keys]
    records = []

    header = (f"{'mode':<14} {'time_s':>8} {'req_s':>8} {'dispatches':>10} "
              f"{'host_syncs':>10} {'pool_MiB':>9}")
    print(header, flush=True)
    rows = {}

    # per-request baseline: fixed worst-case cap, one solve per request
    # (each mode gets a fresh detached tracker — isolated measurement)
    tr_seq = telemetry.Tracker()
    with Timer() as t_seq:
        seq = [solver.solve(g, cap=batch.DEFAULT_CAP, block=block,
                            tracker=tr_seq)
               for g in gs]
    n_max = max(g.n for g in gs)
    seq_pool = frontier.frontier_bytes(batch.DEFAULT_CAP,
                                       bitset.n_words(n_max))
    rows["sequential"] = (t_seq.seconds, _counters(tr_seq), seq_pool, seq)

    # the service: continuous batching + plan_capacity-sized lane pool
    tr_srv = telemetry.Tracker()
    sched = TwScheduler(lanes=lanes, block=block, tracker=tr_srv)
    rids = [sched.submit(g) for g in gs]
    with Timer() as t_srv:
        done = sched.run()
    srv = [done[r] for r in rids]
    srv_pool = sched.pool_bytes()
    rows[f"service={lanes}"] = (t_srv.seconds, _counters(tr_srv),
                                srv_pool, srv)

    for mode, (secs, c, pool, results) in rows.items():
        print(f"{mode:<14} {secs:>8.2f} "
              f"{len(gs) / max(secs, 1e-9):>8.2f} {c['dispatches']:>10} "
              f"{c['host_syncs']:>10} {pool / 2**20:>9.2f}", flush=True)
        emit(f"serve_throughput/{mode}", secs,
             f"req_s={len(gs) / max(secs, 1e-9):.2f};"
             f"dispatches={c['dispatches']};host_syncs={c['host_syncs']};"
             f"pool_bytes={pool}")
        records.append(dict(mode=mode, shards=1, wall_s=secs,
                            req_s=len(gs) / max(secs, 1e-9),
                            dispatches=c["dispatches"],
                            host_syncs=c["host_syncs"], pool_bytes=pool))

    # parity: the service is pure scheduling — every request's result is
    # bit-identical to its solo solve
    for key, a, b in zip(keys, seq, srv):
        assert (a.width, a.exact, a.expanded, a.lb, a.ub) == \
            (b.width, b.exact, b.expanded, b.lb, b.ub), (key, a, b)

    (ts, cs, _, _), (tm, cm, pool_m, _) = \
        rows["sequential"], rows[f"service={lanes}"]
    d_ratio = cs["dispatches"] / max(cm["dispatches"], 1)
    assert cm["dispatches"] < cs["dispatches"], \
        "service must batch rungs into fewer dispatches"
    print(f"-> service: {d_ratio:.1f}x fewer dispatches, "
          f"{ts / max(tm, 1e-9):.2f}x wall-clock, "
          f"{len(gs) / max(tm, 1e-9):.2f} req/s", flush=True)
    emit("serve_throughput/summary", tm,
         f"dispatch_reduction={d_ratio:.2f}x;"
         f"speedup={ts / max(tm, 1e-9):.2f}x")

    records.append(run_overlap(keys, gs, seq, lanes=lanes, block=block))
    records.extend(run_pipeline(keys, gs, seq, lanes=lanes, block=block))
    records.extend(run_shards(lanes=lanes, block=block, quick=quick))

    if json_path:
        import json as json_lib
        with open(json_path, "w") as f:
            json_lib.dump({"bench": "serve_throughput", "stream": keys,
                           "lanes": lanes, "modes": records}, f, indent=2)
        print(f"-> wrote {json_path}", flush=True)
    return rows


def run_overlap(keys, gs, seq, *, lanes: int, block: int):
    """ISSUE 5's acceptance evidence: the async scheduler admits a
    mid-flight burst without waiting for pool idle, in fewer scheduler
    rounds than the blocking two-phase pattern, with per-request results
    (incl. the per_k reassembled from streamed events) bit-identical to
    sequential ``solver.solve``."""
    # keep the early phase below the pool width so the mid-flight burst
    # has free slots to land in (a full pool admits FIFO as slots free —
    # correct, but the next-dispatch evidence needs free lanes)
    half = min(max(1, len(gs) // 2), max(1, lanes // 2))
    early, late = list(zip(keys, gs))[:half], list(zip(keys, gs))[half:]
    free = max(0, lanes - half)

    # blocking two-phase baseline: drain to idle, then serve the burst
    blocking = TwScheduler(lanes=lanes, block=block)
    b_rids = [blocking.submit(g) for _k, g in early]
    blocking.run()
    b_rids += [blocking.submit(g) for _k, g in late]
    blocking.run()

    # async overlap: the burst lands while dispatch 1 is in flight and is
    # admitted immediately (host bookkeeping under the flying device)
    tr = telemetry.Tracker()
    overlap = TwScheduler(lanes=lanes, block=block, tracker=tr)
    events = {}

    def submit(g):
        evs = []
        rid = overlap.submit(g, on_event=evs.append)
        events[rid] = evs
        return rid

    with Timer() as t_async:
        rids = [submit(g) for _k, g in early]
        launched = overlap.launch()
        rids += [submit(g) for _k, g in late]     # mid-flight arrivals
        overlap.poll_admissions()
        if launched:
            overlap.sync()
        done = overlap.run()
    c = _counters(tr)

    late_adm = [next(e["round"] for e in events[r] if e["event"] ==
                     "admitted") for r in rids[half:]]
    mode = f"async={lanes}"
    print(f"{mode:<14} {t_async.seconds:>8.2f} "
          f"{len(gs) / max(t_async.seconds, 1e-9):>8.2f} "
          f"{c['dispatches']:>10} {c['host_syncs']:>10} "
          f"{overlap.pool_bytes() / 2**20:>9.2f}", flush=True)
    print(f"-> overlap: late burst admitted at round(s) {late_adm} while "
          f"round 1 was in flight; {overlap.rounds} rounds vs "
          f"{blocking.rounds} blocking two-phase rounds", flush=True)
    # the burst lands in the free lanes for the NEXT dispatch (round 2),
    # never waiting for the pool to go idle; past the free lanes it
    # queues FIFO behind them as slots recycle
    assert all(r <= 2 for r in late_adm[:free]), \
        "mid-flight arrivals must be admitted for the next dispatch"
    assert overlap.rounds < blocking.rounds, \
        "overlap must beat waiting for pool idle"

    # parity incl. the streamed per_k deltas
    for key, ref, rid in zip(keys, seq, rids):
        res = done[rid]
        assert (ref.width, ref.exact, ref.expanded, ref.per_k) == \
            (res.width, res.exact, res.expanded, res.per_k), (key, ref, res)
        streamed = {}
        for e in events[rid]:
            if e["event"] == "rung_decided":
                streamed.setdefault(e["block"], {})[e["k"]] = {
                    "feasible": e["feasible"], "inexact": e["inexact"],
                    "expanded": e["expanded"]}
        searched = {blk: pk for blk, pk in res.per_k.items() if pk}
        assert streamed == searched, (key, streamed, searched)
    emit("serve_throughput/async_overlap", t_async.seconds,
         f"rounds={overlap.rounds};blocking_rounds={blocking.rounds};"
         f"late_admit_rounds={'+'.join(map(str, late_adm))};"
         f"dispatches={c['dispatches']}")
    return dict(mode=mode, shards=1, wall_s=t_async.seconds,
                req_s=len(gs) / max(t_async.seconds, 1e-9),
                dispatches=c["dispatches"], host_syncs=c["host_syncs"],
                rounds=overlap.rounds, blocking_rounds=blocking.rounds,
                pool_bytes=overlap.pool_bytes())


def run_pipeline(keys, gs, seq, *, lanes: int, block: int):
    """ISSUE 6's acceptance evidence: depth-2 pipelined dispatch shows
    fewer idle host-sync gaps than depth-1 serving of the same stream
    (every depth-2 sync past the first finds the next round already in
    flight), with per-request results bit-identical to depth 1 and to
    sequential ``solver.solve``."""
    records, stats = [], {}
    for depth in (1, 2):
        tr = telemetry.Tracker()
        sched = TwScheduler(lanes=lanes, block=block, pipeline=depth,
                            tracker=tr)
        rids = [sched.submit(g) for g in gs]
        with Timer() as t:
            done = sched.run()
        c = _counters(tr)
        for key, ref, rid in zip(keys, seq, rids):
            res = done[rid]
            assert (ref.width, ref.exact, ref.expanded, ref.per_k) == \
                (res.width, res.exact, res.expanded, res.per_k), \
                (key, ref, res)
        mode = f"pipeline={depth}"
        print(f"{mode:<14} {t.seconds:>8.2f} "
              f"{len(gs) / max(t.seconds, 1e-9):>8.2f} "
              f"{c['dispatches']:>10} {c['host_syncs']:>10} "
              f"{sched.pool_bytes() / 2**20:>9.2f}", flush=True)
        emit(f"serve_throughput/{mode}", t.seconds,
             f"req_s={len(gs) / max(t.seconds, 1e-9):.2f};"
             f"dispatches={c['dispatches']};host_syncs={c['host_syncs']};"
             f"rounds={sched.rounds};idle_syncs={sched.idle_syncs};"
             f"covered_syncs={sched.covered_syncs}")
        stats[depth] = (sched.idle_syncs, sched.covered_syncs)
        records.append(dict(mode=mode, shards=1, wall_s=t.seconds,
                            req_s=len(gs) / max(t.seconds, 1e-9),
                            dispatches=c["dispatches"],
                            host_syncs=c["host_syncs"],
                            rounds=sched.rounds,
                            idle_syncs=sched.idle_syncs,
                            covered_syncs=sched.covered_syncs,
                            pool_bytes=sched.pool_bytes()))
    print(f"-> pipeline: depth 2 ran {stats[2][0]} idle / {stats[2][1]} "
          f"covered host syncs vs depth 1's {stats[1][0]} idle "
          f"(device kept busy across the sync gap)", flush=True)
    assert stats[2][1] > 0, "depth 2 must cover syncs with queued rounds"
    assert stats[2][0] < stats[1][0], \
        "depth 2 must show fewer idle host-sync gaps than depth 1"
    return records


def run_shards(*, lanes: int, block: int, quick: bool = False):
    """ISSUE 7's acceptance evidence: one heavy request submitted with
    ``shards=4`` — its rungs decided by 4-way sharded dispatches
    (``core.shard``: owner-hash frontier split + work donation) and its
    ladder climbing 4 rungs per round from its 4-slot entitlement —
    finishes in measurably fewer scheduler rounds than the identical
    request with ``shards=1``, while the concurrent small requests keep
    completing.  Every request's result is asserted bit-identical to
    sequential ``solver.solve`` in both runs."""
    heavy_key = "myciel4" if quick else "queen5_5"
    heavy = get_instance(heavy_key)
    small_keys = ["myciel3", "petersen", "myciel3"]
    smalls = [get_instance(k) for k in small_keys]
    ref_h = solver.solve(heavy, block=block)
    ref_s = [solver.solve(g, block=block) for g in smalls]

    records, done_rounds = [], {}
    for s in (1, 4):
        tr = telemetry.Tracker()
        sched = TwScheduler(lanes=lanes, block=block, tracker=tr)
        evs = []
        with Timer() as t:
            rid_h = sched.submit(heavy, shards=s, on_event=evs.append)
            rids = [sched.submit(g) for g in smalls]
            done = sched.run()
        c = _counters(tr)
        done_rounds[s] = next(e["rounds"] for e in evs
                              if e["event"] == "done")
        rh = done[rid_h]
        assert (rh.width, rh.exact, rh.expanded, rh.per_k) == \
            (ref_h.width, ref_h.exact, ref_h.expanded, ref_h.per_k), \
            (heavy_key, s, rh, ref_h)
        for key, rid, ref in zip(small_keys, rids, ref_s):
            res = done[rid]
            assert (res.width, res.exact, res.expanded) == \
                (ref.width, ref.exact, ref.expanded), (key, s, res, ref)
        mode = f"shards={s}"
        print(f"{mode:<14} {t.seconds:>8.2f} "
              f"{(1 + len(smalls)) / max(t.seconds, 1e-9):>8.2f} "
              f"{c['dispatches']:>10} {c['host_syncs']:>10} "
              f"{sched.pool_bytes() / 2**20:>9.2f}", flush=True)
        emit(f"serve_throughput/{mode}", t.seconds,
             f"heavy={heavy_key};heavy_done_round={done_rounds[s]};"
             f"rounds={sched.rounds};dispatches={c['dispatches']};"
             f"donations={c['shard_donations']};"
             f"donated_rows={c['shard_donated_rows']};"
             f"idle_steps={c['shard_idle_steps']};"
             f"peak_occupancy={c['shard_peak_occupancy']}")
        records.append(dict(
            mode=mode, shards=s, wall_s=t.seconds, heavy=heavy_key,
            heavy_done_round=done_rounds[s], rounds=sched.rounds,
            dispatches=c["dispatches"], host_syncs=c["host_syncs"],
            shard_donations=c["shard_donations"],
            shard_donated_rows=c["shard_donated_rows"],
            shard_idle_steps=c["shard_idle_steps"],
            shard_peak_occupancy=c["shard_peak_occupancy"],
            pool_bytes=sched.pool_bytes()))
    print(f"-> shards: heavy ({heavy_key}) done at round "
          f"{done_rounds[4]} sharded vs {done_rounds[1]} unsharded; "
          f"smalls completed in both runs", flush=True)
    assert done_rounds[4] < done_rounds[1], \
        "sharded heavy request must finish in fewer scheduler rounds"
    return records


if __name__ == "__main__":
    import sys
    lanes = 8
    if "--lanes" in sys.argv:
        lanes = int(sys.argv[sys.argv.index("--lanes") + 1])
    if "--quick" in sys.argv and "--lanes" not in sys.argv:
        lanes = 4
    json_path = None
    if "--json" in sys.argv:
        json_path = sys.argv[sys.argv.index("--json") + 1]
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        lanes=lanes, json_path=json_path)
