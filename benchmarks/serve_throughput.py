"""Solve-service throughput bench: per-request solving vs the
continuous-batching lane scheduler (``repro.serve.twscheduler``).

ISSUE 4's motivation quantified: a service answering one solve request
at a time issues one fused dispatch per (request, block, k) and the
device idles between them; the lane scheduler packs every in-flight
request's current deepening rung into shared multi-lane dispatches and
right-sizes the pooled frontier buffers with ``batch.plan_capacity``.
This bench pushes a mixed Table-1 instance stream through

  * ``sequential`` — ``[solver.solve(g) for g in stream]`` (per-request)
  * ``service=L``  — ``TwScheduler(lanes=L)`` continuous batching

and reports requests/sec, dispatch and host-sync counts, and the pooled
frontier footprint, asserting full result parity (width/exactness/
expanded — the default config carries no padding caveat) and the
dispatch reduction.  On CPU absolute times measure XLA's CPU backend;
the dispatch/sync reduction is the portable signal (wall-clock becomes
meaningful on real TPU hardware, as with engine_sync).

    python -m benchmarks.serve_throughput              # fast stream
    python -m benchmarks.serve_throughput --quick      # CI-sized
    python -m benchmarks.serve_throughput --full
    python -m benchmarks.serve_throughput --lanes 16
"""
from __future__ import annotations

from repro.core import batch, engine as engine_lib, solver
from repro.core import bitset, frontier
from repro.serve.twscheduler import TwScheduler

from .common import Timer, emit, get_instance

# the acceptance stream: 8 mixed Table-1 instances (small and mid blocks
# interleaved so lanes genuinely overlap requests of different depths)
STREAM = ["myciel3", "petersen", "queen5_5", "desargues",
          "myciel4", "petersen", "myciel3", "queen5_5"]
# CI-sized: small blocks only (plan_capacity stays well under DEFAULT_CAP,
# so the footprint cut is visible) and a 4-lane pool — the vmapped lane
# program compiles slowly on CPU (ROADMAP: TPU-vs-CPU compile note)
STREAM_QUICK = ["myciel3", "petersen", "myciel3", "petersen",
                "myciel3", "petersen", "myciel3", "petersen"]
STREAM_FULL = STREAM + ["queen6_6", "mcgee", "dyck", "myciel4"]


def run(full: bool = False, quick: bool = False, lanes: int = 8,
        block: int = 1 << 10):
    keys = STREAM_FULL if full else (STREAM_QUICK if quick else STREAM)
    gs = [get_instance(k) for k in keys]

    header = (f"{'mode':<14} {'time_s':>8} {'req_s':>8} {'dispatches':>10} "
              f"{'host_syncs':>10} {'pool_MiB':>9}")
    print(header, flush=True)
    rows = {}

    # per-request baseline: fixed worst-case cap, one solve per request
    engine_lib.reset_counters()
    with Timer() as t_seq:
        seq = [solver.solve(g, cap=batch.DEFAULT_CAP, block=block)
               for g in gs]
    n_max = max(g.n for g in gs)
    seq_pool = frontier.frontier_bytes(batch.DEFAULT_CAP,
                                       bitset.n_words(n_max))
    rows["sequential"] = (t_seq.seconds, dict(engine_lib.COUNTERS),
                         seq_pool, seq)

    # the service: continuous batching + plan_capacity-sized lane pool
    engine_lib.reset_counters()
    sched = TwScheduler(lanes=lanes, block=block)
    rids = [sched.submit(g) for g in gs]
    with Timer() as t_srv:
        done = sched.run()
    srv = [done[r] for r in rids]
    srv_pool = sched.pool_bytes()
    rows[f"service={lanes}"] = (t_srv.seconds, dict(engine_lib.COUNTERS),
                                srv_pool, srv)

    for mode, (secs, c, pool, results) in rows.items():
        print(f"{mode:<14} {secs:>8.2f} "
              f"{len(gs) / max(secs, 1e-9):>8.2f} {c['dispatches']:>10} "
              f"{c['host_syncs']:>10} {pool / 2**20:>9.2f}", flush=True)
        emit(f"serve_throughput/{mode}", secs,
             f"req_s={len(gs) / max(secs, 1e-9):.2f};"
             f"dispatches={c['dispatches']};host_syncs={c['host_syncs']};"
             f"pool_bytes={pool}")

    # parity: the service is pure scheduling — every request's result is
    # bit-identical to its solo solve
    for key, a, b in zip(keys, seq, srv):
        assert (a.width, a.exact, a.expanded, a.lb, a.ub) == \
            (b.width, b.exact, b.expanded, b.lb, b.ub), (key, a, b)

    (ts, cs, _, _), (tm, cm, pool_m, _) = \
        rows["sequential"], rows[f"service={lanes}"]
    d_ratio = cs["dispatches"] / max(cm["dispatches"], 1)
    assert cm["dispatches"] < cs["dispatches"], \
        "service must batch rungs into fewer dispatches"
    print(f"-> service: {d_ratio:.1f}x fewer dispatches, "
          f"{ts / max(tm, 1e-9):.2f}x wall-clock, "
          f"{len(gs) / max(tm, 1e-9):.2f} req/s", flush=True)
    emit("serve_throughput/summary", tm,
         f"dispatch_reduction={d_ratio:.2f}x;"
         f"speedup={ts / max(tm, 1e-9):.2f}x")
    return rows


if __name__ == "__main__":
    import sys
    lanes = 8
    if "--lanes" in sys.argv:
        lanes = int(sys.argv[sys.argv.index("--lanes") + 1])
    if "--quick" in sys.argv and "--lanes" not in sys.argv:
        lanes = 4
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        lanes=lanes)
