"""Benchmark harness entry point: one section per paper table.

    PYTHONPATH=src python -m benchmarks.run            # fast suite
    PYTHONPATH=src python -m benchmarks.run --full     # adds heavy graphs
    PYTHONPATH=src python -m benchmarks.run --pallas   # adds kernel sweep

Output contract: ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import sys


def main() -> None:
    full = "--full" in sys.argv
    pallas = "--pallas" in sys.argv

    print("# table1: general benchmark (paper Table 1)", flush=True)
    from . import table1_general
    table1_general.run(full=full, json_path="BENCH_solver.json")

    print("# engine_sync: fused vs host-loop engine (dispatches + syncs)",
          flush=True)
    from . import engine_sync
    engine_sync.run(full=full)

    print("# batch_throughput: multi-lane engine vs sequential dispatches",
          flush=True)
    from . import batch_throughput
    batch_throughput.run(full=full)

    print("# serve_throughput: solve service (continuous batching) vs "
          "per-request solving", flush=True)
    from . import serve_throughput
    serve_throughput.run(full=full, quick=not full,
                         lanes=8 if full else 4)

    print("# serve_load: open-loop arrival trace vs the persistent "
          "service (submit->done latency percentiles)", flush=True)
    from . import serve_load
    serve_load.run(quick=not full)

    print("# cache_effect: content-addressed result cache (hit rate, "
          "warm-hit latency, bit-identity vs uncached)", flush=True)
    from . import cache_effect
    cache_effect.run(full=full, quick=not full,
                     json_path="BENCH_cache.json")

    print("# shard_scaling: intra-request scale-out (sharded frontier "
          "vs sequential)", flush=True)
    from . import shard_scaling
    shard_scaling.run(full=full, quick=not full)

    print("# bounds_quality: anytime heuristic bounds engine (rung "
          "reduction + ub-lb gap vs time)", flush=True)
    from . import bounds_quality
    bounds_quality.run(full=full, quick=not full)

    print("# table2: work-size x memory sweep (paper Tables 2/3)",
          flush=True)
    from . import table2_worksize
    table2_worksize.run(pallas=pallas)

    print("# table4: minor-min-width on/off (paper Tables 4/5)", flush=True)
    from . import table4_mmw
    table4_mmw.run()

    print("# table6: loop scheduling (paper Table 6)", flush=True)
    from . import table6_unnesting
    table6_unnesting.run()

    print("# simplicial: beyond-paper pruning (paper §5 future work)",
          flush=True)
    from . import table_simplicial
    table_simplicial.run()

    print("# lm: substrate microbench", flush=True)
    from . import lm_microbench
    lm_microbench.run()

    print("# roofline: dry-run derived terms (see EXPERIMENTS.md)",
          flush=True)
    try:
        from . import roofline
        roofline.main()
    except Exception as e:                      # noqa: BLE001
        print(f"roofline,0,unavailable ({e!r})")


if __name__ == "__main__":
    main()
