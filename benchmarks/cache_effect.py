"""Result-cache effect benchmark: hit rate and warm-hit latency vs the
duplicate-rate dial (DESIGN.md §16).

For each duplicate rate, one generated workload trace (``repro.
workload.quick_spec``, ``iso_rate=0`` — see below) is replayed twice
over the real wire via ``serve_load.run_trace`` in closed-loop mode:
cold (``cache=0``) and warm (``cache=64``).  The run then *asserts* the
tentpole guarantees, not just reports them:

  * **bit-identity** — every arrival's end-to-end wire result (width,
    exact, lb, ub, expanded, order, per_k) is identical between the
    cached and uncached runs.  ``iso_rate`` is pinned to 0 here: a
    relabeled duplicate's warm hit returns its *root's* (label-
    invariant) surface while a cold solve re-runs the label-dependent
    plan heuristics, so strict bit-identity is an identical-resubmission
    guarantee (the iso verdict surface is covered by
    ``tests/test_cache.py``);
  * **every duplicate hits** — closed-loop replay finishes each root
    before its duplicates arrive, so the duplicate set is exactly
    cache-hittable and must be a subset of the observed hit set;
  * **zero device dispatches per hit** — asserted inside ``run_trace``
    from each hit rid's telemetry scope.

Reported per rate: hit rate, warm-hit p50 vs cold p50 (the headline
"instant hits" number), and total device dispatches saved.

    python -m benchmarks.cache_effect --quick --json BENCH_cache.json
"""
from __future__ import annotations

import json as json_lib

from repro.workload import generate, quick_spec

from .common import emit
from .serve_load import _pct, run_trace  # noqa: F401 — shared percentile

_RESULT_FIELDS = ("width", "exact", "lb", "ub", "expanded", "order",
                  "per_k")


def _norm(res: dict) -> tuple:
    """Comparable projection of one wire result.  Both runs' results
    crossed the same JSON wire (``per_k``'s nested block/k keys are
    strings in both), so field-by-field equality IS bit-identity of the
    full surface."""
    return tuple(res.get(f) for f in _RESULT_FIELDS)


def run(full: bool = False, quick: bool = True, json_path: str = None):
    rates = [0.0, 0.25, 0.5, 0.75] if full else [0.0, 0.5]
    requests = 24 if full else 16
    records = []
    for rate in rates:
        spec = quick_spec(duplicate_rate=rate, iso_rate=0.0,
                          requests=requests, seed=11)
        arrivals = generate(spec)
        dups = [a.idx for a in arrivals if a.dup_of is not None]
        cold = run_trace(arrivals, cache=0, closed=True)
        warm = run_trace(arrivals, cache=64, closed=True)

        # bit-identity: the cache is invisible in the result surface
        for a in arrivals:
            c, w = _norm(cold["results"][a.idx]), _norm(warm["results"][a.idx])
            assert c == w, (rate, a.idx, a.name, c, w)
        # an uncached pool serves no hits; a cached closed loop serves
        # every duplicate from the cache (zero-dispatch asserted inside
        # run_trace per hit)
        assert cold["hits"] == 0, cold["hits"]
        missed = set(dups) - set(warm["hit_idxs"])
        assert not missed, (rate, sorted(missed))

        cs = warm["cache_stats"]
        rec = dict(duplicate_rate=rate, n=len(arrivals), dups=len(dups),
                   hits=warm["hits"], hit_rate=round(cs["hit_rate"], 4),
                   cold_p50_s=cold["miss_p50_s"],
                   warm_hit_p50_s=warm["hit_p50_s"],
                   warm_miss_p50_s=warm["miss_p50_s"],
                   dispatches_cold=cold["dispatches"],
                   dispatches_warm=warm["dispatches"],
                   bit_identical=True)
        records.append(rec)
        hit_p50 = warm["hit_p50_s"]
        cold_p50 = cold["miss_p50_s"] or 0.0
        print(f"cache_effect: dup_rate={rate:.2f} n={len(arrivals)} "
              f"hits={warm['hits']}/{len(dups)} dup "
              f"hit_rate={cs['hit_rate']:.2f} "
              f"warm_hit_p50={(hit_p50 or 0) * 1e3:.2f}ms "
              f"cold_p50={cold_p50 * 1e3:.1f}ms "
              f"dispatches {cold['dispatches']}->{warm['dispatches']} "
              f"bit_identical=yes", flush=True)
        emit(f"cache_effect/dup{rate:g}", hit_p50 or 0.0,
             f"hits={warm['hits']};dups={len(dups)};"
             f"hit_rate={cs['hit_rate']:.3f};"
             f"cold_p50_s={cold_p50:.4f};"
             f"dispatches_cold={cold['dispatches']};"
             f"dispatches_warm={warm['dispatches']};bit_identical=yes")
    if json_path:
        with open(json_path, "w", encoding="utf-8") as f:
            json_lib.dump({"bench": "cache_effect", "records": records},
                          f, indent=2)
        print(f"-> wrote {json_path}", flush=True)
    return records


if __name__ == "__main__":
    import sys
    json_path = None
    if "--json" in sys.argv:
        json_path = sys.argv[sys.argv.index("--json") + 1]
    run(full="--full" in sys.argv, quick="--quick" in sys.argv,
        json_path=json_path)
