"""Deterministic synthetic LM data pipeline.

Counter-based (stateless) generation: batch ``step`` is a pure function of
(seed, step), so any restart — same or different host/device count — replays
the exact stream (the determinism leg of the fault-tolerance story).

The stream is a noisy affine-recurrence language: ``t_{i+1} = (a*t_i + c +
eps) mod V`` with p_noise-random resets, so an LM can push loss well below
log(V) and training curves are meaningful, while generation stays O(1) per
token and vectorised.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, p_noise: float = 0.15):
        self.vocab = vocab
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.p_noise = p_noise
        self.a = 31 % vocab or 1
        self.c = 17 % vocab

    def batch_at(self, step: int, batch: int | None = None,
                 batch_offset: int = 0):
        """Global batch for ``step`` (or a [offset, offset+batch) slice of it
        for per-host sharded loading)."""
        b = batch if batch is not None else self.batch
        rng = np.random.Philox(key=self.seed, counter=[0, 0, 0, step])
        gen = np.random.Generator(rng)
        full = gen.integers(0, self.vocab,
                            size=(self.batch, self.seq + 1), dtype=np.int64)
        noise = gen.random((self.batch, self.seq + 1)) < self.p_noise
        seqs = np.empty((self.batch, self.seq + 1), dtype=np.int64)
        seqs[:, 0] = full[:, 0]
        for i in range(1, self.seq + 1):
            pred = (self.a * seqs[:, i - 1] + self.c) % self.vocab
            seqs[:, i] = np.where(noise[:, i], full[:, i], pred)
        sl = seqs[batch_offset:batch_offset + b]
        return {
            "tokens": sl[:, :-1].astype(np.int32),
            "targets": sl[:, 1:].astype(np.int32),
            "mask": np.ones((b, self.seq), np.float32),
        }
