"""Optimizers: AdamW and Adafactor (factored second moments).

Adafactor is the memory story for the 400B MoE: O(n+m) second-moment state
for an (n, m) matrix instead of O(n*m), plus bf16 momentum — ~2.x
bytes/param of optimizer state instead of 8 (fp32 AdamW m+v), which is what
fits 16 GB/chip HBM on a single pod (DESIGN.md §6).

State trees mirror the param tree structure exactly (leaf-for-leaf via
flatten/unflatten), so param shardings map onto optimizer state directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g.astype(jnp.float32) * scale, tree), norm


def warmup_cosine(step, *, peak, warmup, total, floor=0.1):
    step = step.astype(jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


# ------------------------------------------------------------------- AdamW

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)
    gl, treedef = jax.tree.flatten(grads)
    ml = treedef.flatten_up_to(state["m"])
    vl = treedef.flatten_up_to(state["v"])
    pl = treedef.flatten_up_to(params)
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(gl, ml, vl, pl):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** cf)
        vh = v / (1 - b2 ** cf)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        new_m.append(m)
        new_v.append(v)
        new_p.append((p.astype(jnp.float32) - lr * step).astype(p.dtype))
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": c})


# --------------------------------------------------------------- Adafactor

def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params):
    def vstate(p):
        if _factored(p.shape):
            # store row/col stats concatenated is awkward; keep two leaves in
            # a fixed-width tuple so the tree structure stays regular
            return (jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return (jnp.zeros(p.shape, jnp.float32),
                jnp.zeros((1,), jnp.float32))        # dummy second slot
    return {"v": jax.tree.map(vstate, params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                              params),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, *, lr, b1=0.9, decay=0.8,
                     eps=1e-30, weight_decay=0.0, clip_threshold=1.0,
                     **_ignored):
    c = state["count"] + 1
    beta2 = 1.0 - c.astype(jnp.float32) ** (-decay)
    gl, treedef = jax.tree.flatten(grads)
    pl = treedef.flatten_up_to(params)
    ml = treedef.flatten_up_to(state["m"])
    vl = treedef.flatten_up_to(state["v"])    # leaves are 2-tuples
    new_m, new_v, new_p = [], [], []
    for g, p, m, v in zip(gl, pl, ml, vl):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr = beta2 * v[0] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v[1] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            pre = (vr / denom)[..., None] * vc[..., None, :]
            update = g * jax.lax.rsqrt(jnp.maximum(pre, eps))
            nv = (vr, vc)
        else:
            vv = beta2 * v[0] + (1 - beta2) * g2
            update = g * jax.lax.rsqrt(jnp.maximum(vv, eps))
            nv = (vv, v[1])
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        mm = b1 * m.astype(jnp.float32) + (1 - b1) * update
        step = mm + weight_decay * p.astype(jnp.float32)
        new_v.append(nv)
        new_m.append(mm.astype(jnp.bfloat16))
        new_p.append((p.astype(jnp.float32) - lr * step).astype(p.dtype))
    return (jax.tree.unflatten(treedef, new_p),
            {"v": jax.tree.unflatten(treedef, new_v),
             "m": jax.tree.unflatten(treedef, new_m),
             "count": c})


def opt_init(name: str):
    return {"adamw": adamw_init, "adafactor": adafactor_init}[name]


def opt_update(name: str):
    return {"adamw": adamw_update, "adafactor": adafactor_update}[name]


def opt_state_bytes(name: str, params) -> int:
    """Analytic optimizer-state footprint (for the dry-run memory report)."""
    total = 0
    for p in jax.tree.leaves(params):
        n = 1
        for s in p.shape:
            n *= s
        if name == "adamw":
            total += 8 * n
        else:
            total += 2 * n                        # bf16 momentum
            if _factored(p.shape):
                rows = n // p.shape[-1]
                total += 4 * (rows + n // rows if len(p.shape) == 2
                              else rows + (n // p.shape[-2]))
            else:
                total += 4 * n
    return total
