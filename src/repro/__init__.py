"""repro: 'Computing Treewidth on the GPU' as a multi-pod JAX/TPU framework.

Public entry points:
  repro.core.solver.solve / repro.core.distributed.solve_distributed
  repro.models.Model + repro.configs.get_config
  repro.launch.{dryrun,train,serve,solve,supervisor}
"""
