"""Checkpointing: sharded-state save/restore with async write + elastic
restore.

Layout:  <dir>/step_<n>/
            meta.json          — step, leaf paths, shapes/dtypes
            <leafpath>.npy     — one file per pytree leaf (full logical array)

Arrays are written as *logical* (unsharded) arrays: restore re-shards onto
whatever mesh the new process brings up (elastic scaling).  At real pod
scale this becomes per-shard files + OCDBT-style indexing (orbax); the
format here keeps the same API surface at CPU-test scale (DESIGN.md §8).

Writes happen on a background thread (async checkpointing) so the train
loop never blocks on disk; ``wait()`` joins before the next save or exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import numpy as np
import jax


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_seg(p) for p in path)
        out.append((key, leaf))
    return out


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, state, step: int, blocking: bool = False):
        self.wait()
        host = [(k, np.asarray(v)) for k, v in _flatten_with_paths(state)]

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            meta = {"step": step, "leaves": []}
            for k, arr in host:
                fn = k.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                meta["leaves"].append(
                    {"key": k, "file": fn, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, abstract_state, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``abstract_state``; device_put with
        ``shardings`` (same tree structure) if given — this is where elastic
        re-sharding happens."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        by_key = {l["key"]: l for l in meta["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        leaves = []
        shard_flat = None
        if shardings is not None:
            shard_flat = [s for _, s in
                          jax.tree_util.tree_flatten_with_path(shardings)[0]]
        for i, (pathk, leaf) in enumerate(flat):
            key = "/".join(_seg(p) for p in pathk)
            arr = np.load(os.path.join(path, by_key[key]["file"]))
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
