"""Train state + train step builder (remat, grad accumulation, compression)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import causal_lm_loss
from repro.optim import optimizers as opt_lib
from repro.sharding import rules as rules_lib
from repro.utils import compat


def init_state(model, key, tcfg):
    params = model.init(key)
    return {"params": params,
            "opt": opt_lib.opt_init(tcfg.optimizer)(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(model, tcfg):
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    params = model.abstract()
    opt = jax.eval_shape(opt_lib.opt_init(tcfg.optimizer), params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_shardings(model, tcfg, mesh):
    pshard = rules_lib.param_shardings(model.spec, mesh)
    repl = rules_lib.replicated(mesh)

    def opt_shard_like():
        # optimizer state mirrors param structure; factored adafactor leaves
        # reduce over the last/penultimate dim -> drop that sharding dim
        if tcfg.optimizer == "adamw":
            return {"m": pshard, "v": pshard,
                    "count": repl}

        def fact(ns):
            # ns: NamedSharding of the param; derive row/col stats shardings
            spec = list(ns.spec) + [None] * 8
            rank = len(ns.spec)
            if rank >= 2:
                row = P(*ns.spec[:-1])
                col = P(*(tuple(ns.spec[:-2]) + (ns.spec[-1],)))
            else:
                row = P(*ns.spec)
                col = P()
            return (NamedSharding(mesh, row), NamedSharding(mesh, col))

        from repro.models.params import map_spec
        vshard = jax.tree.map(fact, pshard,
                              is_leaf=lambda x: isinstance(x, NamedSharding))
        return {"v": vshard, "m": pshard, "count": repl}

    return {"params": pshard, "opt": opt_shard_like(), "step": repl}


def _loss_fn(model, tcfg, params, batch):
    cfg = model.cfg
    kw = {}
    if "enc_embeds" in batch:
        kw["enc_embeds"] = batch["enc_embeds"]
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    logits, _, aux = model.apply(params, batch["tokens"], mode="train", **kw)
    loss, metrics = causal_lm_loss(logits, batch["targets"], cfg,
                                   batch.get("mask"), z_loss=tcfg.z_loss)
    total = loss + 0.01 * aux
    metrics = dict(metrics, aux=aux, loss=loss)
    return total, metrics


def build_train_step(model, tcfg, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    * microbatch > 0: gradient accumulation via lax.scan over batch slices
      (activation memory / microbatch, same math).
    * grad_compression="int8": per-DP-shard int8 quantised all-reduce with
      error-feedback-free stochastic-free rounding, under shard_map with the
      model axes left to GSPMD (`auto`).  Beyond-paper distributed trick;
      quality validated in tests/test_train.py.
    """
    update_fn = opt_lib.opt_update(tcfg.optimizer)

    def grads_of(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            nm = tcfg.microbatch
            b = batch["tokens"].shape[0]
            assert b % nm == 0

            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), g = jax.value_and_grad(
                    lambda p: _loss_fn(model, tcfg, p, mb), has_aux=True)(
                        params)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            mbs = jax.tree.map(
                lambda x: x.reshape((nm, b // nm) + x.shape[1:]), batch)
            # the (B,)->(nm, B/nm) reshape must keep the DP sharding on the
            # inner batch dim, or GSPMD replicates every microbatch slice
            amesh = compat.get_abstract_mesh()
            if getattr(amesh, "axis_names", None):
                dp = tuple(a for a in ("pod", "data")
                           if a in amesh.axis_names)
                dpn = 1
                for a in dp:
                    dpn *= amesh.shape[a]
                if dp and dpn > 1 and (b // nm) % dpn == 0:
                    mbs = jax.tree.map(
                        lambda x: jax.lax.with_sharding_constraint(
                            x, NamedSharding(amesh, P(
                                None, dp, *([None] * (x.ndim - 2))))), mbs)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"nll": 0.0, "aux": 0.0, "loss": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (g, ms), _ = jax.lax.scan(micro, (g0, m0), mbs)
            g = jax.tree.map(lambda x: x / nm, g)
            ms = jax.tree.map(lambda x: x / nm, ms)
            return g, ms
        (_, metrics), g = jax.value_and_grad(
            lambda p: _loss_fn(model, tcfg, p, batch), has_aux=True)(params)
        return g, metrics

    def _gather_specs():
        """FSDP-free param specs (model axes only) from the ambient mesh."""
        amesh = compat.get_abstract_mesh()
        if not getattr(amesh, "axis_names", None):
            return None
        gather_rules = dict(rules_lib.DEFAULT_RULES, embed=())
        from repro.models.params import map_spec
        return map_spec(
            lambda p: NamedSharding(amesh, rules_lib.spec_for(
                p.shape, p.axes, amesh, gather_rules)), model.spec)

    def _fsdp_specs():
        amesh = compat.get_abstract_mesh()
        if not getattr(amesh, "axis_names", None):
            return None
        from repro.models.params import map_spec
        return map_spec(
            lambda p: NamedSharding(amesh, rules_lib.spec_for(
                p.shape, p.axes, amesh)), model.spec)

    def train_step(state, batch):
        params_in = state["params"]
        if getattr(tcfg, "gather_once", False):
            gs = _gather_specs()
            if gs is not None:
                # one all-gather per step, hoisted out of the microbatch
                # scan; grads are constrained back to the FSDP layout below,
                # which lowers to a single reduce-scatter after accumulation
                params_in = jax.tree.map(
                    jax.lax.with_sharding_constraint, params_in, gs)
        grads, metrics = grads_of(params_in, batch)
        if getattr(tcfg, "gather_once", False):
            fs = _fsdp_specs()
            if fs is not None:
                grads = jax.tree.map(
                    jax.lax.with_sharding_constraint, grads, fs)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = opt_lib.warmup_cosine(state["step"], peak=tcfg.learning_rate,
                                   warmup=tcfg.warmup_steps,
                                   total=tcfg.total_steps)
        new_params, new_opt = update_fn(
            grads, state["opt"], state["params"], lr=lr, b1=tcfg.b1,
            weight_decay=tcfg.weight_decay)       # optimizer on FSDP shards
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step


# ------------------------------------------------- int8 DP grad compression

def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g, axis_name):
    """int8-quantised all-reduce with a *shared* scale: pmax the max-abs
    (one scalar collective), quantise everywhere with the same step, sum
    int32, rescale.  ~3.5x wire reduction on the DP axis (int8+scalar vs
    f32) at <1% relative error on the averaged gradient."""
    gmax = jax.lax.pmax(jnp.max(jnp.abs(g.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(1, axis_name)
    return (qsum.astype(jnp.float32) * scale) / n


def build_compressed_grads(model, tcfg, mesh):
    """Data-parallel gradient computation with int8 compressed all-reduce.

    shard_map over the DP axes with the model axes left automatic; grads
    are averaged (not summed) across DP shards.
    """
    dp = rules_lib.dp_axes(mesh)

    def local(params, batch):
        (_, metrics), g = jax.value_and_grad(
            lambda p: _loss_fn(model, tcfg, p, batch), has_aux=True)(params)
        g = jax.tree.map(lambda x: compressed_psum(x, dp), g)
        metrics = jax.tree.map(
            lambda x: jax.lax.pmean(x, dp), metrics)
        return g, metrics

    pspec = jax.tree.map(lambda _: P(), model.abstract())
    # shard_map with axis_names restricted to the DP axes leaves the
    # remaining mesh axes automatic (TP composes via GSPMD)
    return compat.shard_map(local, mesh=mesh,
                            in_specs=(pspec, P(dp)),
                            out_specs=(pspec, P()),
                            axis_names=set(dp))
