"""Treewidth solver CLI (the paper's workload).

    python -m repro.launch.solve --graph queen5_5
    python -m repro.launch.solve --graph myciel4 --mode bloom --mmw
    python -m repro.launch.solve --graph myciel3 --backend pallas --simplicial
    python -m repro.launch.solve --graph queen6_6 --distributed --devices 8
    python -m repro.launch.solve --graph myciel4 --batch 4
    python -m repro.launch.solve --graph queen6_6 --shards 4
    python -m repro.launch.solve --dimacs path/to/graph.gr

``--batch N`` runs the iterative-deepening ladder speculatively: each
dispatch decides N consecutive widths through the multi-lane engine
(``repro.core.batch``), and the smallest feasible one wins — same
results, fewer dispatches.

``--shards S`` scales one rung *out* instead: the frontier is split
across S vmapped shard lanes (owner-hash routing + work donation,
``repro.core.shard``), multiplying per-level throughput and aggregate
frontier capacity for a single heavy instance — results bit-identical
to the sequential ladder.

``--backend`` selects the op implementations through the registry
(``repro.core.backend``): "jax" reference or the fused Pallas wavefront
kernel ("pallas"; interpret mode off-TPU).  The pre-registry ``impl=``
spelling survives only as a hidden deprecated alias.  Unsupported
combinations are rejected here with a capability error before anything
is traced.

``--cap`` defaults to auto-sizing (``repro.core.batch.plan_capacity``).
To serve a *stream* of solve requests through one lane pool instead of
solving one instance, see ``python -m repro.launch.twserve``.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="",
                    help="generator name (see core.graph.REGISTRY)")
    ap.add_argument("--dimacs", default="", help="DIMACS/.gr file")
    ap.add_argument("--cap", type=int, default=None,
                    help="frontier rows per level (power of two). Default: "
                         "auto — repro.core.batch.plan_capacity right-sizes "
                         "the buffer per preprocessed block (drop-free "
                         "state bound, clamped to 2^17) instead of the old "
                         "fixed 2^18; results are bit-identical, small "
                         "blocks just stop paying the worst-case footprint. "
                         "--distributed still defaults to 2^18 (sharded "
                         "caps are split across devices, not planned)")
    ap.add_argument("--block", type=int, default=1 << 10)
    ap.add_argument("--mode", default="sort", choices=["sort", "bloom"])
    ap.add_argument("--engine", default="fused", choices=["fused", "host"],
                    help="wavefront driver: device-resident while_loop "
                         "(one dispatch per k) or per-level host loop")
    ap.add_argument("--batch", type=int, default=1, metavar="LANES",
                    help="speculative deepening width: decide k..k+LANES-1 "
                         "concurrently in one multi-lane dispatch "
                         "(core.batch; fused engine only, results "
                         "bit-identical to --batch 1). Default 1")
    ap.add_argument("--shards", type=int, default=1, metavar="S",
                    help="intra-request scale-out: split each rung's "
                         "frontier across S vmapped shard lanes with "
                         "work donation (core.shard; fused engine only, "
                         "results bit-identical to --shards 1). Default 1")
    ap.add_argument("--donate-ratio", type=float, default=None,
                    help="sharded work-donation trigger: rebalance when "
                         "the max shard exceeds ratio x mean occupancy "
                         "(default core.shard.DEFAULT_DONATE_RATIO)")
    ap.add_argument("--mmw", action="store_true")
    ap.add_argument("--simplicial", action="store_true",
                    help="enable simplicial-vertex branch collapse")
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"],
                    help="op implementations (repro.core.backend registry): "
                         "jax reference or fused pallas kernels")
    ap.add_argument("--impl", default=None, choices=["jax", "pallas"],
                    help=argparse.SUPPRESS)   # deprecated alias of --backend
    ap.add_argument("--schedule", default="doubling",
                    choices=["doubling", "while", "linear", "matmul"])
    ap.add_argument("--no-paths", action="store_true")
    ap.add_argument("--no-clique", action="store_true")
    ap.add_argument("--no-preprocess", action="store_true")
    ap.add_argument("--reconstruct", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--heuristics", type=int, default=0, metavar="N",
                    help="anytime bounds-improver rounds applied at plan "
                         "time (randomized elimination sweeps + contraction "
                         "lower bounds, DESIGN.md §15); tightens the ladder, "
                         "never the verdict")
    ap.add_argument("--seed", type=int, default=0,
                    help="pins every heuristic draw (clique restarts, "
                         "randomized sweeps, contractions)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    if args.impl is not None:
        print("[solve] --impl is deprecated; use --backend", file=sys.stderr)
        args.backend = args.impl

    from repro.core import backend as backend_lib
    from repro.core import distributed as dist_lib
    from repro.core import graph as graph_lib
    from repro.core import solver as solver_lib

    # fail on unsupported backend/flag combos here, with an actionable
    # message, instead of deep inside a jit
    try:
        backend_lib.validate(args.backend, mode=args.mode,
                             schedule=args.schedule, use_mmw=args.mmw,
                             use_simplicial=args.simplicial,
                             lanes=args.batch, shards=args.shards)
    except backend_lib.BackendCapabilityError as e:
        print(f"[solve] unsupported configuration: {e}", file=sys.stderr)
        return 2

    if args.dimacs:
        g = graph_lib.read_dimacs(args.dimacs)
    elif args.graph in graph_lib.REGISTRY:
        g = graph_lib.REGISTRY[args.graph]()
    else:
        print(f"unknown graph {args.graph!r}; known: "
              f"{sorted(graph_lib.REGISTRY)}")
        return 2

    print(f"[solve] {g.name}: n={g.n} m={g.n_edges}", flush=True)
    if args.distributed and args.batch > 1:
        print("[solve] --batch applies to the single-device solver only; "
              "ignoring it under --distributed", file=sys.stderr)
    if args.distributed:
        mesh = dist_lib.make_solver_mesh()
        cap = args.cap if args.cap is not None else 1 << 18
        kw = {}
        if args.donate_ratio is not None:
            kw["donate_ratio"] = args.donate_ratio
        res = dist_lib.solve_distributed(
            g, mesh, cap_local=cap // max(1, mesh.devices.size),
            block=args.block, use_mmw=args.mmw,
            use_simplicial=args.simplicial,
            schedule=args.schedule, backend=args.backend,
            use_clique=not args.no_clique, use_paths=not args.no_paths,
            use_preprocess=not args.no_preprocess, verbose=args.verbose,
            engine=args.engine, **kw)
    else:
        res = solver_lib.solve(
            g, cap=args.cap, block=args.block, mode=args.mode,
            use_mmw=args.mmw, backend=args.backend, schedule=args.schedule,
            use_simplicial=args.simplicial,
            use_clique=not args.no_clique, use_paths=not args.no_paths,
            use_preprocess=not args.no_preprocess,
            reconstruct=args.reconstruct, verbose=args.verbose,
            engine=args.engine, lanes=args.batch, shards=args.shards,
            donate_ratio=args.donate_ratio,
            heuristics=args.heuristics, seed=args.seed)

    print(f"[solve] treewidth={res.width} exact={res.exact} "
          f"lb={res.lb} ub={res.ub} states_expanded={res.expanded} "
          f"time={res.time_sec:.2f}s")
    if res.order is not None:
        width = solver_lib.order_width(g, res.order)
        print(f"[solve] elimination order verified: width={width}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
