"""Restart-on-failure supervisor: the single-host stand-in for a cluster
controller.  Wraps any launch command; non-zero exits trigger a relaunch
(bounded count), and the wrapped trainer resumes from its newest checkpoint.

    python -m repro.launch.supervisor --max-restarts 3 -- \
        python -m repro.launch.train --arch qwen3-0.6b --reduced ...
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--backoff-sec", type=float, default=0.5)
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args(argv)
    cmd = args.cmd
    if cmd and cmd[0] == "--":
        cmd = cmd[1:]
    assert cmd, "no command given"

    attempts = 0
    while True:
        print(f"[supervisor] launch attempt {attempts}: {' '.join(cmd)}",
              flush=True)
        rc = subprocess.run(cmd).returncode
        if rc == 0:
            print("[supervisor] success", flush=True)
            return 0
        attempts += 1
        print(f"[supervisor] exit code {rc} "
              f"(attempt {attempts}/{args.max_restarts})", flush=True)
        if attempts > args.max_restarts:
            print("[supervisor] giving up", flush=True)
            return rc
        time.sleep(args.backoff_sec)


if __name__ == "__main__":
    sys.exit(main())
