"""Serving driver: batched requests through the continuous-batching
scheduler.

    python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 12 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve.engine import Engine
from repro.serve.scheduler import Request, Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: {model.n_params()/1e6:.1f}M params, "
          f"{args.slots} slots", flush=True)

    engine = Engine(model, batch=args.slots, cache_len=args.cache_len)
    sched = Scheduler(engine, params)

    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    for r in range(args.requests):
        plen = rng.randint(args.prompt_len // 2, args.prompt_len + 1)
        prompt = rng.randint(0, cfg.vocab, size=(plen,)).astype(np.int32)
        sched.submit(Request(rid=r, prompt=prompt,
                             max_tokens=args.max_new))
    done = sched.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done.values())
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for rid in sorted(done)[:4]:
        print(f"  req {rid}: {done[rid].output[:8]}...")
    return 0


if __name__ == "__main__":
    main()
