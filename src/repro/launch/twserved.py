"""Persistent treewidth solve service: a long-lived socket front end over
the async scheduler.

``twserve`` (the sibling CLI) drains one request stream and exits;
``twserved`` is the serving rung the ROADMAP asked for — a process that
stays up, admits requests *while dispatches are in flight* (the
scheduler's launch/sync overlap, DESIGN.md §11), and streams per-rung
anytime lb/ub verdicts to clients before the final width is decided.

    python -m repro.launch.twserved --port 7421 --lanes 4 --block 32

Protocol: newline-delimited JSON over TCP (scriptable from ``nc``; see
``repro.serve.client`` for the reference client).  One request object
per line:

    {"op": "submit", "graph": "petersen"}            -> {"ok": true, "rid": 0}
    {"op": "submit", "n": 4, "edges": [[0,1],[1,2],[2,3]],
     "mode": "bloom", "speculate": 2}                -> {"ok": true, "rid": 1}
    {"op": "submit", "graph": "queen5", "priority": 1,
     "deadline_s": 2.5}                              -> {"ok": true, "rid": 2}
    {"op": "submit", "graph": "queen5", "shards": 4} -> {"ok": true, "rid": 3}
    {"op": "status", "rid": 0}   -> {"ok": true, "state": "running", "lb": 2, "ub": 4}
    {"op": "stream", "rid": 0}   -> one event per line, ends with a terminal
                                    event ({"event": "done" | "cancelled" | "error"})
    {"op": "result", "rid": 0}   -> blocks -> {"ok": true, "result": {"width": ...}}
    {"op": "cancel", "rid": 0}   -> {"ok": true, "cancelled": true}
    {"op": "metrics"}            -> {"ok": true, "pool": {...}, "requests": {...}}
    {"op": "metrics", "rid": 0}  -> same, "requests" filtered to rid 0
    {"op": "cache_stats"}        -> {"ok": true, "enabled": true, "hits": 3, ...}
    {"op": "shutdown"}           -> {"ok": true}  (drains in-flight, exits)

Result cache (DESIGN.md §16): the server keeps a content-addressed
cache of finished solves keyed on the *canonical* graph form × the
effective config (``--cache N`` entries, LRU; 0 disables).  A repeat
submission — even an isomorphically relabeled one — resolves at submit
time with a synthesized event stream flagged ``"cached": true`` and
never touches the queue or the device; ``"no_cache": true`` on a submit
line forces a fresh solve and suppresses insertion.  ``cache_stats``
returns the hit/miss/eviction counters.

``metrics`` returns the scheduler's scoped telemetry snapshot
(``TwScheduler.metrics``): pool-level counters/gauges/timings plus the
per-request child scopes — live requests snapshotted in place, finished
ones as frozen at their terminal event.  ``--metrics-jsonl PATH``
additionally streams every telemetry record (one JSON line each) to a
file for offline analysis.

Traffic shaping (DESIGN.md §12): ``--max-queue`` bounds the admission
queue — an over-limit submit is *rejected*, not queued::

    {"ok": false, "error": "admission queue full ...", "retry_after": 1.5}

``priority`` (higher = more urgent, weighted FIFO — the base class is
never starved) and ``deadline_s`` (seconds; past it the request is
preempted and resolves with its monotone anytime lb/ub, ``exact`` false,
``timed_out`` true) ride the submit line like any other knob;
``--pipeline 2`` keeps a second dispatch round in flight so the device
stays busy across each host sync.

Anytime bounds engine (DESIGN.md §15): ``heuristics`` budgets the
improver rounds interleaved with a request's exact rungs (``bounds``
events stream every movement), ``heuristic_only: true`` serves bounds
without any exact rung — graphs beyond exact-DP reach terminate with
``exact = (lb == ub)`` — and ``seed`` pins the heuristic draws::

    {"op": "submit", "graph": "mcgee", "heuristic_only": true,
     "heuristics": 8, "seed": 7}                     -> {"ok": true, "rid": 4}

Architecture: one **driver thread** owns all JAX work and steps the
scheduler (``launch`` → ``poll_admissions`` → ``sync``); socket threads
(one per connection, stdlib ``socketserver``) only call the scheduler's
thread-safe ``submit``/``status`` surface and read per-request event
queues — so a submission landing during a device dispatch is admitted
mid-flight and packed into the next one.  A per-request override the
backend cannot run fails that submit alone ({"ok": false, "error":
"..."}); the pool keeps serving.
"""
from __future__ import annotations

import argparse
import json
import socketserver
import sys
import threading
import traceback
from typing import Callable, Dict, Optional

DEFAULT_PORT = 7421

# finished requests retained for status/result/stream replay before the
# oldest are evicted (bounds a long-lived server's memory)
DEFAULT_KEEP_RESULTS = 1024


# event names that end a request's stream (mirrors the scheduler's
# terminal model: done covers deadline expiry via ``timed_out``)
_TERMINAL_EVENTS = ("done", "cancelled", "error")


class _EventLog:
    """Append-only per-request event history with blocking iteration —
    the bridge between the driver thread (producer) and any number of
    ``stream``/``result`` connections (consumers, each replaying from
    the start).  ``closed`` flips when the terminal event lands;
    ``readers`` counts registered consumers — eviction must skip a log
    that is unclosed or still being read (``TwServer._evict``), or a
    blocked reader would see a finished solve vanish under it."""

    def __init__(self):
        self.events = []
        self.cond = threading.Condition()
        self.readers = 0
        self.closed = False

    def push(self, ev: dict) -> None:
        with self.cond:
            self.events.append(ev)
            if ev.get("event") in _TERMINAL_EVENTS:
                self.closed = True
            self.cond.notify_all()

    def acquire(self) -> None:
        with self.cond:
            self.readers += 1

    def release(self) -> None:
        with self.cond:
            self.readers -= 1

    @property
    def busy(self) -> bool:
        with self.cond:
            return self.readers > 0

    def iter_events(self, stopped: Callable[[], bool]):
        """Yield events in order until the terminal one; ``stopped()`` is
        the give-up probe — during a shutdown *drain* it must stay False
        so blocked consumers still receive the results of admitted
        work."""
        i = 0
        while True:
            with self.cond:
                while i >= len(self.events):
                    if stopped():
                        return
                    self.cond.wait(timeout=0.2)
            ev = self.events[i]
            i += 1
            yield ev
            if ev.get("event") in _TERMINAL_EVENTS:
                return


def _wire_to_graph(msg: dict):
    from repro.core import graph as graph_lib

    if "graph" in msg:
        name = msg["graph"]
        if name not in graph_lib.REGISTRY:
            raise ValueError(f"unknown graph {name!r}; known: "
                             f"{sorted(graph_lib.REGISTRY)}")
        return graph_lib.REGISTRY[name]()
    if "n" in msg:
        return graph_lib.from_edges(int(msg["n"]), msg.get("edges", []),
                                    name=msg.get("name", "wire"))
    raise ValueError('submit needs "graph": <registry name> or '
                     '"n" + "edges"')


_KNOBS = ("reconstruct", "start_k", "mode", "use_mmw", "use_simplicial",
          "cap", "speculate", "shards", "priority", "deadline_s",
          "heuristics", "heuristic_only", "seed", "no_cache")


class TwServer:
    """The persistent service: scheduler + driver thread + TCP front end.

    Built separately from ``main`` so tests can run it in-process::

        srv = TwServer(port=0, lanes=2, block=32)   # port 0: ephemeral
        srv.start()
        ... TwClient(port=srv.port) ...
        srv.close()
    """

    def __init__(self, *, host: str = "127.0.0.1", port: int = DEFAULT_PORT,
                 keep_results: int = DEFAULT_KEEP_RESULTS,
                 metrics_jsonl=None, **sched_kw):
        from repro.core import telemetry
        from repro.serve.twscheduler import TwScheduler

        self.sched = TwScheduler(**sched_kw)
        self._metrics_sink = None
        if metrics_jsonl is not None:
            # stream every telemetry record of this pool's scope tree
            # (pool + per-request children) as JSON lines
            self._metrics_sink = telemetry.JsonlSink(metrics_jsonl)
            self.sched.tracker.add_sink(self._metrics_sink)
        self.keep_results = max(1, int(keep_results))
        self._logs: Dict[int, _EventLog] = {}
        self._logs_lock = threading.Lock()   # _logs map + eviction vs readers
        self._stop = threading.Event()
        self._wake = threading.Condition()
        self._driver: Optional[threading.Thread] = None

        outer = self

        class _Handler(socketserver.StreamRequestHandler):
            def handle(self):
                line = self.rfile.readline()
                if not line:
                    return
                try:
                    msg = json.loads(line)
                    outer._handle(msg, self.wfile)
                except Exception as e:      # noqa: BLE001 — wire boundary
                    _send(self.wfile, {"ok": False, "error": str(e)})

        class _TCP(socketserver.ThreadingTCPServer):
            allow_reuse_address = True
            daemon_threads = True

        self._tcp = _TCP((host, port), _Handler)
        self.host, self.port = self._tcp.server_address[:2]

    # ------------------------------------------------------------ lifecycle

    def start(self) -> None:
        """Spawn the driver and acceptor threads; returns immediately."""
        self._driver = threading.Thread(target=self._drive,
                                        name="twserved-driver", daemon=True)
        self._driver.start()
        self._acceptor = threading.Thread(target=self._tcp.serve_forever,
                                          name="twserved-accept",
                                          daemon=True)
        self._acceptor.start()

    def close(self) -> None:
        """Stop accepting, drain the driver, release the socket."""
        self._stop.set()
        with self._wake:
            self._wake.notify_all()
        self._tcp.shutdown()
        self._tcp.server_close()
        if self._driver is not None:
            self._driver.join(timeout=30)
        if self._metrics_sink is not None:
            self._metrics_sink.close()

    def serve_until_shutdown(self) -> None:
        """Block the calling thread until a shutdown request arrives."""
        self._stop.wait()
        self.close()

    # --------------------------------------------------------------- driver

    def _drive(self):
        """The one thread that owns JAX: overlapped scheduler steps while
        busy, condition-wait while idle.  A raising step must never kill
        the only thread that advances the pool — it is logged, the
        scheduler recovers its in-flight state, and driving resumes."""
        while not self._stop.is_set():
            try:
                stepped = self.sched.step()
                self._evict()
            except Exception:        # noqa: BLE001 — keep the pool alive
                traceback.print_exc()
                self.sched.recover()
                self._stop.wait(timeout=0.5)    # never a hot error loop
                continue
            if not stepped:
                with self._wake:
                    self._wake.wait(timeout=0.2)
        # drain: finish what was admitted before the shutdown request
        try:
            self.sched.run()
        except Exception:            # noqa: BLE001
            traceback.print_exc()
            self.sched.recover()

    def _evict(self):
        """Bound a long-lived server's memory: keep only the newest
        ``keep_results`` *terminal* requests' results/event logs (evicted
        rids answer ``status``/``result``/``stream`` as unknown).  A log
        that is not yet closed (its terminal event has not been
        delivered) or that a blocked ``stream``/``result`` reader is
        still draining is skipped this pass — evicting it would turn a
        finished solve into a bogus "server shut down" error for that
        reader."""
        sched = self.sched
        with self._logs_lock:
            term = sched.terminal
            if len(term) <= self.keep_results:
                return
            for rid in sorted(term)[:len(term) - self.keep_results]:
                log = self._logs.get(rid)
                if log is not None and (log.busy or not log.closed):
                    continue
                term.pop(rid, None)
                sched.done.pop(rid, None)
                sched.errors.pop(rid, None)
                sched.req_metrics.pop(rid, None)
                self._logs.pop(rid, None)

    def _reader(self, rid: int) -> _EventLog:
        """Look up a request's event log and register as a reader in one
        atomic step (vs ``_evict``), so the log cannot be evicted between
        the lookup and the registration."""
        with self._logs_lock:
            log = self._logs.get(rid)
            if log is None:
                raise ValueError(f"unknown rid {rid}")
            log.acquire()
        return log

    def _stopped_and_drained(self) -> bool:
        """The give-up probe for blocked stream/result consumers: only
        after the shutdown drain finished can a missing done event never
        arrive."""
        return self._stop.is_set() and not (
            self._driver is not None and self._driver.is_alive())

    # ------------------------------------------------------------- protocol

    def _handle(self, msg: dict, wfile):
        op = msg.get("op")
        if op == "ping":
            _send(wfile, {"ok": True})
        elif op == "submit":
            if self._stop.is_set():
                raise RuntimeError("server is shutting down")
            from repro.serve.slots import QueueFull

            g = _wire_to_graph(msg)
            knobs = {k: msg[k] for k in _KNOBS if msg.get(k) is not None}
            log = _EventLog()
            try:
                rid = self.sched.submit(g, on_event=log.push, **knobs)
            except QueueFull as e:        # backpressure: shed with a hint
                _send(wfile, {"ok": False, "error": str(e),
                              "retry_after": e.retry_after})
                return
            with self._logs_lock:
                self._logs[rid] = log
            with self._wake:
                self._wake.notify_all()
            _send(wfile, {"ok": True, "rid": rid})
        elif op == "status":
            _send(wfile, {"ok": True, **self.sched.status(_rid(msg))})
        elif op == "metrics":
            rid = int(msg["rid"]) if msg.get("rid") is not None else None
            _send(wfile, {"ok": True, **self.sched.metrics(rid)})
        elif op == "cache_stats":
            _send(wfile, {"ok": True, **self.sched.cache_stats()})
        elif op == "cancel":
            cancelled = self.sched.cancel(_rid(msg))
            with self._wake:
                self._wake.notify_all()
            _send(wfile, {"ok": True, "cancelled": cancelled})
        elif op == "stream":
            log = self._reader(_rid(msg))
            try:
                for ev in log.iter_events(self._stopped_and_drained):
                    _send(wfile, {"ok": True, **ev})
            finally:
                log.release()
        elif op == "result":
            rid = _rid(msg)
            log = self._reader(rid)
            try:
                for _ev in log.iter_events(self._stopped_and_drained):
                    pass                  # block until the terminal event
                res = self.sched.done.get(rid)
                if res is None:
                    t = self.sched.terminal.get(rid)
                    if t == "cancelled":
                        raise RuntimeError(f"request {rid} was cancelled")
                    if t == "error":
                        raise RuntimeError(self.sched.errors.get(
                            rid, f"request {rid} failed at admission"))
                    # shutdown hit before this solve
                    raise RuntimeError("server shut down before the result")
                out = {"width": res.width, "exact": res.exact,
                       "lb": res.lb, "ub": res.ub,
                       "expanded": res.expanded, "order": res.order,
                       "per_k": res.per_k}
                if self.sched.terminal.get(rid) == "timeout":
                    out["timed_out"] = True
                _send(wfile, {"ok": True, "result": out})
            finally:
                log.release()
        elif op == "shutdown":
            _send(wfile, {"ok": True})
            self._stop.set()
            with self._wake:
                self._wake.notify_all()
            # shut the acceptor down from a side thread (we are inside a
            # handler of this very server)
            threading.Thread(target=self._tcp.shutdown, daemon=True).start()
        else:
            raise ValueError(f"unknown op {op!r}")


def _jsonable(x):
    """json.dumps ``default=``: numpy/jax scalars and arrays (a result's
    ``order``, ``per_k`` counters, event payload fields) coerce to plain
    Python values instead of killing the wire response."""
    if hasattr(x, "tolist"):
        return x.tolist()
    if hasattr(x, "item"):
        return x.item()
    raise TypeError(f"not JSON serializable: {type(x).__name__}")


def _send(wfile, obj: dict) -> None:
    try:
        wfile.write((json.dumps(obj, default=_jsonable) + "\n").encode())
        wfile.flush()
    except (BrokenPipeError, ConnectionResetError):
        pass                        # client went away mid-stream


def _rid(msg: dict) -> int:
    if "rid" not in msg:
        raise ValueError('missing "rid"')
    return int(msg["rid"])


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="persistent treewidth solve service (JSON lines/TCP)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=DEFAULT_PORT,
                    help=f"TCP port (default {DEFAULT_PORT}; 0 = ephemeral)")
    ap.add_argument("--lanes", type=int, default=8,
                    help="lane pool size: max requests per shared dispatch")
    ap.add_argument("--cap", type=int, default=None,
                    help="frontier rows per lane (power of two). Default: "
                         "auto via batch.plan_capacity")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="bound the pooled frontier memory; 0 reads the "
                         "device's free-memory stats")
    ap.add_argument("--block", type=int, default=1 << 11)
    ap.add_argument("--mode", default="sort", choices=["sort", "bloom"])
    ap.add_argument("--mmw", action="store_true")
    ap.add_argument("--simplicial", action="store_true")
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"])
    ap.add_argument("--schedule", default=None,
                    choices=["doubling", "while", "linear", "matmul"])
    ap.add_argument("--no-preprocess", action="store_true")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue; over-limit submits "
                         "are rejected with a retry_after hint")
    ap.add_argument("--pipeline", type=int, default=1,
                    help="dispatch pipeline depth: rounds kept in flight "
                         "before a sync is forced (2 hides host syncs)")
    ap.add_argument("--prio-weight", type=int, default=4,
                    help="weighted-FIFO anti-starvation ratio: preferential "
                         "admissions per base-class admission")
    ap.add_argument("--donate-ratio", type=float, default=None,
                    help="work-donation trigger for sharded requests "
                         "(submit knob \"shards\"): rebalance when the "
                         "max shard exceeds ratio x mean occupancy "
                         "(default core.shard.DEFAULT_DONATE_RATIO)")
    ap.add_argument("--cache", type=int, default=256, metavar="N",
                    help="content-addressed result cache entries (LRU; "
                         "0 disables). Isomorphic resubmissions resolve "
                         "at submit without touching the device; the "
                         "cache_stats op and the no_cache submit knob "
                         "expose/bypass it (DESIGN.md §16)")
    ap.add_argument("--keep-results", type=int,
                    default=DEFAULT_KEEP_RESULTS,
                    help="finished requests retained for status/result/"
                         "stream replay before the oldest are evicted")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append every telemetry record of the pool's "
                         "scope tree to PATH as JSON lines (the metrics "
                         "op returns snapshots; this streams the raw "
                         "mutation log)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.core import backend as backend_lib

    budget = None
    if args.budget_mb is not None:
        budget = "auto" if args.budget_mb == 0 \
            else int(args.budget_mb * 2**20)
    try:
        srv = TwServer(host=args.host, port=args.port,
                       keep_results=args.keep_results,
                       metrics_jsonl=args.metrics_jsonl,
                       lanes=args.lanes,
                       cap=args.cap, block=args.block, mode=args.mode,
                       use_mmw=args.mmw, use_simplicial=args.simplicial,
                       backend=args.backend, schedule=args.schedule,
                       use_preprocess=not args.no_preprocess,
                       max_queue=args.max_queue, pipeline=args.pipeline,
                       prio_weight=args.prio_weight,
                       donate_ratio=args.donate_ratio,
                       budget_bytes=budget, cache=args.cache,
                       verbose=args.verbose)
    except backend_lib.BackendCapabilityError as e:
        print(f"[twserved] unsupported pool configuration: {e}",
              file=sys.stderr)
        return 2
    srv.start()
    print(f"[twserved] listening on {srv.host}:{srv.port} "
          f"(lanes={args.lanes}, backend={args.backend}, mode={args.mode})",
          flush=True)
    try:
        srv.serve_until_shutdown()
    except KeyboardInterrupt:
        srv.close()
    print("[twserved] shut down", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
