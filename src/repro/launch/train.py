"""Training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Resumes automatically from the newest checkpoint in --ckpt-dir; pair with
launch/supervisor.py for restart-on-crash.  --crash-at-step N injects a
failure for the fault-tolerance test.  Data is counter-based synthetic, so
restarts replay the stream exactly.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config, reduced
from repro.data.synthetic import SyntheticLM
from repro.models import Model
from repro.train import step as step_lib
from repro.train.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--crash-at-step", type=int, default=-1,
                    help="fault injection for supervisor tests")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps, microbatch=args.microbatch,
                       optimizer=args.optimizer)
    model = Model(cfg)
    print(f"[train] {cfg.name}: {model.n_params()/1e6:.1f}M params",
          flush=True)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=tcfg.seed)
    step_fn = jax.jit(step_lib.build_train_step(model, tcfg),
                      donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        abstract = step_lib.abstract_state(model, tcfg)
        state, start = mgr.restore(abstract)
        print(f"[train] resumed from step {start}", flush=True)
    else:
        state = step_lib.init_state(model, jax.random.PRNGKey(tcfg.seed),
                                    tcfg)

    marker = (os.path.join(args.ckpt_dir, ".crash_injected")
              if args.ckpt_dir else "")
    t0 = time.time()
    for step in range(start, args.steps):
        if step == args.crash_at_step and not (
                marker and os.path.exists(marker)):
            # one-shot fault injection: mark so the restarted run proceeds
            if marker:
                with open(marker, "w") as f:
                    f.write(str(step))
            if mgr is not None:
                # the injection simulates a crash *after* the last
                # checkpoint became durable (what the restart test
                # verifies); without this join the daemon writer thread
                # races the exit and the restart nondeterministically
                # finds no checkpoint (a real mid-write crash is still
                # safe — .tmp dirs are ignored — just not resumable)
                mgr.wait()
            print(f"[train] injected crash at step {step}", flush=True)
            raise SystemExit(17)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        front = _frontends(cfg, args.batch)
        batch.update(front)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(state, step + 1)          # async
    if mgr is not None:
        mgr.save(state, args.steps, blocking=True)
    print("[train] done", flush=True)
    return state


def _frontends(cfg, batch):
    out = {}
    if cfg.frontend == "audio":
        out["enc_embeds"] = jnp.zeros((batch, cfg.encoder_len, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision":
        out["prefix_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


if __name__ == "__main__":
    main()
