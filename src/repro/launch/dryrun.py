import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# init.  The dry-run (and only the dry-run) builds the production mesh out
# of 512 placeholder host devices; tests/benches keep 1 device.

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp                                    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, TrainConfig, applicable,
                           get_config, input_specs)         # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models import Model                              # noqa: E402
from repro.sharding import rules as rules_lib               # noqa: E402
from repro.train import step as step_lib                    # noqa: E402
from repro.utils import compat                              # noqa: E402
from repro.utils import hlo as hlo_lib                      # noqa: E402
from repro.utils import hlo2 as hlo2_lib                    # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")


def dryrun_config(arch: str, constrain: bool = False):
    """bf16 compute for the roofline target (197 TF/s bf16 peak)."""
    cfg = get_config(arch).replace(dtype="bfloat16", param_dtype="bfloat16")
    if constrain:
        cfg = cfg.replace(constrain_acts=True)
    return cfg


def tcfg_for(cfg) -> TrainConfig:
    n = Model(cfg).n_params()
    opt = "adafactor" if n > 100e9 else "adamw"
    micro = 8 if n > 100e9 else (4 if n > 8e9 else 0)
    remat = cfg.remat if cfg.remat != "none" else \
        ("dots" if n > 2e9 else "none")
    return TrainConfig(optimizer=opt, microbatch=micro), remat


def _front_kw(cfg, specs):
    kw = {}
    if "enc_embeds" in specs:
        kw["enc_embeds"] = specs["enc_embeds"]
    if "prefix_embeds" in specs:
        kw["prefix_embeds"] = specs["prefix_embeds"]
    return kw


def lower_cell(arch: str, shape_name: str, mesh, constrain: bool = False,
               gather_once: bool = False, remat_override: str = "",
               micro_override: int = -1):
    cfg = dryrun_config(arch, constrain)
    if remat_override:
        cfg = cfg.replace(remat=remat_override)
    if constrain or gather_once:
        compat.set_mesh(mesh)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}
    model = Model(cfg)
    specs = input_specs(cfg, shape)
    bsh = rules_lib.batch_shardings_for(specs, mesh)

    if shape.kind == "train":
        tcfg, remat = tcfg_for(cfg)
        if remat_override:
            remat = remat_override
        import dataclasses as _dc
        if gather_once:
            tcfg = _dc.replace(tcfg, gather_once=True)
        if micro_override >= 0:
            tcfg = _dc.replace(tcfg, microbatch=micro_override)
        if remat != cfg.remat:
            cfg = cfg.replace(remat=remat)
            model = Model(cfg)
        state_abs = step_lib.abstract_state(model, tcfg)
        state_sh = step_lib.state_shardings(model, tcfg, mesh)
        fn = step_lib.build_train_step(model, tcfg)
        jitted = jax.jit(fn, in_shardings=(state_sh, bsh),
                         out_shardings=(state_sh, None))
        lowered = jitted.lower(state_abs, specs)
    else:
        params_abs = model.abstract()
        params_sh = rules_lib.param_shardings(model.spec, mesh)
        cache_len = shape.seq_len
        b = shape.global_batch
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(b, cache_len))
        cache_sh = rules_lib.cache_shardings(cache_abs, mesh)
        if shape.kind == "prefill":
            def fn(params, cache, batch):
                kw = _front_kw(cfg, batch)
                logits, cache, _ = model.apply(
                    params, batch["tokens"], mode="prefill", cache=cache,
                    **kw)
                return logits[:, -1], cache
        else:
            def fn(params, cache, batch):
                logits, cache, _ = model.apply(
                    params, batch["tokens"], mode="decode", cache=cache,
                    pos=batch["pos"])
                return logits[:, 0], cache
        jitted = jax.jit(fn, in_shardings=(params_sh, cache_sh, bsh),
                         out_shardings=(None, cache_sh))
        lowered = jitted.lower(params_abs, cache_abs, specs)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis_dict(compiled)
    text = compiled.as_text()
    coll = hlo_lib.collective_bytes(text)            # body-once (raw)
    coll_scaled = hlo2_lib.collective_bytes_scaled(text)  # x trip counts
    n_devices = mesh.devices.size
    result = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_devices),
        "n_params": Model(cfg).n_params(),
        "compile_sec": round(compile_s, 1),
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
        "collectives": {k: float(v) for k, v in coll.items()},
        "collectives_scaled": {k: float(v) for k, v in coll_scaled.items()},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", -1),
        },
        "hlo_ops": {
            k: hlo_lib.count_ops(text, k)
            for k in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute", "while", "fusion")
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--constrain", action="store_true",
                    help="activation sharding constraints (PERF variant)")
    ap.add_argument("--gather-once", action="store_true",
                    help="hoist FSDP param all-gather out of microbatching")
    ap.add_argument("--tp", type=int, default=0,
                    help="override model-axis size (mesh 256/tp x tp)")
    ap.add_argument("--remat", default="",
                    help="override remat policy (none|dots|full)")
    ap.add_argument("--microbatch", type=int, default=-1,
                    help="override microbatch count")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    for multi_pod in meshes:
        if args.tp:
            mesh = jax.make_mesh((256 // args.tp, args.tp),
                                 ("data", "model"))
            mesh_name = f"{256 // args.tp}x{args.tp}"
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
            mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape_name in shapes:
                suffix = ""
                if args.constrain:
                    suffix += "__opt"
                if args.gather_once:
                    suffix += "__g1"
                if args.remat:
                    suffix += f"__r{args.remat}"
                if args.microbatch >= 0:
                    suffix += f"__m{args.microbatch}"
                tag = f"{arch}__{shape_name}__{mesh_name}" + suffix
                path = os.path.join(args.out, tag + ".json")
                t0 = time.time()
                try:
                    res = lower_cell(arch, shape_name, mesh,
                                     constrain=args.constrain,
                                     gather_once=args.gather_once,
                                     remat_override=args.remat,
                                     micro_override=args.microbatch)
                except Exception as e:            # noqa: BLE001
                    res = {"status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                res["wall_sec"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    mem = res["memory"]
                    extra = (f" flops/dev={res['flops_per_device']:.3e}"
                             f" coll={res['collectives_scaled']['wire_bytes']:.3e}B"
                             f" mem[args={mem['argument_bytes']:.2e}"
                             f" temp={mem['temp_bytes']:.2e}"
                             f" out={mem['output_bytes']:.2e}]B"
                             f" compile={res['compile_sec']}s")
                elif status == "error":
                    extra = " " + res["error"][:120]
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
