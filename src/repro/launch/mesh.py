"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests / benches keep their single CPU
device while the dry-run forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e pod slice); multi-pod adds a leading 'pod'
    axis of 2 (512 chips).  Axis roles: pod = pure DP (one grad all-reduce
    per step), data = FSDP/DP, model = TP/EP/SP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist, as (data, model) — for tests/examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
