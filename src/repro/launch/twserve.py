"""Treewidth solve service CLI: a request stream through the
continuous-batching lane scheduler (``repro.serve.twscheduler``).

    python -m repro.launch.twserve --graphs myciel3,petersen,queen5_5
    python -m repro.launch.twserve --graphs myciel4 --repeat 4 --lanes 4
    python -m repro.launch.twserve --random 8 --lanes 8 --backend pallas
    python -m repro.launch.twserve --graphs queen5_5,myciel3 --compare

Every request is one graph; the scheduler packs all in-flight requests'
current deepening rungs into shared multi-lane dispatches (DESIGN.md
§10).  ``--compare`` additionally runs the same stream through
sequential per-request ``solver.solve`` calls, asserts result parity,
and reports the dispatch/sync reduction.

This CLI drains one fixed stream and exits; for the long-lived service
process (submit over TCP while dispatches are in flight, per-request
knobs, streamed rung events) see ``repro.launch.twserved`` and its
client ``repro.serve.client``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", default="",
                    help="comma-separated generator names "
                         "(see core.graph.REGISTRY)")
    ap.add_argument("--random", type=int, default=0, metavar="N",
                    help="append N random gnp(n, p) requests")
    ap.add_argument("--n", type=int, default=14,
                    help="vertex count for --random instances")
    ap.add_argument("--p", type=float, default=0.3,
                    help="edge probability for --random instances")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=1,
                    help="submit the stream this many times")
    ap.add_argument("--lanes", type=int, default=8,
                    help="lane pool size: max requests per shared dispatch")
    ap.add_argument("--cap", type=int, default=None,
                    help="frontier rows per lane (power of two). Default: "
                         "auto — batch.plan_capacity right-sizes each "
                         "dispatch from its largest lane's drop-free state "
                         "bound, <= the old fixed 2^17 default")
    ap.add_argument("--cap-max", type=int, default=None,
                    help="clamp for the auto-sized --cap (default 2^17)")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="bound the whole lane pool's frontier memory; "
                         "pass 0 to read the device's free-memory stats")
    ap.add_argument("--block", type=int, default=1 << 11)
    ap.add_argument("--mode", default="sort", choices=["sort", "bloom"])
    ap.add_argument("--mmw", action="store_true")
    ap.add_argument("--simplicial", action="store_true",
                    help="enable simplicial-vertex branch collapse")
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"],
                    help="op implementations (repro.core.backend registry)")
    ap.add_argument("--schedule", default=None,
                    choices=["doubling", "while", "linear", "matmul"])
    ap.add_argument("--reconstruct", action="store_true",
                    help="request a certified elimination order per solve")
    ap.add_argument("--shards", type=int, default=1,
                    help="scale every request out across this many pool "
                         "slots (sharded frontier + work donation; must "
                         "be <= --lanes)")
    ap.add_argument("--donate-ratio", type=float, default=None,
                    help="work-donation trigger for sharded requests "
                         "(default core.shard.DEFAULT_DONATE_RATIO)")
    ap.add_argument("--no-preprocess", action="store_true")
    ap.add_argument("--compare", action="store_true",
                    help="also solve the stream sequentially; assert "
                         "parity and report the dispatch reduction")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.core import backend as backend_lib
    from repro.core import engine as engine_lib
    from repro.core import graph as graph_lib
    from repro.core import solver as solver_lib
    from repro.core.bitset import n_words as bitset_words
    from repro.serve.twscheduler import TwScheduler

    gs = []
    for name in filter(None, args.graphs.split(",")):
        if name not in graph_lib.REGISTRY:
            print(f"unknown graph {name!r}; known: "
                  f"{sorted(graph_lib.REGISTRY)}", file=sys.stderr)
            return 2
        gs.append(graph_lib.REGISTRY[name]())
    for i in range(args.random):
        gs.append(graph_lib.gnp(args.n, args.p, args.seed + i))
    gs = gs * max(1, args.repeat)
    if not gs:
        print("empty request stream: pass --graphs and/or --random",
              file=sys.stderr)
        return 2

    budget = None
    if args.budget_mb is not None:
        budget = "auto" if args.budget_mb == 0 \
            else int(args.budget_mb * 2**20)
    kw = dict(cap=args.cap, block=args.block, mode=args.mode,
              use_mmw=args.mmw, use_simplicial=args.simplicial,
              backend=args.backend, schedule=args.schedule,
              use_preprocess=not args.no_preprocess)
    if args.cap_max is not None:
        kw["cap_max"] = args.cap_max
    try:
        sched = TwScheduler(lanes=args.lanes, budget_bytes=budget,
                            donate_ratio=args.donate_ratio,
                            verbose=args.verbose, **kw)
    except backend_lib.BackendCapabilityError as e:
        print(f"[twserve] unsupported configuration: {e}", file=sys.stderr)
        return 2

    rids = [sched.submit(g, reconstruct=args.reconstruct,
                         shards=args.shards) for g in gs]
    engine_lib.reset_counters()
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    serve_counters = dict(engine_lib.COUNTERS)

    for rid, g in zip(rids, gs):
        r = done[rid]
        line = (f"[twserve] req {rid} ({g.name}): width={r.width} "
                f"exact={r.exact} lb={r.lb} ub={r.ub} "
                f"expanded={r.expanded}")
        if r.order is not None:
            line += f" order_width={solver_lib.order_width(g, r.order)}"
        print(line, flush=True)
    print(f"[twserve] {len(gs)} requests in {dt:.2f}s "
          f"({len(gs) / max(dt, 1e-9):.2f} req/s), "
          f"{sched.rounds} shared dispatches, "
          f"{serve_counters['dispatches']} total dispatches, "
          f"{serve_counters['host_syncs']} host syncs", flush=True)

    if args.compare:
        solve_kw = dict(kw)
        solve_kw.pop("cap_max", None)
        engine_lib.reset_counters()
        t0 = time.time()
        seq = [solver_lib.solve(g, reconstruct=args.reconstruct,
                                **solve_kw) for g in gs]
        seq_dt = time.time() - t0
        seq_counters = dict(engine_lib.COUNTERS)
        # bit-parity is only promised outside the §8/§10 padding caveats:
        # MMW sees padding rows, and bloom hashes over the padded word
        # count (lanes padded into a larger W than their solo run draw a
        # different Monte-Carlo false-positive set)
        one_word = len({bitset_words(g.n) for g in gs}) <= 1
        caveat_free = not args.mmw and (args.mode == "sort" or one_word)
        if caveat_free:
            for rid, g, a in zip(rids, gs, seq):
                b = done[rid]
                assert (a.width, a.exact, a.expanded) == \
                    (b.width, b.exact, b.expanded), (g.name, a, b)
            verdict = "parity OK"
        else:
            verdict = ("parity not asserted (MMW/bloom padding caveats, "
                       "DESIGN.md §10)")
        ratio = seq_counters["dispatches"] / \
            max(serve_counters["dispatches"], 1)
        print(f"[twserve] sequential: {seq_dt:.2f}s, "
              f"{seq_counters['dispatches']} dispatches -> {verdict}, "
              f"{ratio:.1f}x fewer dispatches batched", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
