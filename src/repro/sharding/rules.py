"""Logical-axis -> mesh-axis mapping with divisibility fallback.

2D "FSDP x TP" layout (MaxText-style):
  embed  -> data axis   (fully-sharded parameters across DP)
  heads/kv/mlp/vocab/expert -> model axis (tensor/expert parallel)
  pod    -> pure DP (params replicated across pods; one grad all-reduce)

A mapping is applied only when the dimension is divisible by the mesh axis
size and the mesh axis is not already consumed by another dimension of the
same tensor; otherwise the dimension falls back to replicated.  This is what
makes odd dimensions (25 heads in hymba, 49155-vocab before padding) lower
everywhere — at reduced efficiency, which the roofline table then exposes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import map_spec, Param

DEFAULT_RULES = {
    "embed": ("data",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "layers": (),
}


def _mesh_size(mesh: Mesh, names: Tuple[str, ...]) -> int:
    size = 1
    for nm in names:
        size *= mesh.shape[nm]
    return size


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh: Mesh, rules=None) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    parts = []
    for dim, ax in zip(shape, axes):
        target = ()
        if ax is not None:
            for cand in rules.get(ax, ()):
                if cand in mesh.shape and cand not in used \
                        and dim % _mesh_size(mesh, (cand,)) == 0:
                    target = target + (cand,)
                    used.add(cand)
                    break   # one mesh axis per dim in the default layout
        if len(target) == 0:
            parts.append(None)
        elif len(target) == 1:
            parts.append(target[0])
        else:
            parts.append(target)
    return P(*parts)


def param_shardings(spec_tree, mesh: Mesh, rules=None):
    """Tree of NamedSharding matching a Param spec tree."""
    return map_spec(
        lambda p: NamedSharding(mesh, spec_for(p.shape, p.axes, mesh, rules)),
        spec_tree)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh: Mesh, ndim: int = 2,
                   batch_size: Optional[int] = None) -> NamedSharding:
    """Shard the leading batch dim over (pod, data); replicate when the
    batch does not divide (e.g. long_500k's global batch of 1)."""
    dp = dp_axes(mesh)
    if batch_size is not None and batch_size % max(_mesh_size(mesh, dp), 1):
        return NamedSharding(mesh, P(*([None] * ndim)))
    return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))


def batch_shardings_for(specs: dict, mesh: Mesh) -> dict:
    return {k: batch_sharding(mesh, len(v.shape), v.shape[0])
            for k, v in specs.items()}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_shardings(cache_abs, mesh: Mesh):
    """Shardings for a stacked decode/prefill cache pytree.

    Entries are (reps, B, ...) — batch (dim 1) shards over DP; dim 2 shards
    over the model axis when divisible.  For KV caches dim 2 is the
    *sequence*: a 32k cache with kv_heads < model-axis size still spreads
    16-way (sequence-sharded attention — GSPMD inserts the partial-softmax
    reduces).  For SSM states dim 2 is d_inner, giving plain TP.  Heads that
    do divide (e.g. phi-3's 32 kv heads) are handled by the same rule since
    their dim-2 (seq) shards first; see §Perf for the head-sharded variant.
    """
    def one(leaf):
        shape = leaf.shape
        parts: list = [None] * len(shape)
        dp = dp_axes(mesh)
        dpn = _mesh_size(mesh, dp)
        if len(shape) >= 2 and dpn > 1 and shape[1] % dpn == 0:
            parts[1] = dp
        if len(shape) >= 3 and "model" in mesh.shape:
            msz = mesh.shape["model"]
            if shape[2] % msz == 0:
                parts[2] = "model"
        return NamedSharding(mesh, P(*parts))
    return jax.tree.map(one, cache_abs)
