"""Pallas TPU kernel: wavefront state expansion.

Replaces the paper's thread-per-(state, vertex) DFS (Listing 1, lines 7-19)
with a blocked, divergence-free bitset fixpoint executed on the VPU:

  * the packed adjacency matrix (n x W uint32, <= 8 KiB at n=256) is pinned
    in VMEM for every grid step (the analogue of the paper putting adjacency
    lists in constant memory);
  * each grid step processes a ``block`` of states resident in VMEM
    (the analogue of the work-group size knob from Table 2);
  * the component-closure doubling loop has a static trip count
    ceil(log2 n) — zero branch divergence by construction.

``reach_block`` is the factored kernel body: the closure/reach/degree math
shared with the fused wavefront kernel (``repro.kernels.wavefront``), which
composes it with feasibility masking and the pruning rules in one VMEM
pass.  This standalone kernel emits only deg_S(v); child construction /
dedup happen outside.  Validated in interpret mode against ``ref.expand_ref``
and the python DFS oracle (tests/test_kernels_expand.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

U32 = jnp.uint32


def reach_block(adj, states, *, n: int):
    """Closure + reach + degrees for a block of states, all in registers/VMEM.

    adj (n, W) uint32; states (B, W) uint32 ->
      (deg (B, n) int32, reach (B, n, W) uint32, q (B, n, W) uint32)
    where reach[b, v] is the eliminated-graph adjacency row of v under
    state b and q = reach \\ S \\ {v} (the paper's Q(S, v) set).
    Rows for v in S are garbage; callers mask them.
    """
    b, w = states.shape
    eye = common.eye_words(n, w)
    steps = common.log2_ceil(max(n, 2))

    s_bits = common.unpack(states, n)              # (B, n)
    masked_adj = adj[None, :, :] & states[:, None, :]      # N(i) ∩ S
    z = jnp.where(s_bits[:, :, None], masked_adj | eye[None], U32(0))

    for _ in range(steps):                         # static: no divergence
        z = z | common.bor_matmul(z, z, n)

    rows_adj = jnp.broadcast_to(adj[None], (b, n, w))
    nb = common.bor_matmul(z, rows_adj, n)         # N(component(i))
    reach = adj[None] | common.bor_matmul(masked_adj, nb, n)
    q = (reach & ~states[:, None, :]) & ~eye[None]
    deg = common.popcount(q)
    return deg, reach, q


def _expand_kernel(adj_ref, states_ref, deg_ref, *, n: int):
    deg, _reach, _q = reach_block(adj_ref[...], states_ref[...], n=n)
    deg_ref[...] = deg                             # (B, n)


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def expand_degrees_pallas(adj: jnp.ndarray, states: jnp.ndarray, *, n: int,
                          block: int = 16, interpret: bool = True):
    """deg_S(v) for every state row and vertex v.  states (B, W) must have
    B % block == 0 (callers pad; padding rows give garbage, mask outside)."""
    bt, w = states.shape
    assert bt % block == 0, (bt, block)
    grid = (bt // block,)
    kernel = functools.partial(_expand_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, 0)),        # adjacency: pinned
            pl.BlockSpec((block, w), lambda i: (i, 0)),    # states tile
        ],
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, n), jnp.int32),
        interpret=interpret,
    )(adj, states)
