"""Pallas TPU kernel: wavefront state expansion.

Replaces the paper's thread-per-(state, vertex) DFS (Listing 1, lines 7-19)
with a blocked, divergence-free bitset fixpoint executed on the VPU:

  * the packed adjacency matrix (n x W uint32, <= 8 KiB at n=256) is pinned
    in VMEM for every grid step (the analogue of the paper putting adjacency
    lists in constant memory);
  * each grid step processes a ``block`` of states resident in VMEM
    (the analogue of the work-group size knob from Table 2);
  * the component-closure doubling loop has a static trip count
    ceil(log2 n) — zero branch divergence by construction.

The kernel computes deg_S(v) for all (state, v) pairs in the block; child
construction / dedup happen outside (they are memory ops, not compute).
Validated in interpret mode against ``ref.expand_ref`` and the python DFS
oracle (tests/test_kernels_expand.py).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32


def _log2_ceil(n: int) -> int:
    b = 1
    while (1 << b) < n:
        b += 1
    return b


def _unpack(words, n):
    """(..., W) uint32 -> (..., n) bool."""
    idx = jnp.arange(n, dtype=jnp.int32)
    w = jnp.take(words, idx >> 5, axis=-1)
    return ((w >> (idx & 31).astype(U32)) & U32(1)).astype(jnp.bool_)


def _bor_matmul(mask, rows, n):
    """Batched OR-AND semiring product.

    mask (B, n, W), rows (B, n, W) -> out (B, n, W):
      out[b, i] = OR_j { rows[b, j] : bit j of mask[b, i] }.
    """
    bits = _unpack(mask, n)                        # (B, n, n)
    sel = jnp.where(bits[..., None], rows[:, None, :, :], U32(0))
    return jax.lax.reduce(sel, U32(0), jax.lax.bitwise_or, (2,))


def _eye_words(n, w):
    """Identity bitset matrix built from iota (Pallas kernels cannot capture
    host constants)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, w), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, w), 1)
    return jnp.where(cols == (rows >> 5),
                     U32(1) << (rows & 31).astype(U32), U32(0))


def _expand_kernel(adj_ref, states_ref, deg_ref, *, n: int, steps: int):
    adj = adj_ref[...]                             # (n, W)   VMEM-resident
    states = states_ref[...]                       # (B, W)
    b, w = states.shape
    eye = _eye_words(n, w)

    s_bits = _unpack(states, n)                    # (B, n)
    masked_adj = adj[None, :, :] & states[:, None, :]      # N(i) ∩ S
    z = jnp.where(s_bits[:, :, None], masked_adj | eye[None], U32(0))

    for _ in range(steps):                         # static: no divergence
        z = z | _bor_matmul(z, z, n)

    rows_adj = jnp.broadcast_to(adj[None], (b, n, w))
    nb = _bor_matmul(z, rows_adj, n)               # N(component(i))
    reach = adj[None] | _bor_matmul(masked_adj, nb, n)
    q = (reach & ~states[:, None, :]) & ~eye[None]
    deg = jnp.sum(jax.lax.population_count(q).astype(jnp.int32), axis=-1)
    deg_ref[...] = deg                             # (B, n)


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def expand_degrees_pallas(adj: jnp.ndarray, states: jnp.ndarray, *, n: int,
                          block: int = 16, interpret: bool = True):
    """deg_S(v) for every state row and vertex v.  states (B, W) must have
    B % block == 0 (callers pad; padding rows give garbage, mask outside)."""
    bt, w = states.shape
    assert bt % block == 0, (bt, block)
    grid = (bt // block,)
    steps = _log2_ceil(max(n, 2))
    kernel = functools.partial(_expand_kernel, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, 0)),        # adjacency: pinned
            pl.BlockSpec((block, w), lambda i: (i, 0)),    # states tile
        ],
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, n), jnp.int32),
        interpret=interpret,
    )(adj, states)
