"""Jit'd public wrapper for the expansion kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from .kernel import expand_degrees_pallas


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def expand_degrees(adj: jnp.ndarray, states: jnp.ndarray, *, n: int,
                   block: int = 16, interpret: bool | None = None):
    """Degrees deg_S(v) for a batch of states; pads the batch to the kernel
    block size and strips the padding again.

    adj: (n, W) uint32; states: (B, W) uint32 -> (B, n) int32.
    """
    if interpret is None:
        interpret = default_interpret()
    b, w = states.shape
    pad = (-b) % block
    if pad:
        states = jnp.concatenate(
            [states, jnp.zeros((pad, w), dtype=states.dtype)], axis=0)
    out = expand_degrees_pallas(adj, states, n=n, block=block,
                                interpret=interpret)
    return out[:b]
