"""Pure-jnp oracle for the expansion kernel.

Computes, for a block of states, the eliminated-graph degree of every
candidate vertex — identical math to ``repro.core.components`` (which is
itself validated against the paper's DFS oracle in tests), expressed here
standalone so the kernel test has a self-contained reference.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

U32 = jnp.uint32


def _unpack(words, n):
    idx = jnp.arange(n, dtype=jnp.int32)
    w = jnp.take(words, idx >> 5, axis=-1)
    return ((w >> (idx & 31).astype(U32)) & U32(1)).astype(jnp.bool_)


def _or_matmul(mask_words, rows, n):
    bits = _unpack(mask_words, n)
    sel = jnp.where(bits[..., None], rows, U32(0))
    return jax.lax.reduce(sel, U32(0), jax.lax.bitwise_or, (bits.ndim - 1,))


def _eye(n, w):
    out = np.zeros((n, w), dtype=np.uint32)
    idx = np.arange(n)
    out[idx, idx >> 5] = np.uint32(1) << (idx & 31).astype(np.uint32)
    return jnp.asarray(out)


def _log2_ceil(n):
    b = 1
    while (1 << b) < n:
        b += 1
    return b


def expand_ref(adj: jnp.ndarray, states: jnp.ndarray, n: int) -> jnp.ndarray:
    """adj (n, W) uint32, states (B, W) uint32 -> degrees (B, n) int32."""
    w = adj.shape[-1]
    eye = _eye(n, w)

    def one(s):
        s_bits = _unpack(s, n)
        z = jnp.where(s_bits[:, None], (adj & s[None, :]) | eye, U32(0))
        for _ in range(_log2_ceil(max(n, 2))):
            z = z | _or_matmul(z, z, n)
        nb = _or_matmul(z, adj, n)
        reach = adj | _or_matmul(adj & s[None, :], nb, n)
        q = (reach & ~s[None, :]) & ~eye
        return jnp.sum(jax.lax.population_count(q).astype(jnp.int32), axis=-1)

    return jax.vmap(one)(states)
