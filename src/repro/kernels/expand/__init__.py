from .ops import expand_degrees
from .ref import expand_ref
