"""Pallas TPU kernels for the paper's compute hot spots.

common.py   — shared capture-free in-kernel bitset helpers
expand/     — wavefront state expansion (Listing 1 inner loops)
mmw/        — minor-min-width lower bound (§3.3)
bloom/      — Bloom-filter dedup with sequential atomic-OR semantics (§3.2)
wavefront/  — the fused inner loop: expand + feasibility + simplicial +
              MMW in one VMEM pass, emitting (children, feasible) directly

Each op is registered next to its pure-JAX reference implementation in the
backend registry (``repro.core.backend``); the solver engines dispatch
through the registry via a single ``backend=`` knob.
"""
