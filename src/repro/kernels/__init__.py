"""Pallas TPU kernels for the paper's compute hot spots.

expand/ — wavefront state expansion (Listing 1 inner loops)
bloom/  — Bloom-filter dedup with sequential atomic-OR semantics (§3.2)
"""
