from .ops import mmw_bounds
from .ref import mmw_bounds_ref
