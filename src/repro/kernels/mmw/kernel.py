"""Pallas TPU kernel: minor-min-width lower bound (paper §3.3).

The GPU version tracks degrees + a disjoint-set forest and re-runs DFS over
the original graph per contraction.  The TPU form keeps the per-state
eliminated-graph adjacency (the reach matrix, already produced by the
expansion kernel) as an (n, W) bitset tile in VMEM and performs each
contraction as pure bitset algebra — column clear + column select + two row
writes — with a **static trip count** of n-1 contraction steps and per-state
done-masking instead of divergent early exit (the branch-divergence story of
the paper's §4.5, resolved structurally).

``mmw_block`` is the factored kernel body; the fused wavefront kernel
(``repro.kernels.wavefront``) reuses it on the reach tiles it already holds
in VMEM, so the prune never materialises reach in HBM.

Grid: one step per state block; everything stays in VMEM
(block x n x W uint32 ~ 64 KiB at n=64, W=2, block=128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

U32 = jnp.uint32
BIG = 1 << 20          # python int: pallas kernels cannot capture arrays


def mmw_block(reach, states, kk, *, n: int):
    """Batched minor-min-width bounds, pure jnp (runs inside any kernel).

    reach (B, n, W) uint32 eliminated-graph rows; states (B, W); kk scalar
    int32.  Returns (B,) int32 bounds; values freeze once > kk, matching
    ``repro.core.mmw.mmw_bound``'s early exit bit for bit.
    """
    b, _, w = reach.shape
    eye = common.eye_words(n, w)
    universe = common.full_words(n, w)

    active = universe[None, :] & ~states                     # (B, W)
    act_bits = common.unpack(active, n)                      # (B, n)
    adjm = jnp.where(act_bits[..., None],
                     (reach & active[:, None, :]) & ~eye[None], U32(0))
    lb = jnp.zeros((b,), jnp.int32)
    nact = common.popcount(active)

    def step(_, carry):
        adjm, active, lb, nact = carry
        act_bits = common.unpack(active, n)                  # (B, n)
        live = (nact > 1) & (lb <= kk)                       # done-masking
        d = jnp.where(act_bits, common.popcount(adjm), BIG)  # (B, n)
        v = jnp.argmin(d, axis=-1).astype(jnp.int32)         # (B,)
        dv = jnp.take_along_axis(d, v[:, None], axis=-1)[:, 0]
        d2 = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, d.shape, 1) == v[:, None],
            BIG, d)
        second = jnp.min(d2, axis=-1)
        lb_new = jnp.maximum(lb, jnp.where(nact >= 2,
                                           jnp.minimum(second, BIG - 1), 0))
        vrow = jnp.take_along_axis(
            adjm, v[:, None, None].repeat(w, axis=-1), axis=1)[:, 0]
        nb_bits = common.unpack(vrow, n)
        dn = jnp.where(nb_bits, d, BIG)
        u = jnp.where(dv > 0, jnp.argmin(dn, axis=-1), v).astype(jnp.int32)
        uhot = common.onehot_words(u, w)                     # (B, W)
        vhot = common.onehot_words(v, w)
        urow = jnp.take_along_axis(
            adjm, u[:, None, None].repeat(w, axis=-1), axis=1)[:, 0]
        merged = (vrow | urow) & active & ~uhot & ~vhot
        merged_bits = common.unpack(merged, n)               # (B, n)
        adjm2 = adjm & ~uhot[:, None, :]
        adjm2 = jnp.where(merged_bits[..., None],
                          adjm2 | vhot[:, None, :],
                          adjm2 & ~vhot[:, None, :])
        rowsel = jax.lax.broadcasted_iota(jnp.int32, (b, n), 1)
        adjm2 = jnp.where((rowsel == v[:, None])[..., None],
                          merged[:, None, :], adjm2)
        adjm2 = jnp.where((rowsel == u[:, None])[..., None],
                          U32(0), adjm2)
        active2 = active & ~uhot

        adjm = jnp.where(live[:, None, None], adjm2, adjm)
        active = jnp.where(live[:, None], active2, active)
        lb = jnp.where(live, lb_new, lb)
        nact = jnp.where(live, nact - 1, nact)
        return adjm, active, lb, nact

    _, _, lb, _ = jax.lax.fori_loop(0, max(n - 1, 1), step,
                                    (adjm, active, lb, nact))
    return lb


def _mmw_kernel(reach_ref, states_ref, k_ref, lb_ref, *, n: int):
    lb_ref[...] = mmw_block(reach_ref[...], states_ref[...], k_ref[0], n=n)


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def mmw_bounds_pallas(reach, states, k, *, n: int, block: int = 64,
                      interpret: bool = True):
    """MMW lower bounds for a batch of states.

    reach (B, n, W) uint32 eliminated-graph rows; states (B, W); k scalar.
    B must be a multiple of block.  Returns (B,) int32 bounds (exceeding k
    means prunable; values freeze once > k, matching core.mmw early exit).
    """
    bt, _, w = reach.shape
    assert bt % block == 0
    kernel = functools.partial(_mmw_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(bt // block,),
        in_specs=[
            pl.BlockSpec((block, n, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, w), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bt,), jnp.int32),
        interpret=interpret,
    )(reach, states, k)
