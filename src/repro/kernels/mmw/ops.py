"""Jit'd wrapper for the MMW kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from .kernel import mmw_bounds_pallas


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def mmw_bounds(reach, states, k, *, n: int, block: int = 64,
               interpret: bool | None = None):
    """MMW lower bounds, padding the batch to the kernel block size."""
    if interpret is None:
        interpret = default_interpret()
    b = reach.shape[0]
    pad = (-b) % block
    if pad:
        reach = jnp.concatenate(
            [reach, jnp.zeros((pad,) + reach.shape[1:], reach.dtype)])
        states = jnp.concatenate(
            [states, jnp.zeros((pad,) + states.shape[1:], states.dtype)])
    k = jnp.asarray(k, jnp.int32)[None]
    out = mmw_bounds_pallas(reach, states, k, n=n, block=block,
                            interpret=interpret)
    return out[:b]
