"""Oracle for the MMW kernel: the validated core implementation, vmapped."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mmw as mmw_core


def mmw_bounds_ref(reach, states, k, n: int):
    return jax.vmap(lambda r, s: mmw_core.mmw_bound(r, s, k, n))(
        reach, states)
