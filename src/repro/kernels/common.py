"""Shared in-kernel bitset helpers for the Pallas kernels.

Pallas TPU kernels cannot capture host-side constant arrays (everything the
kernel touches must be an input Ref or built from ``iota``), so the packed
bitset primitives from ``repro.core.bitset`` are re-expressed here in a
capture-free form.  Every kernel in ``repro.kernels`` builds on these —
they are the single source of truth for the in-kernel bit algebra, and the
math is identical word-for-word to the core versions (pinned by the parity
tests in tests/test_kernels_*.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32


def log2_ceil(n: int) -> int:
    """Static doubling trip count: smallest b with 2**b >= n (n >= 2)."""
    b = 1
    while (1 << b) < n:
        b += 1
    return b


def unpack(words, n):
    """(..., W) uint32 packed bitset -> (..., n) bool."""
    idx = jnp.arange(n, dtype=jnp.int32)
    w = jnp.take(words, idx >> 5, axis=-1)
    return ((w >> (idx & 31).astype(U32)) & U32(1)).astype(jnp.bool_)


def popcount(words):
    """(..., W) -> (...,) int32 set size."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32),
                   axis=-1)


def eye_words(n, w):
    """(n, W) identity bitset matrix, built from iota (capture-free)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, w), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, w), 1)
    return jnp.where(cols == (rows >> 5),
                     U32(1) << (rows & 31).astype(U32), U32(0))


def onehot_words(i, w):
    """(...,) int32 vertex ids -> (..., W) single-bit masks."""
    words = jnp.arange(w, dtype=jnp.int32)
    return jnp.where(words == (i[..., None] >> 5),
                     U32(1) << (i[..., None] & 31).astype(U32), U32(0))


def full_words(n, w):
    """(W,) bitset of the full universe {0..n-1} (capture-free)."""
    full = jnp.full((w,), U32(0xFFFFFFFF))
    rem = n - 32 * (n // 32)
    last = n // 32
    mask = jnp.where(jnp.arange(w) < last, full,
                     jnp.where(jnp.arange(w) == last,
                               (U32(1) << U32(rem)) - U32(1) if rem else U32(0),
                               U32(0)))
    if n % 32 == 0:
        mask = jnp.where(jnp.arange(w) < n // 32, full, U32(0))
    return mask


def bor_matmul(mask, rows, n):
    """Batched OR-AND semiring product.

    mask (B, n, W), rows (B, n, W) -> out (B, n, W):
      out[b, i] = OR_j { rows[b, j] : bit j of mask[b, i] }.
    """
    bits = unpack(mask, n)                         # (B, n, n)
    sel = jnp.where(bits[..., None], rows[:, None, :, :], U32(0))
    return jax.lax.reduce(sel, U32(0), jax.lax.bitwise_or, (2,))


def default_interpret() -> bool:
    """Pallas runs natively on TPU; everywhere else use interpret mode."""
    return jax.default_backend() != "tpu"
