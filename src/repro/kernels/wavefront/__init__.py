from .ops import wavefront_expand
from .ref import wavefront_ref
