"""Pallas TPU kernel: the fused Listing-1 inner loop.

The paper's 77x speedup comes from doing the *entire* per-state pipeline —
component closure, deg_S(v), the degree test, and the pruning rules — in one
on-device pass with adjacency pinned in constant memory (§3).  The unfused
kernels in ``repro.kernels.expand`` / ``repro.kernels.mmw`` reproduce the
pieces; this kernel composes their factored bodies (``reach_block``,
``mmw_block``) into a single VMEM-resident pass per state block, following
the persistent-kernel design of the GPU branch-and-reduce literature
(Yamout et al.; Almasri et al. — both keep the whole per-state pipeline in
one kernel):

  bitset closure -> deg_S(v) -> feasibility mask
                 -> simplicial collapse (optional)
                 -> MMW prune (optional)
  ==> (children, feasible)

The (B, n, W) reach tensor lives only in VMEM inside the kernel — it is
never materialised in HBM (the pure-JAX backend streams it through HBM
between ops).  The kernel emits exactly what dedup needs: the child bitsets
and their feasibility mask.

Memory per grid step: ~4 * block * n * W * 4 bytes of (n, W) tiles plus the
transient (block, n, n) unpack of the OR-AND product — ~0.5 MiB at
block=8, n=64, well inside VMEM.

Validated in interpret mode against ``ref.wavefront_ref`` (the jax backend
composition) and transitively against the python DFS/MMW/simplicial oracles
(tests/test_kernels_wavefront.py, tests/test_engine_parity.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.expand import simplicial_viol
from repro.kernels import common
from repro.kernels.expand.kernel import reach_block
from repro.kernels.mmw.kernel import mmw_block

U32 = jnp.uint32


def _wavefront_kernel(adj_ref, states_ref, valid_ref, k_ref, allowed_ref,
                      children_ref, feas_ref, *, n: int, use_mmw: bool,
                      use_simplicial: bool):
    adj = adj_ref[...]                             # (n, W)   VMEM-pinned
    states = states_ref[...]                       # (B, W)
    valid = valid_ref[...] != 0                    # (B,)
    kk = k_ref[0]
    allowed = allowed_ref[...]                     # (W,)
    b, w = states.shape
    eye = common.eye_words(n, w)

    deg, reach, q = reach_block(adj, states, n=n)  # all VMEM-resident

    s_bits = common.unpack(states, n)              # (B, n)
    allowed_bits = common.unpack(allowed, n)       # (n,)
    feas = ((deg <= kk)
            & ~s_bits
            & allowed_bits[None, :]
            & valid[:, None])

    if use_simplicial:
        closed = reach | eye[None]
        # the exact witness scan from core.expand (capture-free pure jnp):
        # single source for the parity-critical rule
        simp = feas & ~simplicial_viol(q, closed, n)
        # collapse: if any simplicial candidate, keep only the lowest-index
        has = jnp.any(simp, axis=-1, keepdims=True)
        idx = jnp.argmax(simp, axis=-1)            # first True
        iota = jax.lax.broadcasted_iota(jnp.int32, (b, n), 1)
        only = (iota == idx[:, None]) & simp
        feas = jnp.where(has, only, feas)

    if use_mmw:
        lbs = mmw_block(reach, states, kk, n=n)    # (B,) — reach stays VMEM
        feas = feas & (lbs <= kk)[:, None]

    children_ref[...] = states[:, None, :] | eye[None]
    feas_ref[...] = feas.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "block", "use_mmw",
                                             "use_simplicial", "interpret"))
def wavefront_pallas(adj, states, valid, k, allowed, *, n: int,
                     block: int = 8, use_mmw: bool = False,
                     use_simplicial: bool = False, interpret: bool = True):
    """Fused expand + prune for a batch of states.

    adj (n, W); states (B, W) with B % block == 0; valid (B,) int32;
    k (1,) int32; allowed (W,).  Returns (children (B, n, W) uint32,
    feasible (B, n) int32) — padding rows come back all-infeasible.
    """
    bt, w = states.shape
    assert bt % block == 0, (bt, block)
    kernel = functools.partial(_wavefront_kernel, n=n, use_mmw=use_mmw,
                               use_simplicial=use_simplicial)
    return pl.pallas_call(
        kernel,
        grid=(bt // block,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, 0)),        # adjacency: pinned
            pl.BlockSpec((block, w), lambda i: (i, 0)),    # states tile
            pl.BlockSpec((block,), lambda i: (i,)),        # valid tile
            pl.BlockSpec((1,), lambda i: (0,)),            # k scalar
            pl.BlockSpec((w,), lambda i: (0,)),            # allowed: pinned
        ],
        out_specs=[
            pl.BlockSpec((block, n, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, n, w), U32),
            jax.ShapeDtypeStruct((bt, n), jnp.int32),
        ],
        interpret=interpret,
    )(adj, states, valid.astype(jnp.int32), k, allowed)
