"""Jit'd public wrapper for the fused wavefront kernel.

``wavefront_expand`` is the pallas implementation of the backend registry's
``wavefront_expand`` op (see ``repro.core.backend``): same signature as the
jax reference composition in ``repro.core.expand.wavefront_expand``, same
outputs bit for bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from .kernel import wavefront_pallas


@functools.partial(jax.jit, static_argnames=("n", "schedule", "use_mmw",
                                             "use_simplicial", "block",
                                             "interpret"))
def wavefront_expand(adj, states, valid, k, allowed, *, n: int,
                     schedule: str = "doubling", use_mmw: bool = False,
                     use_simplicial: bool = False, block: int = 8,
                     interpret: bool | None = None):
    """Fused expand + feasibility + pruning, padding to the kernel block.

    adj (n, W) uint32; states (B, W) uint32; valid (B,) bool; k scalar
    int32; allowed (W,) uint32 -> (children (B, n, W), feasible (B, n) bool).
    """
    if schedule != "doubling":
        # the registry rejects this combination before dispatch; this guard
        # catches direct callers
        raise ValueError(
            f"pallas wavefront kernel fuses the closure fixpoint with a "
            f"static doubling schedule; schedule={schedule!r} is jax-only")
    if interpret is None:
        interpret = default_interpret()
    b, w = states.shape
    pad = (-b) % block
    if pad:
        states = jnp.concatenate(
            [states, jnp.zeros((pad, w), dtype=states.dtype)], axis=0)
        valid = jnp.concatenate(
            [valid, jnp.zeros((pad,), dtype=bool)], axis=0)
    kdev = jnp.asarray(k, jnp.int32).reshape(1)
    children, feas = wavefront_pallas(
        adj, states, valid, kdev, allowed, n=n, block=block,
        use_mmw=use_mmw, use_simplicial=use_simplicial, interpret=interpret)
    return children[:b], feas[:b].astype(jnp.bool_)
