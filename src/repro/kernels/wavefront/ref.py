"""Reference for the fused wavefront kernel: the jax backend composition.

The kernel's contract is exactly "what the jax backend computes, in one
VMEM pass": expand -> feasibility -> simplicial collapse -> MMW prune.
The reference therefore *is* the registered jax implementation
(``repro.core.expand.wavefront_expand``), which is itself validated against
the python DFS / simplicial / MMW oracles in the core test suite — the
same layering as ``repro.kernels.mmw.ref``.
"""
from __future__ import annotations

from repro.core.expand import wavefront_expand as wavefront_ref  # noqa: F401
