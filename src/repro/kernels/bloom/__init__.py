from .ops import bloom_insert, make_filter_words
from .ref import bloom_ref
