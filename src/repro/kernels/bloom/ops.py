"""Jit'd public wrapper for the Bloom kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from .kernel import bloom_insert_pallas


def make_filter_words(m_bits: int) -> jnp.ndarray:
    assert m_bits % 32 == 0
    return jnp.zeros((m_bits // 32,), dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("m_bits", "k_hashes", "block",
                                             "interpret"))
def bloom_insert(filter_words, states, valid, *, m_bits: int,
                 k_hashes: int = 17, block: int = 256,
                 interpret: bool | None = None):
    """Insert states (B, W) into the packed filter; returns (was_new, filter).

    Pads the batch to the kernel block size with invalid rows.
    """
    if interpret is None:
        interpret = default_interpret()
    b, w = states.shape
    pad = (-b) % block
    if pad:
        states = jnp.concatenate(
            [states, jnp.zeros((pad, w), dtype=states.dtype)], axis=0)
        valid = jnp.concatenate([valid, jnp.zeros((pad,), dtype=bool)], axis=0)
    was_new, filt = bloom_insert_pallas(
        filter_words, states, valid, m_bits=m_bits, k_hashes=k_hashes,
        block=block, interpret=interpret)
    return was_new[:b], filt
