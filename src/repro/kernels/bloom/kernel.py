"""Pallas TPU kernel: Bloom-filter insert/query with atomic-OR semantics.

The paper leans on the GPU's hardware atomic OR plus 65 536 mutexes to make
concurrent inserts of the *same* element safe (§3.2).  TPUs expose no
atomics through XLA; the TPU-native equivalent used here is **sequential
grid semantics**: Pallas grid steps execute in order on a core, so inserts
within a kernel invocation are serialised by construction and the
mutex/false-negative problem disappears.  Across devices, the distributed
solver hash-partitions states so each filter shard has a single writer
(DESIGN.md §2) — ownership replaces atomicity.

The filter itself is bit-packed uint32 (as on the GPU) and is updated
in place via input/output aliasing.  Murmur3 is recomputed inside the
kernel (uint32 arithmetic on the VPU).

NOTE on memory spaces: the filter is declared with a whole-array BlockSpec.
On a real TPU a multi-megabyte filter would stream through VMEM in DMA'd
tiles; random-probe scatter into HBM is the one part of the paper's design
that has no efficient TPU analogue — which is exactly why the framework's
default dedup is the sort-based one (see dedup.py).  This kernel is the
paper-faithful artifact, validated in interpret mode.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bloom import C1, C2, MIX1, MIX2, SEED1, SEED2

U32 = jnp.uint32


def _rotl(x, r):
    r = np.uint32(r)
    return (x << r) | (x >> np.uint32(32 - r))


def _murmur_scalar(words, w: int, seed):
    """Murmur3-32 of a (w,) uint32 vector -> scalar uint32 (unrolled)."""
    h = jnp.asarray(seed, dtype=U32)
    for j in range(w):
        kv = words[j]
        kv = kv * C1
        kv = _rotl(kv, 15)
        kv = kv * C2
        h = h ^ kv
        h = _rotl(h, 13)
        h = h * np.uint32(5) + np.uint32(0xE6546B64)
    h = h ^ np.uint32(w * 4)
    h = h ^ (h >> np.uint32(16))
    h = h * MIX1
    h = h ^ (h >> np.uint32(13))
    h = h * MIX2
    h = h ^ (h >> np.uint32(16))
    return h


def _bloom_kernel(states_ref, valid_ref, filt_in_ref, new_ref, filt_ref, *,
                  w: int, m_bits: int, k_hashes: int, block: int):
    del filt_in_ref  # aliased with filt_ref (in-place update)

    def insert_one(i, _):
        words = states_ref[i, :]
        valid = valid_ref[i] != 0
        h1 = _murmur_scalar(words, w, SEED1)
        h2 = _murmur_scalar(words, w, SEED2)

        def probe(j, carry):
            any_zero = carry
            idx = (h1 + jnp.asarray(j, U32) * h2) % np.uint32(m_bits)
            word_idx = (idx >> np.uint32(5)).astype(jnp.int32)
            bit = U32(1) << (idx & np.uint32(31))
            old = filt_ref[pl.dslice(word_idx, 1)][0]
            new_word = jnp.where(valid, old | bit, old)
            filt_ref[pl.dslice(word_idx, 1)] = new_word[None]
            return any_zero | ((old & bit) == 0)

        any_zero = jax.lax.fori_loop(0, k_hashes, probe, jnp.bool_(False))
        new_ref[i] = (valid & any_zero).astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, block, insert_one, 0)


@functools.partial(jax.jit, static_argnames=("m_bits", "k_hashes", "block",
                                             "interpret"))
def bloom_insert_pallas(filter_words: jnp.ndarray, states: jnp.ndarray,
                        valid: jnp.ndarray, *, m_bits: int,
                        k_hashes: int = 17, block: int = 256,
                        interpret: bool = True):
    """Sequentially insert ``states`` rows; returns (was_new (B,), filter).

    B must be a multiple of ``block`` (callers pad with valid=0 rows).
    """
    bt, w = states.shape
    assert bt % block == 0
    m_words = filter_words.shape[0]
    grid = (bt // block,)
    kernel = functools.partial(_bloom_kernel, w=w, m_bits=m_bits,
                               k_hashes=k_hashes, block=block)
    was_new, filt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, w), lambda i: (i, 0)),     # states tile
            pl.BlockSpec((block,), lambda i: (i,)),         # valid tile
            pl.BlockSpec((m_words,), lambda i: (0,)),       # filter (aliased)
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((m_words,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt,), jnp.int32),
            jax.ShapeDtypeStruct((m_words,), jnp.uint32),
        ],
        input_output_aliases={2: 1},
        interpret=interpret,
    )(states, valid.astype(jnp.int32), filter_words)
    return was_new.astype(jnp.bool_), filt
