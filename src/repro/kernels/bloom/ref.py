"""Pure-jnp/python oracle for the Bloom kernel.

Sequential semantics: elements are inserted one at a time in row order, and
``was_new[i]`` reflects the filter state after rows 0..i-1 — exactly what the
paper's mutex-striped atomic OR guarantees for intra-batch duplicates.
"""
from __future__ import annotations

import numpy as np

from repro.core import bloom as bloom_core


def bloom_ref(filter_words: np.ndarray, states: np.ndarray,
              valid: np.ndarray, m_bits: int, k_hashes: int):
    """filter_words: (m_words,) uint32 (packed bits);  states: (B, W) uint32.

    Returns (was_new (B,) bool, updated filter_words)."""
    filt = filter_words.copy()
    b = states.shape[0]
    was_new = np.zeros((b,), dtype=bool)
    for i in range(b):
        if not valid[i]:
            continue
        h1 = bloom_core.murmur3_ref(states[i], int(bloom_core.SEED1))
        h2 = bloom_core.murmur3_ref(states[i], int(bloom_core.SEED2))
        any_zero = False
        for j in range(k_hashes):
            idx = (h1 + j * h2) % m_bits
            word, bit = idx >> 5, idx & 31
            if not (int(filt[word]) >> bit) & 1:
                any_zero = True
                filt[word] = np.uint32(int(filt[word]) | (1 << bit))
        was_new[i] = any_zero
    return was_new, filt
