"""Distributed wavefront solver: the paper's single-GPU loop on a TPU mesh.

Mapping (DESIGN.md §4):

  * the frontier is sharded over the ``data`` mesh axis (and the ``pod``
    axis in multi-pod meshes) — each device owns ``cap_local`` state slots;
  * expansion + intra-chunk dedup are embarrassingly parallel (no
    collectives), executed under ``shard_map``;
  * duplicate elimination across devices uses **ownership routing**:
    every candidate state is hash-partitioned (murmur3 mod D) to a unique
    owner device via ``all_to_all``, and the owner performs an exact sorted
    dedup of everything it receives.  This replaces the paper's atomic-OR
    Bloom filter + mutex striping: with a single writer per state there is
    nothing to synchronise;
  * load balance comes from the hash itself (multinomial balance,
    O(sqrt) deviation) — the explicit analogue of the paper's observation
    that states can be processed independently.  Straggler mitigation is
    structural: every device runs the identical dense program;
  * capacity overflow (local buffer, send bucket, owner buffer) drops
    states and marks the run inexact — the paper's list-overflow semantics,
    now per shard;
  * the frontier (plus k/level cursor) can be checkpointed each level and
    restored onto a *different* device count (elastic restart).

Runs on any mesh with a ``data`` axis; CPU tests force multiple host
devices via XLA_FLAGS (see tests/test_distributed_tw.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import compat

from . import backend as backend_lib
from . import bitset, bounds, dedup
from . import engine as engine_lib
from . import preprocess as preprocess_lib
from . import shard as shard_lib
from . import telemetry
from .graph import Graph
from .solver import SolveResult

U32 = jnp.uint32


def make_solver_mesh(devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    return Mesh(np.asarray(devices), ("data",))


# ------------------------------------------------------------ device-local fn

def _local_expand(adj, states, count, k, allowed, *, n, cap_local, block,
                  use_mmw, use_simplicial, schedule, backend):
    """Expand the local states in block chunks; returns (buf, count, drops).

    Pure per-device computation (no collectives) — the shared
    ``engine.chunk_sweep`` (identical math to the single-device path),
    bound by the device-resident local count: no host participation, no
    wasted chunks, and one compiled program regardless of frontier size
    (the old ``lax.scan`` needed a host sync per level to pick its trip
    count, and a recompile per trip-count bucket).  Cross-chunk dedup is
    deferred to the owner device after routing.
    """
    return engine_lib.chunk_sweep(
        adj, allowed, k, states, count, block, n=n, cap=cap_local,
        mode="sort", use_mmw=use_mmw, m_bits=1, k_hashes=1,
        schedule=schedule, backend=backend, use_simplicial=use_simplicial,
        max_chunks=-(-cap_local // block), cross_dedup=False)


def _build_buckets(rows, count, ndev, cap_send, w):
    """Group valid rows by owner device -> (send (ndev, cap_send, W),
    send_counts (ndev,), dropped).  Thin prefix-count adapter over the
    shared ownership router in ``core.shard`` (same hash, same sort/scatter
    on a single device's shards and on the mesh)."""
    del w
    valid = jnp.arange(rows.shape[0], dtype=jnp.int32) < count
    return shard_lib.route_states(rows, valid, ndev, cap_send)


def _donate(buf, cnt, counts_all, me, *, ndev, cap_local, cap_send, w,
            axes, donate_ratio):
    """Mesh work donation: rebalance post-dedup rows across devices.

    Every device computes the identical water-filling plan from the
    all-gathered counts (``shard.donation_plan``), so the transfer matrix
    ``T[d, e]`` needs no negotiation: device d sends its surplus rows
    (beyond its keep target) in contiguous runs to the deficit devices via
    a second ``all_to_all``, and reads its own receive counts from
    ``T[:, me]`` locally.  Per-edge transfers are clamped to ``cap_send``
    (partial donation; the remainder simply stays at the donor), so no
    state is ever dropped by a donation.  Returns
    (buf, cnt, stats (4,) [triggered, rows_moved, idle, peak]) with stats
    identical on every device (pure functions of ``counts_all``).
    """
    targets, trig, _moved = shard_lib.donation_plan(counts_all, donate_ratio)
    give = jnp.maximum(counts_all - targets, 0)
    take = jnp.maximum(targets - counts_all, 0)
    zero1 = jnp.zeros((1,), jnp.int32)
    gg = jnp.concatenate([zero1, jnp.cumsum(give).astype(jnp.int32)])
    gt = jnp.concatenate([zero1, jnp.cumsum(take).astype(jnp.int32)])
    t_mat = jnp.maximum(
        0, jnp.minimum(gg[1:, None], gt[None, 1:])
        - jnp.maximum(gg[:-1, None], gt[None, :-1]))
    t_mat = jnp.where(trig, jnp.minimum(t_mat, cap_send), 0) \
        .astype(jnp.int32)

    row_t = t_mat[me]                         # rows I send to each device
    keep = cnt - jnp.sum(row_t)
    off = jnp.concatenate([zero1, jnp.cumsum(row_t).astype(jnp.int32)])
    flat = jnp.arange(ndev * cap_send, dtype=jnp.int32)
    eidx, j = flat // cap_send, flat % cap_send
    src = keep + off[eidx] + j
    sval = j < row_t[eidx]
    send = jnp.where(sval[:, None],
                     buf[jnp.clip(src, 0, cap_local - 1)], 0).astype(U32)
    recv = jax.lax.all_to_all(send.reshape(ndev, cap_send, w), axes,
                              split_axis=0, concat_axis=0, tiled=False)
    rcnt = t_mat[:, me]                       # rows I receive, known locally
    rrows = recv.reshape(ndev * cap_send, w)
    rval = j < rcnt[eidx]
    mask_keep = jnp.arange(cap_local, dtype=jnp.int32) < keep
    buf = jnp.where(mask_keep[:, None], buf, 0)
    pos = keep + jnp.cumsum(rval.astype(jnp.int32)) - 1
    dest = jnp.where(rval, pos, cap_local)
    buf = buf.at[dest].set(rrows, mode="drop")
    cnt = keep + jnp.sum(rcnt)

    stats = jnp.stack([trig.astype(jnp.int32), jnp.sum(t_mat),
                       jnp.sum((counts_all == 0).astype(jnp.int32)),
                       jnp.max(counts_all)])
    return buf, cnt, stats


def _make_level_shardmap(mesh, *, n, cap_local, block, cap_send,
                         use_mmw, use_simplicial, schedule, backend,
                         donate_ratio=None):
    """The per-level SPMD program: local expand -> ownership all_to_all ->
    owner dedup -> (threshold donation).  Returned un-jitted so it can be
    embedded either in a host-driven per-level jit or inside the fused
    while_loop.  Outputs (states, counts, dropped, stats) with ``stats``
    the replicated shard-health vector of ``shard.sharded_decide_loop``
    (zeros when donation is disabled — the plan needs the same all_gather
    the stats do)."""
    ndev = mesh.devices.size
    axes = tuple(mesh.axis_names)

    def local_fn(adj, states, count, k, allowed):
        # shard_map views: states (cap_local, W), count (1,)
        w = adj.shape[-1]
        out, ocount, drop_local = _local_expand(
            adj, states, count[0], k, allowed, n=n, cap_local=cap_local,
            block=block, use_mmw=use_mmw, use_simplicial=use_simplicial,
            schedule=schedule, backend=backend)
        # ownership routing (all_to_all over the flattened device axes)
        send, send_counts, drop_send = _build_buckets(
            out, ocount, ndev, cap_send, w)
        recv = jax.lax.all_to_all(send, axes, split_axis=0, concat_axis=0,
                                  tiled=False)
        rcounts = jax.lax.all_to_all(send_counts, axes, split_axis=0,
                                     concat_axis=0, tiled=False)
        rows = recv.reshape(ndev * cap_send, w)
        rvalid = (jnp.arange(cap_send, dtype=jnp.int32)[None, :]
                  < rcounts[:, None]).reshape(-1)
        buf, cnt, drop_own = dedup.dedup_compact(rows, rvalid, cap_local)
        if donate_ratio is not None:
            me = jnp.asarray(0, jnp.int32)
            for ax in axes:
                me = me * mesh.shape[ax] + jax.lax.axis_index(ax)
            counts_all = jax.lax.all_gather(cnt, axes).astype(jnp.int32)
            buf, cnt, stats = _donate(
                buf, cnt, counts_all, me, ndev=ndev, cap_local=cap_local,
                cap_send=cap_send, w=w, axes=axes,
                donate_ratio=donate_ratio)
        else:
            stats = jnp.zeros((4,), jnp.int32)
        dropped = (drop_local + drop_send + drop_own)[None]
        return (buf, cnt[None].astype(jnp.int32),
                dropped.astype(jnp.int32), stats)

    spec_sharded = P(axes)
    return compat.shard_map(
        local_fn, mesh,
        in_specs=(P(), spec_sharded, spec_sharded, P(), P()),
        out_specs=(spec_sharded, spec_sharded, spec_sharded, P()))


_DIST_FN_CACHE: dict = {}


def _dist_fns(mesh, *, n, cap_local, block, cap_send, use_mmw,
              use_simplicial, schedule, backend, donate_ratio=None):
    """(jitted per-level fn, jitted fused decide fn) for one config.

    Module-level cache: jit compilation caches key on function identity, so
    rebuilding the closures per ``decide`` call (the old behaviour) forced
    a retrace for every k of the iterative deepening."""
    key = (mesh, n, cap_local, block, cap_send, use_mmw, use_simplicial,
           schedule, backend, donate_ratio)
    if key in _DIST_FN_CACHE:
        return _DIST_FN_CACHE[key]

    level_sm = _make_level_shardmap(
        mesh, n=n, cap_local=cap_local, block=block, cap_send=cap_send,
        use_mmw=use_mmw, use_simplicial=use_simplicial, schedule=schedule,
        backend=backend, donate_ratio=donate_ratio)

    def fused_decide_fn(adj, states, counts, k, target, allowed):
        """Whole decide loop device-resident: mirrors engine._fused_decide
        with the level step replaced by the sharded SPMD program."""
        zero = jnp.asarray(0, jnp.int32)

        def cond(c):
            _states, counts, level, _expanded, _dropped, _stats = c
            return (level < target) & (jnp.sum(counts) > 0)

        def body(c):
            states, counts, level, expanded, dropped, stats = c
            expanded = expanded + jnp.sum(counts)
            states, counts, drop, lstats = level_sm(adj, states, counts, k,
                                                    allowed)
            stats = jnp.stack([stats[0] + lstats[0], stats[1] + lstats[1],
                               stats[2] + lstats[2],
                               jnp.maximum(stats[3], lstats[3])])
            return (states, counts, level + 1, expanded,
                    dropped + jnp.sum(drop), stats)

        _states, counts, _level, expanded, dropped, stats = \
            jax.lax.while_loop(cond, body, (states, counts, zero, zero,
                                            zero, jnp.zeros((4,), jnp.int32)))
        return jnp.sum(counts) > 0, dropped, expanded, stats

    fns = (jax.jit(level_sm), jax.jit(fused_decide_fn))
    _DIST_FN_CACHE[key] = fns
    return fns


# ------------------------------------------------------------------- driver

@dataclasses.dataclass
class DistFrontier:
    states: jax.Array        # (D*cap_local, W) sharded over mesh axes
    counts: jax.Array        # (D,) int32 sharded
    level: int
    k: int


def _init_frontier(mesh, cap_local, w):
    axes = tuple(mesh.axis_names)
    ndev = mesh.devices.size
    sh_states = NamedSharding(mesh, P(axes))
    sh_counts = NamedSharding(mesh, P(axes))
    states = jnp.zeros((ndev * cap_local, w), dtype=U32)
    counts = np.zeros((ndev,), dtype=np.int32)
    counts[0] = 1                                  # the empty set, on dev 0
    return (jax.device_put(states, sh_states),
            jax.device_put(jnp.asarray(counts), sh_counts))


def decide_launch(g: Graph, k: int, clique, mesh: Mesh, *,
                  cap_local: int, block: int, use_mmw: bool = False,
                  use_simplicial: bool = False,
                  schedule: str = "doubling", backend: str = "jax",
                  donate_ratio: Optional[float]
                  = shard_lib.DEFAULT_DONATE_RATIO,
                  resume: Optional[dict] = None,
                  tracker=None) -> engine_lib.DispatchHandle:
    """Enqueue one fused mesh-sharded decide; return its in-flight handle.

    The mesh twin of ``shard.decide_sharded_async``: one dispatch runs the
    whole rung device-resident (level loop, ownership all_to_all, owner
    dedup, threshold donation), and ``handle.result()`` performs the one
    deferred host sync, yielding a one-element ``[batch.LaneResult]`` so a
    mesh rung drops into the same serving/sync machinery as a lane or a
    vmapped shard group.  This is the path that unifies the distributed
    solver with the serving pool: ``decide_distributed(engine="fused")``
    is launch + immediate ``result()``."""
    from . import batch as batch_lib

    backend_lib.validate(backend, mode="sort", schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial)
    n = g.n
    block = engine_lib.validate_geometry(cap_local, block)
    target = n - max(k + 1, len(clique))
    if target <= 0:
        res = [batch_lib.LaneResult(True, False, 0)]
        return engine_lib.DispatchHandle((), lambda host: res,
                                         _result=res, _done=True)
    w = bitset.n_words(n)
    ndev = mesh.devices.size
    adj_dev = jnp.asarray(g.packed())
    allowed_dev = jnp.asarray(_allowed_words(n, clique))
    cap_send = max(32, (2 * cap_local) // ndev)

    states, counts = _init_frontier(mesh, cap_local, w)
    start_level, expanded0, inexact0 = 0, 0, False
    if resume is not None:
        states, counts = _restore(mesh, resume, cap_local, w)
        start_level = resume["level"]
        expanded0 = int(resume.get("expanded", 0))
        inexact0 = bool(resume.get("inexact", False))

    _level_fn, fused_fn = _dist_fns(
        mesh, n=n, cap_local=cap_local, block=block, cap_send=cap_send,
        use_mmw=use_mmw, use_simplicial=use_simplicial, schedule=schedule,
        backend=backend, donate_ratio=donate_ratio)
    feas_dev, drop_dev, exp_dev, stats_dev = fused_fn(
        adj_dev, states, counts, jnp.asarray(k, jnp.int32),
        jnp.asarray(target - start_level, jnp.int32), allowed_dev)
    tr = telemetry.get(tracker)
    tr.count(dispatches=1)

    def finalize(host):
        feas, drop, exp, stats = host
        shard_lib._record_stats(stats, tracker=tr)
        return [batch_lib.LaneResult(bool(feas),
                                     inexact0 or int(drop) > 0,
                                     expanded0 + int(exp))]

    return engine_lib.DispatchHandle(
        (feas_dev, drop_dev, exp_dev, stats_dev), finalize, tracker=tr)


def _allowed_words(n: int, clique) -> np.ndarray:
    allowed = np.asarray(bitset.full(n)).copy()
    for v in clique:
        allowed[v >> 5] &= ~np.uint32(np.uint32(1) << np.uint32(v & 31))
    return allowed


def decide_distributed(g: Graph, k: int, clique: list, mesh: Mesh, *,
                       cap_local: int, block: int, use_mmw: bool = False,
                       use_simplicial: bool = False,
                       schedule: str = "doubling", backend: str = "jax",
                       checkpoint_cb=None, resume: Optional[dict] = None,
                       engine: str = "fused",
                       donate_ratio: Optional[float]
                       = shard_lib.DEFAULT_DONATE_RATIO,
                       tracker=None):
    """Distributed decision: is tw(g) <= k?  Mirrors solver.decide.

    ``engine="fused"`` runs the whole level loop as one device-resident
    program (the sharded analogue of ``engine.fused_decide``): zero host
    syncs until the verdict.  Per-level checkpointing needs host snapshots,
    so a ``checkpoint_cb`` forces the host loop.  ``donate_ratio`` tunes
    the per-level work donation (None disables it)."""
    tr = telemetry.get(tracker)
    if engine == "fused" and checkpoint_cb is None:
        with tr.time_block("rung_s"):
            res = decide_launch(
                g, k, clique, mesh, cap_local=cap_local, block=block,
                use_mmw=use_mmw, use_simplicial=use_simplicial,
                schedule=schedule, backend=backend,
                donate_ratio=donate_ratio, resume=resume,
                tracker=tr).result()[0]
        return res.feasible, res.inexact, res.expanded

    backend_lib.validate(backend, mode="sort", schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial)
    n = g.n
    block = engine_lib.validate_geometry(cap_local, block)
    target = n - max(k + 1, len(clique))
    if target <= 0:
        return True, False, 0
    w = bitset.n_words(n)
    ndev = mesh.devices.size
    adj_dev = jnp.asarray(g.packed())
    allowed_dev = jnp.asarray(_allowed_words(n, clique))
    cap_send = max(32, (2 * cap_local) // ndev)

    states, counts = _init_frontier(mesh, cap_local, w)
    start_level, expanded, inexact = 0, 0, False
    if resume is not None:
        states, counts = _restore(mesh, resume, cap_local, w)
        start_level = resume["level"]
        expanded = int(resume.get("expanded", 0))
        inexact = bool(resume.get("inexact", False))

    level_fn, _fused_fn = _dist_fns(
        mesh, n=n, cap_local=cap_local, block=block, cap_send=cap_send,
        use_mmw=use_mmw, use_simplicial=use_simplicial, schedule=schedule,
        backend=backend, donate_ratio=donate_ratio)
    kdev = jnp.asarray(k, jnp.int32)

    for level in range(start_level, target):
        counts_h = np.asarray(counts)
        tr.count(host_syncs=1)
        expanded += int(counts_h.sum())              # states popped this level
        with tr.time_block("level_s"):
            states, counts, dropped, stats = level_fn(
                adj_dev, states, counts, kdev, allowed_dev)
            tr.count(dispatches=1)
            inexact |= int(jnp.sum(dropped)) > 0
            total = int(jnp.sum(counts))
            tr.count(host_syncs=2)
        # frontier occupancy across the mesh vs the planned local capacity
        tr.gauge_max("frontier_peak_rows", total)
        shard_lib._record_stats(np.asarray(stats), tracker=tr)
        if checkpoint_cb is not None:
            checkpoint_cb(dict(level=level + 1, k=k, expanded=expanded,
                               inexact=inexact,
                               states=np.asarray(states),
                               counts=np.asarray(counts)))
        if total == 0:
            return False, inexact, expanded
    return True, inexact, expanded


def _restore(mesh, ckpt: dict, cap_local: int, w: int):
    """Elastic restore: reshard host rows onto the current mesh size."""
    axes = tuple(mesh.axis_names)
    ndev = mesh.devices.size
    old_counts = ckpt["counts"]
    old_states = ckpt["states"]
    old_ndev = len(old_counts)
    old_cap = old_states.shape[0] // old_ndev
    rows = []
    for d in range(old_ndev):
        c = int(old_counts[d])
        rows.append(old_states[d * old_cap: d * old_cap + c])
    rows = np.concatenate(rows, axis=0) if rows else np.zeros((0, w), np.uint32)
    # round-robin rows across the new device count
    states = np.zeros((ndev * cap_local, w), dtype=np.uint32)
    counts = np.zeros((ndev,), dtype=np.int32)
    for i, r in enumerate(rows):
        d = i % ndev
        if counts[d] < cap_local:
            states[d * cap_local + counts[d]] = r
            counts[d] += 1
    sh = NamedSharding(mesh, P(axes))
    return (jax.device_put(jnp.asarray(states), sh),
            jax.device_put(jnp.asarray(counts), sh))


def solve_distributed(g: Graph, mesh: Mesh, *, cap_local: int = 1 << 14,
                      block: int = 1 << 8, use_mmw: bool = False,
                      use_simplicial: bool = False,
                      schedule: str = "doubling", backend: str = "jax",
                      use_clique: bool = True, use_paths: bool = True,
                      use_preprocess: bool = True,
                      checkpoint_cb=None, verbose: bool = False,
                      engine: str = "fused",
                      donate_ratio: Optional[float]
                      = shard_lib.DEFAULT_DONATE_RATIO,
                      impl: Optional[str] = None,
                      tracker=None) -> SolveResult:
    """Distributed analogue of solver.solve (width only, no reconstruction)."""
    t0 = time.time()
    if impl is not None:
        warnings.warn("solve_distributed(impl=...) is deprecated; use "
                      "backend=...", DeprecationWarning, stacklevel=2)
        backend = impl
    if g.n == 0:
        return SolveResult(0, True, 0, 0, 0, 0.0, [], {})

    parts = [g]
    base_lb = 0
    if use_preprocess:
        pre = preprocess_lib.preprocess(g)
        parts, base_lb = [b.g for b in pre.blocks], pre.lb

    width, exact, expanded = base_lb, True, 0
    lbs = ubs = base_lb
    for part in parts:
        if part.n - 1 <= width:
            continue
        clique = bounds.greedy_max_clique(part) if use_clique else []
        lb = max(bounds.lower_bound(part), len(clique) - 1)
        ub, _ = bounds.upper_bound(part)
        lbs, ubs = max(lbs, lb), max(ubs, ub)
        if lb >= ub:
            width = max(width, ub)
            continue
        paths = bounds.disjoint_paths_matrix(part, cap=ub) if use_paths else None
        found = ub
        any_inexact = False
        for k in range(lb, ub):
            gk = part.with_edges(bounds.paths_edges(part, paths, k)) \
                if use_paths else part
            feasible, inexact, exp = decide_distributed(
                gk, k, clique, mesh, cap_local=cap_local, block=block,
                use_mmw=use_mmw, use_simplicial=use_simplicial,
                schedule=schedule, backend=backend,
                checkpoint_cb=checkpoint_cb, engine=engine,
                donate_ratio=donate_ratio, tracker=tracker)
            expanded += exp
            any_inexact |= inexact
            if verbose:
                print(f"  [dist:{part.name}] k={k} feasible={feasible} "
                      f"exp={exp} inexact={inexact}", flush=True)
            if feasible:
                found = k
                break
        width = max(width, found)
        exact &= not any_inexact
    return SolveResult(width, exact, lbs, max(ubs, width), expanded,
                       time.time() - t0, None, None)
