"""Instance preprocessing (host-side, numpy).

The paper preprocesses with the safe-separator rules of the authors'
BZTreewidth PACE submission (split on components, articulation points/pairs/
triplets, (almost-)clique separators).  We implement the first two levels —
connected components and articulation points (biconnected blocks) — plus
simplicial-vertex reduction; these are exactly safe (tw = max over parts).
Articulation pairs/triplets and almost-clique separators are documented as
out of scope (DESIGN.md §7): they need the full machinery of [5] and change
results only by further shrinking instances.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .graph import Graph


def connected_components(g: Graph) -> list:
    seen = np.zeros(g.n, dtype=bool)
    comps = []
    for s in range(g.n):
        if seen[s]:
            continue
        stack, comp = [s], []
        seen[s] = True
        while stack:
            v = stack.pop()
            comp.append(v)
            for u in np.nonzero(g.adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(int(u))
        comps.append(sorted(comp))
    return comps


def biconnected_blocks(g: Graph) -> list:
    """Iterative Hopcroft-Tarjan; returns vertex sets of biconnected blocks.

    tw(G) = max over blocks tw(G[block]) (articulation splits are safe)."""
    n = g.n
    num = [-1] * n
    low = [0] * n
    blocks = []
    estack = []
    cnt = [0]

    for root in range(n):
        if num[root] != -1:
            continue
        stack = [(root, -1, iter(np.nonzero(g.adj[root])[0]))]
        num[root] = low[root] = cnt[0]
        cnt[0] += 1
        while stack:
            v, parent, it = stack[-1]
            advanced = False
            for u in it:
                u = int(u)
                if num[u] == -1:
                    estack.append((v, u))
                    num[u] = low[u] = cnt[0]
                    cnt[0] += 1
                    stack.append((u, v, iter(np.nonzero(g.adj[u])[0])))
                    advanced = True
                    break
                elif u != parent and num[u] < num[v]:
                    estack.append((v, u))
                    low[v] = min(low[v], num[u])
            if advanced:
                continue
            stack.pop()
            if stack:
                pv = stack[-1][0]
                low[pv] = min(low[pv], low[v])
                if low[v] >= num[pv]:
                    # pv is an articulation point (or root): pop a block
                    block = set()
                    while estack:
                        a, b = estack[-1]
                        if num[a] >= num[v]:
                            estack.pop()
                            block.update((a, b))
                        else:
                            break
                    if estack and estack[-1] == (pv, v):
                        estack.pop()
                    block.update((pv, v))
                    blocks.append(sorted(block))
        if not blocks and n == 1:
            blocks.append([root])
    # isolated vertices form their own trivial blocks
    covered = set()
    for b in blocks:
        covered.update(b)
    for v in range(n):
        if v not in covered:
            blocks.append([v])
    return blocks


def simplicial_reduce(g: Graph) -> tuple:
    """Repeatedly remove simplicial vertices (N(v) is a clique).

    Safe: tw(G) = max(deg(v), tw(G - v)).  Returns (reduced graph,
    lower bound from removed vertices, kept-vertex original ids)."""
    adj = g.adj.copy()
    alive = np.ones(g.n, dtype=bool)
    lb = 0
    changed = True
    while changed:
        changed = False
        for v in range(g.n):
            if not alive[v]:
                continue
            nbrs = np.nonzero(adj[v] & alive)[0]
            d = len(nbrs)
            if d == 0:
                alive[v] = False
                changed = True
                continue
            sub = adj[np.ix_(nbrs, nbrs)]
            if d * (d - 1) == int(sub.sum()):   # clique
                lb = max(lb, d)
                adj[v, :] = False
                adj[:, v] = False
                alive[v] = False
                changed = True
    keep = np.nonzero(alive)[0]
    if len(keep) == 0:
        return Graph(0, np.zeros((0, 0), dtype=bool), g.name + "_red"), lb, keep
    sub = Graph(len(keep), adj[np.ix_(keep, keep)], g.name + "_red")
    return sub, lb, keep


@dataclasses.dataclass
class Preprocessed:
    blocks: list          # list of Graph
    lb: int               # lower bound established by reductions
    original: Graph


def preprocess(g: Graph, split_blocks: bool = True) -> Preprocessed:
    """Full pipeline: simplicial reduce -> biconnected blocks -> reduce each."""
    red, lb, _ = simplicial_reduce(g)
    parts: list = []
    if red.n:
        if split_blocks:
            for blk in biconnected_blocks(red):
                if len(blk) >= 2:
                    sub, lb2, _ = simplicial_reduce(red.subgraph(blk))
                    lb = max(lb, lb2)
                    if sub.n:
                        parts.append(sub)
        else:
            parts.append(red)
    # largest first: the hard block dominates runtime, fail fast
    parts.sort(key=lambda s: -s.n)
    return Preprocessed(parts, lb, g)
