"""Instance preprocessing (host-side, numpy).

The paper preprocesses with the safe-separator rules of the authors'
BZTreewidth PACE submission (split on components, articulation points/pairs/
triplets, (almost-)clique separators).  We implement the first two levels —
connected components and articulation points (biconnected blocks) — plus
simplicial-vertex reduction; these are exactly safe (tw = max over parts).
Articulation pairs/triplets and almost-clique separators are documented as
out of scope (DESIGN.md §7): they need the full machinery of [5] and change
results only by further shrinking instances.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .graph import Graph


def connected_components(g: Graph) -> list:
    seen = np.zeros(g.n, dtype=bool)
    comps = []
    for s in range(g.n):
        if seen[s]:
            continue
        stack, comp = [s], []
        seen[s] = True
        while stack:
            v = stack.pop()
            comp.append(v)
            for u in np.nonzero(g.adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(int(u))
        comps.append(sorted(comp))
    return comps


def biconnected_blocks(g: Graph) -> list:
    """Iterative Hopcroft-Tarjan; returns vertex sets of biconnected blocks.

    tw(G) = max over blocks tw(G[block]) (articulation splits are safe)."""
    n = g.n
    num = [-1] * n
    low = [0] * n
    blocks = []
    estack = []
    cnt = [0]

    for root in range(n):
        if num[root] != -1:
            continue
        stack = [(root, -1, iter(np.nonzero(g.adj[root])[0]))]
        num[root] = low[root] = cnt[0]
        cnt[0] += 1
        while stack:
            v, parent, it = stack[-1]
            advanced = False
            for u in it:
                u = int(u)
                if num[u] == -1:
                    estack.append((v, u))
                    num[u] = low[u] = cnt[0]
                    cnt[0] += 1
                    stack.append((u, v, iter(np.nonzero(g.adj[u])[0])))
                    advanced = True
                    break
                elif u != parent and num[u] < num[v]:
                    estack.append((v, u))
                    low[v] = min(low[v], num[u])
            if advanced:
                continue
            stack.pop()
            if stack:
                pv = stack[-1][0]
                low[pv] = min(low[pv], low[v])
                if low[v] >= num[pv]:
                    # pv is an articulation point (or root): pop a block
                    block = set()
                    while estack:
                        a, b = estack[-1]
                        if num[a] >= num[v]:
                            estack.pop()
                            block.update((a, b))
                        else:
                            break
                    if estack and estack[-1] == (pv, v):
                        estack.pop()
                    block.update((pv, v))
                    blocks.append(sorted(block))
        if not blocks and n == 1:
            blocks.append([root])
    # isolated vertices form their own trivial blocks
    covered = set()
    for b in blocks:
        covered.update(b)
    for v in range(n):
        if v not in covered:
            blocks.append([v])
    return blocks


def simplicial_reduce(g: Graph) -> tuple:
    """Repeatedly remove simplicial vertices (N(v) is a clique).

    Safe: tw(G) = max(deg(v), tw(G - v)).  Returns (reduced graph,
    lower bound from removed vertices, kept-vertex original ids,
    removed-vertex original ids in removal order).  The removal order is
    an elimination-order prefix: replaying it eliminates each vertex while
    its neighborhood is a clique (degree = the recorded bound, no fill),
    which is what lets ``stitch_block_orders`` splice the removals back
    into a certified global order."""
    adj = g.adj.copy()
    alive = np.ones(g.n, dtype=bool)
    lb = 0
    removed: list = []
    changed = True
    while changed:
        changed = False
        for v in range(g.n):
            if not alive[v]:
                continue
            nbrs = np.nonzero(adj[v] & alive)[0]
            d = len(nbrs)
            if d == 0:
                alive[v] = False
                removed.append(int(v))
                changed = True
                continue
            sub = adj[np.ix_(nbrs, nbrs)]
            if d * (d - 1) == int(sub.sum()):   # clique
                lb = max(lb, d)
                adj[v, :] = False
                adj[:, v] = False
                alive[v] = False
                removed.append(int(v))
                changed = True
    keep = np.nonzero(alive)[0]
    if len(keep) == 0:
        return (Graph(0, np.zeros((0, 0), dtype=bool), g.name + "_red"),
                lb, keep, removed)
    sub = Graph(len(keep), adj[np.ix_(keep, keep)], g.name + "_red")
    return sub, lb, keep, removed


@dataclasses.dataclass
class Block:
    """One solver unit plus the vertex maps reconstruction needs.

    ``g`` is the reduced block graph handed to the solver; ``vmap[i]`` is
    the original-graph id of solver vertex ``i``; ``removed`` lists the
    block-local simplicial reduction removals (original ids, removal
    order); ``vertices`` is the full block vertex set in original ids —
    including removed and articulation vertices — which is what the
    stitcher's block-cut forest is built from.  A block can be fully
    reduced away (``g.n == 0``): it is kept here anyway because its
    vertices (e.g. both endpoints of a bridge) still have to be placed in
    the global elimination order."""
    g: Graph
    vmap: np.ndarray
    removed: list
    vertices: list


@dataclasses.dataclass
class Preprocessed:
    blocks: list          # list of Block, largest solver graph first
    lb: int               # lower bound established by reductions
    original: Graph
    removed: list         # top-level reduction removals (original ids, order)


def preprocess(g: Graph, split_blocks: bool = True) -> Preprocessed:
    """Full pipeline: simplicial reduce -> biconnected blocks -> reduce each."""
    red, lb, keep, removed0 = simplicial_reduce(g)
    parts: list = []
    if red.n:
        if split_blocks:
            for blk in biconnected_blocks(red):
                blk = sorted(blk)
                orig = keep[np.asarray(blk, dtype=int)]   # red ids -> g ids
                sub, lb2, keep2, rem2 = simplicial_reduce(red.subgraph(blk))
                lb = max(lb, lb2)
                vmap = (orig[np.asarray(keep2, dtype=int)] if sub.n
                        else np.zeros(0, dtype=int))
                parts.append(Block(sub, vmap,
                                   [int(orig[v]) for v in rem2],
                                   [int(v) for v in orig]))
        else:
            parts.append(Block(red, keep.astype(int), [],
                               [int(v) for v in keep]))
    # largest first: the hard block dominates runtime, fail fast
    parts.sort(key=lambda b: -b.g.n)
    return Preprocessed(parts, lb, g, removed0)


def stitch_block_orders(pre: Preprocessed, block_orders: list) -> list:
    """Stitch per-block elimination orders into one order for the original
    graph, leaf-to-root over the block-cut forest.

    ``block_orders[i]`` is an elimination order of ``pre.blocks[i].g`` in
    block-local solver indices (``None`` means "any order" — used for
    blocks the solver skipped because they cannot beat the width found so
    far, where every order is within budget).

    Why this preserves width: processing a leaf block eliminates its
    vertices *except* the one articulation vertex it still shares with an
    unprocessed block.  At that moment every neighbor of an eliminated
    vertex lies inside the block (all other blocks containing it are
    already collapsed into their articulation vertices), so replay degrees
    equal the block-local ones; and restricting an elimination order to an
    induced subgraph never increases its width (the restricted fill-in is
    a subgraph of the restricted full fill-in).  Fill edges stay inside
    the block, so the residual graph seen by later blocks is exactly the
    original minus processed block interiors and the recursion goes
    through.  Block-local reduction removals are replayed first — they are
    simplicial at that point in the block, with degree bounded by the
    reduction lower bound."""
    full = []
    for b, loc in zip(pre.blocks, block_orders):
        loc = list(range(b.g.n)) if loc is None else list(loc)
        full.append(list(b.removed) + [int(b.vmap[v]) for v in loc])
    owner: dict = {}
    for i, b in enumerate(pre.blocks):
        for v in b.vertices:
            owner.setdefault(v, set()).add(i)
    remaining = set(range(len(pre.blocks)))
    order = list(pre.removed)
    done = set(order)
    while remaining:
        leaf = cut = None
        for i in sorted(remaining):
            shared = [v for v in pre.blocks[i].vertices
                      if len(owner[v] & remaining) > 1]
            if len(shared) <= 1:
                leaf, cut = i, (shared[0] if shared else None)
                break
        assert leaf is not None, "block-cut forest has no leaf block"
        for v in full[leaf]:
            if v != cut and v not in done:
                order.append(v)
                done.add(v)
        remaining.discard(leaf)
    # isolated originals never entering any block (already in pre.removed
    # for reduced graphs; this is a safety net for degenerate inputs)
    order.extend(v for v in range(pre.original.n) if v not in done)
    return order
