"""Bit-parallel elimination reachability.

The paper computes, for every (state S, candidate v), the degree of v in the
graph left after eliminating S — with one stack-based DFS per pair (Listing 1,
lines 7-19).  On a TPU there are no divergent per-thread stacks, so we replace
the DFS with dense bitset algebra computed once per state and shared by ALL
candidates:

  Z   (n, W): component closure of G[S] — ``Z[i]`` = S-vertices in the same
              connected component of G[S] as i (for i in S; else empty).
  NB  (n, W): ``NB[i] = N(Z[i])`` — the G-neighborhood of i's S-component.
  R   (n, W): ``R[v] = N(v)  ∪  ⋃_{i ∈ N(v)∩S} NB[i]`` — everything v reaches
              through S, i.e. Q(S, v) ∪ (S-internal vertices).

  deg_S(v) = |R[v] \\ S \\ {v}|        (the paper's ``degree`` variable)

The closure fixpoint uses **doubling**: ``Z ← Z ∨ (Z∧S)·Z`` converges in
⌈log2 n⌉ steps, giving a static trip count (no data-dependent control flow —
the TPU analogue of eliminating branch divergence).  A ``while_loop``
early-exit variant is kept for the paper's Table-6 style scheduling sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitset

U32 = jnp.uint32


def _log2_ceil(n: int) -> int:
    b = 1
    while (1 << b) < n:
        b += 1
    return b


def closure(adj: jnp.ndarray, s_words: jnp.ndarray, n: int,
            schedule: str = "doubling", unroll: int = 1) -> jnp.ndarray:
    """Component closure Z of G[S].  adj: (n, W) packed;  s_words: (W,)."""
    w = adj.shape[-1]
    s_bits = bitset.unpack(s_words, n)                      # (n,)
    eye = _eye_words(n, w)
    # distance-1 closure restricted to S rows/cols
    z0 = jnp.where(s_bits[:, None], (adj & s_words[None, :]) | eye, U32(0))

    if schedule == "doubling":
        steps = _log2_ceil(max(n, 2))

        def body(_, z):
            return z | bitset.or_matmul(z, z, n)

        return jax.lax.fori_loop(0, steps, body, z0, unroll=unroll)

    if schedule == "while":
        def cond(carry):
            z, changed = carry
            return changed

        def body(carry):
            z, _ = carry
            z2 = z | bitset.or_matmul(z, z, n)
            return z2, jnp.any(z2 != z)

        z, _ = jax.lax.while_loop(cond, body, (z0, jnp.bool_(True)))
        return z

    if schedule == "linear":
        # one-hop propagation per step (closest analogue of the paper's
        # per-level BFS); needs up to n steps instead of log n.
        m = jnp.where(s_bits[:, None], adj & s_words[None, :], U32(0))

        def cond(carry):
            _, changed = carry
            return changed

        def body(carry):
            z, _ = carry
            z2 = z | jnp.where(s_bits[:, None], bitset.or_matmul(m, z, n), U32(0))
            return z2, jnp.any(z2 != z)

        z, _ = jax.lax.while_loop(cond, body, (z0, jnp.bool_(True)))
        return z

    raise ValueError(f"unknown schedule {schedule!r}")


def eliminated_degrees_matmul(adj: jnp.ndarray, s_words: jnp.ndarray, n: int):
    """deg_S(v) via dense 0/1 float matmuls (the MXU formulation, §Perf).

    The OR-AND semiring product is computed as ``(A @ B) > 0`` on f32 0/1
    matrices: on TPU this runs on the systolic array instead of the VPU; on
    CPU it hits the optimized GEMM.  Same math as ``eliminated_degrees``
    (validated against it and the DFS oracle in tests).

    Returns (degrees (n,) int32, reach packed (n, W)).
    """
    f32 = jnp.float32
    a_bits = bitset.unpack(adj, n).astype(f32)              # (n, n)
    s_bits = bitset.unpack(s_words, n).astype(f32)          # (n,)
    eye = jnp.eye(n, dtype=f32)
    # distance-1 closure of G[S]: rows/cols restricted to S, plus identity
    m = a_bits * s_bits[None, :] * s_bits[:, None]
    z = jnp.minimum(m + eye * s_bits[:, None], 1.0)

    for _ in range(_log2_ceil(max(n, 2))):
        z = jnp.minimum(z + (z @ z), 1.0)                   # doubling
        z = (z > 0).astype(f32)

    nb = ((z @ a_bits) > 0).astype(f32)                     # N(component)
    via_s = ((a_bits * s_bits[None, :]) @ nb > 0).astype(f32)
    reach = jnp.minimum(a_bits + via_s, 1.0)
    q = reach * (1.0 - s_bits)[None, :] * (1.0 - jnp.eye(n, dtype=f32))
    degrees = jnp.sum(q, axis=-1).astype(jnp.int32)
    return degrees, bitset.pack(q > 0, n)


@functools.lru_cache(maxsize=None)
def _eye_np(n: int, w: int):
    import numpy as np
    out = np.zeros((n, w), dtype=np.uint32)
    idx = np.arange(n)
    out[idx, idx >> 5] = np.uint32(1) << (idx & 31).astype(np.uint32)
    return out


def _eye_words(n: int, w: int) -> jnp.ndarray:
    return jnp.asarray(_eye_np(n, w))


def reach_matrix(adj: jnp.ndarray, s_words: jnp.ndarray, n: int,
                 schedule: str = "doubling") -> jnp.ndarray:
    """R (n, W): for every vertex v, the set reachable from v through S
    (Q(S, v) plus internal S vertices).  Rows for v in S are garbage and must
    be masked by the caller."""
    z = closure(adj, s_words, n, schedule=schedule)
    nb = bitset.or_matmul(z, adj, n)                        # N(component(i))
    via_s = bitset.or_matmul(adj & s_words[None, :], nb, n)  # hop through S
    return adj | via_s


def eliminated_degrees(adj: jnp.ndarray, s_words: jnp.ndarray, n: int,
                       schedule: str = "doubling") -> jnp.ndarray:
    """deg_S(v) for every v (value for v in S is meaningless; mask it).

    Returns (degrees (n,) int32, reach R (n, W)) — R is reused by MMW.
    """
    r = reach_matrix(adj, s_words, n, schedule=schedule)
    w = adj.shape[-1]
    eye = _eye_words(n, w)
    q = (r & ~s_words[None, :]) & ~eye                      # drop S and self
    return bitset.popcount(q), r
