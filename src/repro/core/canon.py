"""Canonical graph labeling and content-addressed solve keys (DESIGN.md §16).

At serving scale, repeat submissions of the same instance must be cache
hits even when the client relabeled the vertices: the cache key has to be
a *complete* graph invariant, not a hash of the adjacency bytes as
submitted.  This module computes a deterministic canonical labeling by
partition refinement plus an individualization tie-break search — the
classic McKay scheme, sized for the ≤64-vertex graphs the exact solver
handles (it runs on any ``n``; the search is exact at every size, only
its worst-case cost grows):

  1. **Refinement** — iterate the 1-WL color update (a vertex's color
     becomes the rank of ``(old color, multiset of neighbor colors)``)
     until the partition is equitable.  Ranks are taken over the sorted
     signature set, so the refined coloring is isomorphism-invariant.
  2. **Individualization search** — while a color class has ≥2 vertices,
     split on the first such class: individualize each member in turn,
     re-refine, and recurse.  Two prunings keep the tree small without
     breaking canonicity: children whose refined partition has a
     non-minimal *invariant* (class sizes + equitable quotient rows —
     a pure function of the colored graph) are dropped, and a child is
     skipped when an already-discovered automorphism fixing the current
     individualization prefix maps an explored sibling onto it (the two
     subtrees are mirror images).  Automorphisms are harvested for free
     whenever two leaves produce the same canonical bytes.
  3. **Leaf** — a discrete coloring *is* a permutation; the canonical
     form is the lexicographically smallest packed adjacency matrix over
     the surviving leaves.

``canonical_form(g)`` returns ``(bytes, perm)`` with ``perm[v]`` the
canonical label of vertex ``v``; two graphs are isomorphic iff their
``bytes`` are equal, and ``g.relabel(perm)`` *is* the canonical graph.

``cache_key(g, config)`` hashes the canonical form together with the
*effective* solve configuration into the result-cache key
(``repro.serve.cache``).  Everything feeding the digest is a primitive
rendered by value (never python ``hash()``), so keys are stable across
processes and ``PYTHONHASHSEED`` values.  One deliberate exception to
canonicalization: ``mode="bloom"`` results are Monte-Carlo and *label-
dependent* (the filter hashes state bitsets, so a relabeling changes the
false-positive pattern and thus ``expanded``) — bloom keys therefore
hash the as-submitted adjacency, and only bit-identical resubmissions
hit.  See DESIGN.md §16 for the full coherence argument.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import Graph

# leaf automorphisms retained for sibling pruning; beyond this the search
# still terminates (pruning just degrades), it only exists to bound the
# per-node scan on pathologically symmetric inputs
_MAX_AUTOMORPHISMS = 64

# domain separator + version for the digest: bump when the canonical
# form or the config rendering changes, so stale persisted keys (if a
# cache is ever spilled to disk) can never alias fresh ones
_KEY_VERSION = b"twkey1"


def _adj_masks(g: Graph) -> List[int]:
    """Row bitmasks of the adjacency matrix (python ints, any n)."""
    masks = []
    for v in range(g.n):
        row = 0
        for u in np.nonzero(g.adj[v])[0]:
            row |= 1 << int(u)
        masks.append(row)
    return masks


def _neighbor_color_counts(masks: List[int], colors: List[int],
                           v: int) -> Tuple[Tuple[int, int], ...]:
    """Sorted (color, count) pairs over v's neighborhood."""
    cnt: Dict[int, int] = {}
    m = masks[v]
    while m:
        low = m & -m
        u = low.bit_length() - 1
        m ^= low
        c = colors[u]
        cnt[c] = cnt.get(c, 0) + 1
    return tuple(sorted(cnt.items()))


def _refine(n: int, masks: List[int], colors: List[int]) -> List[int]:
    """1-WL refinement to the coarsest equitable partition below
    ``colors``.  Returned color ids are signature ranks — a pure function
    of the colored graph, so the refined coloring is iso-invariant."""
    ncolors = len(set(colors))
    while True:
        sigs = [(colors[v], _neighbor_color_counts(masks, colors, v))
                for v in range(n)]
        ranks = {s: i for i, s in enumerate(sorted(set(sigs)))}
        colors = [ranks[s] for s in sigs]
        if len(ranks) == ncolors:
            return colors
        ncolors = len(ranks)


def _partition_invariant(n: int, masks: List[int],
                         colors: List[int]) -> tuple:
    """Iso-invariant summary of an equitable coloring: per color class
    (in color order) its size and one member's neighbor-color counts —
    well-defined because equitability makes every member's counts equal.
    Used to prune non-minimal siblings in the search; any invariant
    works, a discriminating one prunes more."""
    sizes: Dict[int, int] = {}
    rep: Dict[int, int] = {}
    for v, c in enumerate(colors):
        sizes[c] = sizes.get(c, 0) + 1
        rep.setdefault(c, v)
    return tuple((c, sizes[c], _neighbor_color_counts(masks, colors, rep[c]))
                 for c in sorted(rep))


def _canon_bytes(n: int, masks: List[int], perm) -> bytes:
    """Packed adjacency matrix of the relabeled graph, rows in canonical
    order, each row little-endian over canonical columns."""
    inv = [0] * n
    for v, c in enumerate(perm):
        inv[c] = v
    row_bytes = (n + 7) // 8
    out = bytearray()
    for i in range(n):
        m = masks[inv[i]]
        row = 0
        while m:
            low = m & -m
            u = low.bit_length() - 1
            m ^= low
            row |= 1 << perm[u]
        out += row.to_bytes(row_bytes, "little")
    return bytes(out)


def canonical_form(g: Graph) -> Tuple[bytes, Tuple[int, ...]]:
    """Canonical form of ``g``: ``(bytes, perm)``.

    ``bytes`` is the packed adjacency matrix of the canonically
    relabeled graph — equal iff two graphs are isomorphic (it fully
    reconstructs the graph, so equality is exact, not a heuristic).
    ``perm[v]`` is the canonical label of vertex ``v``:
    ``g.relabel(list(perm))`` has exactly the adjacency ``bytes`` packs.
    Deterministic: a pure function of the adjacency matrix."""
    n = g.n
    if n == 0:
        return b"", ()
    masks = _adj_masks(g)
    best: List[Optional[object]] = [None, None]     # bytes, perm
    autos: List[Tuple[int, ...]] = []

    def search(colors: List[int], fixed: Tuple[int, ...]) -> None:
        colors = _refine(n, masks, colors)
        if len(set(colors)) == n:                   # discrete: a leaf
            b = _canon_bytes(n, masks, colors)
            if best[0] is None or b < best[0]:
                best[0], best[1] = b, tuple(colors)
            elif b == best[0] and len(autos) < _MAX_AUTOMORPHISMS:
                # two labelings onto the same canonical graph compose to
                # an automorphism — harvested for sibling pruning
                p_best, p_here = best[1], colors
                inv_here = [0] * n
                for v, c in enumerate(p_here):
                    inv_here[c] = v
                phi = tuple(inv_here[p_best[v]] for v in range(n))
                if phi != tuple(range(n)) and phi not in autos:
                    autos.append(phi)
            return
        # canonical target cell: first color with >= 2 members
        counts: Dict[int, int] = {}
        for c in colors:
            counts[c] = counts.get(c, 0) + 1
        target = min(c for c, k in counts.items() if k > 1)
        cell = [v for v in range(n) if colors[v] == target]
        kids = []
        for v in cell:
            child = [2 * c for c in colors]
            child[v] = 2 * colors[v] + 1            # split v from its class
            rc = _refine(n, masks, child)
            kids.append((_partition_invariant(n, masks, rc), v, rc))
        min_inv = min(k[0] for k in kids)
        # orbit pruning: automorphisms fixing the individualization prefix
        # act on the cell; siblings in one orbit root identical subtrees,
        # so explore one representative per orbit.  Union-find components
        # under the generators are exactly the orbits of the generated
        # subgroup.
        parent = list(range(n))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for phi in autos:
            if all(phi[f] == f for f in fixed):
                for v in range(n):
                    ra, rb = find(v), find(phi[v])
                    if ra != rb:
                        parent[ra] = rb
        tried: List[int] = []
        for inv_k, v, rc in kids:
            if inv_k != min_inv:
                continue        # iso-invariant choice: drop worse siblings
            if any(find(v) == find(u) for u in tried):
                continue        # an automorphism maps a tried sibling here
            tried.append(v)
            search(rc, fixed + (v,))
            # autos discovered inside the subtree may merge orbits
            for phi in autos:
                if all(phi[f] == f for f in fixed):
                    for u in range(n):
                        ra, rb = find(u), find(phi[u])
                        if ra != rb:
                            parent[ra] = rb

    search([0] * n, ())
    return best[0], best[1]          # type: ignore[return-value]


def graph_key(g: Graph) -> str:
    """Hex digest of the canonical form alone (no config): equal iff
    isomorphic.  What trace replay tools use to dedup reference solves."""
    b, _perm = canonical_form(g)
    h = hashlib.sha256()
    h.update(_KEY_VERSION)
    h.update(b"\0g\0")
    h.update(str(g.n).encode())
    h.update(b"\0")
    h.update(b)
    return h.hexdigest()


def _render_value(v) -> str:
    """Deterministic primitive rendering for the config half of the key.
    Only value types with stable reprs are accepted — anything else is a
    bug in the caller (a non-primitive would make keys process-local)."""
    if v is None or isinstance(v, (bool, int, str)):
        return repr(v)
    if isinstance(v, float):
        return repr(float(v))
    if isinstance(v, (tuple, list)):
        return "[" + ",".join(_render_value(x) for x in v) + "]"
    raise TypeError(
        f"cache-key config values must be primitives, got {type(v).__name__}")


def config_blob(config: dict) -> bytes:
    """Canonical byte rendering of an effective-config dict (sorted keys,
    value-rendered primitives — never python ``hash()``)."""
    parts = [f"{k}={_render_value(config[k])}" for k in sorted(config)]
    return ";".join(parts).encode()


def cache_key(g: Graph, config: dict, *,
              canonical: bool = True) -> Tuple[str, Tuple[int, ...]]:
    """Content-addressed result-cache key: ``(hexdigest, perm)``.

    ``canonical=True`` (exact-dedup modes) keys on the canonical form, so
    isomorphic resubmissions — including adversarially relabeled
    duplicates — address the same entry; ``perm`` maps submitted labels
    to canonical ones (the cache stores elimination orders in canonical
    space and translates through ``perm`` on both insert and hit).
    ``canonical=False`` (``mode="bloom"``: Monte-Carlo, label-dependent)
    keys on the as-submitted adjacency with the identity ``perm``.

    The digest covers a version tag, the vertex count, the graph bytes
    and the rendered config — stable across processes (no ``hash()``)."""
    if canonical:
        b, perm = canonical_form(g)
    else:
        b = g.packed().tobytes()
        perm = tuple(range(g.n))
    h = hashlib.sha256()
    h.update(_KEY_VERSION)
    h.update(b"\0c\0" if canonical else b"\0r\0")
    h.update(str(g.n).encode())
    h.update(b"\0")
    h.update(b)
    h.update(b"\0")
    h.update(config_blob(config))
    return h.hexdigest(), perm
