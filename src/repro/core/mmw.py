"""Minor-min-width lower bound on the *implicit* eliminated graph.

Paper §3.3: MMW repeatedly contracts a minimum-degree vertex with its
minimum-degree neighbour; the largest minimum (and, improved, the second
smallest) degree seen is a treewidth lower bound.  The paper avoids storing
intermediate graphs (shared-memory limits) by re-running DFS over the
original graph plus a disjoint-set forest.

On TPU we already have, per state S, the eliminated-graph adjacency rows
``R_S`` (a byproduct of degree computation — the paper makes the same reuse
observation).  The contraction loop then becomes branch-free bitset algebra
on an (n, W) matrix held in registers/VMEM: contracting u into v is one
column clear, one column select, and two row writes.  A disjoint-set forest
is unnecessary — merged vertices are absorbed into the surviving row.

The isolated-vertex case is folded into the same code path by "contracting
v with itself", which simply deactivates it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitset, components

U32 = jnp.uint32
BIG = jnp.int32(1 << 20)


@functools.partial(jax.jit, static_argnames=("n",))
def mmw_bound(reach: jnp.ndarray, s_words: jnp.ndarray, k, n: int):
    """Lower bound for the graph obtained by eliminating S.

    reach: (n, W) — rows of reach_matrix for state S (rows for v in S are
           garbage; masked here).  Early-exits once the bound exceeds k.
    Returns int32 lower bound (>= k+1 means the state can be pruned).
    """
    w = reach.shape[-1]
    eye = components._eye_words(n, w)
    active = bitset.full(n) & ~s_words
    act_bits = bitset.unpack(active, n)
    adjm = jnp.where(act_bits[:, None], (reach & active[None, :]) & ~eye, U32(0))

    def degs(adjm):
        return bitset.popcount(adjm).astype(jnp.int32)

    def cond(carry):
        adjm, active, lb, nact = carry
        return (nact > 1) & (lb <= k)

    def body(carry):
        adjm, active, lb, nact = carry
        act_bits = bitset.unpack(active, n)
        d = jnp.where(act_bits, degs(adjm), BIG)
        v = jnp.argmin(d).astype(jnp.int32)
        dv = d[v]
        # second-smallest active degree is also a lower bound [BK'11]
        d2 = jnp.where(jnp.arange(n) == v, BIG, d)
        second = jnp.min(d2)
        lb = jnp.maximum(lb, jnp.where(nact >= 2, jnp.minimum(second, BIG - 1), 0))
        # min-degree neighbour of v (v itself when isolated -> deactivate v)
        nb_bits = bitset.unpack(adjm[v], n)
        dn = jnp.where(nb_bits, d, BIG)
        u = jnp.where(dv > 0, jnp.argmin(dn), v).astype(jnp.int32)
        # contract u into v
        uhot = bitset.onehot(u, w)
        vhot = bitset.onehot(v, w)
        merged = (adjm[v] | adjm[u]) & active & ~uhot & ~vhot
        merged_bits = bitset.unpack(merged, n)
        adjm = adjm & ~uhot[None, :]                         # clear column u
        adjm = jnp.where(merged_bits[:, None], adjm | vhot[None, :],
                         adjm & ~vhot[None, :])              # fix column v
        adjm = adjm.at[v].set(merged)
        adjm = adjm.at[u].set(U32(0))   # no-op when u == v (isolated case)
        active = active & ~uhot
        return adjm, active, lb, nact - 1

    nact = bitset.popcount(active).astype(jnp.int32)
    _, _, lb, _ = jax.lax.while_loop(
        cond, body, (adjm, active, jnp.int32(0), nact))
    return lb


def mmw_oracle(adj_bool, s: set, cap: int = 1 << 20) -> int:
    """Pure-python MMW on an explicit eliminated graph (test oracle)."""
    import numpy as np
    n = len(adj_bool)
    a = np.array(adj_bool, dtype=bool).copy()
    # eliminate S (in any order)
    alive = [v for v in range(n) if v not in s]
    for v in sorted(s):
        nbrs = [u for u in range(n) if a[v][u] and u != v]
        for i in nbrs:
            for j in nbrs:
                if i != j:
                    a[i][j] = True
        a[v, :] = False
        a[:, v] = False
    lb = 0
    act = set(alive)
    while len(act) > 1:
        d = {v: int(a[v].sum()) for v in act}
        v = min(act, key=lambda x: (d[x], x))
        rest = sorted(act - {v}, key=lambda x: (d[x], x))
        if rest:
            lb = max(lb, d[rest[0]])
        if d[v] == 0:
            act.remove(v)
            continue
        nbrs = [u for u in act if a[v][u]]
        u = min(nbrs, key=lambda x: (d[x], x))
        # contract u into v
        merged = (a[v] | a[u])
        merged[v] = merged[u] = False
        a[v] = merged
        a[:, v] = merged
        a[u, :] = False
        a[:, u] = False
        act.remove(u)
    return lb
