"""Fixed-capacity frontier buffers.

The GPU implementation bounds its input/output lists at 180M states and
discards overflow (marking the run inexact).  We keep exactly those
semantics per device: a frontier is a fixed ``(cap, W)`` uint32 buffer, a
count, and a drop counter.  Fixed shapes keep every level step jit-stable;
capacity scales with the mesh in the distributed solver.

``Frontier`` is registered as a jax pytree so the device-resident engine
(``repro.core.engine``) can carry it straight through ``lax.while_loop`` /
``lax.scan`` without unpacking — the whole ``decide`` recursion then runs
as one compiled program with the frontier never leaving the device.

The same pytree doubles as the multi-lane carry of ``core.batch``: a
batched frontier simply gives every leaf a leading lane axis
(``lane_frontiers``), and vmap maps the engine over it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Frontier:
    states: jnp.ndarray      # (cap, W) uint32
    count: jnp.ndarray       # () int32
    dropped: jnp.ndarray     # () int32 — overflow accumulator for this level

    @property
    def cap(self) -> int:
        return self.states.shape[0]

    @property
    def w(self) -> int:
        return self.states.shape[1]

    # pytree protocol: all three fields are traced data (no static aux) so
    # a Frontier is a legal while_loop carry / scan state
    def tree_flatten(self):
        return (self.states, self.count, self.dropped), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def empty_frontier(cap: int, w: int) -> Frontier:
    """Frontier holding just the empty set (the DP root)."""
    return Frontier(states=jnp.zeros((cap, w), dtype=jnp.uint32),
                    count=jnp.asarray(1, dtype=jnp.int32),
                    dropped=jnp.asarray(0, dtype=jnp.int32))


def lane_frontiers(lanes: int, cap: int, w: int) -> Frontier:
    """Batched DP roots: one ``{∅}`` frontier per lane.

    Every leaf carries a leading ``lanes`` axis — states ``(lanes, cap,
    W)``, count/dropped ``(lanes,)`` — so the same ``Frontier`` pytree
    doubles as the carry of the vmapped multi-lane engine
    (``core.batch``).  The scalar-frontier ``cap``/``w`` properties do not
    apply to a batched instance (the shapes are shifted by the lane
    axis)."""
    return Frontier(states=jnp.zeros((lanes, cap, w), dtype=jnp.uint32),
                    count=jnp.ones((lanes,), dtype=jnp.int32),
                    dropped=jnp.zeros((lanes,), dtype=jnp.int32))


def shard_frontiers(shards: int, cap: int, w: int) -> Frontier:
    """One instance's DP root split across ``shards`` frontier shards.

    Unlike ``lane_frontiers`` (B independent instances, B roots) a
    sharded frontier holds ONE search: the single ``{∅}`` root lives in
    shard 0 (mirroring ``distributed._init_frontier``) and subsequent
    levels spread across shards by ownership routing (``core.shard``).
    Leaves carry a leading ``shards`` axis: states ``(S, cap, W)``,
    count/dropped ``(S,)``."""
    count = np.zeros((shards,), dtype=np.int32)
    count[0] = 1
    return Frontier(states=jnp.zeros((shards, cap, w), dtype=jnp.uint32),
                    count=jnp.asarray(count),
                    dropped=jnp.zeros((shards,), dtype=jnp.int32))


def frontier_bytes(cap: int, w: int, lanes: int = 1) -> int:
    """Device bytes of a ``(lanes, cap, W)`` uint32 frontier pool.

    This is the *resident* pool only: one level step transiently doubles
    it (the append buffer ``out`` in ``engine.expand_chunk``) and adds the
    ``(block, n, W)`` children tile.  ``batch.plan_capacity`` sizes caps
    against this number (DESIGN.md §10)."""
    return 4 * max(1, lanes) * max(1, cap) * max(1, w)


def lane_to_host(f: Frontier, lane: int) -> np.ndarray:
    """Materialise one lane's live rows from a batched frontier."""
    c = int(f.count[lane])
    return np.asarray(f.states[lane, :c])


def blank_frontier(cap: int, w: int) -> Frontier:
    return Frontier(states=jnp.zeros((cap, w), dtype=jnp.uint32),
                    count=jnp.asarray(0, dtype=jnp.int32),
                    dropped=jnp.asarray(0, dtype=jnp.int32))


def to_host(f: Frontier) -> np.ndarray:
    """Materialise the live rows (for checkpointing / reconstruction)."""
    c = int(f.count)
    return np.asarray(f.states[:c])
