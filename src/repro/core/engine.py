"""Device-resident wavefront engine: the whole ``decide`` loop in one jit.

The paper's speedup (§3, Listing 1) comes from never letting the Held-Karp
frontier leave the GPU; the host only learns the final verdict.  The
original ``solver.decide`` instead synchronised twice per level (reading
``fr.count`` to size the chunk loop and to test emptiness), serialising
kernel dispatch exactly the way the persistent-worklist literature warns
against.  This module fuses both loops:

  * the per-level loop becomes an outer ``lax.while_loop`` whose carry is
    the ``Frontier`` pytree plus (level, expanded, dropped) counters, with
    the paper's empty-frontier early exit as part of the loop condition;
  * the per-chunk loop becomes an inner ``lax.while_loop`` over fixed-shape
    ``block``-row slices of the frontier buffer, with the trip count bound
    by the *device-resident* count (no host round-trip, no wasted chunks);
  * expansion, simplicial collapse, MMW pruning, sort/Bloom dedup and
    overflow accounting all happen inside the loop body via
    ``expand_chunk`` — the single shared implementation of the paper's
    Listing-1 inner loop, also used by the host-loop path and the
    distributed solver.  Every op inside it resolves through the backend
    registry (``core.backend``): ``backend="jax"`` composes the reference
    implementations, ``backend="pallas"`` dispatches the fused wavefront
    kernel that runs the whole expand→prune pipeline in one VMEM pass.

One ``fused_decide`` call therefore issues exactly one dispatch and one
device→host transfer per k, versus O(levels × chunks) for the host loop.
The host path survives as ``engine="host"`` (reconstruction needs per-level
snapshots, checkpointing needs per-level host callbacks).

``COUNTERS`` tracks dispatches and host syncs for both engines so
``benchmarks/engine_sync.py`` can report the difference on the Table 1
instances.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from . import backend as backend_lib
from . import dedup
from . import frontier as frontier_lib
from . import telemetry

U32 = jnp.uint32

# dispatch/sync accounting (host-side, zero overhead on device):
#   dispatches — jitted program launches issued by a solver path
#   host_syncs — device->host scalar/buffer reads that block on the device
# plus shard-health counters fed by the sharded engine (core.shard /
# core.distributed): donation events/rows, idle-shard level steps, and the
# peak per-shard occupancy seen (a max, not a sum).
#
# Accounting lives in ``core.telemetry`` now (thread-safe, scoped,
# pluggable sinks — DESIGN.md §14); ``COUNTERS`` survives as a deprecated
# read-only view over the root tracker so historical asserts keep working.
COUNTERS = telemetry.COUNTERS


def reset_counters():
    """Deprecated: zero the process-root tracker (``telemetry.reset``)."""
    telemetry.reset()


def count(dispatches: int = 0, host_syncs: int = 0, **extra: int):
    """Deprecated shim: count on the process-root tracker.  Library code
    now threads an explicit ``tracker=`` instead."""
    kw = dict(extra)
    if dispatches:
        kw["dispatches"] = dispatches
    if host_syncs:
        kw["host_syncs"] = host_syncs
    telemetry.root().count(**kw)


@dataclasses.dataclass
class DispatchHandle:
    """An issued device program whose host sync is deferred.

    JAX dispatches asynchronously: the jitted call returns device arrays
    immediately while the device keeps computing, and the host only
    blocks when it *reads* them.  The engine entry points exploit that by
    splitting every decide into launch (enqueue the program, hold the
    result arrays) and ``result()`` (the single deferred ``device_get``):
    between the two, the caller owns the host — the async solve service
    (``repro.serve.twscheduler``) runs admission and planning for the
    *next* dispatch there, overlapping host bookkeeping with device work.

    ``result()`` performs the one host sync (counted in ``COUNTERS``),
    converts through ``finalize``, and caches — calling it again is free.
    ``ready()`` is a non-blocking poll of the underlying arrays.

        h = fused_decide_launch(adj, allowed, k, target, n=n, cap=cap, ...)
        ...                       # host free while the device works
        feasible, inexact, expanded, fr = h.result()   # the only sync
    """
    arrays: Any                     # pytree of in-flight device arrays
    finalize: Callable[[Any], Any]  # host values -> caller-shaped result
    tracker: Any = None             # telemetry scope (None = process root)
    _result: Any = None
    _done: bool = False
    _t0: float = dataclasses.field(default_factory=time.perf_counter)

    def ready(self) -> bool:
        """Has the device finished?  Never blocks (best-effort: arrays
        without an ``is_ready`` probe report True)."""
        return all(getattr(a, "is_ready", lambda: True)()
                   for a in jax.tree_util.tree_leaves(self.arrays))

    def result(self):
        """Block for the verdict: one host sync, then cached.  The sync
        and the launch→result wall-clock land on the handle's tracker."""
        if not self._done:
            host = jax.device_get(self.arrays)
            tr = telemetry.get(self.tracker)
            tr.count(host_syncs=1)
            tr.timing("dispatch_wall_s", time.perf_counter() - self._t0)
            self._result = self.finalize(host)
            self.arrays = None       # release the device references
            self._done = True
        return self._result

    def discard(self) -> None:
        """Abandon the dispatch without ever reading it: release the
        device references and mark the handle done with no result.  The
        program still runs to completion on device (a launched XLA
        program cannot be aborted), but the host never blocks on it and
        no ``host_syncs`` is counted — the traffic-shaping scheduler uses
        this for whole-round abandonment (``recover``) and cancelled
        requests whose verdicts nobody will read.  After ``discard``,
        ``result()`` returns ``None``."""
        if not self._done:
            self.arrays = None
            self._result = None
            self._done = True


def validate_geometry(cap: int, block: int, *, adaptive: bool = False) -> int:
    """Fail fast on buffer geometry the chunk slicer cannot walk cleanly.

    ``dynamic_slice`` clamps out-of-range starts, so a block that does not
    divide the buffer capacity would silently re-expand earlier rows under
    a wrong valid mask.  ``adaptive=True`` checks every block size the host
    loop's per-level adaptation (``max(32, min(block, 2^j))``) can pick.
    Returns the (possibly clamped) block.
    """
    block = min(block, cap)
    sizes = ({max(32, min(block, 1 << j)) for j in range(26)}
             if adaptive else {block})
    bad = sorted(b for b in sizes if cap % b)
    if bad:
        raise ValueError(
            f"block ({bad[0]}{' via adaptive sizing' if adaptive else ''}) "
            f"must divide cap ({cap}): the chunk slicer walks the buffer "
            "in block strides. Use a power-of-two cap >= block")
    return block


# ------------------------------------------------------------- chunk kernel

def expand_chunk(adj, states_chunk, chunk_valid, k, out, ocount, dropped,
                 filt, allowed, *, n, cap, block, mode, use_mmw, m_bits,
                 k_hashes, schedule, backend, use_simplicial=False):
    """Expand one chunk of states and append deduped children to ``out``.

    The paper's Listing-1 inner loop in one place: called from the host
    chunk loop (``solver._chunk_step``), from the fused while_loop below,
    and from the distributed per-device expansion.  Pure function of its
    arguments — safe inside any jit / while_loop / shard_map context.

    Every op dispatches through the backend registry: under
    ``backend="pallas"`` the whole expand → feasibility → prune pipeline
    runs as one fused VMEM-resident kernel emitting (children, feasible)
    directly; under ``backend="jax"`` the same pipeline is composed from
    the reference implementations in ``core/*``.
    """
    w = adj.shape[-1]
    children, feas = backend_lib.get_op("wavefront_expand", backend)(
        adj, states_chunk, chunk_valid, k, allowed, n=n, schedule=schedule,
        use_mmw=use_mmw, use_simplicial=use_simplicial)

    flat = children.reshape(block * n, w)
    fmask = feas.reshape(block * n)

    # intra-chunk exact dedup (paper: mutex-striped atomic inserts)
    skeys, keep = backend_lib.get_op("sort_dedup", backend)(flat, fmask)

    if mode == "bloom":
        keep, filt = backend_lib.get_op("bloom_query_insert", backend)(
            filt, skeys, keep, m_bits=m_bits, k_hashes=k_hashes)

    pos = ocount + jnp.cumsum(keep.astype(jnp.int32)) - 1
    write = keep & (pos < cap)
    out = out.at[jnp.where(write, pos, cap)].set(skeys, mode="drop")
    n_keep = jnp.sum(keep.astype(jnp.int32))
    written = jnp.minimum(n_keep, jnp.maximum(0, cap - ocount))
    dropped = dropped + (n_keep - written)
    ocount = ocount + written
    return out, ocount, dropped, filt


# ------------------------------------------------------------- fused level

# below this frontier size a level runs as one narrow chunk instead of a
# full-``block``-wide one — the device analogue of the host loop's adaptive
# block (early levels have tiny frontiers; a fixed wide block pays full
# padding cost per level)
SMALL_BLOCK = 128


def chunk_sweep(adj, allowed, k, states, count_, blk, *, n, cap, mode,
                use_mmw, m_bits, k_hashes, schedule, backend,
                use_simplicial, max_chunks=None, cross_dedup=True):
    """Expand ``count_`` rows of ``states`` in ``blk``-row chunks, on device.

    The data-dependent chunk loop shared by the fused level step and the
    distributed per-device expansion (which passes ``cross_dedup=False`` —
    its cross-chunk dedup happens at the owner after routing — and a
    ``max_chunks`` bound from its local capacity).  Returns
    (out, ocount, dropped).

    Lane-aware by construction: nothing here reads the true vertex count —
    ``n`` only sizes the (static) candidate axis, while which vertices
    exist rides in ``allowed`` and which rows are live rides in ``count_``.
    The multi-lane engine exploits that by padding every lane to a common
    ``n`` and vmapping the caller (``core.batch``); the chunk while_loop
    then trips ``max_l ceil(count_l / blk)`` times with finished lanes'
    carries frozen per the while_loop batching rule."""
    w = adj.shape[-1]
    zero = jnp.asarray(0, jnp.int32)
    out = jnp.zeros((cap, w), dtype=U32)
    filt = backend_lib.get_op("bloom_make_filter", backend)(
        m_bits if mode == "bloom" else None)

    def chunk_cond(c):
        more = c[0] * blk < count_
        if max_chunks is not None:
            more = more & (c[0] < max_chunks)
        return more

    def chunk_body(c):
        ci, out, ocount, dropped, filt = c
        lo = ci * blk
        states_chunk = jax.lax.dynamic_slice(states, (lo, zero), (blk, w))
        chunk_valid = (jnp.arange(blk, dtype=jnp.int32) + lo) < count_
        out, ocount, dropped, filt = expand_chunk(
            adj, states_chunk, chunk_valid, k, out, ocount, dropped, filt,
            allowed, n=n, cap=cap, block=blk, mode=mode, use_mmw=use_mmw,
            m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
            backend=backend, use_simplicial=use_simplicial)
        return ci + 1, out, ocount, dropped, filt

    _, out, ocount, dropped, _ = jax.lax.while_loop(
        chunk_cond, chunk_body, (zero, out, zero, zero, filt))

    if mode == "sort" and cross_dedup:
        # cross-chunk exact dedup, only when the level actually spanned
        # multiple chunks (single-chunk output is already sorted-unique);
        # the full-``cap`` sort is the priciest op in the level, so the
        # gate matters.  Drop-neutral: n_keep <= ocount <= cap, drop2 == 0.
        def _cross_dedup():
            valid = jnp.arange(cap, dtype=jnp.int32) < ocount
            buf, written, drop2 = dedup.dedup_compact(out, valid, cap)
            return buf, written, dropped + drop2

        out, ocount, dropped = jax.lax.cond(
            count_ > blk, _cross_dedup, lambda: (out, ocount, dropped))
    return out, ocount, dropped


def _level_step(adj, allowed, k, fr, *, n, cap, block, mode, use_mmw,
                m_bits, k_hashes, schedule, backend, use_simplicial):
    """One wavefront level, fully on device.  Traced inside the while body.

    Chunk trip count is ``ceil(count / block)`` with the count read from the
    carried frontier — a data-dependent while_loop, so small frontiers pay
    for one chunk, not ``cap / block``.  Levels whose whole frontier fits in
    ``SMALL_BLOCK`` rows take a narrow single-chunk branch instead
    (``lax.cond`` — both branches compiled once, runtime picks per level).
    """
    small = min(block, SMALL_BLOCK)
    count_ = fr.count
    kwargs = dict(n=n, cap=cap, mode=mode, use_mmw=use_mmw, m_bits=m_bits,
                  k_hashes=k_hashes, schedule=schedule, backend=backend,
                  use_simplicial=use_simplicial)

    if small == block:
        out, ocount, dropped = chunk_sweep(adj, allowed, k, fr.states,
                                           count_, block, **kwargs)
    else:
        out, ocount, dropped = jax.lax.cond(
            count_ <= small,
            lambda: chunk_sweep(adj, allowed, k, fr.states, count_, small,
                                **kwargs),
            lambda: chunk_sweep(adj, allowed, k, fr.states, count_, block,
                                **kwargs))

    return frontier_lib.Frontier(out, ocount.astype(jnp.int32),
                                 dropped.astype(jnp.int32))


def decide_loop(adj, allowed, k, target, fr, *, n, cap, block, mode,
                use_mmw, m_bits, k_hashes, schedule, backend,
                use_simplicial):
    """Run up to ``target`` wavefront levels; stop early on emptiness.

    Returns (frontier, levels_run, expanded, dropped_total) — all on
    device.  Feasibility is ``frontier.count > 0`` (the loop only stops
    short of ``target`` when a level produced no states).

    Undecorated on purpose: ``fused_decide`` jits it for the single-lane
    path, and the multi-lane engine (``core.batch``) vmaps it over a
    leading lane axis.  Under vmap the two data-dependent ``while_loop``s
    become masked loops — a lane whose condition goes false has its carry
    frozen by the batching rule's ``select`` while other lanes keep
    stepping, which is exactly the per-lane early exit the batched engine
    needs (and why batched results stay bit-identical per lane).  ``n`` is
    the (static) padded lane width; a lane's true vertex count is carried
    dynamically by its ``allowed`` mask and ``target``.
    """
    zero = jnp.asarray(0, jnp.int32)

    def cond(carry):
        fr, level, _expanded, _dropped = carry
        return (level < target) & (fr.count > 0)

    def body(carry):
        fr, level, expanded, dropped = carry
        expanded = expanded + fr.count
        new_fr = _level_step(adj, allowed, k, fr, n=n, cap=cap, block=block,
                             mode=mode, use_mmw=use_mmw, m_bits=m_bits,
                             k_hashes=k_hashes, schedule=schedule,
                             backend=backend, use_simplicial=use_simplicial)
        return new_fr, level + 1, expanded, dropped + new_fr.dropped

    fr, level, expanded, dropped = jax.lax.while_loop(
        cond, body, (fr, zero, zero, zero))
    return fr, level, expanded, dropped


_fused_decide = functools.partial(
    jax.jit,
    static_argnames=("n", "cap", "block", "mode", "use_mmw", "m_bits",
                     "k_hashes", "schedule", "backend",
                     "use_simplicial"))(decide_loop)


def fused_decide_launch(adj_dev, allowed_dev, k: int, target, *, n, cap,
                        block, mode, use_mmw, m_bits, k_hashes, schedule,
                        backend="jax", use_simplicial=False, fr=None,
                        max_levels=None, tracker=None) -> DispatchHandle:
    """Enqueue one fused decide; return its in-flight ``DispatchHandle``.

    The program is dispatched (counted) but the host does NOT wait: the
    returned handle holds the device arrays, and ``handle.result()``
    performs the single deferred sync, yielding the same
    ``(feasible, inexact, expanded, frontier_host)`` tuple
    ``fused_decide`` returns.  Callers that have other host work — the
    async solve service packing its next dispatch — do it between the
    two."""
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits)
    block = validate_geometry(cap, block)
    w = adj_dev.shape[-1]
    if fr is None:
        fr = frontier_lib.empty_frontier(cap, w)
    levels = target if max_levels is None else min(target, max_levels)
    kdev = jnp.asarray(k, dtype=jnp.int32)
    tdev = jnp.asarray(levels, dtype=jnp.int32)

    fr, level, expanded, dropped = _fused_decide(
        adj_dev, allowed_dev, kdev, tdev, fr, n=n, cap=cap, block=block,
        mode=mode, use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
        schedule=schedule, backend=backend, use_simplicial=use_simplicial)
    tr = telemetry.get(tracker)
    tr.count(dispatches=1)

    def finalize(host):
        states_h, count_h, expanded_h, dropped_h = host
        feasible = int(count_h) > 0
        inexact = int(dropped_h) > 0
        fr_host = frontier_lib.Frontier(np.asarray(states_h),
                                        np.asarray(count_h),
                                        np.asarray(dropped_h))
        return feasible, inexact, int(expanded_h), fr_host

    return DispatchHandle((fr.states, fr.count, expanded, dropped),
                          finalize, tracker=tr)


def fused_decide(adj_dev, allowed_dev, k: int, target, *, n, cap, block,
                 mode, use_mmw, m_bits, k_hashes, schedule, backend="jax",
                 use_simplicial=False, fr=None, max_levels=None,
                 tracker=None):
    """Host entry point: one dispatch, one sync, full verdict.

    ``fr`` seeds the frontier (defaults to the DP root {∅}); ``max_levels``
    truncates the run (used by the parity tests to compare intermediate
    frontiers against the host loop level by level).

    Returns (feasible, inexact, expanded, frontier_host) where
    ``frontier_host`` is the final (states, count, dropped_total) pulled to
    the host in the same single transfer as the verdict.  This is the
    blocking form of ``fused_decide_launch`` — launch + immediate
    ``result()``.
    """
    return fused_decide_launch(
        adj_dev, allowed_dev, k, target, n=n, cap=cap, block=block,
        mode=mode, use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
        schedule=schedule, backend=backend, use_simplicial=use_simplicial,
        fr=fr, max_levels=max_levels, tracker=tracker).result()
