"""Iterative-deepening treewidth solver (single device).

Structure mirrors the paper exactly (Listing 1 + §3.1 optimizations):

  for k = lb .. ub-1:                      (iterative deepening)
      G_k = G + edges{pairs with >= k+1 vertex-disjoint paths}   [rule 2]
      frontier = { {} }
      for level = 0 .. n - max(k+1, |C|) - 1:                    [rules 1,3]
          expand every S by every candidate v not in S u C,
              keeping S u {v} iff deg_S(v) <= k
          dedup (exact sort | Bloom filter)
          if frontier empty: k infeasible
      k feasible -> tw = k

Overflow of the fixed-capacity lists drops states and marks the run inexact
(identical to the paper's * semantics).  ``mode="bloom"`` reproduces the
paper's Monte-Carlo dedup; ``mode="sort"`` (default) is the exact
beyond-paper variant.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import backend as backend_lib
from . import bitset, bloom, bounds, dedup, engine as engine_lib
from . import frontier as frontier_lib
from . import expand
from . import preprocess as preprocess_lib
from .graph import Graph

U32 = jnp.uint32


# --------------------------------------------------------------- chunk step

@functools.partial(
    jax.jit,
    static_argnames=("n", "cap", "block", "mode", "use_mmw", "m_bits",
                     "k_hashes", "schedule", "backend", "use_simplicial"),
    donate_argnums=(4, 7),
)
def _chunk_step(adj, states_chunk, chunk_valid, k, out, ocount, dropped,
                filt, allowed, *, n, cap, block, mode, use_mmw, m_bits,
                k_hashes, schedule, backend, use_simplicial=False):
    """Expand one chunk of states and append deduped children to ``out``.

    Thin jitted wrapper over ``engine.expand_chunk`` — the single shared
    implementation of the Listing-1 inner loop (also used by the fused
    device-resident engine and the distributed solver)."""
    return engine_lib.expand_chunk(
        adj, states_chunk, chunk_valid, k, out, ocount, dropped, filt,
        allowed, n=n, cap=cap, block=block, mode=mode, use_mmw=use_mmw,
        m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
        backend=backend, use_simplicial=use_simplicial)


@functools.partial(jax.jit, static_argnames=("cap",), donate_argnums=(0,))
def _final_dedup(out, ocount, cap: int):
    valid = jnp.arange(cap) < ocount
    return dedup.dedup_compact(out, valid, cap)


# --------------------------------------------------------------- level loop

@dataclasses.dataclass
class LevelStats:
    expanded: int = 0
    generated: int = 0
    dropped: int = 0


def _pow2_at_least(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def run_level(adj_dev, fr: frontier_lib.Frontier, k: int, allowed_dev,
              *, n: int, cap: int, block: int, mode: str, use_mmw: bool,
              m_bits: int, k_hashes: int, schedule: str,
              backend: str = "jax", use_simplicial: bool = False):
    """One wavefront level: expand all states in ``fr`` into a new frontier.

    Host-loop engine: syncs on ``fr.count`` to size the chunk loop (the
    fused engine in ``core.engine`` keeps this loop on device)."""
    w = fr.w
    count = int(fr.count)
    engine_lib.count(host_syncs=1)
    # adaptive block: early levels / small instances have tiny frontiers —
    # a fixed 1024-row block pays full padding cost per chunk (§Perf iter).
    # Rounding to powers of two bounds the number of jit signatures at
    # log2(block).
    block = max(32, min(block, _pow2_at_least(max(count, 1))))
    if cap % block:
        # dynamic_slice clamps out-of-range starts, so a non-dividing block
        # would silently re-expand earlier rows with the wrong valid mask
        raise ValueError(f"block ({block}) must divide cap ({cap})")
    out = jnp.zeros((cap, w), dtype=U32)
    ocount = jnp.asarray(0, dtype=jnp.int32)
    dropped = jnp.asarray(0, dtype=jnp.int32)
    filt = backend_lib.get_op("bloom_make_filter", backend)(
        m_bits if mode == "bloom" else None)
    kdev = jnp.asarray(k, dtype=jnp.int32)

    n_chunks = max(1, -(-count // block))
    for c in range(n_chunks):
        lo = c * block
        states_chunk = jax.lax.dynamic_slice(fr.states, (lo, 0), (block, w))
        chunk_valid = (jnp.arange(block, dtype=jnp.int32) + lo) < fr.count
        out, ocount, dropped, filt = _chunk_step(
            adj_dev, states_chunk, chunk_valid, kdev, out, ocount, dropped,
            filt, allowed_dev, n=n, cap=cap, block=block, mode=mode,
            use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
            schedule=schedule, backend=backend,
            use_simplicial=use_simplicial)
        engine_lib.count(dispatches=1)

    if mode == "sort" and n_chunks > 1:
        out, ocount, drop2 = _final_dedup(out, ocount, cap)
        # cross-chunk duplicates removed; drops before dedup stay counted
        dropped = dropped + drop2
        engine_lib.count(dispatches=1)

    new_fr = frontier_lib.Frontier(out, ocount, dropped)
    stats = LevelStats(expanded=count, generated=int(ocount),
                       dropped=int(dropped))
    engine_lib.count(host_syncs=2)
    return new_fr, stats


# ----------------------------------------------------------------- decision

@dataclasses.dataclass
class DecideResult:
    feasible: bool
    inexact: bool
    expanded: int
    levels: Optional[list]    # host snapshots when reconstructing


def decide(g: Graph, k: int, clique: list, *, cap: int, block: int,
           mode: str, use_mmw: bool, m_bits: int, k_hashes: int,
           schedule: str, backend: str = "jax",
           use_simplicial: bool = False, keep_levels: bool = False,
           engine: str = "fused") -> DecideResult:
    """Is tw(g) <= k?  (Monte-Carlo 'no' possible in bloom mode / overflow.)

    ``engine="fused"`` runs the whole level/chunk recursion as one compiled
    program on the device (one dispatch, one sync — §3's design point);
    ``engine="host"`` drives the level loop from the host, which is the
    only engine that can snapshot per-level frontiers (``keep_levels``,
    needed for order reconstruction).  ``backend`` picks the op
    implementations (jax reference vs fused pallas kernels) through the
    registry — validated here, before any tracing starts."""
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits)
    n = g.n
    target = n - max(k + 1, len(clique))
    if target <= 0:
        return DecideResult(True, False, 0, [] if keep_levels else None)

    w = bitset.n_words(n)
    adj_dev = jnp.asarray(g.packed())
    allowed = np.asarray(bitset.full(n)).copy()
    for v in clique:
        allowed[v >> 5] &= ~np.uint32(np.uint32(1) << np.uint32(v & 31))
    allowed_dev = jnp.asarray(allowed)

    if keep_levels:
        engine = "host"            # per-level snapshots need the host loop
    if engine not in ("host", "fused"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "host":
        # fail before any level runs, like the fused engine does — not at
        # the first level whose adapted block happens not to divide cap
        engine_lib.validate_geometry(cap, block, adaptive=True)

    if engine == "fused":
        feasible, inexact, expanded, _fr = engine_lib.fused_decide(
            adj_dev, allowed_dev, k, target, n=n, cap=cap, block=block,
            mode=mode, use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
            schedule=schedule, backend=backend,
            use_simplicial=use_simplicial)
        return DecideResult(feasible, inexact, expanded, None)

    fr = frontier_lib.empty_frontier(cap, w)
    expanded = 0
    inexact = False
    levels = [frontier_lib.to_host(fr)] if keep_levels else None

    for _level in range(target):
        fr, stats = run_level(adj_dev, fr, k, allowed_dev, n=n, cap=cap,
                              block=block, mode=mode, use_mmw=use_mmw,
                              m_bits=m_bits, k_hashes=k_hashes,
                              schedule=schedule, backend=backend,
                              use_simplicial=use_simplicial)
        expanded += stats.expanded
        inexact |= stats.dropped > 0
        if keep_levels:
            levels.append(frontier_lib.to_host(fr))
        engine_lib.count(host_syncs=1)
        if int(fr.count) == 0:
            return DecideResult(False, inexact, expanded, levels)
    return DecideResult(True, inexact, expanded, levels)


# ----------------------------------------------------------- reconstruction

def reconstruct_order(g: Graph, k: int, clique: list, levels: list) -> list:
    """Backtrack an elimination order from host level snapshots; numpy only."""
    n = g.n
    adjb = [list(map(bool, row)) for row in g.adj]
    final = levels[-1]
    assert len(final) > 0
    cur = final[0]
    order_rev = []
    for lev in range(len(levels) - 1, 0, -1):
        prev_set = {bytes(row.tobytes()) for row in levels[lev - 1]}
        cur_set = bitset.np_unpack(cur, n)
        found = False
        for v in sorted(cur_set):
            parent = cur.copy()
            parent[v >> 5] &= ~(np.uint32(1) << np.uint32(v & 31))
            if bytes(parent.tobytes()) in prev_set:
                d = expand.degree_oracle(adjb, cur_set - {v}, v)
                if d <= k:
                    order_rev.append(v)
                    cur = parent
                    found = True
                    break
        assert found, "reconstruction failed: no parent in previous level"
    order = list(reversed(order_rev))
    remaining = sorted(set(range(n)) - set(order))
    return order + remaining


def order_width(g: Graph, order: list) -> int:
    """Replay an elimination order; max degree at elimination (oracle)."""
    adj = [set(np.nonzero(g.adj[v])[0]) for v in range(g.n)]
    width = 0
    for v in order:
        width = max(width, len(adj[v]))
        nbrs = list(adj[v])
        for i in range(len(nbrs)):
            for j in range(i + 1, len(nbrs)):
                adj[nbrs[i]].add(nbrs[j])
                adj[nbrs[j]].add(nbrs[i])
        for u in nbrs:
            adj[u].discard(v)
        adj[v].clear()
    return width


# --------------------------------------------------------------- top level

@dataclasses.dataclass
class SolveResult:
    width: int
    exact: bool
    lb: int
    ub: int
    expanded: int
    time_sec: float
    order: Optional[list] = None
    per_k: Optional[dict] = None


def solve_block(g: Graph, *, cap: int, block: int, mode: str, use_mmw: bool,
                m_bits: int, k_hashes: int, schedule: str, use_clique: bool,
                use_paths: bool, reconstruct: bool, start_k: Optional[int],
                verbose: bool, backend: str = "jax",
                use_simplicial: bool = False,
                engine: str = "fused") -> SolveResult:
    t0 = time.time()
    if g.n <= 1:
        return SolveResult(0, True, 0, 0, 0, time.time() - t0, list(range(g.n)), {})

    clique = bounds.greedy_max_clique(g) if use_clique else []
    lb = max(bounds.lower_bound(g), len(clique) - 1)
    ub, ub_order = bounds.upper_bound(g)
    if start_k is not None:
        lb = start_k
    per_k: dict = {}
    if lb >= ub:
        return SolveResult(ub, True, lb, ub, 0, time.time() - t0, ub_order, per_k)

    paths = bounds.disjoint_paths_matrix(g, cap=ub) if use_paths else None
    expanded_total = 0
    any_inexact = False
    for k in range(lb, ub):
        gk = g.with_edges(bounds.paths_edges(g, paths, k)) if use_paths else g
        res = decide(gk, k, clique, cap=cap, block=block, mode=mode,
                     use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
                     schedule=schedule, backend=backend,
                     use_simplicial=use_simplicial,
                     keep_levels=reconstruct, engine=engine)
        expanded_total += res.expanded
        per_k[k] = {"feasible": res.feasible, "inexact": res.inexact,
                    "expanded": res.expanded}
        if verbose:
            print(f"  [{g.name}] k={k} feasible={res.feasible} "
                  f"expanded={res.expanded} inexact={res.inexact}", flush=True)
        if res.feasible:
            order = None
            if reconstruct:
                order = reconstruct_order(gk, k, clique, res.levels)
            return SolveResult(k, not any_inexact, lb, ub, expanded_total,
                               time.time() - t0, order, per_k)
        if res.inexact:
            any_inexact = True
            # a state leading to a width-k order may have been dropped:
            # anything concluded beyond this k is a candidate value only
            # (paper: struck-through entries). We keep going like the paper.
    return SolveResult(ub, not any_inexact, lb, ub, expanded_total,
                       time.time() - t0, ub_order, per_k)


def solve(g: Graph, *, cap: int = 1 << 17, block: int = 1 << 11,
          mode: str = "sort", use_mmw: bool = False, m_bits: int = 1 << 24,
          k_hashes: int = bloom.DEFAULT_K, schedule: Optional[str] = None,
          use_clique: bool = True, use_paths: bool = True,
          use_preprocess: bool = True, reconstruct: bool = False,
          start_k: Optional[int] = None, verbose: bool = False,
          backend: str = "jax", use_simplicial: bool = False,
          engine: str = "fused", impl: Optional[str] = None) -> SolveResult:
    """Compute the treewidth of ``g``.  See module docstring for modes.

    ``engine`` selects the wavefront driver: "fused" (device-resident
    ``lax.while_loop``, one dispatch per k) or "host" (per-level host loop;
    forced automatically where reconstruction needs level snapshots).
    ``backend`` selects the op implementations through the registry
    (``repro.core.backend``): "jax" reference or fused "pallas" kernels.
    ``schedule=None`` resolves to the backend's default closure fixpoint
    ("while" for jax, the static "doubling" baked into the pallas kernels).
    ``impl`` is the deprecated spelling of ``backend``."""
    t0 = time.time()
    if impl is not None:
        warnings.warn("solve(impl=...) is deprecated; use backend=...",
                      DeprecationWarning, stacklevel=2)
        backend = impl
    if schedule is None:
        schedule = "doubling" if backend == "pallas" else "while"
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits)
    if g.n == 0:
        return SolveResult(0, True, 0, 0, 0, 0.0, [], {})
    if not use_preprocess:
        res = solve_block(g, cap=cap, block=block, mode=mode, use_mmw=use_mmw,
                          m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
                          use_clique=use_clique, use_paths=use_paths,
                          reconstruct=reconstruct, start_k=start_k,
                          verbose=verbose, backend=backend,
                          use_simplicial=use_simplicial, engine=engine)
        return res

    pre = preprocess_lib.preprocess(g)
    width, exact, expanded = pre.lb, True, 0
    lbs, ubs = pre.lb, pre.lb
    per_k: dict = {}
    for part in pre.blocks:
        if part.n - 1 <= width:      # a block can't beat the current width
            continue
        res = solve_block(part, cap=cap, block=block, mode=mode,
                          use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
                          schedule=schedule, use_clique=use_clique,
                          use_paths=use_paths, reconstruct=False,
                          start_k=start_k, verbose=verbose, backend=backend,
                          use_simplicial=use_simplicial, engine=engine)
        width = max(width, res.width)
        exact &= res.exact
        expanded += res.expanded
        lbs = max(lbs, res.lb)
        ubs = max(ubs, res.ub)
        per_k[part.name] = res.per_k
    return SolveResult(width, exact, lbs, max(ubs, width), expanded,
                       time.time() - t0, None, per_k)
