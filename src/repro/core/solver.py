"""Iterative-deepening treewidth solver (single device).

Structure mirrors the paper exactly (Listing 1 + §3.1 optimizations):

  for k = lb .. ub-1:                      (iterative deepening)
      G_k = G + edges{pairs with >= k+1 vertex-disjoint paths}   [rule 2]
      frontier = { {} }
      for level = 0 .. n - max(k+1, |C|) - 1:                    [rules 1,3]
          expand every S by every candidate v not in S u C,
              keeping S u {v} iff deg_S(v) <= k
          dedup (exact sort | Bloom filter)
          if frontier empty: k infeasible
      k feasible -> tw = k

Overflow of the fixed-capacity lists drops states and marks the run inexact
(identical to the paper's * semantics).  ``mode="bloom"`` reproduces the
paper's Monte-Carlo dedup; ``mode="sort"`` (default) is the exact
beyond-paper variant.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import backend as backend_lib
from . import bitset, bloom, bounds, dedup, engine as engine_lib
from . import frontier as frontier_lib
from . import expand
from . import preprocess as preprocess_lib
from . import telemetry
from .graph import Graph

U32 = jnp.uint32


# --------------------------------------------------------------- chunk step

@functools.partial(
    jax.jit,
    static_argnames=("n", "cap", "block", "mode", "use_mmw", "m_bits",
                     "k_hashes", "schedule", "backend", "use_simplicial"),
    donate_argnums=(4, 7),
)
def _chunk_step(adj, states_chunk, chunk_valid, k, out, ocount, dropped,
                filt, allowed, *, n, cap, block, mode, use_mmw, m_bits,
                k_hashes, schedule, backend, use_simplicial=False):
    """Expand one chunk of states and append deduped children to ``out``.

    Thin jitted wrapper over ``engine.expand_chunk`` — the single shared
    implementation of the Listing-1 inner loop (also used by the fused
    device-resident engine and the distributed solver)."""
    return engine_lib.expand_chunk(
        adj, states_chunk, chunk_valid, k, out, ocount, dropped, filt,
        allowed, n=n, cap=cap, block=block, mode=mode, use_mmw=use_mmw,
        m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
        backend=backend, use_simplicial=use_simplicial)


@functools.partial(jax.jit, static_argnames=("cap",), donate_argnums=(0,))
def _final_dedup(out, ocount, cap: int):
    valid = jnp.arange(cap) < ocount
    return dedup.dedup_compact(out, valid, cap)


# --------------------------------------------------------------- level loop

@dataclasses.dataclass
class LevelStats:
    expanded: int = 0
    generated: int = 0
    dropped: int = 0


def _pow2_at_least(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def run_level(adj_dev, fr: frontier_lib.Frontier, k: int, allowed_dev,
              *, n: int, cap: int, block: int, mode: str, use_mmw: bool,
              m_bits: int, k_hashes: int, schedule: str,
              backend: str = "jax", use_simplicial: bool = False,
              tracker=None):
    """One wavefront level: expand all states in ``fr`` into a new frontier.

    Host-loop engine: syncs on ``fr.count`` to size the chunk loop (the
    fused engine in ``core.engine`` keeps this loop on device)."""
    tr = telemetry.get(tracker)
    w = fr.w
    count = int(fr.count)
    tr.count(host_syncs=1)
    # adaptive block: early levels / small instances have tiny frontiers —
    # a fixed 1024-row block pays full padding cost per chunk (§Perf iter).
    # Rounding to powers of two bounds the number of jit signatures at
    # log2(block).
    block = max(32, min(block, _pow2_at_least(max(count, 1))))
    if cap % block:
        # dynamic_slice clamps out-of-range starts, so a non-dividing block
        # would silently re-expand earlier rows with the wrong valid mask
        raise ValueError(f"block ({block}) must divide cap ({cap})")
    out = jnp.zeros((cap, w), dtype=U32)
    ocount = jnp.asarray(0, dtype=jnp.int32)
    dropped = jnp.asarray(0, dtype=jnp.int32)
    filt = backend_lib.get_op("bloom_make_filter", backend)(
        m_bits if mode == "bloom" else None)
    kdev = jnp.asarray(k, dtype=jnp.int32)

    n_chunks = max(1, -(-count // block))
    for c in range(n_chunks):
        lo = c * block
        states_chunk = jax.lax.dynamic_slice(fr.states, (lo, 0), (block, w))
        chunk_valid = (jnp.arange(block, dtype=jnp.int32) + lo) < fr.count
        out, ocount, dropped, filt = _chunk_step(
            adj_dev, states_chunk, chunk_valid, kdev, out, ocount, dropped,
            filt, allowed_dev, n=n, cap=cap, block=block, mode=mode,
            use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
            schedule=schedule, backend=backend,
            use_simplicial=use_simplicial)
        tr.count(dispatches=1)

    if mode == "sort" and n_chunks > 1:
        out, ocount, drop2 = _final_dedup(out, ocount, cap)
        # cross-chunk duplicates removed; drops before dedup stay counted
        dropped = dropped + drop2
        tr.count(dispatches=1)

    new_fr = frontier_lib.Frontier(out, ocount, dropped)
    stats = LevelStats(expanded=count, generated=int(ocount),
                       dropped=int(dropped))
    tr.count(host_syncs=2)
    # occupancy vs the planned capacity: how full the frontier buffer
    # actually got (the host loop sees every level, so this is the true
    # per-level peak; compare against the ``frontier_cap`` gauge)
    tr.gauge_max("frontier_peak_rows", stats.generated)
    return new_fr, stats


# ----------------------------------------------------------------- decision

@dataclasses.dataclass
class DecideResult:
    feasible: bool
    inexact: bool
    expanded: int
    levels: Optional[list]    # host snapshots when reconstructing


def decide(g: Graph, k: int, clique: list, *, cap: int, block: int,
           mode: str, use_mmw: bool, m_bits: int, k_hashes: int,
           schedule: str, backend: str = "jax",
           use_simplicial: bool = False, keep_levels: bool = False,
           engine: str = "fused", tracker=None) -> DecideResult:
    """Is tw(g) <= k?  (Monte-Carlo 'no' possible in bloom mode / overflow.)

    ``engine="fused"`` runs the whole level/chunk recursion as one compiled
    program on the device (one dispatch, one sync — §3's design point);
    ``engine="host"`` drives the level loop from the host, which is the
    only engine that can snapshot per-level frontiers (``keep_levels``,
    needed for order reconstruction).  ``backend`` picks the op
    implementations (jax reference vs fused pallas kernels) through the
    registry — validated here, before any tracing starts."""
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits)
    tr = telemetry.get(tracker)
    n = g.n
    target = n - max(k + 1, len(clique))
    if target <= 0:
        return DecideResult(True, False, 0, [] if keep_levels else None)

    w = bitset.n_words(n)
    adj_dev = jnp.asarray(g.packed())
    allowed_dev = jnp.asarray(bitset.np_allowed(n, clique))

    if keep_levels:
        engine = "host"            # per-level snapshots need the host loop
    if engine not in ("host", "fused"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "host":
        # fail before any level runs, like the fused engine does — not at
        # the first level whose adapted block happens not to divide cap
        engine_lib.validate_geometry(cap, block, adaptive=True)

    if engine == "fused":
        with tr.time_block("rung_s"):
            feasible, inexact, expanded, _fr = engine_lib.fused_decide(
                adj_dev, allowed_dev, k, target, n=n, cap=cap, block=block,
                mode=mode, use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
                schedule=schedule, backend=backend,
                use_simplicial=use_simplicial, tracker=tr)
        # the fused loop only surfaces the final frontier, so this is a
        # lower bound on the true per-level peak (the host loop's gauge
        # sees every level)
        tr.gauge_max("frontier_peak_rows", int(_fr.count))
        return DecideResult(feasible, inexact, expanded, None)

    fr = frontier_lib.empty_frontier(cap, w)
    expanded = 0
    inexact = False
    levels = [frontier_lib.to_host(fr)] if keep_levels else None

    with tr.time_block("rung_s"):
        for _level in range(target):
            fr, stats = run_level(adj_dev, fr, k, allowed_dev, n=n, cap=cap,
                                  block=block, mode=mode, use_mmw=use_mmw,
                                  m_bits=m_bits, k_hashes=k_hashes,
                                  schedule=schedule, backend=backend,
                                  use_simplicial=use_simplicial, tracker=tr)
            expanded += stats.expanded
            inexact |= stats.dropped > 0
            if keep_levels:
                levels.append(frontier_lib.to_host(fr))
            tr.count(host_syncs=1)
            if int(fr.count) == 0:
                return DecideResult(False, inexact, expanded, levels)
    return DecideResult(True, inexact, expanded, levels)


# ----------------------------------------------------------- reconstruction

def reconstruct_order(g: Graph, k: int, clique: list, levels: list) -> list:
    """Backtrack an elimination order from host level snapshots; numpy only."""
    n = g.n
    adjb = [list(map(bool, row)) for row in g.adj]
    final = levels[-1]
    assert len(final) > 0
    cur = final[0]
    order_rev = []
    for lev in range(len(levels) - 1, 0, -1):
        prev_set = {bytes(row.tobytes()) for row in levels[lev - 1]}
        cur_set = bitset.np_unpack(cur, n)
        found = False
        for v in sorted(cur_set):
            parent = cur.copy()
            parent[v >> 5] &= ~(np.uint32(1) << np.uint32(v & 31))
            if bytes(parent.tobytes()) in prev_set:
                d = expand.degree_oracle(adjb, cur_set - {v}, v)
                if d <= k:
                    order_rev.append(v)
                    cur = parent
                    found = True
                    break
        assert found, "reconstruction failed: no parent in previous level"
    order = list(reversed(order_rev))
    remaining = sorted(set(range(n)) - set(order))
    return order + remaining


def order_width(g: Graph, order: list) -> int:
    """Replay an elimination order; max degree at elimination (oracle)."""
    adj = [set(np.nonzero(g.adj[v])[0]) for v in range(g.n)]
    width = 0
    for v in order:
        width = max(width, len(adj[v]))
        nbrs = list(adj[v])
        for i in range(len(nbrs)):
            for j in range(i + 1, len(nbrs)):
                adj[nbrs[i]].add(nbrs[j])
                adj[nbrs[j]].add(nbrs[i])
        for u in nbrs:
            adj[u].discard(v)
        adj[v].clear()
    return width


# --------------------------------------------------------------- top level

@dataclasses.dataclass
class SolveResult:
    width: int
    exact: bool
    lb: int
    ub: int
    expanded: int
    time_sec: float
    order: Optional[list] = None
    per_k: Optional[dict] = None


@dataclasses.dataclass
class BlockPlan:
    """Everything iterative deepening needs to run one block.

    Shared between ``solve_block`` (sequential and speculative lanes) and
    ``batch.solve_many`` (cross-instance lanes) so the two drivers cannot
    drift in bounds, start-k, or exactness semantics.  ``result`` is set
    when no search is needed (trivial graph, ``lb >= ub``, or a forced
    ``start_k`` at/above ``ub``); its ``time_sec`` is 0 and callers stamp
    their own.
    """
    g: Graph
    clique: list
    lb: int
    ub: int
    ub_order: list
    paths: Optional[np.ndarray]
    k0: int              # first k of the deepening ladder
    forced: bool         # k0 was pushed above the genuine lower bound
    result: Optional[SolveResult] = None

    def graph_at(self, k: int) -> Graph:
        """G_k: the paper's rule-2 graph (improved edges for width k)."""
        if self.paths is None:
            return self.g
        return self.g.with_edges(bounds.paths_edges(self.g, self.paths, k))

    def exact_at(self, k: int, any_inexact: bool) -> bool:
        """Is 'feasible at k' an exactness proof?  Only when no state was
        dropped below k AND infeasibility of k-1 was actually established
        — either k-1 < lb (genuine bound) or k-1 was decided in this run.
        A user-forced ``start_k`` above lb satisfies neither at ``k0``."""
        return (not any_inexact) and not (self.forced and k == self.k0)


def plan_block(g: Graph, *, use_clique: bool, use_paths: bool,
               start_k: Optional[int], heuristics: int = 0,
               seed: int = 0) -> BlockPlan:
    """Bounds + deepening schedule for one block.

    ``start_k`` moves the ladder's starting rung but never the *reported*
    lower bound: ``lb`` stays the genuine bound, and a start above it is
    flagged ``forced`` so a feasible verdict at that rung cannot be
    reported exact (nothing proved ``tw > start_k - 1``).

    ``heuristics > 0`` runs that many anytime improver rounds
    (``core.bounds_engine``) before scheduling the ladder: a tightened lb
    raises ``k0`` genuinely (not ``forced`` — the skipped rungs are
    refuted by a minor argument), a tightened ub shortens the ladder with
    a replayable order certificate.  ``seed`` pins every heuristic
    (clique restarts, randomized sweeps, contractions) so the plan is a
    pure function of ``(g, knobs)``; the defaults reproduce the
    heuristic-free plan bit-for-bit."""
    if g.n <= 1:
        return BlockPlan(g, [], 0, 0, list(range(g.n)), None, 0, False,
                         SolveResult(0, True, 0, 0, 0, 0.0,
                                     list(range(g.n)), {}))
    clique = bounds.greedy_max_clique(g, seed=seed) if use_clique else []
    lb = max(bounds.lower_bound(g, seed=seed), len(clique) - 1)
    ub, ub_order = bounds.upper_bound(g, seed=seed)
    if heuristics:
        from . import bounds_engine
        imp = bounds_engine.improve(g, lb, ub, ub_order,
                                    rounds=int(heuristics), seed=seed)
        lb, ub = imp.lb, imp.ub
        ub_order = imp.ub_order if imp.ub_order is not None else ub_order
    if lb >= ub:
        return BlockPlan(g, clique, lb, ub, ub_order, None, lb, False,
                         SolveResult(ub, True, lb, ub, 0, 0.0, ub_order, {}))
    k0, forced = lb, False
    if start_k is not None:
        k0 = max(0, int(start_k))
        forced = k0 > lb
        if k0 >= ub:
            warnings.warn(
                f"start_k={start_k} >= upper bound {ub} for {g.name}: no "
                "search performed, returning the heuristic ub as an "
                "inexact result", stacklevel=3)
            return BlockPlan(g, clique, lb, ub, ub_order, None, k0, forced,
                             SolveResult(ub, False, lb, ub, 0, 0.0,
                                         ub_order, {}))
    paths = bounds.disjoint_paths_matrix(g, cap=ub) if use_paths else None
    return BlockPlan(g, clique, lb, ub, ub_order, paths, k0, forced)


def solve_block(g: Graph, *, cap: Optional[int], block: int, mode: str,
                use_mmw: bool,
                m_bits: int, k_hashes: int, schedule: str, use_clique: bool,
                use_paths: bool, reconstruct: bool, start_k: Optional[int],
                verbose: bool, backend: str = "jax",
                use_simplicial: bool = False,
                engine: str = "fused", lanes: int = 1, shards: int = 1,
                donate_ratio: Optional[float] = None,
                heuristics: int = 0, seed: int = 0,
                tracker=None) -> SolveResult:
    """Iterative deepening on one (biconnected) block.

    ``cap=None`` right-sizes the frontier buffer for this block with
    ``batch.plan_capacity`` (drop-free state bound, clamped to
    ``batch.DEFAULT_CAP``) — bit-identical results, far smaller buffers
    for small blocks.

    ``lanes > 1`` enables speculative deepening: ``decide`` for
    ``k, k+1, ..., k+lanes-1`` runs as one multi-lane dispatch
    (``batch.decide_batch``) and the smallest feasible rung wins.
    Accounting mirrors the sequential ladder exactly — rungs above the
    first feasible one are discarded uncounted — so widths, exactness,
    ``expanded`` and ``per_k`` are bit-identical to ``lanes=1``.
    Speculation needs the fused device loop and no level snapshots;
    with ``engine="host"`` or ``reconstruct=True`` it falls back to
    sequential rungs.

    ``shards > 1`` decides each rung with the frontier split across S
    concurrent workers (``core.shard``: single-writer ownership routing +
    threshold work donation) — bit-identical verdicts/``expanded``/
    ``per_k``, aggregate frontier capacity S× larger.  Sharding takes the
    whole device, so it forces ``lanes=1``; reconstruction replays the
    winning rung on the host engine uncounted (the scheduler's
    ``_certify`` pattern).  ``shards=1`` is exactly the unsharded path
    (no wrapper, no counter drift)."""
    t0 = time.time()
    tr = telemetry.get(tracker)
    plan = plan_block(g, use_clique=use_clique, use_paths=use_paths,
                      start_k=start_k, heuristics=heuristics, seed=seed)
    if plan.result is not None:
        return dataclasses.replace(plan.result, time_sec=time.time() - t0)
    if cap is None:
        from . import batch as batch_lib
        cap = batch_lib.plan_capacity(g.n, block=block)
    # planned capacity for this block — read it against the
    # ``frontier_peak_rows`` high-watermark the engines ratchet
    tr.gauge("frontier_cap", cap)

    shard_n = max(1, int(shards))
    if shard_n > 1 and engine != "fused":
        shard_n = 1       # the host loop is single-frontier only
    spec = max(1, int(lanes))
    if spec > 1 and (reconstruct or engine != "fused" or shard_n > 1):
        spec = 1          # snapshots/host loop/sharding are single-lane only
    decide_kw = dict(cap=cap, block=block, mode=mode, use_mmw=use_mmw,
                     m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
                     backend=backend, use_simplicial=use_simplicial)
    per_k: dict = {}
    expanded_total = 0
    any_inexact = False
    k = plan.k0
    while k < plan.ub:
        ks = list(range(k, min(k + spec, plan.ub)))
        if shard_n > 1:
            from . import shard as shard_lib
            with tr.time_block("rung_s"):
                results = [shard_lib.decide_sharded(
                    plan.graph_at(ks[0]), ks[0], plan.clique,
                    shards=shard_n, donate_ratio=donate_ratio,
                    tracker=tr, **decide_kw)]
        elif spec > 1:
            from . import batch as batch_lib
            with tr.time_block("rung_s"):
                results = batch_lib.decide_batch(
                    g, ks, plan.clique,
                    graphs=[plan.graph_at(kk) for kk in ks],
                    tracker=tr, **decide_kw)
        else:
            results = [decide(plan.graph_at(ks[0]), ks[0], plan.clique,
                              keep_levels=reconstruct, engine=engine,
                              tracker=tr, **decide_kw)]
        for kk, res in zip(ks, results):
            expanded_total += res.expanded
            # per-rung accounting, mirroring ``batch.InstanceState.feed``
            # so a solo solve and a served request report the same
            # rung-level counters
            counts = dict(rungs_decided=1, expanded=res.expanded)
            if res.inexact:
                counts["rung_overflows"] = 1
            tr.count(**counts)
            per_k[kk] = {"feasible": res.feasible, "inexact": res.inexact,
                         "expanded": res.expanded}
            if verbose:
                print(f"  [{g.name}] k={kk} feasible={res.feasible} "
                      f"expanded={res.expanded} inexact={res.inexact}",
                      flush=True)
            if res.feasible:
                order = None
                if reconstruct:
                    levels = getattr(res, "levels", None)
                    if levels is None:
                        # sharded rung: replay the winning k on the host
                        # engine for snapshots, uncounted (the scheduler's
                        # ``_certify`` pattern — expanded stays the ladder's)
                        levels = decide(plan.graph_at(kk), kk, plan.clique,
                                        keep_levels=True, engine="host",
                                        tracker=tr, **decide_kw).levels
                    order = reconstruct_order(plan.graph_at(kk), kk,
                                              plan.clique, levels)
                return SolveResult(kk, plan.exact_at(kk, any_inexact),
                                   plan.lb, plan.ub, expanded_total,
                                   time.time() - t0, order, per_k)
            if res.inexact:
                any_inexact = True
                # a state leading to a width-k order may have been dropped:
                # anything concluded beyond this k is a candidate value only
                # (paper: struck-through entries). We keep going like the
                # paper.
        k = ks[-1] + 1
    return SolveResult(plan.ub, not any_inexact, plan.lb, plan.ub,
                       expanded_total, time.time() - t0, plan.ub_order,
                       per_k)


@dataclasses.dataclass
class SuiteFold:
    """Accumulator folding per-block results into one instance result —
    the single source of ``solve``'s preprocess-path semantics, shared
    with ``batch.solve_many`` so the two drivers cannot drift."""
    width: int
    exact: bool = True
    expanded: int = 0
    lbs: int = 0
    ubs: int = 0
    per_k: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def start(cls, lb: int) -> "SuiteFold":
        return cls(width=lb, lbs=lb, ubs=lb)

    def skip(self, g: Graph) -> bool:
        """A block can't beat the width found so far (and then any
        elimination order of it fits the width budget)."""
        return g.n - 1 <= self.width

    def add(self, name: str, res: SolveResult) -> None:
        self.width = max(self.width, res.width)
        self.exact &= res.exact
        self.expanded += res.expanded
        self.lbs = max(self.lbs, res.lb)
        self.ubs = max(self.ubs, res.ub)
        self.per_k[name] = res.per_k

    def result(self, elapsed: float, order=None) -> SolveResult:
        return SolveResult(self.width, self.exact, self.lbs,
                           max(self.ubs, self.width), self.expanded,
                           elapsed, order, self.per_k)


def solve(g: Graph, *, cap: Optional[int] = None, block: int = 1 << 11,
          mode: str = "sort", use_mmw: bool = False, m_bits: int = 1 << 24,
          k_hashes: int = bloom.DEFAULT_K, schedule: Optional[str] = None,
          use_clique: bool = True, use_paths: bool = True,
          use_preprocess: bool = True, reconstruct: bool = False,
          start_k: Optional[int] = None, verbose: bool = False,
          backend: str = "jax", use_simplicial: bool = False,
          engine: str = "fused", lanes: int = 1, shards: int = 1,
          donate_ratio: Optional[float] = None,
          heuristics: int = 0, seed: int = 0,
          impl: Optional[str] = None, tracker=None) -> SolveResult:
    """Compute the treewidth of ``g``.  See module docstring for modes.

    ``cap`` bounds the frontier buffer (rows per level).  The default
    ``cap=None`` auto-sizes it per preprocessed block with
    ``batch.plan_capacity``: the block's drop-free state bound, clamped
    to ``batch.DEFAULT_CAP`` (= the old fixed ``1 << 17`` default) —
    results are bit-identical to the fixed buffer, small blocks just stop
    paying its footprint.  Pass an explicit power of two to pin it.
    ``engine`` selects the wavefront driver: "fused" (device-resident
    ``lax.while_loop``, one dispatch per k) or "host" (per-level host loop;
    forced automatically where reconstruction needs level snapshots).
    ``backend`` selects the op implementations through the registry
    (``repro.core.backend``; the ad-hoc ``impl=`` string it replaced
    survives only as a deprecated alias of this knob): "jax" reference or
    fused "pallas" kernels.
    ``schedule=None`` resolves to the backend's default closure fixpoint
    ("while" for jax, the static "doubling" baked into the pallas kernels).
    ``lanes > 1`` turns the deepening ladder speculative: each dispatch
    decides ``lanes`` consecutive k concurrently through the multi-lane
    engine (``core.batch``) — same results, fewer dispatches.
    ``shards > 1`` splits each rung's *frontier* across S concurrent
    workers instead (``core.shard``: single-writer ownership routing,
    threshold work donation tuned by ``donate_ratio``) — bit-identical
    results with S× the aggregate frontier capacity; forces ``lanes=1``.
    ``heuristics > 0`` runs that many anytime bounds-improver rounds
    (``core.bounds_engine``) before each block's ladder: an improved lb
    skips already-refuted rungs, an improved ub clamps the ladder with an
    order certificate — the reported width/exactness never change, only
    the number of exact rungs paid for them.  ``seed`` pins every
    heuristic for bit-reproducible plans.
    ``reconstruct=True`` returns a certified elimination order; with
    preprocessing on, each block is reconstructed with the host engine and
    the block-local orders are stitched back through the preprocess vertex
    maps (``preprocess.stitch_block_orders``).  To batch *across*
    instances, see ``batch.solve_many``; to serve a concurrent request
    stream, see ``repro.serve.twscheduler``."""
    t0 = time.time()
    if impl is not None:
        warnings.warn("solve(impl=...) is deprecated; use backend=...",
                      DeprecationWarning, stacklevel=2)
        backend = impl
    if schedule is None:
        schedule = "doubling" if backend == "pallas" else "while"
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits, lanes=int(lanes),
                         shards=int(shards))
    if g.n == 0:
        return SolveResult(0, True, 0, 0, 0, 0.0, [], {})
    solve_kw = dict(cap=cap, block=block, mode=mode, use_mmw=use_mmw,
                    m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
                    use_clique=use_clique, use_paths=use_paths,
                    start_k=start_k, verbose=verbose, backend=backend,
                    use_simplicial=use_simplicial, engine=engine,
                    lanes=lanes, shards=shards, donate_ratio=donate_ratio,
                    heuristics=heuristics, seed=seed, tracker=tracker)
    if not use_preprocess:
        return solve_block(g, reconstruct=reconstruct, **solve_kw)

    pre = preprocess_lib.preprocess(g)
    fold = SuiteFold.start(pre.lb)
    block_orders: list = [None] * len(pre.blocks)
    for i, part in enumerate(pre.blocks):
        if fold.skip(part.g):
            continue
        res = solve_block(part.g, reconstruct=reconstruct, **solve_kw)
        fold.add(part.g.name, res)
        block_orders[i] = res.order
    order = None
    if reconstruct:
        order = stitch_and_verify(g, pre, block_orders, fold.width)
    return fold.result(time.time() - t0, order)


def stitch_and_verify(g: Graph, pre, block_orders: list,
                      width: int) -> Optional[list]:
    """Stitch per-block elimination orders into a global certificate and
    replay-check it (shared by ``solve`` and the lane drivers in
    ``core.batch`` / ``repro.serve.twscheduler`` so their reconstruction
    semantics cannot drift).  Returns ``None`` (with a warning) if the
    stitched order replays above the computed width."""
    order = preprocess_lib.stitch_block_orders(pre, block_orders)
    replay = order_width(g, order)
    if replay > width:
        warnings.warn(
            f"stitched elimination order replays at width {replay} > "
            f"computed width {width}; dropping the order (please "
            "report — this indicates a preprocess/stitch bug)",
            stacklevel=2)
        return None
    return order
