"""Batched multi-lane decide engine: one dispatch decides B subproblems.

The fused engine (``core.engine``) already keeps a single ``decide(g, k)``
on device, but the iterative-deepening driver and suite workloads still
issue every decide as its own program — early levels and small instances
leave the device nearly idle.  This module adds the missing batching axis
(component-aware parallel branching in the GPU-vertex-cover sense: run
independent subproblems concurrently until each saturates the device):

  * ``_lanes_decide`` vmaps ``engine.decide_loop`` over a leading lane
    axis.  Each lane carries its own padded ``(adj, allowed, k, target)``
    and ``Frontier`` slice; the while_loop batching rule folds per-lane
    early exit into the masked loop condition (a finished lane's carry is
    frozen by ``select`` while the others keep stepping), so every lane's
    result is bit-identical to running it alone.
  * ``decide_lanes`` is the host entry: pad, pack, one dispatch, one sync.
  * ``decide_batch(g, ks)`` — speculative deepening: decide
    ``k, k+1, ..`` for one graph concurrently (used by
    ``solver.solve_block(lanes=...)``; smallest feasible rung wins).
  * ``solve_many(graphs)`` — suite driver: pads instances/biconnected
    blocks to a common ``(n_max, W)`` and schedules lanes across the whole
    suite, replicating ``solver.solve``'s per-instance semantics exactly
    (same ``plan_block`` bounds, same skip rule, same accounting).
  * ``InstanceState`` — the per-request unit those drivers (and the serve
    scheduler, ``repro.serve.twscheduler``) advance rung by rung.
  * ``plan_capacity`` — the memory model: right-sizes per-lane frontier
    buffers from the block's state space, the chunk geometry and an
    optional device-memory budget instead of the fixed worst-case ``cap``
    (DESIGN.md §10).

Padding semantics: a lane of true size ``n_g`` is embedded at the bottom
of the common ``n_max`` index space; padding vertices are isolated in
``adj`` and cleared from ``allowed``, so they are never feasible
candidates and never perturb closures — the DP explores exactly the real
graph and frontier buffers match the unpadded run bit for bit (padded
state words are zero, so sort order is preserved too).  Two documented
caveats, both absent when lanes share one true ``n`` (e.g. speculative
deepening): (1) MMW pruning sees the padding vertices as isolated
degree-0 rows, which can only *weaken* the bound — verdicts are
unchanged, but ``expanded`` under ``use_mmw=True`` may exceed the
sequential count; (2) Bloom hashes cover all ``W`` words, so a lane
padded to a larger word count draws a different (still Monte-Carlo
correct) false-positive set than its sequential run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from . import backend as backend_lib
from . import bitset, bloom
from . import engine as engine_lib
from . import frontier as frontier_lib
from . import preprocess as preprocess_lib
from . import telemetry
from .graph import Graph

U32 = jnp.uint32

# default lane width of one dispatch: enough to cover a suite round or a
# deepening ladder without blowing the frontier-buffer footprint
# (B * cap * W words resident per dispatch)
DEFAULT_MAX_LANES = 8

# the historical fixed frontier capacity (solver.solve's old default).
# ``cap=None`` everywhere now means "plan_capacity, clamped to this":
# callers that want the old behaviour pass the constant explicitly.
DEFAULT_CAP = 1 << 17


def plan_capacity(n: int, w: Optional[int] = None, *, lanes: int = 1,
                  block: int = 1 << 11, cap_max: int = DEFAULT_CAP,
                  budget_bytes=None) -> int:
    """Right-size the per-lane frontier capacity for an ``n``-vertex block.

    Replaces the fixed ``cap`` default with the smallest power-of-two
    buffer that provably never drops a state the fixed buffer would have
    kept, so auto-sized runs stay bit-identical to fixed-``cap`` runs
    (DESIGN.md §10).  The bound: a level holds at most ``C(n, l)``
    distinct size-``l`` subsets, so with exact inter-level dedup the
    append stream of one level is at most ``count * n <=
    n * C(n, floor(n/2))`` rows — a buffer that large can never overflow,
    and above ``cap_max`` the plan clamps to ``cap_max`` exactly like the
    fixed default did.  Small preprocessed blocks are where this bites:
    an ``n=10`` block plans 4096 rows instead of 2^17, cutting the
    multi-lane pool footprint ~32x per lane.

    The planned cap never goes below ``block`` (chunk geometry — and with
    it Bloom-mode insert order — must match a fixed-``cap`` run of the
    same ``block``), nor below 32 (the engine's smallest adaptive chunk).

    ``budget_bytes`` optionally bounds the whole ``lanes``-wide pool:
    ``lanes * cap * W * 4`` bytes is kept under the budget (pass
    ``w = bitset.n_words(n_padded)`` for padded dispatches, and
    ``budget_bytes="auto"`` to read ``backend.device_memory_budget()``).
    A binding budget may reintroduce drops — runs stay correct, but carry
    the usual overflow inexactness instead of the parity guarantee.

    Runnable example::

        from repro.core import batch
        batch.plan_capacity(10, block=1 << 11)            # -> 4096
        batch.plan_capacity(25)                           # -> 131072 (2^17)
        batch.plan_capacity(14, 1, lanes=8,               # pool under a
                            budget_bytes=8 * 1024 * 4)    # 32 KiB budget
    """
    if n <= 1:
        need = 1
    else:
        need = n * math.comb(n, n // 2) + 1
    cap_hi = _pow2_floor(cap_max)      # an explicit cap_max is a ceiling:
    cap = min(_pow2_at_least(need), cap_hi)   # round DOWN, never past it
    cap = max(cap, 32, _pow2_at_least(min(block, cap_hi)))
    if budget_bytes == "auto":
        budget_bytes = backend_lib.device_memory_budget()
    if budget_bytes is not None:
        row_bytes = 4 * max(1, w if w is not None else bitset.n_words(n))
        afford = int(budget_bytes) // (max(1, lanes) * row_bytes)
        cap = max(32, min(cap, _pow2_floor(afford)))
    return cap


@dataclasses.dataclass(frozen=True)
class Lane:
    """One subproblem: decide tw(g) <= k, skipping ``clique`` (never
    eliminated — some optimal order ends with the max clique)."""
    g: Graph
    k: int
    clique: tuple = ()


@dataclasses.dataclass
class LaneResult:
    """Per-lane verdict; field-compatible with ``solver.DecideResult``
    minus the host level snapshots (lanes never keep levels)."""
    feasible: bool
    inexact: bool
    expanded: int


def _pow2_at_least(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _pow2_floor(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


@functools.partial(
    jax.jit,
    static_argnames=("n", "cap", "block", "mode", "use_mmw", "m_bits",
                     "k_hashes", "schedule", "backend", "use_simplicial"))
def _lanes_decide(adj, allowed, k, target, fr, *, n, cap, block, mode,
                  use_mmw, m_bits, k_hashes, schedule, backend,
                  use_simplicial):
    """``engine.decide_loop`` vmapped over the leading lane axis.

    adj (B, n, W) / allowed (B, W) / k, target (B,) / fr with lane-leading
    leaves.  One compiled program, one launch, B verdicts."""
    def one_lane(a, al, kk, tt, f):
        return engine_lib.decide_loop(
            a, al, kk, tt, f, n=n, cap=cap, block=block, mode=mode,
            use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
            schedule=schedule, backend=backend,
            use_simplicial=use_simplicial)
    return jax.vmap(one_lane)(adj, allowed, k, target, fr)


def _pack_lanes(lanes: Sequence[Lane], n_max: int, w: int):
    """Host-side padding: embed every lane in the common (n_max, W) space.

    Padding vertices stay isolated (zero adjacency rows) and are cleared
    from ``allowed``; ``target`` counts the lane's *true* levels, so the
    loop runs exactly as long as the unpadded decide would.  A lane whose
    target is <= 0 is trivially feasible and exits before its first level
    — the batched mirror of ``solver.decide``'s early return."""
    b = len(lanes)
    adj = np.zeros((b, n_max, w), dtype=np.uint32)
    allowed = np.zeros((b, w), dtype=np.uint32)
    ks = np.zeros((b,), dtype=np.int32)
    targets = np.zeros((b,), dtype=np.int32)
    for i, lane in enumerate(lanes):
        p = lane.g.packed()
        adj[i, :lane.g.n, :p.shape[1]] = p
        allowed[i] = bitset.np_allowed(lane.g.n, lane.clique, w)
        ks[i] = lane.k
        targets[i] = max(0, lane.g.n - max(lane.k + 1, len(lane.clique)))
    return adj, allowed, ks, targets


_TRIVIAL = Graph(1, np.zeros((1, 1), dtype=bool), "pad")


def _empty_dispatch() -> engine_lib.DispatchHandle:
    """A no-op handle: zero lanes, nothing dispatched, nothing to sync."""
    return engine_lib.DispatchHandle((), lambda host: [],
                                     _result=[], _done=True)


def decide_lanes_async(lanes: Sequence[Lane], *, cap: Optional[int] = None,
                       block: int, mode: str,
                       use_mmw: bool, m_bits: int, k_hashes: int,
                       schedule: str,
                       backend: str = "jax", use_simplicial: bool = False,
                       n_pad: Optional[int] = None,
                       lane_pad: Optional[int] = None,
                       cap_max: int = DEFAULT_CAP,
                       budget_bytes=None,
                       tracker=None) -> engine_lib.DispatchHandle:
    """Enqueue one multi-lane dispatch without blocking on its verdicts.

    The vmapped program is dispatched (counted) and the per-lane result
    arrays are held on device in the returned
    ``engine.DispatchHandle``; ``handle.result()`` performs the single
    deferred host sync and yields the ``List[LaneResult]``
    ``decide_lanes`` would have returned.  Between launch and result the
    host is free — the async solve service (``repro.serve.twscheduler``)
    admits and plans newly arrived requests there, so they are packed
    into the *next* dispatch instead of waiting for an idle pool.

        h = batch.decide_lanes_async([batch.Lane(g, 3)], block=32,
                                     mode="sort", use_mmw=False,
                                     m_bits=1 << 12, k_hashes=4,
                                     schedule="while")
        ...                      # host-side work overlaps the device
        [verdict] = h.result()   # the only host sync

    All knobs and padding/auto-``cap`` semantics are exactly
    ``decide_lanes``'s (which is now just launch + immediate result).
    """
    if not lanes:
        return _empty_dispatch()
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits, lanes=len(lanes))
    live = len(lanes)
    n_max = max(lane.g.n for lane in lanes)
    if n_pad is not None:
        if n_pad < n_max:
            raise ValueError(f"n_pad ({n_pad}) < largest lane n ({n_max})")
        n_max = n_pad
    n_max = max(1, n_max)
    if lane_pad is not None and lane_pad > live:
        lanes = list(lanes) + [Lane(_TRIVIAL, 0)] * (lane_pad - live)
    w = bitset.n_words(n_max)
    if cap is None:
        cap = max(plan_capacity(lane.g.n, w, lanes=len(lanes), block=block,
                                cap_max=cap_max, budget_bytes=budget_bytes)
                  for lane in lanes)
    block = engine_lib.validate_geometry(cap, block)

    adj, allowed, ks, targets = _pack_lanes(lanes, n_max, w)
    fr = frontier_lib.lane_frontiers(len(lanes), cap, w)
    out_fr, _levels, expanded, dropped = _lanes_decide(
        jnp.asarray(adj), jnp.asarray(allowed), jnp.asarray(ks),
        jnp.asarray(targets), fr, n=n_max, cap=cap, block=block, mode=mode,
        use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
        schedule=schedule, backend=backend, use_simplicial=use_simplicial)
    tr = telemetry.get(tracker)
    tr.count(dispatches=1)

    def finalize(host):
        counts_h, exp_h, drop_h = host
        out = [LaneResult(bool(counts_h[i] > 0), bool(drop_h[i] > 0),
                          int(exp_h[i])) for i in range(live)]
        # per-lane work accounting for the batch layer: how many real
        # lanes this dispatch decided, the states they expanded, and how
        # many hit the overflow (inexact) path
        tr.count(lanes_decided=live,
                 lane_expanded=sum(r.expanded for r in out),
                 lane_overflows=sum(1 for r in out if r.inexact))
        return out

    return engine_lib.DispatchHandle((out_fr.count, expanded, dropped),
                                     finalize, tracker=tr)


def decide_lanes(lanes: Sequence[Lane], *, cap: Optional[int] = None,
                 block: int, mode: str,
                 use_mmw: bool, m_bits: int, k_hashes: int, schedule: str,
                 backend: str = "jax", use_simplicial: bool = False,
                 n_pad: Optional[int] = None,
                 lane_pad: Optional[int] = None,
                 cap_max: int = DEFAULT_CAP,
                 budget_bytes=None,
                 tracker=None) -> List[LaneResult]:
    """Decide every lane in one dispatch; one host sync for all verdicts.

    ``n_pad`` pins the padded vertex count (callers batching many rounds
    pass a global n_max so every round hits the same compiled program);
    ``lane_pad`` rounds the lane axis up with trivial lanes for the same
    reason (compiled-program cache keyed on B).

    ``cap=None`` sizes the shared per-lane buffer with ``plan_capacity``:
    the largest lane's drop-free bound, clamped to ``cap_max`` (and to
    ``budget_bytes`` over the whole pool when given) — results stay
    bit-identical to a fixed-``cap`` dispatch per the plan's guarantee.

    Blocking form of ``decide_lanes_async`` — launch + immediate
    ``result()``.
    """
    return decide_lanes_async(
        lanes, cap=cap, block=block, mode=mode, use_mmw=use_mmw,
        m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
        backend=backend, use_simplicial=use_simplicial, n_pad=n_pad,
        lane_pad=lane_pad, cap_max=cap_max,
        budget_bytes=budget_bytes, tracker=tracker).result()


def decide_batch(g: Graph, ks: Sequence[int], clique: Sequence[int] = (),
                 *, graphs: Optional[Sequence[Graph]] = None,
                 cap: Optional[int] = None,
                 block: int, mode: str, use_mmw: bool, m_bits: int,
                 k_hashes: int, schedule: str, backend: str = "jax",
                 use_simplicial: bool = False,
                 tracker=None) -> List[LaneResult]:
    """Speculative deepening primitive: decide tw(g) <= k for several k in
    one dispatch.

    ``graphs`` optionally overrides the graph per rung — the deepening
    driver passes the paths-rule-augmented ``G_k`` for each k (rule 2
    admits more edges at higher k, so the lanes genuinely differ).  All
    lanes share the true ``n``, so results are bit-identical to the
    sequential ``decide`` loop for every mode/pruning combination."""
    if graphs is not None and len(graphs) != len(ks):
        raise ValueError("graphs must align with ks")
    lanes = [Lane(graphs[i] if graphs is not None else g, int(k),
                  tuple(clique)) for i, k in enumerate(ks)]
    return decide_lanes(lanes, cap=cap, block=block, mode=mode,
                        use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
                        schedule=schedule, backend=backend,
                        use_simplicial=use_simplicial, tracker=tracker)


# ----------------------------------------------------------- suite driver

@dataclasses.dataclass
class _Run:
    """Iterative deepening in progress on one block (mirrors the ladder
    state of ``solver.solve_block``)."""
    plan: object                  # solver.BlockPlan
    k: int
    idx: int = 0                  # index into the preprocess block list
    expanded: int = 0
    any_inexact: bool = False
    per_k: dict = dataclasses.field(default_factory=dict)


class InstanceState:
    """One input graph's scheduler state: the solve()-shaped fold over its
    preprocessed blocks (``solver.SuiteFold`` — the same accumulator
    ``solve`` uses, so the two drivers cannot drift), advanced block by
    block as lane verdicts are fed back.

    This is the per-request unit of both lane drivers: ``solve_many``
    walks a whole suite of them, and the serve scheduler
    (``repro.serve.twscheduler``) keeps one per admitted request, feeding
    each slot's rung verdict after every shared dispatch.  ``result`` is
    set (a ``solver.SolveResult``) once the instance is decided; until
    then ``run`` names the block rung currently occupying a lane.

    ``reconstruct=True`` additionally certifies the result with an
    elimination order: when a block's winning rung is found, that single
    rung is replayed once on the host engine (``keep_levels=True``) to
    snapshot its levels — the replay is *not* counted into ``expanded``,
    which keeps the accounting bit-identical to ``solver.solve`` (the
    sequential path also expands the winning rung exactly once) — and the
    block orders are stitched through the preprocess maps exactly like
    ``solve(reconstruct=True)``.  ``recon_kw`` carries the decide kwargs
    for that replay (``cap=None`` re-plans per block via
    ``plan_capacity``, matching the sequential auto-sizing)."""

    def __init__(self, g: Graph, solver_lib, *, use_preprocess: bool,
                 plan_kw: dict, reconstruct: bool = False,
                 recon_kw: Optional[dict] = None, tracker=None):
        self.g = g
        self.solver = solver_lib
        self.plan_kw = plan_kw
        # per-request telemetry scope (the serve scheduler passes each
        # request's child tracker so rung/expanded counts attribute to it
        # and roll up into the pool totals); NULL here, not the root —
        # suite drivers opt in explicitly
        self.tracker = telemetry.NULL if tracker is None else tracker
        self.reconstruct = reconstruct
        self.recon_kw = dict(recon_kw or {})
        self.t0 = time.time()
        self.result: Optional[object] = None     # solver.SolveResult
        self.run: Optional[_Run] = None
        self.pre = None                          # preprocess.Preprocessed
        self.use_pre = use_preprocess
        self.bi = 0
        if g.n == 0:
            self.parts: list = []
            self.fold = None
            self.block_orders: list = []
            self.result = solver_lib.SolveResult(0, True, 0, 0, 0, 0.0,
                                                 [], {})
            return
        if use_preprocess:
            self.pre = preprocess_lib.preprocess(g)
            self.parts = [b.g for b in self.pre.blocks]
            self.fold = solver_lib.SuiteFold.start(self.pre.lb)
        else:
            self.parts = [g]
            self.fold = None      # single block: adopt its result wholesale
        self.block_orders = [None] * len(self.parts)
        self._advance()

    def max_n(self) -> int:
        return max([p.n for p in self.parts], default=1)

    # ------------------------------------------------- anytime accounting

    def bounds(self) -> tuple:
        """Running instance-level ``(lb, ub)`` — the anytime contract.

        lb sources (each a true lower bound on tw(g)): the preprocess
        bound, the fold of finished blocks (their exact widths), the
        current block's ``plan.lb``, and its refuted rungs (k0..k-1
        infeasible ⇒ tw ≥ k — only when k0 was not forced above the
        genuine bound and no state was dropped).  ub sources (each a
        true upper bound per part; the instance ub is their max):
        finished blocks' widths (folded), the current block's heuristic
        ``plan.ub``, and n-1 for blocks not yet planned.  The serve
        scheduler clamps these monotone against the previously streamed
        pair; the deadline/cancel paths resolve with them directly."""
        lb = self.pre.lb if self.pre is not None else 0
        ub_parts = [0]
        if self.fold is not None:
            lb = max(lb, self.fold.lbs)
            if self.fold.exact:
                lb = max(lb, self.fold.width)
            ub_parts.append(self.fold.width)
        run = self.run
        if run is not None:
            lb = max(lb, run.plan.lb)
            if not run.plan.forced and not run.any_inexact:
                lb = max(lb, run.k)
            ub_parts.append(run.plan.ub)
        ub_parts.extend(p.n - 1 for p in self.parts[self.bi:])
        return lb, max(ub_parts)

    def partial(self) -> tuple:
        """``(expanded, per_k)`` accounted so far: finished blocks' fold
        plus the current block's in-progress ladder — the best-so-far
        work accounting a preempted (deadline) or abandoned (cancel)
        request reports instead of nothing."""
        run = self.run
        if self.fold is None:          # use_preprocess=False: solve_block
            if run is None:            # shape — per_k keyed directly by k
                return 0, {}
            return run.expanded, dict(run.per_k)
        expanded = self.fold.expanded
        per_k = dict(self.fold.per_k)
        if run is not None:
            expanded += run.expanded
            per_k[run.plan.g.name] = dict(run.per_k)
        return expanded, per_k

    def anytime_result(self, lb: Optional[int] = None,
                       ub: Optional[int] = None):
        """Resolve the instance *now* with its monotone best-so-far
        bounds (Tamaki's anytime framing, PAPERS.md): ``width=ub``
        (a heuristic order of that width exists), ``exact=False``, and
        the partial ``expanded``/``per_k``.  ``lb``/``ub`` default to
        ``bounds()``; the scheduler passes its stream-clamped pair so
        the terminal result agrees with the streamed events."""
        b_lb, b_ub = self.bounds()
        lb = b_lb if lb is None else lb
        ub = b_ub if ub is None else ub
        expanded, per_k = self.partial()
        return self.solver.SolveResult(ub, False, lb, ub, expanded,
                                       time.time() - self.t0, None, per_k)

    def _fold(self, bres, name: str, idx: int):
        if self.reconstruct:
            self.block_orders[idx] = bres.order
        if not self.use_pre:
            self.result = dataclasses.replace(
                bres, time_sec=time.time() - self.t0)
            return
        self.fold.add(name, bres)

    def _advance(self):
        """Start the next runnable block, or finish the instance."""
        while self.run is None and self.result is None:
            if self.bi >= len(self.parts):
                if self.use_pre:
                    order = None
                    if self.reconstruct:
                        order = self.solver.stitch_and_verify(
                            self.g, self.pre, self.block_orders,
                            self.fold.width)
                    self.result = self.fold.result(
                        time.time() - self.t0, order)
                return
            part = self.parts[self.bi]
            idx = self.bi
            self.bi += 1
            if self.use_pre and self.fold.skip(part):
                continue
            plan = self.solver.plan_block(part, **self.plan_kw)
            if plan.result is not None:
                self._fold(plan.result, part.name, idx)
                continue
            self.run = _Run(plan, k=plan.k0, idx=idx)

    def _certify(self, plan, k: int) -> Optional[list]:
        """Replay the winning rung on the host engine for level snapshots
        and backtrack an elimination order (uncounted — see class doc)."""
        kw = dict(self.recon_kw)
        if kw.get("cap") is None:
            kw["cap"] = plan_capacity(plan.g.n, block=kw.get("block", 32),
                                      cap_max=kw.pop("cap_max", DEFAULT_CAP))
        else:
            kw.pop("cap_max", None)
        res = self.solver.decide(plan.graph_at(k), k, plan.clique,
                                 keep_levels=True, engine="host", **kw)
        return self.solver.reconstruct_order(plan.graph_at(k), k,
                                             plan.clique, res.levels)

    def finish_block(self, k_found: Optional[int]):
        run = self.run
        plan = run.plan
        if k_found is not None:
            order = (self._certify(plan, k_found)
                     if self.reconstruct else None)
            bres = self.solver.SolveResult(
                k_found, plan.exact_at(k_found, run.any_inexact), plan.lb,
                plan.ub, run.expanded, 0.0, order, run.per_k)
        else:
            bres = self.solver.SolveResult(
                plan.ub, not run.any_inexact, plan.lb, plan.ub,
                run.expanded, 0.0, plan.ub_order, run.per_k)
        self.run = None
        self._fold(bres, plan.g.name, run.idx)
        self._advance()

    def feed(self, k: int, res: LaneResult) -> bool:
        """Consume one rung verdict with sequential-ladder accounting.

        Returns ``False`` once the block finished on this verdict (a
        speculative caller must discard its remaining rungs *uncounted* —
        the sequential ladder never ran them), ``True`` while the ladder
        continues.  This is the single accounting path shared by
        ``solve_many`` and the serve scheduler, so ``expanded``/``per_k``
        cannot drift from ``solver.solve_block``'s."""
        run = self.run
        run.expanded += res.expanded
        run.per_k[k] = {"feasible": res.feasible, "inexact": res.inexact,
                        "expanded": res.expanded}
        counts = dict(rungs_decided=1, expanded=res.expanded)
        if res.inexact:
            counts["rung_overflows"] = 1
        self.tracker.count(**counts)
        if res.feasible:
            self.finish_block(k)
            return False
        if res.inexact:
            run.any_inexact = True
        run.k = k + 1
        if run.k >= run.plan.ub:
            self.finish_block(None)
            return False
        return True

    def improve_bounds(self, lb: Optional[int] = None,
                       ub: Optional[int] = None,
                       ub_order: Optional[list] = None) -> dict:
        """Clamp anytime heuristic bounds into the current block's ladder
        (``core.bounds_engine`` improvers; monotone tighten only).

        A tighter ub (with its replayable order certificate) shortens the
        remaining ladder; a tighter lb skips rungs the minor argument has
        already refuted — ``run.k`` jumps forward and the skipped rungs
        are never dispatched, exactly as if ``plan_block`` had known the
        bound at admission.  Neither side can change the final verdict:
        when the clamped ladder closes (``run.k >= plan.ub``) the block
        resolves through the same ``finish_block(None)`` path the
        exhausted ladder uses, with both sides certificate-backed.
        Returns ``{lb_improved, ub_improved, rungs_skipped, finished}``
        (``finished`` = the whole *instance* resolved); hints without a
        certificate order, stale hints, and loosenings are ignored."""
        out = dict(lb_improved=False, ub_improved=False, rungs_skipped=0,
                   finished=False)
        run = self.run
        if run is None or self.result is not None:
            return out
        plan = run.plan
        if ub is not None and ub_order is not None and int(ub) < plan.ub:
            out["rungs_skipped"] += plan.ub - max(int(ub), run.k)
            plan.ub = int(ub)
            plan.ub_order = list(ub_order)
            out["ub_improved"] = True
        if lb is not None and int(lb) > plan.lb:
            plan.lb = min(int(lb), plan.ub)
            out["lb_improved"] = True
            if plan.lb > run.k:
                out["rungs_skipped"] += plan.lb - run.k
                run.k = plan.lb
        if run.k >= plan.ub:
            self.finish_block(None)
        out["finished"] = self.result is not None
        return out


def solve_many(graphs: Sequence[Graph], *, cap: Optional[int] = None,
               block: int = 1 << 11, mode: str = "sort",
               use_mmw: bool = False, m_bits: int = 1 << 24,
               k_hashes: int = bloom.DEFAULT_K,
               schedule: Optional[str] = None, use_clique: bool = True,
               use_paths: bool = True, use_preprocess: bool = True,
               reconstruct: bool = False,
               start_k: Optional[int] = None, verbose: bool = False,
               backend: str = "jax", use_simplicial: bool = False,
               lanes: int = DEFAULT_MAX_LANES,
               speculate: int = 1,
               budget_bytes=None) -> List[object]:
    """Solve a whole suite with cross-instance lane batching.

    Returns one ``solver.SolveResult`` per input, in input order, with the
    exact widths/exactness/bounds/``per_k``/``expanded`` the sequential
    ``[solve(g) for g in graphs]`` loop produces — subject to the two
    padding caveats in the module docstring: under ``use_mmw=True`` the
    padded lanes may expand a superset (verdicts unchanged), and under
    ``mode="bloom"`` a lane padded into a larger word count than its
    sequential run (instances straddling a multiple of 32 vertices) draws
    a different Monte-Carlo false-positive set, so its width/exactness
    carry the usual Bloom-mode probabilistic guarantee rather than
    bit-parity with the sequential run.  The default configuration
    (sort-mode dedup, no MMW) is exactly parity-pinned.  Instead of one
    dispatch per (instance, k), every scheduler round packs all
    instances' current deepening rungs into multi-lane dispatches of up to
    ``lanes`` lanes.  ``speculate > 1`` additionally lets each instance
    occupy that many consecutive-k lanes per round.

    ``cap=None`` (default) sizes each dispatch's shared per-lane buffer
    with ``plan_capacity`` (drop-free bound of the largest lane, clamped
    to ``DEFAULT_CAP`` / ``budget_bytes``) instead of one fixed
    worst-case buffer — small preprocessed blocks stop paying for 2^17
    rows they can never fill, and the parity guarantees above still hold.

    ``reconstruct=True`` certifies every result with a stitched
    elimination order exactly like ``solver.solve(reconstruct=True)``:
    each block's winning rung is replayed once on the host engine for
    level snapshots (uncounted, so ``expanded`` parity is preserved).

    Runnable example (suite batching; for a *concurrent request stream*
    with per-request knobs and streaming, use the serve scheduler —
    DESIGN.md §10/§11)::

        from repro.core import batch, graph
        res = batch.solve_many([graph.myciel(4), graph.petersen()],
                               lanes=8)
        [r.width for r in res]            # -> [10, 4]
    """
    from . import solver as solver_lib   # lazy: solver imports this module

    if schedule is None:
        schedule = "doubling" if backend == "pallas" else "while"
    lanes = int(lanes)
    speculate = max(1, int(speculate))
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits, lanes=lanes)
    decide_kw = dict(cap=cap, block=block, mode=mode, use_mmw=use_mmw,
                     m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
                     backend=backend, use_simplicial=use_simplicial,
                     budget_bytes=budget_bytes)
    plan_kw = dict(use_clique=use_clique, use_paths=use_paths,
                   start_k=start_k)
    recon_kw = dict(cap=cap, block=block, mode=mode, use_mmw=use_mmw,
                    m_bits=m_bits, k_hashes=k_hashes, schedule=schedule,
                    backend=backend, use_simplicial=use_simplicial)

    insts = [InstanceState(g, solver_lib, use_preprocess=use_preprocess,
                           plan_kw=plan_kw, reconstruct=reconstruct,
                           recon_kw=recon_kw) for g in graphs]
    n_pad = max([i.max_n() for i in insts], default=1)
    if cap is None:
        # resolve ONE plan for the whole suite (largest block wins)
        # instead of per dispatch group: per-group caps would mint a new
        # jit signature every time group membership changes, and the
        # vmapped lane program is expensive to compile.  Still <= the old
        # fixed default, and all-small suites keep the full footprint cut.
        w = bitset.n_words(n_pad)
        decide_kw["cap"] = max(plan_capacity(
            p.n, w, lanes=lanes, block=block, budget_bytes=budget_bytes)
            for i in insts for p in i.parts) if any(i.parts for i in insts) \
            else 32

    rnd = 0
    while True:
        live = [inst for inst in insts if inst.run is not None]
        if not live:
            break
        sched = []
        lane_list: list = []
        for inst in live:
            run = inst.run
            ks = list(range(run.k, min(run.k + speculate, run.plan.ub)))
            sched.append((inst, ks))
            lane_list.extend(
                Lane(run.plan.graph_at(kk), kk, tuple(run.plan.clique))
                for kk in ks)
        if verbose:
            print(f"[solve_many] round {rnd}: {len(lane_list)} lanes over "
                  f"{len(live)} instances", flush=True)
        results: list = []
        for lo in range(0, len(lane_list), lanes):
            group = lane_list[lo:lo + lanes]
            results.extend(decide_lanes(
                group, n_pad=n_pad,
                lane_pad=min(lanes, _pow2_at_least(len(group))),
                **decide_kw))
        pos = 0
        for inst, ks in sched:
            name = inst.run.plan.g.name
            rungs = results[pos:pos + len(ks)]
            pos += len(ks)
            for kk, res in zip(ks, rungs):
                if verbose:
                    print(f"  [{name}] k={kk} "
                          f"feasible={res.feasible} "
                          f"expanded={res.expanded} "
                          f"inexact={res.inexact}", flush=True)
                if not inst.feed(kk, res):
                    # block finished on this rung: rungs above it were
                    # never run sequentially — discard them uncounted
                    break
        rnd += 1
    return [inst.result for inst in insts]
