"""Host-side bounds and orderings (numpy).

These run once per instance (not per state), so they stay on the host:
  * greedy max clique  -> the paper's "eliminate the clique last" rule,
    plus clique-number lower bound (omega - 1 <= tw);
  * degeneracy         -> lower bound;
  * min-degree / min-fill elimination orderings -> upper bounds (and the
    initial candidate width for iterative deepening);
  * MMW on the whole graph -> lower bound (the same heuristic the GPU
    kernel applies per state, run once at the root).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def greedy_max_clique(g: Graph, tries: int = 32, seed: int = 0) -> list:
    """Greedy clique from multiple degree-ordered starts; any clique is a
    *valid* skip set, bigger is better."""
    rng = np.random.RandomState(seed)
    best: list = []
    deg = g.degrees()
    order0 = np.argsort(-deg)
    for t in range(tries):
        order = order0 if t == 0 else rng.permutation(g.n)
        clique: list = []
        mask = np.ones(g.n, dtype=bool)
        for v in order:
            if mask[v]:
                clique.append(int(v))
                mask &= g.adj[v]
        if len(clique) > len(best):
            best = clique
    return best


def degeneracy(g: Graph) -> int:
    """Max over the min-degree elimination of current min degree."""
    adj = [set(np.nonzero(g.adj[v])[0]) for v in range(g.n)]
    alive = set(range(g.n))
    out = 0
    while alive:
        v = min(alive, key=lambda x: len(adj[x]))
        out = max(out, len(adj[v]))
        for u in adj[v]:
            adj[u].discard(v)
        alive.discard(v)
    return out


def _elimination_ub(g: Graph, strategy: str, rng=None) -> tuple:
    """Simulate a heuristic elimination; returns (width, order).

    With ``rng`` the index tiebreak is replaced by a per-run random rank,
    turning the greedy sweep into a seeded randomized restart (the
    "randomized contraction order" improver of the bounds engine).
    """
    adj = [set(np.nonzero(g.adj[v])[0]) for v in range(g.n)]
    alive = set(range(g.n))
    width, order = 0, []
    rank = (rng.permutation(g.n) if rng is not None
            else np.arange(g.n, dtype=np.int64))

    def fill_in(v):
        nbrs = list(adj[v])
        cnt = 0
        for i in range(len(nbrs)):
            for j in range(i + 1, len(nbrs)):
                if nbrs[j] not in adj[nbrs[i]]:
                    cnt += 1
        return cnt

    while alive:
        if strategy == "min_degree":
            v = min(alive, key=lambda x: (len(adj[x]), rank[x], x))
        else:  # min_fill
            v = min(alive, key=lambda x: (fill_in(x), len(adj[x]), rank[x], x))
        width = max(width, len(adj[v]))
        nbrs = list(adj[v])
        for i in range(len(nbrs)):
            for j in range(i + 1, len(nbrs)):
                a, b = nbrs[i], nbrs[j]
                adj[a].add(b)
                adj[b].add(a)
        for u in nbrs:
            adj[u].discard(v)
        adj[v].clear()
        alive.discard(v)
        order.append(int(v))
    return width, order


def randomized_order(g: Graph, seed: int, strategy: str = "min_degree") -> tuple:
    """One seeded randomized-restart elimination order; (width, order).

    Deterministic per (g, seed, strategy): the greedy tiebreak is a
    random rank drawn from ``seed``, so distinct seeds explore distinct
    orders while any single seed replays bit-identically.
    """
    if g.n == 0:
        return 0, []
    return _elimination_ub(g, strategy, rng=np.random.RandomState(seed))


def upper_bound(g: Graph, seed: int = 0, restarts: int = 0) -> tuple:
    """Best of min-degree / min-fill. Returns (width, order).

    ``restarts`` adds that many seeded randomized min-degree sweeps on
    top of the two deterministic ones; ``seed`` pins them so the result
    is a pure function of (g, seed, restarts).  The defaults reproduce
    the historical deterministic bound exactly.
    """
    if g.n == 0:
        return 0, []
    w1, o1 = _elimination_ub(g, "min_degree")
    w2, o2 = _elimination_ub(g, "min_fill")
    best = (w1, o1) if w1 <= w2 else (w2, o2)
    for r in range(restarts):
        w, o = randomized_order(g, seed + r)
        if w < best[0]:
            best = (w, o)
    return best


def mmw_root_bound(g: Graph) -> int:
    """MMW lower bound on the whole graph (host mirror of core.mmw)."""
    from .mmw import mmw_oracle
    if g.n <= 1:
        return 0
    return mmw_oracle(g.adj, set())


def lower_bound(g: Graph, seed: int = 0) -> int:
    if g.n <= 1:
        return 0
    lb = max(degeneracy(g), mmw_root_bound(g),
             len(greedy_max_clique(g, tries=8, seed=seed)) - 1)
    return lb


def disjoint_paths_matrix(g: Graph, cap: int = 64) -> np.ndarray:
    """P[u, v] = number of internally-vertex-disjoint u-v paths (capped).

    Vertex-capacity max-flow via BFS augmentation on the standard split
    graph (v_in -> v_out).  Used for the paper's rule: if P[u,v] >= k+1 the
    edge uv may be added when testing width k [Clautiaux et al.].
    Runs once per instance on the host.
    """
    n = g.n
    out = np.zeros((n, n), dtype=np.int32)
    nbrs = [list(np.nonzero(g.adj[v])[0]) for v in range(n)]

    def maxflow(s: int, t: int, limit: int) -> int:
        # node-split network: node 2v = v_in, 2v+1 = v_out
        # edges: v_in->v_out cap 1 (inf for s,t), uv edge: u_out->v_in cap 1
        flow = 0
        # residual as dict-of-dict is slow; use adjacency with capacity map
        capm = {}

        def add(a, b, c):
            capm[(a, b)] = capm.get((a, b), 0) + c
            capm.setdefault((b, a), 0)

        for v in range(n):
            add(2 * v, 2 * v + 1, 1 if v not in (s, t) else limit + 1)
        for u in range(n):
            for v in nbrs[u]:
                add(2 * u + 1, 2 * v, 1)
        adjn = [[] for _ in range(2 * n)]
        for (a, b) in capm:
            adjn[a].append(b)
        src, snk = 2 * s + 1, 2 * t
        while flow <= limit:
            # BFS for augmenting path
            parent = {src: None}
            q = [src]
            while q and snk not in parent:
                nq = []
                for a in q:
                    for b in adjn[a]:
                        if b not in parent and capm[(a, b)] > 0:
                            parent[b] = a
                            nq.append(b)
                q = nq
            if snk not in parent:
                break
            b = snk
            while parent[b] is not None:
                a = parent[b]
                capm[(a, b)] -= 1
                capm[(b, a)] += 1
                b = a
            flow += 1
        return flow

    for u in range(n):
        for v in range(u + 1, n):
            f = maxflow(u, v, cap)
            out[u, v] = out[v, u] = f
    return out


def paths_edges(g: Graph, paths: np.ndarray, k: int) -> np.ndarray:
    """Edges addable at width k: pairs with >= k+1 disjoint paths."""
    extra = (paths >= (k + 1)) & ~g.adj
    np.fill_diagonal(extra, False)
    return extra
