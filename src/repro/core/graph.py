"""Graph representation + instance generators + DIMACS io.

The solver operates on a packed adjacency matrix: ``adj_packed`` is an
``(n, W)`` uint32 array whose row ``v`` is the bitset N(v).  The numpy
boolean matrix is kept for host-side preprocessing.

Generators cover the reproducible subset of the paper's benchmark:
queen graphs, Mycielski graphs, Kneser graphs, LCF-notation cubic graphs
(McGee, Dyck), (torus) grids and seeded random families.  PACE protein /
BN instances are not redistributable offline (see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import itertools
import numpy as np

from . import bitset


@dataclasses.dataclass(frozen=True)
class Graph:
    n: int
    adj: np.ndarray            # (n, n) bool, symmetric, zero diagonal
    name: str = "graph"

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum()) // 2

    @property
    def w(self) -> int:
        return bitset.n_words(self.n)

    def packed(self) -> np.ndarray:
        """(n, W) uint32 packed adjacency."""
        w = self.w
        out = np.zeros((self.n, w), dtype=np.uint32)
        vs, us = np.nonzero(self.adj)
        np.bitwise_or.at(out, (vs, us >> 5), np.uint32(1) << (us & 31).astype(np.uint32))
        return out

    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1).astype(np.int32)

    def neighbors(self, v: int):
        return np.nonzero(self.adj[v])[0]

    def with_edges(self, extra: np.ndarray, name=None) -> "Graph":
        """Return a graph with additional edges OR-ed in (bool (n,n))."""
        a = self.adj | extra | extra.T
        np.fill_diagonal(a, False)
        return Graph(self.n, a, name or self.name)

    def subgraph(self, vertices) -> "Graph":
        vertices = np.asarray(sorted(vertices))
        a = self.adj[np.ix_(vertices, vertices)]
        return Graph(len(vertices), a, f"{self.name}[{len(vertices)}]")

    def relabel(self, perm: np.ndarray) -> "Graph":
        """perm[i] = new label of old vertex i."""
        inv = np.empty_like(perm)
        inv[perm] = np.arange(self.n)
        a = self.adj[np.ix_(inv, inv)]
        return Graph(self.n, a, self.name + "_perm")


def from_edges(n: int, edges, name="graph") -> Graph:
    a = np.zeros((n, n), dtype=bool)
    for u, v in edges:
        if u != v:
            a[u, v] = a[v, u] = True
    return Graph(n, a, name)


# ---------------------------------------------------------------- generators

def path(n: int) -> Graph:
    return from_edges(n, [(i, i + 1) for i in range(n - 1)], f"path{n}")


def cycle(n: int) -> Graph:
    return from_edges(n, [(i, (i + 1) % n) for i in range(n)], f"cycle{n}")


def complete(n: int) -> Graph:
    return from_edges(n, itertools.combinations(range(n), 2), f"K{n}")


def complete_bipartite(a: int, b: int) -> Graph:
    return from_edges(a + b, [(i, a + j) for i in range(a) for j in range(b)],
                      f"K{a}_{b}")


def star(n: int) -> Graph:
    return from_edges(n, [(0, i) for i in range(1, n)], f"star{n}")


def grid(rows: int, cols: int) -> Graph:
    def vid(r, c):
        return r * cols + c
    edges = []
    for r in range(rows):
        for c in range(cols):
            if r + 1 < rows:
                edges.append((vid(r, c), vid(r + 1, c)))
            if c + 1 < cols:
                edges.append((vid(r, c), vid(r, c + 1)))
    return from_edges(rows * cols, edges, f"grid{rows}x{cols}")


def torus_grid(rows: int, cols: int) -> Graph:
    def vid(r, c):
        return r * cols + c
    edges = []
    for r in range(rows):
        for c in range(cols):
            edges.append((vid(r, c), vid((r + 1) % rows, c)))
            edges.append((vid(r, c), vid(r, (c + 1) % cols)))
    return from_edges(rows * cols, edges, f"{rows}x{cols}_torusGrid")


def queen(k: int) -> Graph:
    """k x k queen graph (vertices = squares, edges = queen moves)."""
    def vid(r, c):
        return r * k + c
    edges = []
    for r1, c1 in itertools.product(range(k), repeat=2):
        for r2, c2 in itertools.product(range(k), repeat=2):
            if (r1, c1) >= (r2, c2):
                continue
            if r1 == r2 or c1 == c2 or abs(r1 - r2) == abs(c1 - c2):
                edges.append((vid(r1, c1), vid(r2, c2)))
    return from_edges(k * k, edges, f"queen{k}_{k}")


def mycielski(g: Graph) -> Graph:
    """Mycielski construction: tw grows, chromatic number grows, triangle-free kept."""
    n = g.n
    edges = []
    for u in range(n):
        for v in range(u + 1, n):
            if g.adj[u, v]:
                edges.append((u, v))
                edges.append((u, n + v))
                edges.append((v, n + u))
    for u in range(n):
        edges.append((n + u, 2 * n))
    return from_edges(2 * n + 1, edges, "mycielski")


def myciel(k: int) -> Graph:
    """myciel-k in DIMACS naming: myciel3 is the 11-vertex Grotzsch graph,
    myciel4 has 23 vertices (tw 10), myciel5 has 47 (tw 19)."""
    g = complete(2)
    for _ in range(k - 1):
        g = mycielski(g)
    return Graph(g.n, g.adj, f"myciel{k}")


def kneser(n: int, k: int) -> Graph:
    """Kneser graph K(n, k): vertices = k-subsets, edges = disjoint pairs."""
    subs = list(itertools.combinations(range(n), k))
    sets = [frozenset(s) for s in subs]
    edges = [(i, j) for i in range(len(subs)) for j in range(i + 1, len(subs))
             if not (sets[i] & sets[j])]
    return from_edges(len(subs), edges, f"KneserGraph_{n}_{k}")


def petersen() -> Graph:
    g = kneser(5, 2)
    return Graph(g.n, g.adj, "PetersenGraph")


def lcf(n: int, pattern, reps: int, name: str) -> Graph:
    """LCF-notation cubic Hamiltonian graph: cycle 0..n-1 + chords i -> i+pattern."""
    edges = [(i, (i + 1) % n) for i in range(n)]
    seq = list(pattern) * reps
    assert len(seq) == n
    for i, jump in enumerate(seq):
        edges.append((i, (i + jump) % n))
    return from_edges(n, edges, name)


def mcgee() -> Graph:
    """McGee graph = (3,7)-cage, 24 vertices, LCF [12,7,-7]^8. tw = 7."""
    return lcf(24, [12, 7, -7], 8, "McGeeGraph")


def dyck() -> Graph:
    """Dyck graph, 32 vertices, LCF [5,-5,13,-13]^8. tw = 7."""
    return lcf(32, [5, -5, 13, -13], 8, "DyckGraph")


def desargues() -> Graph:
    return lcf(20, [5, -5, 9, -9], 5, "DesarguesGraph")


def gnp(n: int, p: float, seed: int) -> Graph:
    rng = np.random.RandomState(seed)
    a = rng.rand(n, n) < p
    a = np.triu(a, 1)
    a = a | a.T
    return Graph(n, a, f"gnp_{n}_{p}_{seed}")


def barabasi_albert(n: int, m: int, seed: int) -> Graph:
    """BA preferential attachment (same family as RandomBarabasiAlbert_100_2)."""
    rng = np.random.RandomState(seed)
    edges = []
    targets = list(range(m))
    repeated = []
    for v in range(m, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m)
        targets = list(rng.choice(repeated, size=m, replace=False))
    return from_edges(n, edges, f"BarabasiAlbert_{n}_{m}_{seed}")


def random_tree(n: int, seed: int) -> Graph:
    rng = np.random.RandomState(seed)
    edges = [(i, int(rng.randint(0, i))) for i in range(1, n)]
    return from_edges(n, edges, f"tree_{n}_{seed}")


def random_partial_ktree(n: int, k: int, drop: float, seed: int) -> Graph:
    """Random k-tree minus ``drop`` fraction of edges: treewidth <= k."""
    rng = np.random.RandomState(seed)
    a = np.zeros((n, n), dtype=bool)
    clique = list(range(k + 1))
    for u in range(k + 1):
        for v in range(u + 1, k + 1):
            a[u, v] = a[v, u] = True
    cliques = [clique]
    for v in range(k + 1, n):
        c = cliques[rng.randint(len(cliques))]
        keep = rng.choice(len(c), size=k, replace=False)
        base = [c[i] for i in keep]
        for u in base:
            a[u, v] = a[v, u] = True
        cliques.append(base + [v])
    # drop edges
    es = np.argwhere(np.triu(a, 1))
    kill = es[rng.rand(len(es)) < drop]
    for u, v in kill:
        a[u, v] = a[v, u] = False
    return Graph(n, a, f"partial_{k}tree_{n}_{seed}")


# ---------------------------------------------------------------- DIMACS io

def read_dimacs(path: str) -> Graph:
    """Read a DIMACS ``.col``-style or PACE ``.gr`` graph.

    Tolerant of what real instance files actually contain: comment
    (``c ...`` / ``% ...``) and blank lines anywhere (not just a header
    block), ``e u v`` and bare ``u v`` edge lines mixed, node-weight
    ``n v w`` lines (ignored), a ``p`` header whose format token may be
    missing (``p tw n m`` / ``p edge n m`` / ``p n m``), and both 1- and
    0-based vertex numbering: files touching vertex 0 are taken as
    0-based, everything else shifts down by one (the PACE/DIMACS
    convention).  Self-loops are dropped and duplicate edges collapse
    (``from_edges``); indices past the header's ``n`` grow the graph
    instead of crashing."""
    n, edges = 0, []
    name = path.rsplit("/", 1)[-1].rsplit(".", 1)[0]
    with open(path) as f:
        for line in f:
            t = line.split()
            if not t or t[0] in ("c", "%") or t[0].startswith("%"):
                continue
            if t[0] == "p":
                # "p tw n m" / "p edge n m" / bare "p n m": the vertex
                # count is the first numeric token
                nums = [x for x in t[1:] if x.lstrip("-").isdigit()]
                if not nums:
                    raise ValueError(
                        f"{path}: malformed p header {line.rstrip()!r}")
                n = int(nums[0])
            elif t[0] == "n":
                continue               # node-weight line (some .col files)
            elif t[0] == "e":
                edges.append((int(t[1]), int(t[2])))
            elif len(t) == 2:          # PACE .gr edge line
                edges.append((int(t[0]), int(t[1])))
    if any(u < 0 or v < 0 for u, v in edges):
        raise ValueError(f"{path}: negative vertex index")
    # unified base detection over all edge lines: any vertex 0 => the
    # file is 0-based; otherwise 1-based (shift down)
    if edges and not any(0 in e for e in edges):
        edges = [(u - 1, v - 1) for u, v in edges]
    if edges:
        n = max(n, max(max(e) for e in edges) + 1)
    return from_edges(n, edges, name)


def write_dimacs(g: Graph, path: str) -> None:
    with open(path, "w") as f:
        f.write(f"p tw {g.n} {g.n_edges}\n")
        for u in range(g.n):
            for v in range(u + 1, g.n):
                if g.adj[u, v]:
                    f.write(f"{u + 1} {v + 1}\n")


REGISTRY = {
    "mcgee": mcgee,
    "dyck": dyck,
    "petersen": petersen,
    "desargues": desargues,
    "myciel3": lambda: myciel(3),
    "myciel4": lambda: myciel(4),
    "myciel5": lambda: myciel(5),
    "queen5_5": lambda: queen(5),
    "queen6_6": lambda: queen(6),
    "queen7_7": lambda: queen(7),
    "queen8_8": lambda: queen(8),
    "kneser8_3": lambda: kneser(8, 3),
    "8x6_torusGrid": lambda: torus_grid(8, 6),
    "grid6x6": lambda: grid(6, 6),
    "ba_100_2": lambda: barabasi_albert(100, 2, 42),
}
