"""Packed-uint32 bitset algebra.

All treewidth state in this framework is represented as packed bitsets:
a set over a universe of ``n`` vertices is ``W = ceil(n/32)`` ``uint32``
words.  Everything here is branch-free and vectorises onto the TPU VPU —
this is the data-parallel replacement for the paper's per-thread stacks.

Conventions:
  * bit ``i`` lives in word ``i >> 5`` at position ``i & 31``.
  * bits at positions ``>= n`` are always zero (maintained by construction).
  * functions accept/return ``jnp.uint32`` arrays; shapes documented per fn.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

U32 = jnp.uint32


def n_words(n: int) -> int:
    """Number of uint32 words needed for an n-bit set."""
    return (n + 31) // 32


def zeros(n: int) -> jnp.ndarray:
    return jnp.zeros((n_words(n),), dtype=U32)


def full(n: int) -> jnp.ndarray:
    """Bitset containing {0, ..., n-1}."""
    w = n_words(n)
    out = np.zeros((w,), dtype=np.uint32)
    for i in range(n):
        out[i >> 5] |= np.uint32(1) << np.uint32(i & 31)
    return jnp.asarray(out)


def onehot(i, w: int) -> jnp.ndarray:
    """Bitset {i} with w words. ``i`` may be traced."""
    i = jnp.asarray(i, dtype=jnp.int32)
    words = jnp.arange(w, dtype=jnp.int32)
    return jnp.where(words == (i >> 5), U32(1) << (i & 31).astype(U32), U32(0))


def get_bit(words: jnp.ndarray, i) -> jnp.ndarray:
    """Test bit i of a (..., W) bitset -> (...,) bool."""
    i = jnp.asarray(i, dtype=jnp.int32)
    word = jnp.take(words, i >> 5, axis=-1)
    return ((word >> (i & 31).astype(U32)) & U32(1)).astype(jnp.bool_)


def set_bit(words: jnp.ndarray, i) -> jnp.ndarray:
    return words | onehot(i, words.shape[-1])


def clear_bit(words: jnp.ndarray, i) -> jnp.ndarray:
    return words & ~onehot(i, words.shape[-1])


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Population count over the trailing word axis: (..., W) -> (...,) int32."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32), axis=-1)


def unpack(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """(..., W) bitset -> (..., n) bool."""
    idx = jnp.arange(n, dtype=jnp.int32)
    w = jnp.take(words, idx >> 5, axis=-1)
    return ((w >> (idx & 31).astype(U32)) & U32(1)).astype(jnp.bool_)


def pack(bits: jnp.ndarray, n: int) -> jnp.ndarray:
    """(..., n) bool -> (..., W) bitset."""
    w = n_words(n)
    pad = w * 32 - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1)
    b = bits.reshape(bits.shape[:-1] + (w, 32)).astype(U32)
    shifts = (U32(1) << jnp.arange(32, dtype=U32))
    return jnp.sum(b * shifts, axis=-1).astype(U32)


def select_or(mask_bits: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """OR of the rows selected by a boolean mask.

    mask_bits: (..., n) bool   rows: (n, W)  ->  (..., W)
    This is one row of the OR-AND semiring "matmul"; it replaces the paper's
    DFS neighbour expansion with a dense, divergence-free reduction.
    """
    sel = jnp.where(mask_bits[..., None], rows, U32(0))
    return jax.lax.reduce(sel, U32(0), jax.lax.bitwise_or, (mask_bits.ndim - 1,))


def or_matmul(mask_words: jnp.ndarray, rows: jnp.ndarray, n: int) -> jnp.ndarray:
    """Bit-matrix product in the OR-AND semiring.

    mask_words: (m, W) packed masks;  rows: (n, W)  ->  (m, W) where
    ``out[i] = OR_{j : bit j of mask_words[i]} rows[j]``.
    """
    bits = unpack(mask_words, n)          # (m, n)
    return select_or(bits, rows)          # (m, W)


def np_pack(sets, n: int) -> np.ndarray:
    """Host-side helper: list of python sets / iterables -> (len, W) uint32."""
    w = n_words(n)
    out = np.zeros((len(sets), w), dtype=np.uint32)
    for r, s in enumerate(sets):
        for i in s:
            out[r, i >> 5] |= np.uint32(1) << np.uint32(i & 31)
    return out


def np_unpack(words: np.ndarray, n: int) -> list:
    """(W,) uint32 -> python set."""
    return {i for i in range(n) if (int(words[i >> 5]) >> (i & 31)) & 1}


def np_allowed(n: int, skip=(), w: int = None) -> np.ndarray:
    """Host-side candidate mask: bits 0..n-1 set except ``skip`` (the
    clique skip set), zero-padded to ``w`` words when a lane lives in a
    larger common word space.  Single source for ``solver.decide`` and the
    multi-lane packer — the two must stay bit-identical for lane parity."""
    full_words = np.asarray(full(n))
    out = np.zeros(w if w is not None else len(full_words), dtype=np.uint32)
    out[:len(full_words)] = full_words
    for v in skip:
        out[v >> 5] &= ~np.uint32(np.uint32(1) << np.uint32(v & 31))
    return out
