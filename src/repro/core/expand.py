"""Wavefront expansion: one level of the Held-Karp treewidth DP.

``expand_block`` is the data-parallel replacement of Listing 1 lines 5-22:
for a block of states S it computes, for *every* candidate vertex v at once,
``deg_S(v)`` and the child bitset ``S ∪ {v}``.  ``wavefront_expand`` layers
the feasibility mask and the pruning rules (simplicial collapse, MMW) on
top — it is the **jax reference implementation** of the backend registry's
``wavefront_expand`` op (``repro.core.backend``); the fused Pallas kernel
in ``repro.kernels.wavefront`` computes the same function in one
VMEM-resident pass and is validated against this module bit for bit (and
both against the python oracles in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitset, components
from . import mmw as mmw_lib

U32 = jnp.uint32


@functools.partial(jax.jit, static_argnames=("n", "schedule"))
def expand_block(adj: jnp.ndarray, states: jnp.ndarray, valid: jnp.ndarray,
                 k: jnp.ndarray, allowed: jnp.ndarray, n: int,
                 schedule: str = "doubling"):
    """Expand a block of states.

    adj:     (n, W) packed adjacency
    states:  (B, W) packed state bitsets
    valid:   (B,)   bool
    k:       scalar int32 — target treewidth
    allowed: (W,)   candidate mask (complement of the max-clique skip set)

    Returns (children (B, n, W), feasible (B, n) bool, degrees (B, n) int32,
             reach (B, n, W) — per-state eliminated-graph adjacency, reused
             by the MMW and simplicial pruning rules).
    """
    if schedule == "matmul":
        deg_fn = lambda s: components.eliminated_degrees_matmul(adj, s, n)
        degrees, reach = jax.vmap(deg_fn)(states)
    else:
        deg_fn = lambda s: components.eliminated_degrees(adj, s, n,
                                                         schedule=schedule)
        degrees, reach = jax.vmap(deg_fn)(states)           # (B, n), (B, n, W)

    in_s = bitset.unpack(states, n)                          # (B, n)
    allowed_bits = bitset.unpack(allowed, n)                 # (n,)
    feasible = ((degrees <= k)
                & ~in_s
                & allowed_bits[None, :]
                & valid[:, None])

    w = adj.shape[-1]
    eye = components._eye_words(n, w)                        # (n, W)
    children = states[:, None, :] | eye[None, :, :]          # (B, n, W)
    return children, feasible, degrees, reach


def simplicial_viol(q, closed, n: int):
    """viol (B, n) bool: candidate v has a witness u ∈ Q_v whose closed
    eliminated-graph neighborhood misses part of Q_v (so Q_v is no clique).

    Word-level scan over witnesses u — every intermediate stays (B, n, W).
    Capture-free pure jnp: the fused pallas wavefront kernel imports this
    exact function, so the parity-critical rule has a single source.
    q, closed: (B, n, W).
    """
    def body(u, viol):
        has_u = bitset.get_bit(q, u)                         # (B,n): u ∈ Q_v?
        closed_u = jax.lax.dynamic_index_in_dim(closed, u, axis=1,
                                                keepdims=False)     # (B,W)
        t = jnp.any((q & ~closed_u[:, None, :]) != 0, axis=-1)      # (B,n)
        return viol | (has_u & t)

    b = q.shape[0]
    return jax.lax.fori_loop(0, n, body,
                             jnp.zeros((b, n), dtype=jnp.bool_))


def simplicial_mask(adj, states, reach, feasible, n: int):
    """Per (state, v): is v simplicial in the eliminated graph G_S?

    The paper's §5 names simplicial-vertex detection as the open pruning
    rule; this is its bit-parallel TPU form.  If a state has any feasible
    simplicial candidate, eliminating it first is *safe* (a perfect-
    elimination prefix exists), so all sibling branches can be pruned —
    the caller collapses ``feasible`` to exactly one such v.

    Memory: the scan over witness vertices u keeps every intermediate at
    (B, n, W) — O(B·n·W) words — instead of materialising the pairwise
    (B, n, n, W) miss tensor of the naive formulation (at block=1024,
    n=64, W=2 that 4-D tensor is ~32 MiB, ~8x the frontier buffer; the
    scan peak is ~4 MiB).  Arithmetic cost is unchanged (O(B·n²·W) word
    ops either way).

    adj (n,W); states (B,W); reach (B,n,W); feasible (B,n) ->
    (is_simplicial (B,n) bool).
    """
    w = adj.shape[-1]
    eye = components._eye_words(n, w)
    q = (reach & ~states[:, None, :]) & ~eye[None]           # (B,n,W) Q(S,v)
    # u's eliminated-graph closed neighborhood: reach[u] | {u}
    closed = reach | eye[None]                               # (B,n,W)
    return feasible & ~simplicial_viol(q, closed, n)


def collapse_simplicial(feasible, simp):
    """If any simplicial candidate exists, keep only the lowest-index one."""
    has = jnp.any(simp, axis=-1, keepdims=True)              # (B,1)
    n = feasible.shape[-1]
    idx = jnp.argmax(simp, axis=-1)                          # first True
    only = jax.nn.one_hot(idx, n, dtype=bool) & simp
    return jnp.where(has, only, feasible)


@functools.partial(jax.jit, static_argnames=("n", "schedule", "use_mmw",
                                             "use_simplicial"))
def wavefront_expand(adj, states, valid, k, allowed, *, n: int,
                     schedule: str = "doubling", use_mmw: bool = False,
                     use_simplicial: bool = False):
    """The Listing-1 inner loop, jax backend: expand a block, apply the
    feasibility test and the enabled pruning rules.

    Same signature and bit-identical outputs as the fused pallas kernel
    (``repro.kernels.wavefront.wavefront_expand``); dispatched via the
    ``wavefront_expand`` op of ``repro.core.backend``.

    Returns (children (B, n, W) uint32, feasible (B, n) bool).
    """
    children, feasible, _deg, reach = expand_block(
        adj, states, valid, k, allowed, n, schedule=schedule)

    if use_simplicial:
        simp = simplicial_mask(adj, states, reach, feasible, n)
        feasible = collapse_simplicial(feasible, simp)

    if use_mmw:
        lbs = jax.vmap(lambda r, s: mmw_lib.mmw_bound(r, s, k, n))(
            reach, states)
        feasible = feasible & (lbs <= k)[:, None]

    return children, feasible


def degree_oracle(adj_bool, s: set, v: int) -> int:
    """Host-side python oracle: |Q(S, v)| by explicit BFS (paper Listing 1)."""
    n = len(adj_bool)
    seen = [False] * n
    stack = [v]
    seen[v] = True
    degree = 0
    while stack:
        u = stack.pop()
        for wv in range(n):
            if adj_bool[u][wv] and not seen[wv]:
                seen[wv] = True
                if wv in s:
                    stack.append(wv)
                else:
                    degree += 1
    return degree
