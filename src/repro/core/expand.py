"""Wavefront expansion: one level of the Held-Karp treewidth DP.

``expand_block`` is the data-parallel replacement of Listing 1 lines 5-22:
for a block of states S it computes, for *every* candidate vertex v at once,
``deg_S(v)`` and the child bitset ``S ∪ {v}``.  Pure-JAX path; the Pallas
kernel in ``repro.kernels.expand`` computes the same function with explicit
VMEM tiling and is validated against this module (and both against the
python oracle in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitset, components

U32 = jnp.uint32


@functools.partial(jax.jit, static_argnames=("n", "schedule", "impl"))
def expand_block(adj: jnp.ndarray, states: jnp.ndarray, valid: jnp.ndarray,
                 k: jnp.ndarray, allowed: jnp.ndarray, n: int,
                 schedule: str = "doubling", impl: str = "jax"):
    """Expand a block of states.

    adj:     (n, W) packed adjacency
    states:  (B, W) packed state bitsets
    valid:   (B,)   bool
    k:       scalar int32 — target treewidth
    allowed: (W,)   candidate mask (complement of the max-clique skip set)
    impl:    "jax" (vmap) or "pallas" (VMEM-tiled kernel; no reach output,
             so incompatible with MMW pruning)

    Returns (children (B, n, W), feasible (B, n) bool, degrees (B, n) int32,
             reach (B, n, W) — per-state eliminated-graph adjacency, for MMW;
             None under impl="pallas").
    """
    if impl == "pallas":
        from repro.kernels.expand import expand_degrees
        degrees = expand_degrees(adj, states, n=n)
        reach = None
    elif schedule == "matmul":
        deg_fn = lambda s: components.eliminated_degrees_matmul(adj, s, n)
        degrees, reach = jax.vmap(deg_fn)(states)
    else:
        deg_fn = lambda s: components.eliminated_degrees(adj, s, n,
                                                         schedule=schedule)
        degrees, reach = jax.vmap(deg_fn)(states)           # (B, n), (B, n, W)

    in_s = bitset.unpack(states, n)                          # (B, n)
    allowed_bits = bitset.unpack(allowed, n)                 # (n,)
    feasible = ((degrees <= k)
                & ~in_s
                & allowed_bits[None, :]
                & valid[:, None])

    w = adj.shape[-1]
    eye = components._eye_words(n, w)                        # (n, W)
    children = states[:, None, :] | eye[None, :, :]          # (B, n, W)
    return children, feasible, degrees, reach


def simplicial_mask(adj, states, reach, feasible, n: int):
    """Per (state, v): is v simplicial in the eliminated graph G_S?

    The paper's §5 names simplicial-vertex detection as the open pruning
    rule; this is its bit-parallel TPU form.  If a state has any feasible
    simplicial candidate, eliminating it first is *safe* (a perfect-
    elimination prefix exists), so all sibling branches can be pruned —
    the caller collapses ``feasible`` to exactly one such v.

    adj (n,W); states (B,W); reach (B,n,W); feasible (B,n) ->
    (is_simplicial (B,n) bool).
    """
    w = adj.shape[-1]
    eye = components._eye_words(n, w)
    q = (reach & ~states[:, None, :]) & ~eye[None]           # (B,n,W) Q(S,v)
    q_bits = bitset.unpack(q, n)                             # (B,n,n)
    # u's eliminated-graph closed neighborhood: reach[u] | {u}
    closed = reach | eye[None]                               # (B,n,W)
    # violation[v] = exists u in Q_v with  Q_v \ closed(u) != {}
    miss = q[:, :, None, :] & ~closed[:, None, :, :]         # (B,n,n,W)
    nonzero = jnp.any(miss != 0, axis=-1)                    # (B,n,n)
    viol = jnp.any(q_bits & nonzero, axis=-1)                # (B,n)
    return feasible & ~viol


def collapse_simplicial(feasible, simp):
    """If any simplicial candidate exists, keep only the lowest-index one."""
    has = jnp.any(simp, axis=-1, keepdims=True)              # (B,1)
    n = feasible.shape[-1]
    idx = jnp.argmax(simp, axis=-1)                          # first True
    only = jax.nn.one_hot(idx, n, dtype=bool) & simp
    return jnp.where(has, only, feasible)


def degree_oracle(adj_bool, s: set, v: int) -> int:
    """Host-side python oracle: |Q(S, v)| by explicit BFS (paper Listing 1)."""
    n = len(adj_bool)
    seen = [False] * n
    stack = [v]
    seen[v] = True
    degree = 0
    while stack:
        u = stack.pop()
        for wv in range(n):
            if adj_bool[u][wv] and not seen[wv]:
                seen[wv] = True
                if wv in s:
                    stack.append(wv)
                else:
                    degree += 1
    return degree
