"""Bloom filter duplicate detection (paper-faithful mode).

Murmur3 (32-bit) double hashing exactly as in the paper: two hashes
``h1, h2`` combined linearly, ``H_i = h1 + i*h2`` (Kirsch-Mitzenmacher),
``k = 17`` probes, ``m/n >= 24`` bits per element for a ~1e-5 false-positive
rate.  The GPU's atomic-OR + mutex striping has no XLA analogue; in the
data-parallel setting the filter is updated with a masked scatter-max over a
byte-per-bit array, and *intra-batch* duplicates (the case the paper's
mutexes serialise) are resolved exactly by the sort in ``dedup.py`` or
sequentially inside the Pallas kernel (``repro.kernels.bloom``).

False positives make the solver Monte Carlo exactly as in the paper; the
solver records dedup mode in its stats so results are labelled.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

U32 = jnp.uint32

C1 = np.uint32(0xCC9E2D51)
C2 = np.uint32(0x1B873593)
MIX1 = np.uint32(0x85EBCA6B)
MIX2 = np.uint32(0xC2B2AE35)
SEED1 = np.uint32(0x9747B28C)
SEED2 = np.uint32(0x31415926)
DEFAULT_K = 17           # paper §3.2
DEFAULT_BITS_PER_ELEM = 24


def _rotl(x, r):
    r = np.uint32(r)
    return (x << r) | (x >> np.uint32(32 - r))


def murmur3_words(words: jnp.ndarray, seed) -> jnp.ndarray:
    """Murmur3 x86 32-bit over (..., W) uint32 words -> (...,) uint32.

    Word count is static, so the block loop is unrolled at trace time.
    """
    w = words.shape[-1]
    h = jnp.full(words.shape[:-1], seed, dtype=U32)
    for j in range(w):
        kv = words[..., j]
        kv = kv * C1
        kv = _rotl(kv, 15)
        kv = kv * C2
        h = h ^ kv
        h = _rotl(h, 13)
        h = h * np.uint32(5) + np.uint32(0xE6546B64)
    h = h ^ np.uint32(w * 4)
    h = h ^ (h >> np.uint32(16))
    h = h * MIX1
    h = h ^ (h >> np.uint32(13))
    h = h * MIX2
    h = h ^ (h >> np.uint32(16))
    return h


def murmur3_ref(words, seed: int) -> int:
    """Pure-python oracle for tests."""
    mask = 0xFFFFFFFF

    def rotl(x, r):
        return ((x << r) | (x >> (32 - r))) & mask

    h = seed & mask
    for kv in words:
        kv = int(kv)
        kv = (kv * 0xCC9E2D51) & mask
        kv = rotl(kv, 15)
        kv = (kv * 0x1B873593) & mask
        h ^= kv
        h = rotl(h, 13)
        h = (h * 5 + 0xE6546B64) & mask
    h ^= len(words) * 4
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & mask
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & mask
    h ^= h >> 16
    return h


def probe_indices(words: jnp.ndarray, m_bits: int, k_hashes: int = DEFAULT_K):
    """(..., W) -> (..., k) int32 filter positions H_i = h1 + i*h2 (mod m)."""
    h1 = murmur3_words(words, SEED1)
    h2 = murmur3_words(words, SEED2)
    i = jnp.arange(k_hashes, dtype=U32)
    idx = h1[..., None] + i * h2[..., None]
    return (idx % np.uint32(m_bits)).astype(jnp.int32)


def query(filt: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """filt: (m,) uint8 0/1;  idx: (..., k) -> (...,) bool 'maybe present'."""
    bits = filt[idx]
    return jnp.all(bits == 1, axis=-1)


def insert(filt: jnp.ndarray, idx: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Set the probe bits of all valid elements (masked scatter-max)."""
    m = filt.shape[0]
    safe = jnp.where(valid[..., None], idx, m)              # m == drop slot
    return filt.at[safe.reshape(-1)].max(jnp.uint8(1), mode="drop")


def make_filter(m_bits: int) -> jnp.ndarray:
    return jnp.zeros((m_bits,), dtype=jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m_bits", "k_hashes"))
def query_and_insert(filt, words, valid, m_bits: int, k_hashes: int = DEFAULT_K):
    """Returns (was_new (...,) bool, updated filter).

    Semantics match the paper's insert: an element is 'new' iff any probed
    bit was zero before insertion.  Duplicates *within* ``words`` will all
    report new — callers must intra-batch dedup first (see module docstring).
    """
    idx = probe_indices(words, m_bits, k_hashes)
    present = query(filt, idx)
    was_new = valid & ~present
    return was_new, insert(filt, idx, valid)
