"""Scoped, pluggable telemetry: the observability substrate (DESIGN.md §14).

The paper's whole evaluation (Tables 1–6) is work-size and timing
measurement, but the reproduction grew up funnelling everything through
one process-global dict (``engine.COUNTERS``) — no per-request
attribution, no timings, no way to stream scheduler health off the box,
and a latent race once the twserved driver thread started mutating it
while the main thread read.  This module replaces that with a tree of
``Tracker`` scopes:

  * ``count(name=delta, ...)`` — monotone counters.  A count made on a
    child scope **writes through** to every ancestor atomically, so a
    request scope's counters sum exactly into the pool scope's totals by
    construction (no snapshot-time aggregation to race against).
  * ``gauge(name, value)`` — last-value gauges, recorded on the scope
    they are set on (a parent's "last value" of a child gauge is
    meaningless, so gauges do not roll up).
  * ``gauge_max(name, value)`` — high-watermark gauges; the ratchet
    *does* write through (the pool's peak is the max over its requests).
    ``shard_peak_occupancy`` keeps its legacy max-not-sum semantics here.
  * ``time_block(name)`` — a context manager accumulating wall-clock
    into ``timings[name] = {calls, total_s, max_s}``; ``timing(name, s)``
    is the direct form for spans measured by hand (e.g. launch→result of
    a ``DispatchHandle``).  Timings roll up like counters.
  * ``child(scope)`` — a sub-scope sharing the tree's single lock.
    ``child`` is idempotent per name; ``drop_child`` detaches a finished
    scope (its contributions remain in the ancestors' totals).
  * sinks — every mutation emits one record ``{"ts", "scope", "kind",
    ...}`` to the sinks attached at the call scope *and* every ancestor
    (attach a ``JsonlSink`` at the root and the whole tree streams).
    ``InMemorySink`` buffers records, ``JsonlSink`` appends JSON lines,
    ``StdoutSink`` prints — all duck-typed on ``emit(record)``.

Thread safety: one ``RLock`` per tree, shared by every scope (children
inherit the root's).  All reads (``snapshot``, ``value``, the legacy
``COUNTERS`` view) and writes take it, which fixes the twserved
driver-thread race.  Event rates are per *dispatch/rung/request*, never
per state, so a single lock is nowhere near contended.

Overhead: the default for hot paths is ``NULL`` — a ``NullTracker``
singleton whose methods are empty and whose ``time_block`` returns a
shared no-op context manager; passing it costs one attribute call per
dispatch.  Library entry points take ``tracker=None`` meaning "the
process root" (``telemetry.root()``), preserving the legacy global
accounting that ~30 existing tests assert through the deprecated
read-only ``COUNTERS`` mapping below.
"""
from __future__ import annotations

import json
import sys
import threading
import time
from typing import Any, Dict, IO, Iterator, List, Mapping, Optional


# ------------------------------------------------------------------ sinks

class InMemorySink:
    """Buffer every record in order; ``records`` is the log, ``clear()``
    empties it.  Emission happens under the tree lock, so the order seen
    here is the true global mutation order."""

    def __init__(self) -> None:
        self.records: List[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def clear(self) -> None:
        self.records.clear()


class JsonlSink:
    """Append one JSON line per record to ``path`` (or an open file).

    Flushes per record so the artifact is complete even if the process
    dies mid-run — these are benchmark/CI artifacts, not a hot path.
    """

    def __init__(self, path_or_file: Any) -> None:
        if hasattr(path_or_file, "write"):
            self._f: IO[str] = path_or_file
            self._owns = False
        else:
            self._f = open(path_or_file, "a", encoding="utf-8")
            self._owns = True

    def emit(self, record: dict) -> None:
        self._f.write(json.dumps(record, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._owns:
            self._f.close()


class StdoutSink:
    """Human-oriented one-line-per-record printer (debugging aid)."""

    def __init__(self, file: Optional[IO[str]] = None) -> None:
        self._f = file if file is not None else sys.stdout

    def emit(self, record: dict) -> None:
        scope = record.get("scope") or "<root>"
        kind = record.get("kind")
        if kind == "count":
            body = " ".join(f"{k}+={v}"
                            for k, v in sorted(record["counters"].items()))
        elif kind in ("gauge", "gauge_max"):
            body = f"{record['name']}={record['value']}"
        else:
            body = f"{record['name']}={record['seconds']:.6f}s"
        print(f"[telemetry] {scope} {kind} {body}", file=self._f)


# ------------------------------------------------------------- time block

class _TimeBlock:
    """Context manager created by ``Tracker.time_block``: measures
    ``perf_counter`` wall-clock and records it on exit (also on
    exception — a failed span still took time)."""

    __slots__ = ("_tracker", "_name", "_t0")

    def __init__(self, tracker: "Tracker", name: str) -> None:
        self._tracker = tracker
        self._name = name
        self._t0 = 0.0

    def __enter__(self) -> "_TimeBlock":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self._tracker.timing(self._name, time.perf_counter() - self._t0)


class _NullCtx:
    __slots__ = ()

    def __enter__(self) -> "_NullCtx":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


_NULL_CTX = _NullCtx()


# ---------------------------------------------------------------- tracker

class Tracker:
    """One scope in the telemetry tree.  See the module docstring for the
    write-through/roll-up rules.  Constructing ``Tracker()`` with no
    parent makes an independent root (benchmarks do this to isolate a
    measurement from the process-global accounting)."""

    def __init__(self, scope: str = "", parent: Optional["Tracker"] = None,
                 sinks: Optional[List[Any]] = None) -> None:
        self.scope = scope
        self._parent = parent
        self._lock = parent._lock if parent is not None else threading.RLock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._timings: Dict[str, List[float]] = {}  # [calls, total_s, max_s]
        self._sinks: List[Any] = list(sinks or ())
        self._children: Dict[str, "Tracker"] = {}

    # -- scope tree

    def child(self, scope: str) -> "Tracker":
        """Get-or-create the named sub-scope (idempotent per name)."""
        with self._lock:
            tr = self._children.get(scope)
            if tr is None:
                full = f"{self.scope}/{scope}" if self.scope else scope
                tr = Tracker(full, parent=self)
                self._children[scope] = tr
            return tr

    def drop_child(self, scope: str) -> None:
        """Detach a finished sub-scope.  Its write-through contributions
        stay in this scope's totals; only the per-scope breakdown goes."""
        with self._lock:
            self._children.pop(scope, None)

    def add_sink(self, sink: Any) -> None:
        with self._lock:
            self._sinks.append(sink)

    # -- mutation

    def count(self, **counters: float) -> None:
        """Add the given deltas to this scope and every ancestor."""
        if not counters:
            return
        with self._lock:
            sinks = []
            node: Optional[Tracker] = self
            while node is not None:
                c = node._counters
                for key, val in counters.items():
                    c[key] = c.get(key, 0) + val
                sinks.extend(node._sinks)
                node = node._parent
            if sinks:
                self._emit(sinks, {"kind": "count", "counters": dict(counters)})

    def gauge(self, name: str, value: float) -> None:
        """Record a last-value gauge on this scope only (no roll-up)."""
        with self._lock:
            self._gauges[name] = value
            sinks = self._collect_sinks()
            if sinks:
                self._emit(sinks, {"kind": "gauge", "name": name,
                                   "value": value})

    def gauge_max(self, name: str, value: float) -> None:
        """Ratchet a high-watermark gauge on this scope and every
        ancestor (the parent's peak is the max over its children)."""
        with self._lock:
            node: Optional[Tracker] = self
            while node is not None:
                g = node._gauges
                if value > g.get(name, value - 1):
                    g[name] = value
                node = node._parent
            sinks = self._collect_sinks()
            if sinks:
                self._emit(sinks, {"kind": "gauge_max", "name": name,
                                   "value": value})

    def timing(self, name: str, seconds: float) -> None:
        """Accumulate a measured span into this scope and every ancestor."""
        with self._lock:
            node: Optional[Tracker] = self
            while node is not None:
                t = node._timings.get(name)
                if t is None:
                    node._timings[name] = [1, seconds, seconds]
                else:
                    t[0] += 1
                    t[1] += seconds
                    t[2] = max(t[2], seconds)
                node = node._parent
            sinks = self._collect_sinks()
            if sinks:
                self._emit(sinks, {"kind": "time", "name": name,
                                   "seconds": seconds})

    def time_block(self, name: str) -> _TimeBlock:
        return _TimeBlock(self, name)

    # -- reads

    def value(self, name: str, default: float = 0) -> float:
        """Counter value (falling back to gauges) by name."""
        with self._lock:
            if name in self._counters:
                return self._counters[name]
            return self._gauges.get(name, default)

    def __getitem__(self, name: str) -> float:
        return self.value(name)

    def counters(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def snapshot(self, children: bool = True) -> dict:
        """A plain-JSON view of this scope (and, by default, the live
        sub-tree).  Safe to hand across threads or the wire."""
        with self._lock:
            snap: Dict[str, Any] = {
                "scope": self.scope,
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "timings": {name: {"calls": t[0], "total_s": t[1],
                                   "max_s": t[2]}
                            for name, t in self._timings.items()},
            }
            if children:
                snap["children"] = {name: tr.snapshot(children=True)
                                    for name, tr in self._children.items()}
            return snap

    def reset(self) -> None:
        """Zero this scope and the live sub-tree (structure is kept:
        children stay attached so long-lived scopes survive a reset)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._timings.clear()
            for tr in self._children.values():
                tr.reset()

    # -- internals (call under lock)

    def _collect_sinks(self) -> List[Any]:
        sinks: List[Any] = []
        node: Optional[Tracker] = self
        while node is not None:
            sinks.extend(node._sinks)
            node = node._parent
        return sinks

    def _emit(self, sinks: List[Any], record: dict) -> None:
        record["ts"] = time.time()
        record["scope"] = self.scope
        seen = set()
        for sink in sinks:
            if id(sink) in seen:
                continue
            seen.add(id(sink))
            sink.emit(record)


class NullTracker:
    """The near-zero-overhead default for hot paths: every method is a
    no-op, ``child`` returns itself, ``time_block`` hands back one shared
    no-op context manager.  Use the ``NULL`` singleton."""

    scope = ""

    def child(self, scope: str) -> "NullTracker":
        return self

    def drop_child(self, scope: str) -> None:
        pass

    def add_sink(self, sink: Any) -> None:
        pass

    def count(self, **counters: float) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def gauge_max(self, name: str, value: float) -> None:
        pass

    def timing(self, name: str, seconds: float) -> None:
        pass

    def time_block(self, name: str) -> _NullCtx:
        return _NULL_CTX

    def value(self, name: str, default: float = 0) -> float:
        return default

    def __getitem__(self, name: str) -> float:
        return 0

    def counters(self) -> Dict[str, float]:
        return {}

    def snapshot(self, children: bool = True) -> dict:
        return {"scope": "", "counters": {}, "gauges": {}, "timings": {}}

    def reset(self) -> None:
        pass


NULL = NullTracker()

# the process root: what ``tracker=None`` resolves to everywhere, and what
# the deprecated ``COUNTERS`` view below reads
_ROOT = Tracker()


def root() -> Tracker:
    return _ROOT


def get(tracker: Optional[Any]) -> Any:
    """Resolve a ``tracker=`` argument: ``None`` means the process root
    (legacy global accounting); anything else is used as-is."""
    return _ROOT if tracker is None else tracker


def reset() -> None:
    """Zero the process root (the body of ``engine.reset_counters``)."""
    _ROOT.reset()


# ------------------------------------------------- deprecated COUNTERS view

# the six keys the pre-telemetry global dict carried; the view is frozen
# to them so ``dict(engine.COUNTERS)`` keeps its historical shape even as
# new counters land in the root tracker
LEGACY_KEYS = (
    "dispatches",
    "host_syncs",
    "shard_donations",
    "shard_donated_rows",
    "shard_idle_steps",
    "shard_peak_occupancy",
)


class _CountersView(Mapping):
    """Read-only mapping over the root tracker, shaped like the old
    ``engine.COUNTERS`` dict.  Deprecated: new code reads
    ``telemetry.root().snapshot()`` (or its own ``Tracker``) instead.
    Writes go through ``Tracker.count`` / ``gauge_max`` — item assignment
    here raises, which is what keeps ``grep COUNTERS\\[`` honest."""

    def __getitem__(self, key: str) -> float:
        if key not in LEGACY_KEYS:
            raise KeyError(key)
        return _ROOT.value(key)

    def __iter__(self) -> Iterator[str]:
        return iter(LEGACY_KEYS)

    def __len__(self) -> int:
        return len(LEGACY_KEYS)

    def __repr__(self) -> str:
        return f"COUNTERS({dict(self)!r})"


COUNTERS = _CountersView()
