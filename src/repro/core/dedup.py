"""Exact duplicate elimination by multi-word sort (beyond-paper mode).

The paper dedups with a Bloom filter because GPUs have fast atomic OR and
sorting 180M states on a 2017 GPU was unattractive.  TPUs sort well and XLA
sorts are deterministic, so the framework's default dedup is an exact
lexicographic sort over the packed state words + neighbour-difference mask +
stream compaction.  Zero false positives -> the solver stays Las Vegas
instead of Monte Carlo.  The Bloom path (paper-faithful) lives in bloom.py.

Invalid rows are replaced by the all-ones sentinel, which sorts last and can
never equal a real state (a state of size n is never generated: the DP stops
at ``n - max(k+1, |C|)`` eliminated vertices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

U32 = jnp.uint32
SENTINEL = jnp.uint32(0xFFFFFFFF)


def sort_states(keys: jnp.ndarray, valid: jnp.ndarray):
    """Lexicographically sort rows of (M, W) with invalid rows sent to the end.

    Returns (sorted_keys (M, W), sorted_valid (M,))."""
    m, w = keys.shape
    keys = jnp.where(valid[:, None], keys, SENTINEL)
    cols = tuple(keys[:, j] for j in range(w)) + (valid,)
    out = jax.lax.sort(cols, dimension=0, num_keys=w)
    sorted_keys = jnp.stack(out[:w], axis=1)
    return sorted_keys, out[w]


def unique_mask(sorted_keys: jnp.ndarray, sorted_valid: jnp.ndarray):
    """First-occurrence mask over sorted rows."""
    diff = jnp.any(sorted_keys[1:] != sorted_keys[:-1], axis=1)
    first = jnp.concatenate([jnp.ones((1,), dtype=bool), diff])
    return first & sorted_valid


def compact(rows: jnp.ndarray, keep: jnp.ndarray, cap: int, offset=0):
    """Scatter kept rows into a (cap, W) buffer starting at ``offset``.

    Returns (buffer_update (cap, W), n_kept, n_dropped).  Rows that would land
    past ``cap`` are dropped (the paper's list-overflow semantics)."""
    w = rows.shape[-1]
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1 + offset
    n_keep = jnp.sum(keep.astype(jnp.int32))
    idx = jnp.where(keep & (pos < cap), pos, cap)           # cap == drop slot
    buf = jnp.zeros((cap, w), dtype=U32)
    buf = buf.at[idx].set(rows, mode="drop")
    written = jnp.minimum(n_keep, jnp.maximum(0, cap - offset))
    dropped = n_keep - written
    return buf, written, dropped


@functools.partial(jax.jit, static_argnames=("cap",))
def dedup_compact(keys: jnp.ndarray, valid: jnp.ndarray, cap: int):
    """Sort-dedup rows and compact into a fresh (cap, W) frontier buffer.

    Returns (buffer, count, dropped)."""
    sk, sv = sort_states(keys, valid)
    keep = unique_mask(sk, sv)
    buf, written, dropped = compact(sk, keep, cap)
    return buf, written, dropped
