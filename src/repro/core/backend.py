"""Backend registry: every wavefront op, registered once per backend.

The engine used to hard-wire the pure-JAX implementations from ``core/*``
and leave the Pallas kernels in ``kernels/*`` as validated-but-unwired
artifacts behind an ad-hoc ``impl=`` string (whose pallas path silently
dropped the reach output and crashed mid-jit under MMW/simplicial pruning).
This module collapses that split into one dispatch table:

  * each op — fused expand+prune, sort dedup, Bloom query-and-insert, and
    the standalone degree/MMW/simplicial pieces — is registered under a
    (op, backend) key with a uniform signature;
  * the solver paths (``solver.decide``, ``engine.fused_decide``,
    ``distributed``) and the CLI select implementations with a single
    ``backend=`` knob;
  * unsupported combinations fail **at dispatch time** with a
    ``BackendCapabilityError`` naming the op, the backends that do support
    it, and the fix — never with a bare TypeError deep inside a jit.

Capability table (also rendered in DESIGN.md §3):

  op                 jax   pallas   notes
  wavefront_expand    ✓      ✓      pallas fuses prune rules in one VMEM pass
  expand_degrees      ✓      ✓      degrees only (no reach output)
  mmw_bound           ✓      ✓
  simplicial_mask     ✓      —      pallas form exists only fused
  sort_dedup          ✓      ✓*     *XLA sort on both (TPU sorts are
                                     XLA-native; a hand-rolled pallas sort
                                     would be slower — DESIGN.md §3)
  bloom_query_insert  ✓      ✓      pallas: packed filter, sequential grid
  bloom_make_filter   ✓      ✓      jax: uint8/bit; pallas: packed uint32

Registrations import the heavy pallas machinery lazily so that jax-only
runs never pay the ``jax.experimental.pallas`` import.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

BACKENDS: Tuple[str, ...] = ("jax", "pallas")

# dedup modes understood by the engines; listed here so validation happens
# once at dispatch instead of per call site
DEDUP_MODES: Tuple[str, ...] = ("sort", "bloom")

# closure schedules of the jax reference ops; the pallas kernels bake in
# the static-trip-count doubling schedule (the TPU design point)
JAX_SCHEDULES: Tuple[str, ...] = ("doubling", "while", "linear", "matmul")
PALLAS_SCHEDULES: Tuple[str, ...] = ("doubling",)

# backends whose ops are safe under a leading vmapped lane axis (the
# multi-lane engine in ``core.batch``).  jax ops vmap trivially; the pallas
# kernels batch through pallas_call's batching rule, which lifts the lane
# axis into the grid — pinned bit-for-bit by tests/test_batch.py.  A future
# backend whose kernels lack a batching rule must be left out of this set
# so ``validate(lanes=...)`` rejects it at entry instead of mid-trace.
BATCHED_BACKENDS: Tuple[str, ...] = ("jax", "pallas")


class BackendCapabilityError(ValueError):
    """An op/backend/flag combination the registry cannot dispatch."""


@dataclasses.dataclass(frozen=True)
class OpSpec:
    name: str
    doc: str
    loaders: Dict[str, Callable[[], Callable]]

    def resolve(self, backend: str) -> Callable:
        if backend not in self.loaders:
            have = ", ".join(sorted(self.loaders))
            raise BackendCapabilityError(
                f"op {self.name!r} has no {backend!r} implementation "
                f"(available backends: {have}). {self.doc}")
        return self.loaders[backend]()


_OPS: Dict[str, OpSpec] = {}


def _register(name: str, doc: str, **loaders) -> None:
    _OPS[name] = OpSpec(name=name, doc=doc, loaders=loaders)


def get_op(name: str, backend: str) -> Callable:
    """Resolve an op implementation; raises BackendCapabilityError with the
    available alternatives instead of crashing mid-jit."""
    if backend not in BACKENDS:
        raise BackendCapabilityError(
            f"unknown backend {backend!r}; known backends: "
            f"{', '.join(BACKENDS)}")
    if name not in _OPS:
        raise BackendCapabilityError(
            f"unknown op {name!r}; registered ops: "
            f"{', '.join(sorted(_OPS))}")
    return _OPS[name].resolve(backend)


def ops() -> Tuple[str, ...]:
    return tuple(sorted(_OPS))


def capability_table() -> Dict[str, Tuple[str, ...]]:
    """op name -> backends that implement it (for docs and tests)."""
    return {name: tuple(b for b in BACKENDS if b in spec.loaders)
            for name, spec in sorted(_OPS.items())}


def device_memory_budget(fraction: float = 0.5) -> Optional[int]:
    """Best-effort device memory available for frontier pools, in bytes.

    Reads the default device's allocator stats (populated on TPU/GPU;
    absent on the CPU backend) and hands ``fraction`` of the free bytes to
    the caller — the rest stays headroom for the adjacency/children
    tensors and XLA scratch.  Returns ``None`` when the platform exposes
    no stats, which callers (``batch.plan_capacity``) treat as
    "state-space bound only".  DESIGN.md §10.
    """
    try:
        import jax
        dev = jax.devices()[0]
        stats = getattr(dev, "memory_stats", lambda: None)()
    except Exception:                                # noqa: BLE001
        return None
    if not stats:
        return None
    limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
    if not limit:
        return None
    free = max(0, int(limit) - int(stats.get("bytes_in_use", 0)))
    return int(free * fraction)


def validate(backend: str, *, mode: str = "sort",
             schedule: str = "doubling", use_mmw: bool = False,
             use_simplicial: bool = False,
             m_bits: Optional[int] = None, lanes: int = 1,
             shards: int = 1) -> None:
    """Fail fast on solver configurations the backend cannot run.

    Called at every entry point (``solver.decide``, ``engine.fused_decide``,
    ``distributed.decide_distributed``, ``batch.decide_lanes``, the CLI) so
    an unsupported combo surfaces as one actionable error before any
    tracing starts.  ``lanes > 1`` and ``shards > 1`` additionally require
    the backend's ops to be vmap-safe (``BATCHED_BACKENDS``) — the
    multi-lane engine vmaps whole decide loops, the sharded engine
    (``core.shard``) vmaps the per-shard expand/dedup pipeline.
    """
    if backend not in BACKENDS:
        raise BackendCapabilityError(
            f"unknown backend {backend!r}; known backends: "
            f"{', '.join(BACKENDS)}")
    if lanes < 1:
        raise BackendCapabilityError(
            f"lanes must be >= 1 (got {lanes})")
    if lanes > 1 and backend not in BATCHED_BACKENDS:
        raise BackendCapabilityError(
            f"backend {backend!r} does not support the multi-lane engine "
            f"(batched backends: {', '.join(BATCHED_BACKENDS)}); run with "
            "lanes=1 or switch backend.")
    if shards < 1:
        raise BackendCapabilityError(
            f"shards must be >= 1 (got {shards})")
    if shards > 1 and backend not in BATCHED_BACKENDS:
        raise BackendCapabilityError(
            f"backend {backend!r} does not support the sharded engine "
            f"(batched backends: {', '.join(BATCHED_BACKENDS)}); run with "
            "shards=1 or switch backend.")
    if mode not in DEDUP_MODES:
        raise BackendCapabilityError(
            f"unknown dedup mode {mode!r}; known modes: "
            f"{', '.join(DEDUP_MODES)}")
    schedules = PALLAS_SCHEDULES if backend == "pallas" else JAX_SCHEDULES
    if schedule not in schedules:
        raise BackendCapabilityError(
            f"backend={backend!r} does not implement schedule="
            f"{schedule!r} (supported: {', '.join(schedules)}). The pallas "
            "wavefront kernel bakes in the static doubling fixpoint — the "
            "alternative schedules exist only as jax reference loops; use "
            "schedule='doubling' or backend='jax'.")
    if mode == "bloom" and backend == "pallas" \
            and m_bits is not None and m_bits % 32:
        raise BackendCapabilityError(
            f"backend='pallas' keeps the Bloom filter bit-packed in uint32 "
            f"words, so m_bits must be a multiple of 32 (got {m_bits}). "
            "Round m_bits up or use backend='jax'.")
    # pruning-rule coverage: both rules ride inside the fused pallas
    # wavefront kernel, so nothing to reject here — but resolving the op
    # now turns a future capability regression into an import-time error
    get_op("wavefront_expand", backend)
    if use_mmw:
        get_op("mmw_bound", backend)
    if use_simplicial and backend == "jax":
        # under pallas the rule exists only fused inside wavefront_expand
        get_op("simplicial_mask", "jax")


# ------------------------------------------------------------ registrations
#
# Loader thunks so that importing this module stays cheap and jax-only runs
# never touch jax.experimental.pallas.

def _jax_wavefront_expand():
    from . import expand
    return expand.wavefront_expand


def _pallas_wavefront_expand():
    from repro.kernels.wavefront import wavefront_expand
    return wavefront_expand


def _jax_expand_degrees():
    import jax as _jax
    from . import components

    def expand_degrees(adj, states, *, n, schedule="doubling"):
        deg, _reach = _jax.vmap(
            lambda s: components.eliminated_degrees(adj, s, n,
                                                    schedule=schedule))(states)
        return deg
    return expand_degrees


def _pallas_expand_degrees():
    from repro.kernels.expand import expand_degrees

    def expand_degrees_op(adj, states, *, n, schedule="doubling"):
        del schedule          # the kernel bakes in the doubling fixpoint
        return expand_degrees(adj, states, n=n)
    return expand_degrees_op


def _jax_mmw_bound():
    import jax as _jax
    from . import mmw as mmw_lib

    def mmw_bounds(reach, states, k, *, n):
        return _jax.vmap(
            lambda r, s: mmw_lib.mmw_bound(r, s, k, n))(reach, states)
    return mmw_bounds


def _pallas_mmw_bound():
    from repro.kernels.mmw import mmw_bounds

    def mmw_bounds_op(reach, states, k, *, n):
        return mmw_bounds(reach, states, k, n=n)
    return mmw_bounds_op


def _jax_simplicial_mask():
    from . import expand
    return expand.simplicial_mask


def _sort_dedup():
    from . import dedup

    def sort_dedup(flat, mask):
        skeys, svalid = dedup.sort_states(flat, mask)
        keep = dedup.unique_mask(skeys, svalid)
        return skeys, keep
    return sort_dedup


def _jax_bloom_query_insert():
    from . import bloom

    def query_insert(filt, keys, keep, *, m_bits, k_hashes):
        return bloom.query_and_insert(filt, keys, keep, m_bits, k_hashes)
    return query_insert


def _pallas_bloom_query_insert():
    from repro.kernels.bloom import bloom_insert

    def query_insert(filt, keys, keep, *, m_bits, k_hashes):
        return bloom_insert(filt, keys, keep, m_bits=m_bits,
                            k_hashes=k_hashes)
    return query_insert


def _jax_bloom_make_filter():
    from . import bloom

    def make_filter(m_bits):
        return bloom.make_filter(m_bits if m_bits is not None else 1)
    return make_filter


def _pallas_bloom_make_filter():
    from repro.kernels.bloom import make_filter_words

    def make_filter(m_bits):
        return make_filter_words(m_bits if m_bits is not None else 32)
    return make_filter


_register(
    "wavefront_expand",
    "The fused Listing-1 inner loop: expand + feasibility + simplicial "
    "collapse + MMW prune -> (children, feasible).",
    jax=_jax_wavefront_expand, pallas=_pallas_wavefront_expand)
_register(
    "expand_degrees",
    "deg_S(v) only (no reach / children) — benchmark & test surface for "
    "the unfused expansion kernel.",
    jax=_jax_expand_degrees, pallas=_pallas_expand_degrees)
_register(
    "mmw_bound",
    "Batched minor-min-width lower bounds from precomputed reach rows.",
    jax=_jax_mmw_bound, pallas=_pallas_mmw_bound)
_register(
    "simplicial_mask",
    "Standalone simplicial-candidate mask. The pallas form exists only "
    "fused inside wavefront_expand (it needs the VMEM-resident reach "
    "tiles); use backend='jax' or the fused op.",
    jax=_jax_simplicial_mask)
_register(
    "sort_dedup",
    "Exact lexicographic sort + first-occurrence mask. Registered for "
    "both backends as the same XLA sort: TPU sorting is XLA-native and a "
    "hand-rolled pallas sort would be slower (DESIGN.md §3).",
    jax=_sort_dedup, pallas=_sort_dedup)
_register(
    "bloom_query_insert",
    "Bloom-filter query-and-insert. jax: masked scatter-max on a "
    "byte-per-bit filter; pallas: bit-packed filter with sequential-grid "
    "atomic-OR semantics. Identical was_new bits for intra-batch-unique "
    "inputs (guaranteed by the preceding sort_dedup).",
    jax=_jax_bloom_query_insert, pallas=_pallas_bloom_query_insert)
_register(
    "bloom_make_filter",
    "Backend-matched empty Bloom filter (pass m_bits=None for the dummy "
    "carried through sort-mode loops).",
    jax=_jax_bloom_make_filter, pallas=_pallas_bloom_make_filter)
