"""Intra-request scale-out: one instance's frontier across S shards.

One decide rung (is tw(g) <= k?) normally runs on a single frontier
buffer.  This module splits that frontier across ``S`` shards so S
workers — vmapped lanes on one device, or devices in a mesh — decide one
rung concurrently (DESIGN.md §13):

  * **Expansion** is embarrassingly parallel: each shard runs the shared
    ``engine.chunk_sweep`` over its own rows (intra-chunk dedup only).
  * **Dedup** uses single-writer ownership routing (DESIGN.md §2): every
    candidate state is hash-partitioned (murmur3 mod S) to a unique owner
    shard which performs the exact sorted dedup — the jax analogue of the
    paper's mutex-striped Bloom inserts, with nothing to synchronise.
    Under ``mode="bloom"`` each owner additionally guards its rows with
    its *own* Bloom filter shard: one writer per filter, so inserts race
    with nobody (Monte-Carlo FP drops only, exactly the paper's
    semantics).
  * **Donation** rebalances per-rung load: when post-dedup shard
    occupancy skews past ``donate_ratio`` × the mean, overloaded shards
    donate frontier rows to underloaded ones (the worklist-donation
    pattern of the GPU vertex-cover solvers, arxiv 2204.10402) via a
    water-filling repack.  Only already-owned *parent* rows move;
    ownership of any future child is a pure function of the child's
    hash, so donation can never duplicate or lose a state.

Because the union of the per-shard post-dedup frontiers equals the
single-lane post-dedup frontier level by level (sort mode, no
overflow), the sharded verdict, ``expanded`` count and deepening ladder
are bit-identical to ``engine="fused"`` single-lane — see
``tests/test_shard.py``.  Per-shard capacity equals the single-lane
planned capacity, so a drop-free single-lane plan stays drop-free
sharded (each shard's chunk stream and each owner's receive set are
subsets of what the single-lane buffer provably holds).

The mesh path (``mesh=``) delegates to ``core.distributed``, which
routes through the same ``route_states`` / ``donation_plan`` helpers —
the distributed solver and the serving pool are one engine path.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import backend as backend_lib
from . import bitset, bloom, dedup
from . import engine as engine_lib
from . import frontier as frontier_lib
from . import telemetry
from .graph import Graph

U32 = jnp.uint32

# donate when max shard occupancy exceeds ratio × mean occupancy.  1.5
# tolerates the multinomial noise of hash ownership on healthy levels but
# fires on genuine skew (and on the tiny early levels, where idle shards
# are guaranteed); <= 1.0 rebalances every level.
DEFAULT_DONATE_RATIO = 1.5


# --------------------------------------------------------------- ownership

def route_states(rows: jnp.ndarray, valid: jnp.ndarray, nshards: int,
                 cap_recv: int):
    """Partition valid rows to their owner shard (murmur3 mod S).

    Returns (recv (S, cap_recv, W), counts (S,), dropped).  Rows are
    sorted by (owner, words) first, so each owner's bucket arrives
    lexicographically sorted.  Shared by the single-device sharded engine
    (scatter = the degenerate all_to_all) and the mesh solver in
    ``core.distributed`` (whose buckets feed a real all_to_all).
    """
    m, w = rows.shape
    owner = (bloom.murmur3_words(rows, bloom.SEED1) % np.uint32(nshards)) \
        .astype(jnp.int32)
    owner = jnp.where(valid, owner, nshards)       # invalid rows sort last
    cols = (owner,) + tuple(rows[:, j] for j in range(w))
    srt = jax.lax.sort(cols, dimension=0, num_keys=1 + w)
    owner_s = srt[0]
    rows_s = jnp.stack(srt[1:], axis=1)
    counts = jnp.bincount(owner, length=nshards + 1)[:nshards] \
        .astype(jnp.int32)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    idx = jnp.arange(m, dtype=jnp.int32)
    safe_owner = jnp.minimum(owner_s, nshards - 1)
    pos = idx - starts[safe_owner]
    ok = (owner_s < nshards) & (pos < cap_recv)
    dest = jnp.where(ok, safe_owner * cap_recv + pos, nshards * cap_recv)
    recv = jnp.zeros((nshards * cap_recv, w), dtype=U32)
    recv = recv.at[dest].set(rows_s, mode="drop")
    rcounts = jnp.minimum(counts, cap_recv)
    dropped = jnp.sum(counts - rcounts)
    return recv.reshape(nshards, cap_recv, w), rcounts, dropped


# ---------------------------------------------------------------- donation

def donation_plan(counts: jnp.ndarray, ratio: float):
    """Water-filling donation targets for per-shard occupancies.

    Returns (targets (S,), triggered (bool), moved (rows leaving their
    shard)).  Targets are the balanced occupancy ``total // S`` (+1 for
    the first ``total % S`` shards), so ``sum(targets) == sum(counts)``
    and no row is ever dropped by a donation.  ``triggered`` fires when
    ``max(counts) * S > ratio * total`` — pure arithmetic on the counts
    vector, so every shard (or mesh device) computes the identical plan.
    """
    s = counts.shape[0]
    total = jnp.sum(counts)
    base = total // s
    rem = total - base * s
    targets = (base + (jnp.arange(s, dtype=jnp.int32) < rem)) \
        .astype(jnp.int32)
    trig = (total > 0) & (jnp.max(counts).astype(jnp.float32) * s
                          > float(ratio) * total.astype(jnp.float32))
    moved = jnp.sum(jnp.maximum(counts - targets, 0))
    return targets, trig, moved


def _repack(states: jnp.ndarray, counts: jnp.ndarray,
            targets: jnp.ndarray) -> jnp.ndarray:
    """Redistribute rows so shard d holds ``targets[d]`` rows.

    The single-device donation move: concatenate every shard's live rows
    (in shard order) and re-split at the target boundaries — one gather +
    one scatter, no host participation.  ``sum(targets) == sum(counts)``
    and ``targets <= cap`` (targets are ~total/S, total <= S*cap), so the
    repack is lossless.
    """
    s, cap, w = states.shape
    flat = states.reshape(s * cap, w)
    valid = (jnp.arange(cap, dtype=jnp.int32)[None, :]
             < counts[:, None]).reshape(-1)
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    bounds = jnp.cumsum(targets)
    shard_of = jnp.searchsorted(bounds, rank, side="right") \
        .astype(jnp.int32)
    starts = bounds - targets
    dest = shard_of * cap + (rank - starts[jnp.minimum(shard_of, s - 1)])
    dest = jnp.where(valid & (shard_of < s), dest, s * cap)
    out = jnp.zeros((s * cap, w), dtype=U32).at[dest].set(flat, mode="drop")
    return out.reshape(s, cap, w)


# ----------------------------------------------------------- sharded decide

def sharded_decide_loop(adj, allowed, k, target, fr, *, shards, n, cap,
                        block, mode, use_mmw, m_bits, k_hashes, schedule,
                        backend, use_simplicial, donate_ratio):
    """Run up to ``target`` levels with the frontier split across shards.

    The sharded mirror of ``engine.decide_loop``: same ladder semantics
    (stop early on emptiness, ``expanded`` accumulates the pre-expansion
    frontier size), with each level as

        vmapped local expand  →  ownership route  →  vmapped owner dedup
        →  (Bloom shard probe)  →  threshold donation

    Returns (counts (S,), levels, expanded, dropped, stats) with
    ``stats = [donation_events, donated_rows, idle_shard_steps,
    peak_shard_occupancy]`` (device ints; surfaced through
    ``engine.COUNTERS`` by the dispatch wrappers below).
    """
    s = shards
    w = adj.shape[-1]
    zero = jnp.asarray(0, jnp.int32)
    max_chunks = -(-cap // block)
    lane_idx = jnp.arange(cap, dtype=jnp.int32)

    make_filter = backend_lib.get_op("bloom_make_filter", backend)
    filt0 = make_filter(m_bits if mode == "bloom" else None)
    filts = jnp.stack([filt0] * s)

    def local(st, c):
        # per-shard expansion: intra-chunk dedup only — cross-shard (and
        # cross-chunk) dedup happens at the owner after routing
        return engine_lib.chunk_sweep(
            adj, allowed, k, st, c, block, n=n, cap=cap, mode="sort",
            use_mmw=use_mmw, m_bits=1, k_hashes=1, schedule=schedule,
            backend=backend, use_simplicial=use_simplicial,
            max_chunks=max_chunks, cross_dedup=False)

    def owner_dedup(rows, rvalid):
        return dedup.dedup_compact(rows, rvalid, cap)

    if mode == "bloom":
        query_insert = backend_lib.get_op("bloom_query_insert", backend)

        def bloom_probe(filt, rows, keep):
            # single writer: only this shard ever inserts into this
            # filter shard, and only rows it owns are probed against it
            keep, filt = query_insert(filt, rows, keep, m_bits=m_bits,
                                      k_hashes=k_hashes)
            buf, written, _ = dedup.compact(rows, keep, cap)
            return buf, written, filt

    def cond(c):
        _st, counts, _f, level, _e, _d, _stats = c
        return (level < target) & (jnp.sum(counts) > 0)

    def body(c):
        states, counts, filts, level, expanded, dropped, stats = c
        total = jnp.sum(counts)
        expanded = expanded + total
        idle = jnp.sum((counts == 0).astype(jnp.int32))
        peak = jnp.maximum(stats[3], jnp.max(counts))

        out, ocnt, drop_local = jax.vmap(local)(states, counts)
        rows = out.reshape(s * cap, w)
        valid = (lane_idx[None, :] < ocnt[:, None]).reshape(-1)
        recv, rcounts, drop_route = route_states(rows, valid, s, cap)
        rvalid = lane_idx[None, :] < rcounts[:, None]
        buf, cnts, drop_own = jax.vmap(owner_dedup)(recv, rvalid)
        if mode == "bloom":
            bvalid = lane_idx[None, :] < cnts[:, None]
            buf, cnts, filts = jax.vmap(bloom_probe)(filts, buf, bvalid)

        targets, trig, moved = donation_plan(cnts, donate_ratio)
        buf, cnts = jax.lax.cond(
            trig,
            lambda b, c_: (_repack(b, c_, targets), targets),
            lambda b, c_: (b, c_), buf, cnts)

        stats = jnp.stack([
            stats[0] + trig.astype(jnp.int32),
            stats[1] + jnp.where(trig, moved, 0),
            stats[2] + idle,
            peak,
        ])
        dropped = dropped + jnp.sum(drop_local) + drop_route \
            + jnp.sum(drop_own)
        return (buf, cnts, filts, level + 1, expanded, dropped, stats)

    init = (fr.states, fr.count, filts, zero, zero, zero,
            jnp.zeros((4,), jnp.int32))
    _st, counts, _f, level, expanded, dropped, stats = jax.lax.while_loop(
        cond, body, init)
    return counts, level, expanded, dropped, stats


_sharded_decide = functools.partial(
    jax.jit,
    static_argnames=("shards", "n", "cap", "block", "mode", "use_mmw",
                     "m_bits", "k_hashes", "schedule", "backend",
                     "use_simplicial", "donate_ratio"))(sharded_decide_loop)


# ------------------------------------------------------------ host wrappers

def _record_stats(stats_h, tracker=None) -> None:
    ev, moved, idle, peak = (int(x) for x in stats_h)
    tr = telemetry.get(tracker)
    tr.count(shard_donations=ev, shard_donated_rows=moved,
             shard_idle_steps=idle)
    tr.gauge_max("shard_peak_occupancy", peak)


def decide_sharded_async(g: Graph, k: int, clique=(), *, shards: int,
                         mesh=None, cap: Optional[int] = None,
                         block: int = 1 << 11, mode: str = "sort",
                         use_mmw: bool = False, m_bits: int = 1 << 24,
                         k_hashes: int = bloom.DEFAULT_K,
                         schedule: Optional[str] = None,
                         backend: str = "jax",
                         use_simplicial: bool = False,
                         donate_ratio: Optional[float] = None,
                         n_pad: Optional[int] = None,
                         budget_bytes: Optional[int] = None,
                         tracker=None) -> engine_lib.DispatchHandle:
    """Enqueue one sharded decide rung; return its ``DispatchHandle``.

    ``handle.result()`` yields a one-element list holding a
    ``batch.LaneResult`` — the same shape one lane of the serving pool
    produces, so a sharded rung slots into ``InstanceState.feed`` and the
    scheduler sync loop unchanged.  ``cap`` is the *per-shard* frontier
    capacity; ``cap=None`` plans the same drop-free bound the single-lane
    path would use, which keeps sharded results bit-identical (aggregate
    headroom only grows with S).  ``n_pad`` embeds the graph in a larger
    static vertex space (the multi-lane padding trick — same caveats as
    DESIGN.md §8).  With ``mesh`` spanning >1 devices the rung runs on
    the mesh via ``core.distributed`` instead of vmapped shards.
    """
    from . import batch as batch_lib

    shards = int(shards)
    if schedule is None:
        schedule = "doubling" if backend == "pallas" else "while"
    backend_lib.validate(backend, mode=mode, schedule=schedule,
                         use_mmw=use_mmw, use_simplicial=use_simplicial,
                         m_bits=m_bits, shards=shards)
    ratio = DEFAULT_DONATE_RATIO if donate_ratio is None \
        else float(donate_ratio)

    n = g.n
    target = n - max(k + 1, len(clique))
    if target <= 0:
        res = [batch_lib.LaneResult(True, False, 0)]
        return engine_lib.DispatchHandle((), lambda host: res,
                                         _result=res, _done=True)

    if mesh is not None and getattr(mesh, "devices", None) is not None \
            and mesh.devices.size > 1:
        if mode != "sort":
            raise backend_lib.BackendCapabilityError(
                "mesh-sharded decide performs exact owner dedup only "
                "(mode='sort'); the Bloom filter shards exist on the "
                "single-device sharded engine")
        if cap is None:
            cap = batch_lib.plan_capacity(n, block=block,
                                          budget_bytes=budget_bytes)
        from . import distributed as dist_lib
        return dist_lib.decide_launch(
            g, k, clique, mesh, cap_local=cap, block=block,
            use_mmw=use_mmw, use_simplicial=use_simplicial,
            schedule=schedule, backend=backend, donate_ratio=ratio,
            tracker=tracker)

    n_static = n if n_pad is None else int(n_pad)
    if n_static < n:
        raise ValueError(f"n_pad={n_pad} below instance size {n}")
    w = bitset.n_words(n_static)
    if cap is None:
        cap = batch_lib.plan_capacity(n, w, lanes=shards, block=block,
                                      budget_bytes=budget_bytes)
    block = engine_lib.validate_geometry(cap, block)

    adj = np.zeros((n_static, w), dtype=np.uint32)
    p = g.packed()
    adj[:n, :p.shape[1]] = p
    allowed = bitset.np_allowed(n, clique, w)
    fr = frontier_lib.shard_frontiers(shards, cap, w)

    counts, _level, expanded, dropped, stats = _sharded_decide(
        jnp.asarray(adj), jnp.asarray(allowed),
        jnp.asarray(k, jnp.int32), jnp.asarray(target, jnp.int32), fr,
        shards=shards, n=n_static, cap=cap, block=block, mode=mode,
        use_mmw=use_mmw, m_bits=m_bits, k_hashes=k_hashes,
        schedule=schedule, backend=backend, use_simplicial=use_simplicial,
        donate_ratio=ratio)
    tr = telemetry.get(tracker)
    tr.count(dispatches=1)

    def finalize(host):
        counts_h, expanded_h, dropped_h, stats_h = host
        _record_stats(stats_h, tracker=tr)
        return [batch_lib.LaneResult(int(np.sum(counts_h)) > 0,
                                     int(dropped_h) > 0, int(expanded_h))]

    return engine_lib.DispatchHandle((counts, expanded, dropped, stats),
                                     finalize, tracker=tr)


def decide_sharded(g: Graph, k: int, clique=(), **kw):
    """Blocking sharded decide: launch + immediate ``result()``.

    Returns the single ``batch.LaneResult`` for the rung.
    """
    return decide_sharded_async(g, k, clique, **kw).result()[0]
