"""Anytime heuristic bounds engine (Tamaki-style improvers).

The exact ladder only moves a request's bounds when a Held-Karp rung
decides; on a heavy graph the client stares at admission-time bounds for
the whole climb.  This module supplies the cheap anytime improvers of
Tamaki's "Heuristic computation of exact treewidth" wired around the
paper's $O^*(2^n)$ DP:

  * upper bounds   -- min-degree / min-fill / seeded randomized
    elimination sweeps.  The randomized min-degree sweep also compiles to
    a single vmapped JAX kernel (`ub_orders_async`) so every admitted
    request in the pool shares one dispatch per improver round.
  * lower bounds   -- degeneracy and MMW over randomized edge
    contractions (`contraction_lb`): each step contracts a min-degree
    vertex into a random neighbour; every intermediate graph is a minor,
    so its min degree bounds tw from below.

Improvers only ever *tighten* (ub via a replayable elimination-order
certificate, lb via a minor argument), so consumers may clamp the exact
ladder with them without changing any verdict: rungs below an improved
lb are already refuted, rungs at or above an improved ub are already
certified.  `HeuristicState` packages the bounds-only serving mode
(`heuristic_only=True`) behind the same duck-typed surface the scheduler
uses for exact instances.

Everything is deterministic per (graph, seed): seeds thread explicitly,
never from global RNG state.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, List, Optional, Sequence

import numpy as np

from . import bounds, telemetry
from .graph import Graph

_MIX = 1000003  # seed mixer: keeps per-round streams disjoint


def _round_seed(seed: int, rnd: int) -> int:
    return (int(seed) * _MIX + int(rnd)) % (2 ** 31 - 1)


# ---------------------------------------------------------------------------
# lower-bound improver: MMW on randomized edge contractions (host, numpy)
# ---------------------------------------------------------------------------

def contraction_lb(g: Graph, seed: int = 0) -> int:
    """One seeded MMW contraction sweep; returns a valid lower bound.

    Repeatedly record the current minimum degree (each contracted graph
    is a minor of ``g``, and tw >= degeneracy >= min degree of any
    minor), then contract a minimum-degree vertex into a uniformly
    random neighbour.  Randomizing the partner explores contraction
    sequences the deterministic tiebreak of `mmw.mmw_oracle` never
    visits, so distinct seeds can tighten past the admission-time MMW.
    """
    n = g.n
    if n <= 1:
        return 0
    rng = np.random.RandomState(seed)
    a = g.adj.copy()
    alive = np.ones(n, dtype=bool)
    lb = 0
    while int(alive.sum()) > 1:
        cand = np.nonzero(alive)[0]
        deg = a[cand].sum(axis=1)
        lb = max(lb, int(deg.min()))
        v = int(cand[int(np.argmin(deg))])
        nbrs = np.nonzero(a[v])[0]
        if len(nbrs) == 0:
            alive[v] = False
            continue
        u = int(nbrs[rng.randint(len(nbrs))])
        merged = a[u] | a[v]
        merged[u] = merged[v] = False
        a[u] = merged
        a[:, u] = merged
        a[v] = False
        a[:, v] = False
        alive[v] = False
    return lb


# ---------------------------------------------------------------------------
# host improvement loop (solver path): rounds of ub sweeps + lb contractions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Improvement:
    """Result of a host improvement run; bounds only ever tighten."""
    lb: int
    ub: int
    ub_order: Optional[list]
    lb_moves: int = 0
    ub_moves: int = 0

    @property
    def closed(self) -> bool:
        return self.lb >= self.ub


_UB_STRATEGIES = ("min_degree", "min_fill")


def improve(g: Graph, lb: int = 0, ub: Optional[int] = None,
            ub_order: Optional[list] = None, *, rounds: int = 1,
            seed: int = 0, tracker=None) -> Improvement:
    """Run ``rounds`` improver rounds on the host; monotone by clamping.

    Each round draws one seeded randomized elimination sweep (strategy
    rotating min-degree / min-fill) and one seeded MMW contraction
    sweep.  Pure function of (g, lb, ub, rounds, seed) — the solver and
    the batched scheduler admission agree bit-for-bit.
    """
    tr = telemetry.get(tracker)
    if ub is None:
        ub = max(0, g.n - 1)
    out = Improvement(lb, ub, list(ub_order) if ub_order is not None else None)
    if g.n <= 1:
        return out
    for r in range(max(0, rounds)):
        if out.closed:
            break
        s = _round_seed(seed, r)
        strat = _UB_STRATEGIES[r % len(_UB_STRATEGIES)]
        w, o = bounds.randomized_order(g, s, strat)
        if w < out.ub:
            out.ub, out.ub_order = w, o
            out.ub_moves += 1
            tr.count(heur_ub_improvements=1)
        l = contraction_lb(g, s)
        if l > out.lb:
            out.lb = l
            out.lb_moves += 1
            tr.count(heur_lb_improvements=1)
    return out


# size gates: min-fill and the python MMW oracle are O(n^3)-ish host
# loops — fine for exact-tier graphs, too slow at heuristic-only scale
_EXPENSIVE_N = 64


def quick_bounds(g: Graph, seed: int = 0) -> tuple:
    """Admission-time (lb, ub, ub_order) sized to the graph.

    Below `_EXPENSIVE_N` this matches the exact planner's bounds
    (degeneracy + MMW + clique, min-degree + min-fill); above it the
    cubic sweeps are dropped so admission stays cheap on graphs beyond
    exact-DP reach.
    """
    n = g.n
    if n <= 1:
        return 0, 0, list(range(n))
    if n <= _EXPENSIVE_N:
        lb = bounds.lower_bound(g, seed=seed)
        ub, order = bounds.upper_bound(g, seed=seed)
    else:
        lb = max(bounds.degeneracy(g),
                 len(bounds.greedy_max_clique(g, tries=8, seed=seed)) - 1)
        ub, order = bounds._elimination_ub(g, "min_degree")
    return lb, min(ub, n - 1), order


# ---------------------------------------------------------------------------
# batched ub improver: one vmapped dispatch covers the whole pool
# ---------------------------------------------------------------------------

def _kernel(n: int):
    """Jitted randomized min-degree elimination over (B, n, n) bool adj."""
    import jax
    import jax.numpy as jnp

    eye = np.eye(n, dtype=bool)

    def one(adj, rank):
        def body(i, carry):
            adj, alive, width, order = carry
            deg = adj.sum(axis=1).astype(jnp.int32)
            score = jnp.where(alive, deg * (n + 1) + rank, jnp.int32(2 ** 30))
            v = jnp.argmin(score).astype(jnp.int32)
            width = jnp.maximum(width, deg[v])
            nb = adj[v]
            adj = adj | (nb[:, None] & nb[None, :])
            keep = ~(jnp.arange(n, dtype=jnp.int32) == v)
            adj = adj & keep[:, None] & keep[None, :] & ~eye
            alive = alive & keep
            order = order.at[i].set(v)
            return adj, alive, width, order

        carry = (adj, jnp.ones((n,), dtype=bool), jnp.int32(0),
                 jnp.zeros((n,), dtype=jnp.int32))
        _, _, width, order = jax.lax.fori_loop(0, n, body, carry)
        return width, order

    return jax.jit(jax.vmap(one))


@functools.lru_cache(maxsize=None)
def _kernel_cached(n: int):
    return _kernel(n)


def ub_orders_async(graphs: Sequence[Graph], seeds: Sequence[int], *,
                    tracker=None) -> Any:
    """Launch ONE vmapped randomized min-degree sweep over the pool.

    Pads every lane to a shared n (isolated pad vertices eliminate first
    at degree 0 and cannot raise any width), launches the jitted kernel,
    and returns an `engine.DispatchHandle` whose ``result()`` yields one
    ``(width, order)`` per input graph — the order filtered back to the
    graph's real vertices, the width exactly what a host replay of that
    order produces.  Seeds pick the per-lane random tiebreak rank, so
    each lane is deterministic per (graph, seed).
    """
    from . import engine  # deferred: engine pulls in the backend registry
    import jax.numpy as jnp

    tr = telemetry.get(tracker)
    if not graphs:
        return engine.DispatchHandle((), lambda host: [], _result=[],
                                     _done=True)
    n_max = max(g.n for g in graphs)
    n_pad = max(16, -(-n_max // 16) * 16)      # round up: stable jit shapes
    b = len(graphs)
    adjs = np.zeros((b, n_pad, n_pad), dtype=bool)
    ranks = np.zeros((b, n_pad), dtype=np.int32)
    for i, (g, s) in enumerate(zip(graphs, seeds)):
        adjs[i, :g.n, :g.n] = g.adj
        ranks[i] = np.random.RandomState(int(s) % (2 ** 31 - 1)) \
            .permutation(n_pad).astype(np.int32)
    widths, orders = _kernel_cached(n_pad)(jnp.asarray(adjs),
                                           jnp.asarray(ranks))
    tr.count(heur_dispatches=1, heur_lanes=b)
    ns = [g.n for g in graphs]

    def finalize(host):
        ws, os_ = host
        out = []
        for i, n in enumerate(ns):
            order = [int(v) for v in os_[i] if int(v) < n]
            out.append((int(ws[i]), order))
        return out

    return engine.DispatchHandle((widths, orders), finalize, tracker=tr)


# ---------------------------------------------------------------------------
# heuristic-only serving state (duck-types the scheduler's InstanceState)
# ---------------------------------------------------------------------------

class HeuristicState:
    """Bounds-only request state: no exact rungs, just improver rounds.

    Mirrors the slice of `batch.InstanceState` the scheduler touches
    (``run``/``result``/``bounds``/``partial``/``anytime_result``/
    ``improve_bounds``), with ``run`` pinned to None so the launch loop
    never packs DP rungs for it.  Terminates when lb meets ub (then the
    verdict is *exact* — both sides are certificates) or when the
    improver round budget is spent, with ``exact=(lb == ub)``.
    """

    run = None  # never holds a DP ladder

    def __init__(self, g: Graph, solver_lib, *, seed: int = 0,
                 max_rounds: int = 16, tracker=None):
        self.g = g
        self.solver = solver_lib
        self.seed = int(seed)
        self.max_rounds = max(1, int(max_rounds))
        self.rounds_done = 0
        self.tracker = telemetry.get(tracker)
        self.t0 = time.time()
        self.result = None
        with self.tracker.time_block("heur_admit_s"):
            lb, ub, order = quick_bounds(g, seed=self.seed)
        self.lb, self.ub, self.ub_order = lb, ub, order
        if self.lb >= self.ub:
            self._finalize()

    def bounds(self) -> tuple:
        return self.lb, self.ub

    def partial(self) -> tuple:
        return 0, {}

    def max_n(self) -> int:
        return self.g.n

    def anytime_result(self, lb=None, ub=None):
        lb = self.lb if lb is None else max(lb, self.lb)
        ub = self.ub if ub is None else min(ub, self.ub)
        return self.solver.SolveResult(ub, lb == ub, lb, ub, 0,
                                       time.time() - self.t0,
                                       order=self.ub_order, per_k={})

    def improve_bounds(self, lb=None, ub=None, ub_order=None) -> dict:
        """Clamp in an improver result; monotone tighten only."""
        out = dict(lb_improved=False, ub_improved=False, rungs_skipped=0,
                   finished=False)
        if self.result is not None:
            return out
        if ub is not None and ub < self.ub and ub_order is not None:
            self.ub, self.ub_order = int(ub), list(ub_order)
            out["ub_improved"] = True
        if lb is not None and lb > self.lb:
            self.lb = min(int(lb), self.ub)
            out["lb_improved"] = True
        if self.lb >= self.ub:
            self._finalize()
            out["finished"] = True
        return out

    def step_done(self) -> bool:
        """Account one finished improver round; True once terminal."""
        self.rounds_done += 1
        if self.result is None and self.rounds_done >= self.max_rounds:
            self._finalize()
        return self.result is not None

    def _finalize(self):
        self.result = self.solver.SolveResult(
            self.ub, self.lb == self.ub, self.lb, self.ub, 0,
            time.time() - self.t0, order=self.ub_order, per_k={})
