"""Assigned input shapes + abstract input specs for the dry-run.

Four shapes per architecture (40 cells):
  train_4k     seq 4096,    global batch 256   -> train_step
  prefill_32k  seq 32768,   global batch 32    -> serve prefill
  decode_32k   1 new token, KV cache 32768, global batch 128 -> serve decode
  long_500k    1 new token, context 524288, global batch 1   -> serve decode
               (sub-quadratic archs only; dense-attention archs skip)

``input_specs`` returns ShapeDtypeStructs only — nothing is allocated, which
is what lets 400B-scale cells lower on a CPU host.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig):
    """(runnable?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, (
            f"{cfg.name} uses full attention"
            + (" (enc-dec)" if cfg.cross_attention else "")
            + ": a 524288-token dense KV cache is the quadratic blow-up "
              "this shape excludes (DESIGN.md §5)")
    return True, ""


def token_count(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Text tokens per sample (frontends consume part of the budget)."""
    s = shape.seq_len
    if cfg.frontend == "vision":
        s = s - cfg.frontend_len
    return s


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch: Optional[int] = None) -> dict:
    """Abstract (ShapeDtypeStruct) model inputs for a cell."""
    b = batch if batch is not None else shape.global_batch
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        s = token_count(cfg, shape)
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
    elif shape.kind == "prefill":
        s = token_count(cfg, shape)
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "decode":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }
    else:
        raise ValueError(shape.kind)

    if cfg.frontend == "audio" and shape.kind != "decode":
        specs["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), dt)
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), dt)
    return specs
