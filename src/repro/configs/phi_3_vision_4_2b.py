"""phi-3-vision-4.2b [vlm] — 32L d3072 32H (MHA kv=32) ff8192 vocab32064.
CLIP frontend is a STUB: input_specs provides 576 precomputed patch
embeddings fused as a prefix.  [hf:microsoft/Phi-3-vision-128k-instruct]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32064, head_dim=96,
    block_pattern=(("attn", "mlp"),),
    frontend="vision", frontend_len=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct (phi3-mini + CLIP stub)",
)
