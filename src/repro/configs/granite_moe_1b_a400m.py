"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8) expert-ff 512,
vocab 49155, 32 experts top-8."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512,
    vocab=49155, head_dim=64,
    block_pattern=(("attn", "moe"),),
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512,
                  capacity_factor=1.25),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (32e top-8)",
)
