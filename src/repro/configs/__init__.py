"""Config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from .base import ModelConfig, MoEConfig, SSMConfig, ShapeConfig, \
    TrainConfig, reduced
from .shapes import SHAPES, applicable, input_specs, token_count

_MODULES = {
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-3-8b": "granite_3_8b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-small": "whisper_small",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG
