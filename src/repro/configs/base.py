"""Config dataclasses for the model zoo, shapes, and runs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    shared_expert: bool = False      # llama4: shared expert alongside routed
    router_z_loss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    expand: float = 2.0              # d_inner = expand * d_model (mamba)
    conv_kernel: int = 4
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)
    chunk: int = 128                 # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qk_norm: bool = False
    # layer pattern, cycled: entries from
    #   {"attn", "mlp", "moe", "mamba", "mlstm", "slstm", "hymba"}
    # each entry is one *residual sub-block*; a standard transformer layer is
    # ("attn", "mlp").
    block_pattern: Tuple[Tuple[str, ...], ...] = (("attn", "mlp"),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: Optional[int] = None     # tokens; None = full attention
    rope_theta: float = 10000.0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_len: int = 0                     # e.g. 1500 audio frames
    cross_attention: bool = False
    # modality frontend stub: precomputed embeddings prepended to the text
    frontend: Optional[str] = None           # "audio" | "vision"
    frontend_len: int = 0                    # patches / frames
    # numerics
    dtype: str = "float32"                   # activations / compute
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 256
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_chunk: int = 1024                   # kv-chunk for online-softmax attn
    remat: str = "none"                      # none | full | dots
    constrain_acts: bool = False             # with_sharding_constraint on
    #                                          residual activations (§Perf)
    # notes for DESIGN/EXPERIMENTS (e.g. provenance of the config)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_reps(self) -> int:
        assert self.n_layers % self.pattern_period == 0, \
            (self.name, self.n_layers, self.pattern_period)
        return self.n_layers // self.pattern_period

    def sub_quadratic(self) -> bool:
        """True if decode state is O(1) in context length (SSM/hybrid with
        sliding-window attention only)."""
        kinds = {b for grp in self.block_pattern for b in grp}
        has_full_attn = ("attn" in kinds and self.sliding_window is None) or \
            self.cross_attention
        return not has_full_attn

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    optimizer: str = "adamw"        # adamw | adafactor
    microbatch: int = 0             # 0 = no accumulation
    z_loss: float = 1e-4
    grad_compression: str = "none"  # none | int8 (DP axis, shard_map path)
    gather_once: bool = False       # all-gather FSDP params once per step
    #                                 (outside the microbatch scan), §Perf
    seed: int = 0


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv: int = 2, d_ff: int = 128, vocab: int = 512,
            experts: int = 4) -> ModelConfig:
    """Smoke-test scale-down that preserves the architecture family
    (pattern, MoE/SSM structure, frontends) while shrinking every dimension."""
    period = cfg.pattern_period
    layers = max(period, (layers // period) * period or period)
    kw = dict(
        n_layers=layers, d_model=d_model,
        n_heads=heads, n_kv=min(kv, heads), d_ff=d_ff, vocab=vocab,
        head_dim=d_model // heads,
        vocab_pad_multiple=64,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=experts,
            top_k=min(cfg.moe.top_k, experts), d_ff_expert=d_ff)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, chunk=32)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_len"] = 16
    if cfg.frontend_len:
        kw["frontend_len"] = 8
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    kw["attn_chunk"] = 64
    return cfg.replace(**kw)
