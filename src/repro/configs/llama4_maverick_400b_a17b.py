"""llama4-maverick-400b-a17b [moe] — 48L d5120 40H (GQA kv=8) ff8192,
vocab 202048, MoE 128e top-1, interleaved dense/MoE + shared expert
(to land at ~400B total / ~17B active; DESIGN.md §5).  Adafactor state."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
    vocab=202048, head_dim=128,
    block_pattern=(("attn", "mlp"), ("attn", "moe")),
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  capacity_factor=1.25, shared_expert=True),
    dtype="bfloat16", param_dtype="bfloat16",
    remat="dots",
    source="hf:meta-llama/Llama-4-Maverick family; unverified assignment",
)
