"""deepseek-coder-33b [dense] — 62L d7168 56H (GQA kv=8) ff19200 vocab32256.
Llama architecture. [arXiv:2401.14196]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv=8, d_ff=19200,
    vocab=32256, head_dim=128,
    block_pattern=(("attn", "mlp"),),
    rope_theta=1e5,
    remat="dots",
    source="arXiv:2401.14196 (llama-arch)",
)
