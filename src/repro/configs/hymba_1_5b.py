"""hymba-1.5b [hybrid] — 32L d1600 25H (GQA kv=5) ff5504 ssm_state=16.
Parallel attention + mamba heads per layer, mean-fused; sliding-window
attention (1024) keeps decode state O(1).  [arXiv:2411.13676]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504,
    vocab=32001, head_dim=64,
    block_pattern=(("hymba", "mlp"),),
    sliding_window=1024,
    ssm=SSMConfig(d_state=16, expand=2.0, chunk=128),
    source="arXiv:2411.13676 (parallel attn+mamba heads)",
)
