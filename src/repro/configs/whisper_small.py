"""whisper-small [audio] — 12L enc + 12L dec, d768 12H ff3072 vocab51865.
Conv frontend is a STUB: input_specs provides 1500 precomputed frame
embeddings.  Decoder self-attention uses RoPE (deviation from learned
positions, noted in DESIGN.md).  [arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=51865, head_dim=64,
    block_pattern=(("attn", "gmlp"),),
    tie_embeddings=True,
    encoder_layers=12, encoder_len=1500, cross_attention=True,
    frontend="audio",
    source="arXiv:2212.04356 (enc-dec, conv frontend stubbed)",
)
