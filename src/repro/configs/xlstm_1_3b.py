"""xlstm-1.3b [ssm] — 48L d2048 4H, sLSTM + mLSTM blocks (7:1), d_ff=0.
expand=1.0 keeps the parameter count at the 1.3B point (DESIGN.md §5).
[arXiv:2405.04517]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, d_ff=0,
    vocab=50304,
    block_pattern=tuple([("mlstm",)] * 7 + [("slstm",)]),
    ssm=SSMConfig(d_state=16, expand=1.0, chunk=128),
    source="arXiv:2405.04517 (xLSTM[7:1]); unverified assignment",
)
