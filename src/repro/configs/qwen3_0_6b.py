"""qwen3-0.6b [dense] — 28L d1024 16H (GQA kv=8) ff3072 vocab151936.
qk-norm + GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv=8, d_ff=3072,
    vocab=151936, head_dim=128, qk_norm=True,
    tie_embeddings=True,
    block_pattern=(("attn", "mlp"),),
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-0.6B (qk_norm, GQA, head_dim=128)",
)
