"""granite-3-8b [dense] — 40L d4096 32H (GQA kv=8) ff12800 vocab49155.
Vocab padded 49155 -> 49408 for 16-way TP (loss masks the pad)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=12800,
    vocab=49155, head_dim=128,
    block_pattern=(("attn", "mlp"),),
    rope_theta=1e4,
    source="hf:ibm-granite/granite-3.0-8b-base (GQA)",
)
