"""Experiment-grade workload generation for the solve service.

``repro.workload.generator`` turns a declarative sweep spec — the
vnep-approx experiment shape: random G(n,p) grids × repetitions × a
named-instance mix × knob distributions — into open-loop arrival traces
that ``benchmarks/serve_load.py`` replays against the serving stack,
with duplicate/isomorphic-duplicate dials to exercise the result cache
(DESIGN.md §16).
"""
from .generator import (Arrival, SpecError, SweepSpec, generate,
                        quick_spec, read_trace, write_trace)

__all__ = ["Arrival", "SpecError", "SweepSpec", "generate", "quick_spec",
           "read_trace", "write_trace"]
