"""``python -m repro.workload`` — the trace-generator CLI."""
import sys

from .generator import main

if __name__ == "__main__":
    sys.exit(main())
