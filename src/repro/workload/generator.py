"""Declarative sweep specs → open-loop arrival traces.

The paper evaluates on a hand-picked instance table; the serving North
Star needs *experiment-shaped* load — the parameter-space sweeps the
vnep-approx harness runs (``treewidth_computation_experiments``: nodes ×
connection probability × repetitions), mixed with named Table-1-style
instances, with per-request knob distributions and a duplicate-rate dial
that models real traffic's repeat submissions (the result cache's whole
reason to exist).

A **spec** is a plain dict (JSON-friendly)::

    {
      "seed": 7,
      "requests": 64,                       # total arrivals
      "arrival": {"kind": "poisson", "rate_hz": 40.0},
      "sweep":  {"nodes": [8, 10, 12], "p": [0.2, 0.4], "reps": 3},
      "named":  {"names": ["petersen", "myciel3"], "reps": 2},
      "duplicate_rate": 0.5,                # P(arrival repeats a root)
      "iso_rate": 0.25,                     # P(a duplicate is relabeled)
      "knobs":  {"mode": ["sort", "bloom"], "reconstruct": false}
    }

``SweepSpec.parse`` validates *everything up front* — a bad spec raises
``SpecError`` at parse time, never mid-replay.  ``generate`` expands the
spec into a list of :class:`Arrival`\\ s, each carrying its offset
``t`` (seconds from trace start), a self-contained graph payload
(``n`` + explicit edge list, so replay needs no generator state), its
submit knobs, and duplicate provenance (``dup_of`` = the root arrival's
index; ``iso`` marks a relabeled duplicate — same graph up to
isomorphism, byte-different adjacency, which only a *canonical* cache
key can hit).

Determinism: the whole trace is a pure function of the spec —
``generate(spec)`` twice, or in two processes, yields identical traces
(one ``random.Random(seed)`` drives every draw; G(n,p) instance seeds
are derived arithmetically from the spec seed and grid position, so the
graphs themselves are reproducible via ``graph.gnp``).

CLI::

    python -m repro.workload.generator --quick --duplicate-rate 0.5 \\
        --out trace.jsonl
    python -m repro.workload.generator --spec sweep.json --out trace.jsonl
    python -m benchmarks.serve_load --trace trace.jsonl

Trace format: JSON lines — one meta header line, then one arrival per
line (``read_trace`` round-trips ``write_trace`` exactly).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import random
import sys
from typing import Dict, List, Optional, Tuple

from repro.core import graph as graph_lib

# knobs an arrival may carry — the subset of the submit surface whose
# values are JSON primitives and make sense drawn from a distribution
KNOB_NAMES = ("reconstruct", "start_k", "mode", "use_mmw",
              "use_simplicial", "speculate", "shards", "priority",
              "heuristics", "seed", "no_cache")

_ARRIVAL_KINDS = ("uniform", "poisson")


class SpecError(ValueError):
    """A sweep spec failed validation — raised by ``SweepSpec.parse``
    with the offending field in the message, always before any replay
    starts."""


def _expect(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A validated sweep spec (see the module docstring for the dict
    shape).  Construct via :meth:`parse` — the constructor itself does
    not validate."""
    seed: int
    requests: int
    arrival_kind: str                      # "uniform" | "poisson"
    gap_s: float                           # uniform: fixed gap
    rate_hz: float                         # poisson: arrival rate
    nodes: Tuple[int, ...]
    p: Tuple[float, ...]
    sweep_reps: int
    names: Tuple[str, ...]
    named_reps: int
    duplicate_rate: float
    iso_rate: float
    knobs: Dict[str, object]

    @staticmethod
    def parse(d: dict) -> "SweepSpec":
        _expect(isinstance(d, dict), f"spec must be a dict, got "
                f"{type(d).__name__}")
        known = {"seed", "requests", "arrival", "sweep", "named",
                 "duplicate_rate", "iso_rate", "knobs"}
        extra = set(d) - known
        _expect(not extra, f"unknown spec field(s) {sorted(extra)}; "
                f"known: {sorted(known)}")

        seed = d.get("seed", 0)
        _expect(isinstance(seed, int) and not isinstance(seed, bool),
                f"seed must be an int, got {seed!r}")

        arrival = d.get("arrival", {"kind": "uniform", "gap_s": 0.05})
        _expect(isinstance(arrival, dict), "arrival must be a dict")
        kind = arrival.get("kind", "uniform")
        _expect(kind in _ARRIVAL_KINDS,
                f"arrival.kind must be one of {_ARRIVAL_KINDS}, "
                f"got {kind!r}")
        gap_s = arrival.get("gap_s", 0.05)
        rate_hz = arrival.get("rate_hz", 20.0)
        _expect(isinstance(gap_s, (int, float)) and gap_s >= 0,
                f"arrival.gap_s must be >= 0, got {gap_s!r}")
        _expect(isinstance(rate_hz, (int, float)) and rate_hz > 0,
                f"arrival.rate_hz must be > 0, got {rate_hz!r}")

        sweep = d.get("sweep", {})
        _expect(isinstance(sweep, dict), "sweep must be a dict")
        nodes = tuple(sweep.get("nodes", ()))
        ps = tuple(sweep.get("p", ()))
        sweep_reps = sweep.get("reps", 1)
        for n in nodes:
            _expect(isinstance(n, int) and n >= 1,
                    f"sweep.nodes entries must be ints >= 1, got {n!r}")
        for p in ps:
            _expect(isinstance(p, (int, float)) and 0.0 <= p <= 1.0,
                    f"sweep.p entries must be in [0, 1], got {p!r}")
        _expect(isinstance(sweep_reps, int) and sweep_reps >= 1,
                f"sweep.reps must be an int >= 1, got {sweep_reps!r}")
        _expect(bool(nodes) == bool(ps),
                "sweep needs both nodes and p (or neither)")

        named = d.get("named", {})
        _expect(isinstance(named, dict), "named must be a dict")
        names = tuple(named.get("names", ()))
        named_reps = named.get("reps", 1)
        for nm in names:
            _expect(nm in graph_lib.REGISTRY,
                    f"named.names entry {nm!r} is not in graph.REGISTRY; "
                    f"known: {sorted(graph_lib.REGISTRY)}")
        _expect(isinstance(named_reps, int) and named_reps >= 1,
                f"named.reps must be an int >= 1, got {named_reps!r}")
        _expect(nodes or names,
                "spec generates no instances: give sweep.nodes + sweep.p "
                "and/or named.names")

        base_count = (len(nodes) * len(ps) * sweep_reps
                      + len(names) * named_reps)
        requests = d.get("requests", base_count)
        _expect(isinstance(requests, int) and requests >= 1,
                f"requests must be an int >= 1, got {requests!r}")

        duplicate_rate = d.get("duplicate_rate", 0.0)
        iso_rate = d.get("iso_rate", 0.0)
        for nm, v in (("duplicate_rate", duplicate_rate),
                      ("iso_rate", iso_rate)):
            _expect(isinstance(v, (int, float)) and 0.0 <= v <= 1.0,
                    f"{nm} must be in [0, 1], got {v!r}")

        knobs = d.get("knobs", {})
        _expect(isinstance(knobs, dict), "knobs must be a dict")
        for k, v in knobs.items():
            _expect(k in KNOB_NAMES,
                    f"unknown knob {k!r}; known: {sorted(KNOB_NAMES)}")
            if isinstance(v, list):
                _expect(len(v) >= 1, f"knob {k!r}: empty choice list")

        return SweepSpec(seed=int(seed), requests=int(requests),
                         arrival_kind=kind, gap_s=float(gap_s),
                         rate_hz=float(rate_hz), nodes=nodes,
                         p=tuple(float(p) for p in ps),
                         sweep_reps=int(sweep_reps), names=names,
                         named_reps=int(named_reps),
                         duplicate_rate=float(duplicate_rate),
                         iso_rate=float(iso_rate), knobs=dict(knobs))


@dataclasses.dataclass
class Arrival:
    """One trace entry: submit graph ``(n, edges)`` at offset ``t`` with
    ``knobs``.  ``dup_of`` is the index of the root arrival this one
    duplicates (None for fresh instances); ``iso`` marks a relabeled
    duplicate — isomorphic to its root, byte-different adjacency."""
    idx: int
    t: float
    name: str
    n: int
    edges: List[List[int]]
    knobs: Dict[str, object] = dataclasses.field(default_factory=dict)
    dup_of: Optional[int] = None
    iso: bool = False

    def graph(self) -> graph_lib.Graph:
        return graph_lib.from_edges(self.n, self.edges, name=self.name)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "Arrival":
        return Arrival(idx=int(d["idx"]), t=float(d["t"]),
                       name=str(d["name"]), n=int(d["n"]),
                       edges=[[int(u), int(v)] for u, v in d["edges"]],
                       knobs=dict(d.get("knobs", {})),
                       dup_of=d.get("dup_of"),
                       iso=bool(d.get("iso", False)))


def _edge_list(g: graph_lib.Graph) -> List[List[int]]:
    return [[int(u), int(v)] for u in range(g.n)
            for v in range(u + 1, g.n) if g.adj[u][v]]


def _base_instances(spec: SweepSpec) -> List[Tuple[str, int,
                                                   List[List[int]]]]:
    """The fresh-instance pool: the full G(n,p) grid × reps, then the
    named mix × reps.  G(n,p) seeds are arithmetic in the grid position,
    so instance i of a spec is the same graph in every process."""
    out = []
    for ni, n in enumerate(spec.nodes):
        for pi, p in enumerate(spec.p):
            for rep in range(spec.sweep_reps):
                gseed = (spec.seed * 1000003 + ni * 10007
                         + pi * 101 + rep) % (1 << 32)
                g = graph_lib.gnp(n, p, seed=gseed)
                out.append((f"gnp{n}_p{p:g}_r{rep}", n, _edge_list(g)))
    for nm in spec.names:
        g = graph_lib.REGISTRY[nm]()
        edges = _edge_list(g)
        for rep in range(spec.named_reps):
            out.append((nm if spec.named_reps == 1 else f"{nm}_r{rep}",
                        g.n, edges))
    return out


def _draw_knobs(spec: SweepSpec, rng: random.Random) -> Dict[str, object]:
    """Fixed knob values pass through; list values are per-arrival
    uniform draws."""
    out = {}
    for k in sorted(spec.knobs):            # sorted: draw-order stability
        v = spec.knobs[k]
        out[k] = rng.choice(v) if isinstance(v, list) else v
    return out


def generate(spec: SweepSpec) -> List[Arrival]:
    """Expand a validated spec into its arrival trace (pure function of
    the spec; see the module docstring for the determinism contract).

    Arrival 0 is always fresh; each later slot is a duplicate with
    probability ``duplicate_rate`` — it repeats a uniformly chosen
    earlier *root* (fresh) arrival's graph and knobs, relabeled by a
    random vertex permutation with probability ``iso_rate``.  Fresh
    slots walk the shuffled instance pool, recycling it (new knob draws,
    same graphs) when ``requests`` exceeds the pool."""
    base = _base_instances(spec)
    rng = random.Random(spec.seed)
    rng.shuffle(base)
    arrivals: List[Arrival] = []
    roots: List[int] = []                   # indices of fresh arrivals
    t = 0.0
    fresh_i = 0
    for i in range(spec.requests):
        if i > 0:
            t += (spec.gap_s if spec.arrival_kind == "uniform"
                  else rng.expovariate(spec.rate_hz))
        if roots and rng.random() < spec.duplicate_rate:
            root = arrivals[rng.choice(roots)]
            iso = rng.random() < spec.iso_rate
            n, edges, name = root.n, root.edges, root.name
            if iso and n > 1:
                perm = list(range(n))
                rng.shuffle(perm)
                edges = sorted([sorted([perm[u], perm[v]])
                                for u, v in edges])
                name = f"{name}_iso"
            arrivals.append(Arrival(idx=i, t=round(t, 6), name=name, n=n,
                                    edges=[list(e) for e in edges],
                                    knobs=dict(root.knobs),
                                    dup_of=root.idx, iso=iso))
        else:
            name, n, edges = base[fresh_i % len(base)]
            fresh_i += 1
            arrivals.append(Arrival(idx=i, t=round(t, 6), name=name, n=n,
                                    edges=[list(e) for e in edges],
                                    knobs=_draw_knobs(spec, rng)))
            roots.append(i)
    return arrivals


# ------------------------------------------------------------------ traces

def write_trace(path: str, arrivals: List[Arrival],
                spec: Optional[SweepSpec] = None) -> None:
    """JSONL: one meta header line, then one arrival per line."""
    meta = {"trace": "twworkload", "version": 1,
            "arrivals": len(arrivals)}
    if spec is not None:
        meta["spec"] = dataclasses.asdict(spec)
    with open(path, "w", encoding="utf-8") as f:
        f.write(json.dumps({"meta": meta}) + "\n")
        for a in arrivals:
            f.write(json.dumps(a.to_json()) + "\n")


def read_trace(path: str) -> List[Arrival]:
    """Inverse of ``write_trace`` (meta line optional, so hand-written
    traces replay too)."""
    arrivals = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "meta" in d:
                continue
            arrivals.append(Arrival.from_json(d))
    return arrivals


def quick_spec(duplicate_rate: float = 0.5, iso_rate: float = 0.25,
               requests: int = 16, seed: int = 0) -> SweepSpec:
    """The fast-tier spec: a small G(n,p) grid plus two light named
    instances, 20 ms uniform gaps — what CI's generated-trace smoke and
    ``benchmarks/cache_effect.py`` run."""
    return SweepSpec.parse({
        "seed": seed,
        "requests": requests,
        "arrival": {"kind": "uniform", "gap_s": 0.02},
        "sweep": {"nodes": [8, 10], "p": [0.25, 0.5], "reps": 1},
        "named": {"names": ["petersen", "myciel3"], "reps": 1},
        "duplicate_rate": duplicate_rate,
        "iso_rate": iso_rate,
    })


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="expand a sweep spec into a serve_load arrival trace")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--spec", metavar="PATH",
                     help="JSON sweep spec (module docstring shape)")
    src.add_argument("--quick", action="store_true",
                     help="built-in fast-tier spec (quick_spec)")
    ap.add_argument("--requests", type=int, default=None,
                    help="override the spec's arrival count")
    ap.add_argument("--duplicate-rate", type=float, default=None,
                    help="override the spec's duplicate dial")
    ap.add_argument("--iso-rate", type=float, default=None,
                    help="override the spec's relabeled-duplicate dial")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the spec's seed")
    ap.add_argument("--out", metavar="PATH", default="wl_trace.jsonl",
                    help="trace output path (JSON lines)")
    args = ap.parse_args(argv)

    if args.quick:
        d = dataclasses.asdict(quick_spec())
        # re-nest the flat SweepSpec fields into the parse shape
        d = {"seed": d["seed"], "requests": d["requests"],
             "arrival": {"kind": d["arrival_kind"], "gap_s": d["gap_s"],
                         "rate_hz": d["rate_hz"]},
             "sweep": {"nodes": list(d["nodes"]), "p": list(d["p"]),
                       "reps": d["sweep_reps"]},
             "named": {"names": list(d["names"]), "reps": d["named_reps"]},
             "duplicate_rate": d["duplicate_rate"],
             "iso_rate": d["iso_rate"], "knobs": d["knobs"]}
    else:
        with open(args.spec, "r", encoding="utf-8") as f:
            d = json.load(f)
    if args.requests is not None:
        d["requests"] = args.requests
    if args.duplicate_rate is not None:
        d["duplicate_rate"] = args.duplicate_rate
    if args.iso_rate is not None:
        d["iso_rate"] = args.iso_rate
    if args.seed is not None:
        d["seed"] = args.seed

    try:
        spec = SweepSpec.parse(d)
    except SpecError as e:
        print(f"[workload] bad spec: {e}", file=sys.stderr)
        return 2
    arrivals = generate(spec)
    write_trace(args.out, arrivals, spec)
    dups = sum(1 for a in arrivals if a.dup_of is not None)
    isos = sum(1 for a in arrivals if a.iso)
    span = arrivals[-1].t if arrivals else 0.0
    print(f"[workload] {len(arrivals)} arrivals over {span:.2f}s -> "
          f"{args.out} ({dups} duplicates, {isos} relabeled)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
