"""Slot-based continuous batching scheduler (LM decode).

A fixed pool of B decode slots (``repro.serve.slots.SlotPool`` — the
admission core shared with the treewidth solve scheduler).  Admission is
**token-at-a-time**: a newly admitted request streams its prompt through
the shared batched decode step (one token per tick) until the prompt is
exhausted, then flips to generation.  Finished sequences release their
slot immediately.

Why token-at-a-time instead of a separate batched prefill:
  * one jit signature for the whole serving loop (decode only);
  * exact for *every* architecture — KV caches, sliding-window ring
    buffers, and recurrent SSM states all advance per token with per-slot
    positions, so no padding/masking corrections are ever needed;
  * admission cost is O(prompt_len) ticks, amortised across the batch —
    the classic Orca-style piggyback.  Aligned-batch workloads can use
    Engine.prefill directly (equal-length prompts need no padding).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp

from .slots import SlotPool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_tokens: int
    eos_id: Optional[int] = None
    output: Optional[list] = None


@dataclasses.dataclass
class _Slot:
    request: Request
    pos: int = 0                 # next cache position to write
    fed: int = 0                 # prompt tokens already fed
    generated: int = 0


class Scheduler:
    def __init__(self, engine, params):
        self.engine = engine
        self.params = params
        self.pool = SlotPool(engine.batch)
        self.cache = engine.new_cache()
        self.done: dict = {}
        self._feed = np.zeros((engine.batch, 1), np.int32)

    def submit(self, req: Request):
        req.output = []
        self.pool.submit(req)

    def _admit(self):
        for i, s in self.pool.admit(lambda req: _Slot(request=req)):
            self._feed[i, 0] = s.request.prompt[0]

    def step(self) -> bool:
        """One engine tick: batched decode over all slots."""
        self._admit()
        active = self.pool.active()
        if not active:
            return False
        pos = np.zeros(len(self.pool), np.int32)
        for i, s in active:
            pos[i] = s.pos
        logits, self.cache = self.engine.decode(
            self.params, jnp.asarray(self._feed), self.cache,
            jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for i, s in active:
            s.pos += 1
            if s.fed < len(s.request.prompt) - 1:
                # still streaming the prompt
                s.fed += 1
                self._feed[i, 0] = s.request.prompt[s.fed]
                continue
            # prompt done: nxt[i] is a generated token
            tok = int(nxt[i])
            s.request.output.append(tok)
            s.generated += 1
            finished = (s.generated >= s.request.max_tokens or
                        (s.request.eos_id is not None
                         and tok == s.request.eos_id))
            if finished:
                self.done[s.request.rid] = s.request
                self.pool.release(i)
            else:
                self._feed[i, 0] = tok
        return True

    def run(self, max_ticks: int = 100_000):
        ticks = 0
        while self.pool.busy and ticks < max_ticks:
            if not self.step():
                break
            ticks += 1
        return self.done
