"""Client for the persistent treewidth solve service (``twserved``).

The service (``repro.launch.twserved``) speaks newline-delimited JSON
over a plain TCP socket — one request object per line, one (or, for
``stream``, many) response object(s) per line back — so it is equally
scriptable from this module, from ``nc``/``curl --no-buffer
telnet://...``, or from any language with sockets and JSON.  This module
is the reference client: it is what the tests and
``benchmarks/serve_throughput.py`` use.

Wire operations (see ``repro.launch.twserved`` for the server side):

  {"op": "submit", "graph": "petersen", ...knobs}   -> {"ok": true, "rid": 0}
  {"op": "status", "rid": 0}                        -> {"ok": true, "state": ...}
  {"op": "stream", "rid": 0}    -> one event object per line, ending with a
                                   terminal event (done/cancelled/error)
  {"op": "result", "rid": 0}    -> blocks, then {"ok": true, "result": {...}}
  {"op": "cancel", "rid": 0}                        -> {"ok": true, "cancelled": true}
  {"op": "metrics"}             -> {"ok": true, "pool": {...}, "requests": {...}}
  {"op": "cache_stats"}         -> {"ok": true, "enabled": true, "hits": 3, ...}
  {"op": "shutdown"}                                -> {"ok": true}

Runnable example (start a server first, e.g.
``python -m repro.launch.twserved --port 7421 --lanes 4 --block 32``)::

    from repro.core import graph
    from repro.serve.client import TwClient

    c = TwClient(port=7421)
    rid = c.submit("petersen")                  # by registry name
    rid2 = c.submit(graph.myciel(3), use_mmw=True)   # or a Graph + knobs
    for ev in c.stream(rid):                    # anytime lb/ub rung events
        print(ev["event"], ev.get("k"), ev.get("lb"), ev.get("ub"))
    print(c.result(rid)["width"])
    c.shutdown()

Per-request knobs (``mode``, ``use_mmw``, ``use_simplicial``, ``cap``,
``speculate``, ``shards`` — intra-request scale-out across that many
pool slots — ``reconstruct``, ``start_k``, and the traffic-shaping
pair ``priority``/``deadline_s``) ride through ``submit`` to
``TwScheduler.submit`` — an override the pool's backend cannot run fails
that submit alone with ``TwServerError`` (the scheduler's per-request
``BackendCapabilityError`` surfaced over the wire).  When the server's
admission queue is bounded (``--max-queue``) an over-limit submit raises
``TwServerError`` with ``retry_after`` set — back off that many seconds
and resubmit.  A timed-out request's result carries ``exact: false`` and
``timed_out: true`` with its monotone anytime lb/ub; ``cancel`` ends a
request early (its stream terminates with the ``cancelled`` event and
``result`` raises).
"""
from __future__ import annotations

import json
import socket
from typing import Iterator, Optional, Union

from repro.core.graph import Graph

DEFAULT_PORT = 7421


class TwServerError(RuntimeError):
    """The server answered {"ok": false} — message carries its error.

    ``retry_after`` (seconds, else ``None``) is set when the rejection
    was backpressure: the server's admission queue was at its bound and
    the hint estimates when a slot frees up.
    """

    def __init__(self, msg: str, retry_after: Optional[float] = None):
        super().__init__(msg)
        self.retry_after = retry_after


def graph_to_wire(g: Graph) -> dict:
    """Serialise a ``Graph`` as the wire's {n, edges, name} triple."""
    edges = [[int(u), int(v)] for u in range(g.n) for v in range(u + 1, g.n)
             if g.adj[u][v]]
    return {"n": int(g.n), "edges": edges, "name": g.name}


class TwClient:
    """Thin blocking client: one TCP connection per operation (the
    protocol is stateless per line; ``stream`` holds its connection open
    until the ``done`` event arrives)."""

    def __init__(self, host: str = "127.0.0.1", port: int = DEFAULT_PORT,
                 timeout: Optional[float] = 60.0):
        """``timeout`` covers connecting and the quick operations
        (submit/status/ping/shutdown).  ``result`` and ``stream`` are
        *documented to block* for as long as the solve runs, so they
        read without a deadline by default — pass ``read_timeout`` to
        them to bound the wait."""
        self.host = host
        self.port = port
        self.timeout = timeout

    def _request(self, obj: dict, read_timeout: Optional[float] = -1.0):
        """Open, send one JSON line, yield response lines, close.
        ``read_timeout=-1`` keeps the connect timeout for reads."""
        with socket.create_connection((self.host, self.port),
                                      timeout=self.timeout) as sock:
            sock.sendall((json.dumps(obj) + "\n").encode())
            if read_timeout is None or read_timeout >= 0:
                sock.settimeout(read_timeout)
            with sock.makefile("r", encoding="utf-8") as rf:
                for line in rf:
                    line = line.strip()
                    if line:
                        yield json.loads(line)

    def _rpc(self, obj: dict, read_timeout: Optional[float] = -1.0) -> dict:
        for resp in self._request(obj, read_timeout):
            if not resp.get("ok", False):
                raise TwServerError(resp.get("error", "unknown error"),
                                    retry_after=resp.get("retry_after"))
            return resp
        raise TwServerError("connection closed without a response")

    # ------------------------------------------------------------- surface

    def submit(self, g: Union[Graph, str], **knobs) -> int:
        """Submit one solve request; returns its rid.  ``g`` is a
        ``Graph`` or a ``core.graph.REGISTRY`` generator name; ``knobs``
        are the per-request overrides (``reconstruct``, ``start_k``,
        ``mode``, ``use_mmw``, ``use_simplicial``, ``cap``,
        ``speculate``, ``shards``, ``priority``, ``deadline_s``,
        ``heuristics``, ``heuristic_only``, ``seed``, ``no_cache``).
        ``heuristic_only=True`` serves anytime bounds without any exact
        rung — graphs beyond exact-DP reach terminate with
        ``exact = (lb == ub)``; ``heuristics`` budgets the improver
        rounds and ``seed`` pins their draws.  Raises ``TwServerError``
        with ``retry_after`` set when the server shed the submit under
        backpressure."""
        req = {"op": "submit", **knobs}
        if isinstance(g, str):
            req["graph"] = g
        else:
            req.update(graph_to_wire(g))
        return int(self._rpc(req)["rid"])

    def status(self, rid: int) -> dict:
        """Queued / running (with running lb/ub) / terminal snapshot
        (``done`` — possibly ``timed_out`` — / ``cancelled`` /
        ``error``)."""
        return self._rpc({"op": "status", "rid": rid})

    def cancel(self, rid: int) -> bool:
        """Abandon a queued or running request (frees its lane
        mid-ladder).  True if something was cancelled; False for
        unknown or already-terminal rids (idempotent)."""
        return bool(self._rpc({"op": "cancel", "rid": rid})["cancelled"])

    def result(self, rid: int,
               read_timeout: Optional[float] = None) -> dict:
        """Block until the request finishes (no read deadline unless
        ``read_timeout`` is given); returns the result dict (width,
        exact, lb, ub, expanded, order, per_k; deadline-preempted
        requests additionally carry ``timed_out: true`` and their
        anytime bounds).  Raises ``TwServerError`` for a cancelled or
        admission-failed rid."""
        return self._rpc({"op": "result", "rid": rid},
                         read_timeout)["result"]

    def stream(self, rid: int,
               read_timeout: Optional[float] = None) -> Iterator[dict]:
        """Yield the request's event stream — ``admitted``/``bounds``,
        then per-rung ``rung_started``/``rung_decided`` with running
        monotone lb/ub, then the terminal event (``done`` — flagged
        ``timed_out`` for a deadline preemption — ``cancelled`` or
        ``error``; always last, iteration stops there).  Replays from
        the first event, so streaming a finished request yields its full
        history.  Blocks between events without a read deadline unless
        ``read_timeout`` bounds the gap."""
        for ev in self._request({"op": "stream", "rid": rid},
                                read_timeout):
            if not ev.get("ok", True):
                raise TwServerError(ev.get("error", "unknown error"))
            yield ev
            if ev.get("event") in ("done", "cancelled", "error"):
                return

    def metrics(self, rid: Optional[int] = None) -> dict:
        """The server's scoped telemetry snapshot
        (``TwScheduler.metrics``): ``pool`` carries the pool scope's
        counters/gauges/timings, ``requests`` maps rid -> that request's
        child-scope snapshot (live requests as of now, finished ones as
        frozen at their terminal event).  ``rid`` filters ``requests``
        to one request."""
        req = {"op": "metrics"}
        if rid is not None:
            req["rid"] = int(rid)
        resp = self._rpc(req)
        resp.pop("ok", None)
        return resp

    def cache_stats(self) -> dict:
        """The server's result-cache counters (``TwScheduler.
        cache_stats``): ``enabled`` plus, when a cache is configured,
        entries/capacity/pinned and the hits/misses/insertions/evictions
        counters with the running ``hit_rate``.  A cached submit's
        events and its ``admitted`` line carry ``"cached": true``; the
        ``no_cache`` submit knob bypasses the cache per request."""
        resp = self._rpc({"op": "cache_stats"})
        resp.pop("ok", None)
        return resp

    def ping(self) -> bool:
        try:
            return bool(self._rpc({"op": "ping"})["ok"])
        except OSError:
            return False

    def shutdown(self) -> None:
        """Ask the server process to drain in-flight work and exit."""
        self._rpc({"op": "shutdown"})
