"""Bounded content-addressed result cache with an LRU+pin policy.

Entries are keyed by ``core.canon.cache_key`` digests (canonical graph ×
effective solve config) and hold a finished :class:`SolveResult` plus the
elimination order in *canonical* label space (the scheduler translates
through the submission's canonical permutation on insert and hit, so one
entry serves every isomorphic relabeling).

Policy: plain LRU over unpinned entries, with ``pin``/``unpin`` taking
entries out of eviction consideration (for instances an operator wants
resident — e.g. the Table 1 suite during a benchmark run).  Pins are
honored over capacity: if every entry is pinned the cache grows past
``entries`` rather than evicting a pinned result; eviction resumes once
unpinned entries exist.  All operations are O(1) and thread-safe — the
scheduler calls ``lookup`` on its submit path under client threads and
``insert`` from the driver thread.

The cache never stores in-flight or failed work: the scheduler inserts
only on a clean ``done`` (DESIGN.md §16), so a ``lookup`` hit is always a
complete, replay-verified result.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, Optional

from ..core.solver import SolveResult


@dataclass
class CacheEntry:
    """One finished solve: the result plus its canonical-space order."""
    result: SolveResult                 # order field is canonical-space
    pinned: bool = False
    hits: int = 0


def _copy_result(r: SolveResult) -> SolveResult:
    """Deep-enough copy: callers mutate neither the cache's result nor
    each other's (per_k dicts and order lists are fresh objects)."""
    return replace(
        r,
        order=None if r.order is None else list(r.order),
        per_k=None if r.per_k is None else dict(r.per_k),
    )


class ResultCache:
    """LRU+pin cache mapping content digests to finished SolveResults."""

    def __init__(self, entries: int = 256):
        if entries < 1:
            raise ValueError(f"cache needs entries >= 1, got {entries}")
        self.capacity = int(entries)
        self._lock = threading.Lock()
        self._d: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._insertions = 0
        self._evictions = 0

    # ------------------------------------------------------------- lookups
    def lookup(self, key: str, need_order: bool = False) -> Optional[SolveResult]:
        """Return a private copy of the cached result, or None.

        ``need_order=True`` (a ``reconstruct`` submission) misses on
        entries solved without reconstruction — the scheduler then runs
        the solve and the order-ful result overwrites the entry, so the
        cache monotonically upgrades toward the richer surface."""
        with self._lock:
            e = self._d.get(key)
            if e is None or (need_order and e.result.order is None):
                self._misses += 1
                return None
            self._d.move_to_end(key)
            self._hits += 1
            e.hits += 1
            return _copy_result(e.result)

    def peek(self, key: str) -> Optional[SolveResult]:
        """lookup without touching recency or hit/miss accounting."""
        with self._lock:
            e = self._d.get(key)
            return None if e is None else _copy_result(e.result)

    # ------------------------------------------------------------- updates
    def insert(self, key: str, result: SolveResult) -> int:
        """Store ``result`` under ``key``; returns evictions performed.

        Overwrites an existing entry only when the newcomer is at least
        as rich (has an order when the incumbent does) — a plain re-solve
        must not downgrade an order-ful entry to an order-less one."""
        with self._lock:
            e = self._d.get(key)
            if e is not None:
                if e.result.order is not None and result.order is None:
                    self._d.move_to_end(key)
                    return 0
                e.result = _copy_result(result)
                self._d.move_to_end(key)
                self._insertions += 1
                return 0
            self._d[key] = CacheEntry(result=_copy_result(result))
            self._insertions += 1
            evicted = 0
            if len(self._d) > self.capacity:
                # scan oldest-first for unpinned victims; pinned entries
                # are skipped, which can legitimately leave the cache
                # over capacity
                for k in list(self._d):
                    if len(self._d) <= self.capacity:
                        break
                    if self._d[k].pinned or k == key:
                        continue
                    del self._d[k]
                    evicted += 1
            self._evictions += evicted
            return evicted

    def pin(self, key: str) -> bool:
        with self._lock:
            e = self._d.get(key)
            if e is None:
                return False
            e.pinned = True
            return True

    def unpin(self, key: str) -> bool:
        with self._lock:
            e = self._d.get(key)
            if e is None:
                return False
            e.pinned = False
            return True

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    # --------------------------------------------------------------- intro
    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._d

    def stats(self) -> Dict[str, object]:
        """Counters for the ``cache_stats`` wire op and telemetry
        reconciliation: hits + misses == lookups, insertions - evictions
        == entries (absent overwrites), hit_rate over all lookups."""
        with self._lock:
            lookups = self._hits + self._misses
            return {
                "entries": len(self._d),
                "capacity": self.capacity,
                "pinned": sum(1 for e in self._d.values() if e.pinned),
                "hits": self._hits,
                "misses": self._misses,
                "insertions": self._insertions,
                "evictions": self._evictions,
                "hit_rate": (self._hits / lookups) if lookups else 0.0,
            }
