"""Fixed slot pool + weighted FIFO admission: the continuous-batching core.

Both serving schedulers are the same machine — a fixed pool of B slots,
each holding the in-flight state of one admitted request, advanced by a
shared batched device step, with finished slots recycled to the queue
immediately:

  * ``repro.serve.scheduler``  — LM decode: a slot is a sequence, the
    shared step is one batched decode tick;
  * ``repro.serve.twscheduler`` — treewidth solves: a slot is a solve
    request's current deepening rung, the shared step is one multi-lane
    ``batch.decide_lanes`` dispatch.

This module is the slot/admission mechanics they share; everything
workload-specific (what a slot holds, what one step does, when a slot is
finished) stays in the schedulers.  The async treewidth scheduler
additionally relies on admission being pure host bookkeeping: ``admit``
only touches the queue and the slot table, so it is safe to run while a
batched device dispatch over the *occupied* slots is still in flight
(DESIGN.md §11's overlap invariant) — an occupied slot is never handed
out, and a newly filled one simply joins the next dispatch.

Traffic shaping (DESIGN.md §12) lives at this layer too, because both
schedulers need it and it is pure queue mechanics:

  * **priority classes** — ``submit(item, priority=p)`` files the item
    under integer class ``p`` (higher = more urgent, FIFO within a
    class).  Admission pops from the most urgent non-empty class, but a
    weighted anti-starvation counter guarantees the least urgent class
    one admission per ``prio_weight`` preferential pops — high-priority
    requests jump the queue without starving the base class.
  * **backpressure** — ``max_queue`` bounds the number of *queued*
    (not yet admitted) items; an over-limit ``submit`` raises
    ``QueueFull`` instead of growing the queue unboundedly.  The
    scheduler layer turns that into a reject-with-``retry_after`` reply.

Runnable example::

    pool = SlotPool(2)
    pool.submit("a"); pool.submit("b"); pool.submit("c")
    pool.submit("z", priority=1)            # jumps the FIFO
    pool.admit(lambda item: item.upper())   # -> [(0, "A"), (1, "B")]
    pool.release(0)                         # slot 0 recycles ...
    pool.admit(lambda item: item.upper())   # -> [(0, "Z")]  (priority)
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core import telemetry


class QueueFull(RuntimeError):
    """The admission queue is at ``max_queue``: shed this submit.

    ``retry_after`` (seconds, may be ``None`` at the pool layer) is the
    caller-facing hint: the scheduler estimates it from its recent round
    wall-clock and queue depth before surfacing the rejection.
    """

    def __init__(self, msg: str, retry_after: Optional[float] = None):
        super().__init__(msg)
        self.retry_after = retry_after


class _Shadow:
    """Occupancy marker for the extra slots of a multi-slot admission.

    A request admitted with width S occupies one *primary* slot (holding
    the caller state) plus S-1 shadow slots pointing back at it; shadows
    keep ``free`` honest and are recycled with their primary."""

    __slots__ = ("primary",)

    def __init__(self, primary: int):
        self.primary = primary


class SlotPool:
    """``n_slots`` recyclable slots fed from weighted-FIFO priority queues.

    A slot is either ``None`` (free) or an arbitrary caller state object.
    ``admit`` pops queued items into free slots through a caller ``start``
    callback, which may return ``None`` to signal "finished at admission"
    (e.g. a trivial instance) — the slot then immediately tries the next
    queued item, so trivial requests never waste a batched step.

    ``max_queue`` bounds the queued backlog (``QueueFull`` on overflow);
    ``prio_weight`` is the anti-starvation ratio: at most that many
    consecutive preferential pops before the least urgent waiting class
    is served once.

    ``slots_of`` (optional) maps a queued item to the number of slots it
    occupies — the sharded-request hook: a width-S item is admitted only
    when S slots are free, filling one primary slot plus S-1 ``_Shadow``
    markers that release together.  Admission is head-of-line: when the
    most urgent queued item does not fit, admission stops rather than
    skipping it, so wide requests cannot be starved by a stream of narrow
    ones (the flip side: narrow items behind a waiting wide one wait too
    — DESIGN.md §13)."""

    def __init__(self, n_slots: int, *, max_queue: Optional[int] = None,
                 prio_weight: int = 4,
                 slots_of: Optional[Callable[[object], int]] = None,
                 tracker=None):
        if n_slots < 1:
            raise ValueError(f"need at least one slot (got {n_slots})")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 (got {max_queue})")
        self.slots: List[Optional[object]] = [None] * n_slots
        self.max_queue = max_queue
        self.prio_weight = max(1, int(prio_weight))
        self.slots_of = slots_of
        # Pool-level queue mechanics telemetry; NULL (no-op) unless the
        # owning scheduler hands us its pool tracker.
        self.tracker = telemetry.NULL if tracker is None else tracker
        self._queues: Dict[int, deque] = {}   # priority class -> FIFO
        self._starve = 0   # consecutive preferential pops while base waits

    def __len__(self) -> int:
        return len(self.slots)

    # ------------------------------------------------------------- queueing

    def submit(self, item, priority: int = 0) -> None:
        if self.max_queue is not None and self.qsize >= self.max_queue:
            self.tracker.count(queue_rejections=1)
            raise QueueFull(
                f"admission queue full ({self.qsize} queued, "
                f"max_queue={self.max_queue}); retry later")
        self._queues.setdefault(int(priority), deque()).append(item)
        self.tracker.count(queue_submits=1)
        self.tracker.gauge("queue_depth", self.qsize)

    @property
    def qsize(self) -> int:
        """Items queued (admitted items do not count)."""
        return sum(len(q) for q in self._queues.values())

    def queued(self) -> Iterator[object]:
        """Queued items, most urgent class first, FIFO within a class."""
        for p in sorted(self._queues, reverse=True):
            yield from self._queues[p]

    @property
    def queue(self) -> list:
        """Snapshot of the queued items in class-then-FIFO order."""
        return list(self.queued())

    def discard(self, pred: Callable[[object], bool]) -> Optional[object]:
        """Remove and return the first queued item matching ``pred``
        (cancellation of a not-yet-admitted request); None if absent."""
        for p, q in list(self._queues.items()):
            for item in q:
                if pred(item):
                    q.remove(item)
                    if not q:
                        del self._queues[p]
                    return item
        return None

    def _pick(self) -> Optional[int]:
        """The priority class the next pop serves (no state mutated)."""
        prios = sorted((p for p, q in self._queues.items() if q),
                       reverse=True)
        if not prios:
            return None
        if len(prios) > 1 and self._starve >= self.prio_weight:
            return prios[-1]
        return prios[0]

    def _peek(self):
        """The item the next ``_pop`` would return (queues untouched)."""
        pick = self._pick()
        return None if pick is None else self._queues[pick][0]

    def _pop(self):
        """Weighted-FIFO pop: most urgent class wins, except that after
        ``prio_weight`` consecutive preferential pops while a less urgent
        class waits, the least urgent class is served once."""
        pick = self._pick()
        if pick is None:
            return None
        prios = sorted((p for p, q in self._queues.items() if q),
                       reverse=True)
        if len(prios) == 1:
            self._starve = 0
        elif pick == prios[-1] and self._starve >= self.prio_weight:
            self._starve = 0
        else:
            self._starve += 1
        q = self._queues[pick]
        item = q.popleft()
        if not q:
            del self._queues[pick]
        return item

    # ------------------------------------------------------------ admission

    def _width(self, item) -> int:
        return max(1, int(self.slots_of(item))) if self.slots_of else 1

    def admit(self, start: Callable[[object], Optional[object]]
              ) -> List[Tuple[int, object]]:
        """Fill free slots from the queues; returns [(slot index, state)].

        A width-S item (``slots_of``) is placed in the lowest free slot
        with S-1 shadows in the next free ones; the returned index is the
        primary.  Admission stops at the first queued item that does not
        fit (head-of-line, see class docstring)."""
        admitted = []
        while True:
            item = self._peek()
            if item is None:
                break
            need = self._width(item)
            free = [i for i, s in enumerate(self.slots) if s is None]
            if len(free) < need:
                break
            state = start(self._pop())
            if state is None:
                continue          # finished at admission; slot stays free
            primary = free[0]
            self.slots[primary] = state
            for j in free[1:need]:
                self.slots[j] = _Shadow(primary)
            admitted.append((primary, state))
        if admitted:
            self.tracker.count(admissions=len(admitted))
        self.tracker.gauge("queue_depth", self.qsize)
        return admitted

    def release(self, i: int) -> None:
        """Free slot ``i`` and any shadows it anchors (one call recycles a
        sharded request's whole slot group)."""
        self.slots[i] = None
        for j, s in enumerate(self.slots):
            if isinstance(s, _Shadow) and s.primary == i:
                self.slots[j] = None

    def active(self) -> List[Tuple[int, object]]:
        """Occupied slots in slot order (the batched-step iteration set).

        One entry per admitted item: shadow slots of a multi-slot
        admission are occupied but not listed."""
        return [(i, s) for i, s in enumerate(self.slots)
                if s is not None and not isinstance(s, _Shadow)]

    @property
    def free(self) -> int:
        """Slots currently available to admission."""
        return sum(1 for s in self.slots if s is None)

    @property
    def busy(self) -> bool:
        """Anything queued or in flight?"""
        return bool(self.qsize) or any(s is not None for s in self.slots)
