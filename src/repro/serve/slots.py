"""Fixed slot pool + FIFO admission: the shared continuous-batching core.

Both serving schedulers are the same machine — a fixed pool of B slots,
each holding the in-flight state of one admitted request, advanced by a
shared batched device step, with finished slots recycled to the queue
immediately:

  * ``repro.serve.scheduler``  — LM decode: a slot is a sequence, the
    shared step is one batched decode tick;
  * ``repro.serve.twscheduler`` — treewidth solves: a slot is a solve
    request's current deepening rung, the shared step is one multi-lane
    ``batch.decide_lanes`` dispatch.

This module is the slot/admission mechanics they share; everything
workload-specific (what a slot holds, what one step does, when a slot is
finished) stays in the schedulers.  The async treewidth scheduler
additionally relies on admission being pure host bookkeeping: ``admit``
only touches the queue and the slot table, so it is safe to run while a
batched device dispatch over the *occupied* slots is still in flight
(DESIGN.md §11's overlap invariant) — an occupied slot is never handed
out, and a newly filled one simply joins the next dispatch.

Runnable example::

    pool = SlotPool(2)
    pool.submit("a"); pool.submit("b"); pool.submit("c")
    pool.admit(lambda item: item.upper())   # -> [(0, "A"), (1, "B")]
    pool.release(0)                         # slot 0 recycles ...
    pool.admit(lambda item: item.upper())   # -> [(0, "C")]
"""
from __future__ import annotations

from collections import deque
from typing import Callable, List, Optional, Tuple


class SlotPool:
    """``n_slots`` recyclable slots fed from a FIFO queue.

    A slot is either ``None`` (free) or an arbitrary caller state object.
    ``admit`` pops queued items into free slots through a caller ``start``
    callback, which may return ``None`` to signal "finished at admission"
    (e.g. a trivial instance) — the slot then immediately tries the next
    queued item, so trivial requests never waste a batched step.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot (got {n_slots})")
        self.slots: List[Optional[object]] = [None] * n_slots
        self.queue: deque = deque()

    def __len__(self) -> int:
        return len(self.slots)

    def submit(self, item) -> None:
        self.queue.append(item)

    def admit(self, start: Callable[[object], Optional[object]]
              ) -> List[Tuple[int, object]]:
        """Fill free slots from the queue; returns [(slot index, state)]."""
        admitted = []
        for i, s in enumerate(self.slots):
            if s is not None:
                continue
            while self.queue:
                state = start(self.queue.popleft())
                if state is not None:
                    self.slots[i] = state
                    admitted.append((i, state))
                    break
        return admitted

    def release(self, i: int) -> None:
        self.slots[i] = None

    def active(self) -> List[Tuple[int, object]]:
        """Occupied slots in slot order (the batched-step iteration set)."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    @property
    def free(self) -> int:
        """Slots currently available to admission."""
        return sum(1 for s in self.slots if s is None)

    @property
    def busy(self) -> bool:
        """Anything queued or in flight?"""
        return bool(self.queue) or any(s is not None for s in self.slots)
