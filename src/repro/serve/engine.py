"""Serving engine: jitted prefill / decode steps over a slot-based cache.

The cache is a fixed pool of B slots (one per concurrent sequence), each
with its own position counter — single-token decode steps run for all slots
at once (continuous batching; the scheduler in scheduler.py fills and
recycles slots).  For SSM/hybrid architectures the per-slot "cache" is the
O(1) recurrent state, which is what makes the 524288-token `long_500k`
shape servable at all.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


class Engine:
    def __init__(self, model, batch: int, cache_len: int):
        self.model = model
        self.cfg = model.cfg
        self.batch = batch
        self.cache_len = cache_len
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------- steps

    def _prefill_impl(self, params, tokens, cache, **kw):
        logits, cache, _ = self.model.apply(
            params, tokens, mode="prefill", cache=cache, **kw)
        return logits[:, -1], cache

    def _decode_impl(self, params, tokens, cache, pos):
        logits, cache, _ = self.model.apply(
            params, tokens, mode="decode", cache=cache, pos=pos)
        return logits[:, 0], cache

    # --------------------------------------------------------------- api

    def new_cache(self):
        return self.model.init_cache(self.batch, self.cache_len)

    def prefill(self, params, tokens, cache, **kw):
        """tokens (B, S) for all slots (left-padded prompts share S)."""
        return self._prefill(params, tokens, cache, **kw)

    def decode(self, params, tokens, cache, pos):
        """tokens (B, 1); pos (B,) per-slot positions."""
        return self._decode(params, tokens, cache, pos)

    def generate_greedy(self, params, prompts, max_new: int, **kw):
        """Convenience: batched greedy decode.  prompts (B, S)."""
        b, s = prompts.shape
        assert b == self.batch
        cache = self.new_cache()
        last, cache = self.prefill(params, prompts, cache, **kw)
        out = []
        pos = jnp.full((b,), s, jnp.int32)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        for _ in range(max_new):
            out.append(tok)
            logits, cache = self.decode(params, tok, cache, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            pos = pos + 1
        return jnp.concatenate(out, axis=1)
