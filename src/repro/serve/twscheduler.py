"""Treewidth solve service: asynchronous continuous batching of requests.

The paper keeps the GPU busy by batching many independent wavefront
expansions per dispatch; this module applies the same principle one level
up, at the *request* level, and keeps the host busy too.  A fixed pool of
L lanes (``repro.serve.slots.SlotPool`` — the admission core shared with
the LM scheduler) runs continuous batching over concurrent ``solve``
requests:

  * each admitted request holds one lane with its current iterative-
    deepening rung — the ``(adj, allowed, k)`` of its current
    preprocessed block at its current k;
  * every scheduler step packs all occupied lanes into shared multi-lane
    dispatches (``batch.decide_lanes_async``, DESIGN.md §8/§11): the
    vmapped ``decide_loop`` runs every rung concurrently, a finished
    lane's masked early-exit freezing its carry while the others step;
  * the dispatch is **launched without blocking** (JAX async dispatch:
    the device arrays are held in an ``engine.DispatchHandle``, the host
    sync is deferred).  While the device works, the scheduler runs
    admission and planning for newly arrived requests — they take free
    slots immediately and are packed into the *next* dispatch instead of
    waiting for an idle pool (DESIGN.md §11's overlap pipeline);
  * when the verdicts are synced, each lane's result is fed to its
    request's ``batch.InstanceState`` (the same per-rung accounting
    ``solve``/``solve_many`` use, so results are bit-identical to
    sequential ``solver.solve`` per request) and the slot is immediately
    recycled — to the request's next rung, its next block, or the next
    queued request.

**Traffic shaping (DESIGN.md §12).**  The pool degrades gracefully under
load instead of queuing unboundedly or holding lanes hostage:

  * ``cancel(rid)`` frees the request's lane mid-ladder (queued requests
    are dropped from the queue); in-flight verdicts for a cancelled rid
    are discarded *uncounted* and a terminal ``cancelled`` event is
    emitted;
  * ``submit(deadline_s=...)`` preempts the lane at the first ``sync``
    past the deadline and resolves the request with its monotone
    best-so-far anytime ``lb``/``ub`` (``exact=False``) — Tamaki's
    anytime framing: a timed-out request returns bounds, not nothing;
  * ``submit(priority=...)`` files the request under a priority class:
    admission pops the most urgent class first but guarantees the base
    class one admission per ``prio_weight`` preferential pops
    (weighted FIFO — no starvation);
  * ``max_queue`` bounds the admission queue; over-limit submits raise
    ``slots.QueueFull`` carrying a ``retry_after`` hint estimated from
    the recent round wall-clock and the backlog depth;
  * ``pipeline`` raises the dispatch depth above 1: round N+1's rungs
    (each lane's *projected* next ladder steps) are launched over
    ``engine.DispatchHandle`` before round N syncs, so the device stays
    busy across the host-sync gap.  A rung the sequential ladder never
    ran (its block decided earlier) is discarded uncounted at sync —
    §8's speculation semantics — so parity and COUNTERS semantics are
    preserved; ``idle_syncs``/``covered_syncs`` count how often a sync
    left the device idle vs covered by a queued round.

**Per-request knobs.**  Each ``submit`` may override the pool's dedup
``mode``, the pruning flags (``use_mmw``/``use_simplicial``), pin an
explicit frontier ``cap``, or claim a larger lane share (``speculate`` —
that many consecutive deepening rungs per dispatch, smallest feasible
wins, accounting identical to the sequential ladder).  Requests whose
effective configs match share one vmapped program; incompatible configs
fall back to sub-pool dispatches within the same step (one dispatch per
config group).  An override the backend cannot run raises
``BackendCapabilityError`` from that ``submit`` alone — the pool and its
other requests are unaffected.

**Streaming.**  ``submit(..., on_event=cb)`` streams anytime progress in
the spirit of Tamaki's heuristic-computation work (PAPERS.md): per-rung
``rung_started``/``rung_decided`` events carrying running instance-level
``lb``/``ub`` (lb never decreases, ub never increases; they meet at the
width when the result is exact) and the ``per_k`` delta, then one
terminal event — ``done`` (with ``timed_out: true`` when a deadline
preempted the request), ``cancelled``, or ``error`` (admission failed).
Per request, ``seq`` is strictly increasing, a block's ``rung_decided``
events arrive in increasing k, and the terminal event is last — see
DESIGN.md §11/§12 for the ordering/monotonicity guarantees.  Sinks are
invoked *outside* the scheduler lock (events are buffered under the lock
and delivered after release), so a slow sink never stalls dispatch.

Fairness is structural: admission is weighted FIFO, and every in-flight
request advances exactly one rung (or its ``speculate`` share) per step.

Memory: per-lane frontier buffers are sized by ``batch.plan_capacity``
(``cap=None``); ``budget_bytes`` bounds the step's whole resident
footprint — when config groups, speculation or pipelining make several
dispatches resident at once, the budget is split across them (explicit
per-request ``cap``s are user-pinned and bypass it) — and compiled-
program churn is bounded by ratcheting the padded vertex count, the
planned cap (per config group) and the lane axis — a steady-state
service hits one compiled program per live config group.  See DESIGN.md
§10 (service + memory planning), §11 (async pipeline, grouping, event
guarantees, parity argument) and §12 (traffic shaping).

Runnable example (blocking drain; see ``repro.launch.twserved`` for the
persistent process and ``repro.serve.client`` for its client)::

    from repro.core import graph
    from repro.serve.twscheduler import TwScheduler

    events = []
    sched = TwScheduler(lanes=4, block=32)
    sched.submit(graph.petersen(), on_event=events.append)
    sched.submit(graph.myciel(3), use_mmw=True)    # per-request knob
    rid = sched.submit(graph.queen(5), priority=1) # jumps the queue
    sched.cancel(rid)                              # ... and is abandoned
    results = sched.run()                          # {rid: SolveResult}
    assert events[-1]["event"] == "done"
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core import backend as backend_lib
from repro.core import batch, bitset, bloom
from repro.core import bounds_engine
from repro.core import canon
from repro.core import engine as engine_lib
from repro.core import frontier as frontier_lib
from repro.core import shard as shard_lib
from repro.core import solver as solver_lib
from repro.core import telemetry
from repro.core.graph import Graph

from .cache import ResultCache
from .slots import QueueFull, SlotPool

# Each scheduler instance gets a uniquely-scoped pool tracker (child of
# the process root unless the caller supplies one): test suites build
# many pools per process, and sharing one "pool" scope would merge
# their counters.
_POOL_SEQ = itertools.count()


@dataclasses.dataclass
class SolveRequest:
    """One user query: compute tw(g), optionally with a certified order.

    Fields beyond ``rid``/``g`` are the per-request knobs (``None`` means
    "inherit the pool default"): ``mode`` picks the dedup (``"sort"`` /
    ``"bloom"``), ``use_mmw``/``use_simplicial`` the pruning,
    ``cap`` pins an explicit frontier buffer, and ``speculate`` the lane
    share (that many consecutive deepening rungs per dispatch).
    ``shards`` > 1 scales the request *out* instead of deep: it occupies
    that many pool slots and each of its rungs runs as one sharded
    dispatch (``core.shard``) whose frontier is split across ``shards``
    lanes with work donation — bit-identical verdicts, fewer scheduler
    rounds for heavy instances.
    ``priority`` is the admission class (higher = more urgent) and
    ``deadline`` the absolute ``time.monotonic()`` instant past which the
    request is preempted with its anytime bounds.  ``on_event`` receives
    the streaming event dicts (module docstring).

        req = SolveRequest(0, graph.petersen(), mode="bloom", speculate=2)
    """
    rid: int
    g: Graph
    reconstruct: bool = False
    start_k: Optional[int] = None
    mode: Optional[str] = None
    use_mmw: Optional[bool] = None
    use_simplicial: Optional[bool] = None
    cap: Optional[int] = None
    speculate: int = 1
    shards: int = 1
    priority: int = 0
    deadline: Optional[float] = None
    on_event: Optional[Callable[[dict], None]] = None
    # anytime bounds-engine knobs (core.bounds_engine, DESIGN.md §15):
    # ``heuristics`` is the improver-round budget (None = pool default),
    # ``heuristic_only`` serves bounds without any exact rung and
    # terminates with exact=(lb==ub), ``seed`` pins every heuristic for
    # bit-reproducible bounds (None = pool seed)
    heuristics: Optional[int] = None
    heuristic_only: bool = False
    seed: Optional[int] = None
    # result-cache opt-out (DESIGN.md §16): True forces a fresh solve and
    # suppresses both lookup and insertion for this request
    no_cache: bool = False
    # set by the scheduler at submit/admission (not caller knobs):
    # per-request telemetry child scope, submit instant (admission
    # latency), and the round count at admission (rounds-per-request);
    # cache_key/cache_perm are stamped on a cache miss so ``_finish``
    # knows where (and through which canonical relabeling) to insert
    tracker: object = None
    t_submit: float = 0.0
    round_admitted: int = 0
    cache_key: Optional[str] = None
    cache_perm: Optional[tuple] = None


# the per-request overridable knobs (subset of decide_kw keys)
_OVERRIDES = ("mode", "use_mmw", "use_simplicial")

# improver-round budget a heuristic_only request falls back to when
# neither the request nor the pool names one — enough rounds for the
# randomized improvers to plateau on the Table-1 instances
DEFAULT_HEURISTIC_ROUNDS = 16

# terminal request states (the value of ``TwScheduler.terminal[rid]``);
# "done" and "timeout" carry a result in ``done[rid]``, "error" carries a
# message in ``errors[rid]``, "cancelled" carries neither
TERMINAL_STATES = ("done", "timeout", "cancelled", "error")


def _round32(n: int) -> int:
    """Word-align the padded vertex count: keeps W stable (bloom parity
    for sub-word instances) and bounds jit signatures."""
    return max(32, -(-n // 32) * 32)


class TwScheduler:
    """Asynchronous continuous-batching scheduler over solve requests.

    Constructor knobs mirror ``solver.solve`` and set the pool defaults;
    each ``submit`` may override the per-request subset (class docstring).
    ``cap=None`` (default) auto-sizes each dispatch's per-lane frontier
    buffer via ``batch.plan_capacity``; ``budget_bytes`` (int or
    ``"auto"``) bounds the whole L-lane pool.  Results per request are
    bit-identical to ``solver.solve(g, ...)`` with the same knobs (see
    DESIGN.md §10/§11 for the two padded-lane caveats inherited from §8).

    Traffic-shaping knobs (DESIGN.md §12): ``max_queue`` bounds the
    admission queue (``QueueFull`` with ``retry_after`` on overflow),
    ``prio_weight`` is the weighted-FIFO anti-starvation ratio, and
    ``pipeline`` the dispatch depth — how many launched rounds may be in
    flight before a ``sync`` is forced (depth 2 keeps the device busy
    across the host-sync gap; discarded speculative rungs keep parity).

    Intra-request scale-out (DESIGN.md §13): ``submit(..., shards=S)``
    admits the request into S pool slots and runs each of its ladder
    rungs as one sharded dispatch (``core.shard.decide_sharded_async``)
    — the frontier split S ways with per-rung work donation, verdicts
    bit-identical to the single-lane ladder.  Slot-proportional
    speculation rides along: holding S slots entitles the request to S
    concurrent rung dispatches per round, so its deepening ladder
    climbs ``max(speculate, shards)`` rungs per round and a heavy
    sharded request finishes in measurably fewer scheduler rounds than
    the same request unsharded (overshoot past the winning rung is
    discarded uncounted — the explicit-``speculate`` semantics).
    ``donate_ratio`` tunes the donation trigger for every sharded
    request in the pool (``None`` =
    ``core.shard.DEFAULT_DONATE_RATIO``).

    Two driving styles:

    * blocking drain — ``run()`` (or repeated ``step()``), as in the
      module example;
    * overlapped — ``launch()`` (admit + enqueue dispatches, returns
      immediately), then host-side work / ``poll_admissions()`` while the
      device flies, then ``sync()`` for the oldest round's verdicts.
      ``step()`` is ``launch(); poll_admissions(); sync()`` with the
      sync skipped while the pipeline still has room.

    All public methods take an internal lock, so a persistent front end
    (``repro.launch.twserved``) may ``submit``/``status``/``cancel``
    from server threads while one driver thread steps the pool; the
    device wait in ``sync()`` runs outside the lock, which is what lets
    submissions land *mid-flight*, and event sinks are invoked after the
    lock is released, so a slow sink never stalls dispatch.
    """

    def __init__(self, *, lanes: int = batch.DEFAULT_MAX_LANES,
                 cap: Optional[int] = None, block: int = 1 << 11,
                 mode: str = "sort", use_mmw: bool = False,
                 m_bits: int = 1 << 24, k_hashes: int = bloom.DEFAULT_K,
                 schedule: Optional[str] = None, backend: str = "jax",
                 use_simplicial: bool = False, use_clique: bool = True,
                 use_paths: bool = True, use_preprocess: bool = True,
                 cap_max: int = batch.DEFAULT_CAP, budget_bytes=None,
                 max_queue: Optional[int] = None, prio_weight: int = 4,
                 pipeline: int = 1, donate_ratio: Optional[float] = None,
                 heuristics: int = 0, seed: int = 0,
                 cache=None,
                 verbose: bool = False, tracker=None):
        if schedule is None:
            schedule = "doubling" if backend == "pallas" else "while"
        backend_lib.validate(backend, mode=mode, schedule=schedule,
                             use_mmw=use_mmw, use_simplicial=use_simplicial,
                             m_bits=m_bits, lanes=int(lanes))
        if budget_bytes == "auto":
            budget_bytes = backend_lib.device_memory_budget()
        if pipeline < 1:
            raise ValueError(f"pipeline depth must be >= 1 (got {pipeline})")
        # pool-scope telemetry: every dispatch/queue/request counter this
        # scheduler records lands here (and rolls up to the supplied
        # parent / the process root); per-request child scopes hang off
        # this tracker so a request's counters sum exactly into it
        if tracker is None:
            tracker = telemetry.root().child(f"pool{next(_POOL_SEQ)}")
        self.tracker = tracker
        self.pool = SlotPool(int(lanes), max_queue=max_queue,
                             prio_weight=prio_weight,
                             slots_of=lambda r: getattr(r, "shards", 1),
                             tracker=self.tracker)
        self.cap = cap
        self.donate_ratio = donate_ratio
        self.cap_max = cap_max
        self.budget_bytes = budget_bytes
        self.block = block
        self.pipeline = int(pipeline)
        self.verbose = verbose
        self.decide_kw = dict(block=block, mode=mode, use_mmw=use_mmw,
                              m_bits=m_bits, k_hashes=k_hashes,
                              schedule=schedule, backend=backend,
                              use_simplicial=use_simplicial)
        self.plan_kw = dict(use_clique=use_clique, use_paths=use_paths)
        self.use_preprocess = use_preprocess
        # anytime bounds engine (DESIGN.md §15): pool-default improver
        # budget and heuristic seed; per-rid improver rounds launched so
        # far (launch eligibility — the states themselves enforce their
        # own termination)
        self.heuristics = max(0, int(heuristics))
        self.seed = int(seed)
        # content-addressed result cache (DESIGN.md §16): None = off
        # (the library default — unit tests count dispatches), an int =
        # entry bound for a fresh ``ResultCache``, or a caller-owned
        # ``ResultCache`` shared across pools.  ``launch.twserved``
        # defaults it ON for the serving process.
        if isinstance(cache, int):
            cache = ResultCache(cache) if cache > 0 else None
        self.cache = cache
        self._heur_rounds: Dict[int, int] = {}
        self.done: Dict[int, object] = {}       # rid -> solver.SolveResult
        self.errors: Dict[int, str] = {}        # rid -> admission error
        self.terminal: Dict[int, str] = {}      # rid -> TERMINAL_STATES
        # rid -> terminal telemetry snapshot of the request's child scope
        # (taken at the terminal event, then the child is detached — its
        # contributions stay in the pool totals)
        self.req_metrics: Dict[int, dict] = {}
        self.rounds = 0                          # scheduler steps launched
        self.idle_syncs = 0      # syncs that left the device with no round
        self.covered_syncs = 0   # syncs covered by a pipelined next round
        self._next_rid = 0
        self._lock = threading.RLock()
        # FIFO of launched rounds awaiting sync (pipeline depth entries):
        # (round_no, [(handle, metas), ...], t_launch)
        self._rounds: List[tuple] = []
        # rid -> (run object, next k to launch): the pipeline cursor —
        # which ladder rungs of the request's CURRENT block are already
        # in flight, so round N+1 launches the projected next ones
        self._cursor: Dict[int, tuple] = {}
        # rids whose in-flight verdicts must be dropped uncounted
        # (cancelled / deadline-preempted mid-flight)
        self._discard: Set[int] = set()
        # streaming progress per live rid: [lb, ub, seq] (monotone clamps)
        self._prog: Dict[int, list] = {}
        # events buffered under the lock, delivered after release —
        # a slow sink must never stall dispatch (the delivery lock only
        # serializes sink invocation order, reentrantly)
        self._pending: List[tuple] = []
        self._deliver_lock = threading.RLock()
        self._round_s: Optional[float] = None    # EWMA round wall-clock
        # monotone ratchets: padded n (word-aligned, shared) and, per
        # config group, the planned cap — each bump compiles one new
        # program, steady state reuses it
        self._n_pad = 32
        self._cap_pad: Dict[tuple, int] = {}

    # ------------------------------------------------------------ admission

    def submit(self, g: Graph, *, reconstruct: bool = False,
               start_k: Optional[int] = None,
               rid: Optional[int] = None,
               mode: Optional[str] = None,
               use_mmw: Optional[bool] = None,
               use_simplicial: Optional[bool] = None,
               cap: Optional[int] = None,
               speculate: int = 1,
               shards: int = 1,
               priority: int = 0,
               deadline_s: Optional[float] = None,
               on_event: Optional[Callable[[dict], None]] = None,
               heuristics: Optional[int] = None,
               heuristic_only: bool = False,
               seed: Optional[int] = None,
               no_cache: bool = False) -> int:
        """Queue one solve request; returns its request id.

        ``heuristics`` budgets the anytime bounds-improver rounds the
        scheduler interleaves with this request's exact rungs (None =
        pool default; improvements tighten the ladder, never the
        verdict).  ``heuristic_only=True`` skips the exact DP entirely —
        the request is served purely by improver rounds (admission stays
        cheap on graphs beyond exact-DP reach) and terminates with
        ``exact=(lb == ub)``.  ``seed`` pins every heuristic draw so the
        streamed ``bounds`` events are bit-reproducible per request.

        The keyword subset after ``rid`` is the per-request override
        surface (``SolveRequest``).  An override the pool's backend
        cannot run raises ``BackendCapabilityError`` (an invalid explicit
        ``cap`` raises ``ValueError``) *here*, for this request only —
        the pool keeps serving.  ``shards`` > 1 scales the request out
        across that many pool slots (must fit the pool: ``shards`` >
        ``lanes`` raises ``ValueError``).  ``priority`` picks the
        admission class,
        ``deadline_s`` (seconds from now) arms anytime preemption.
        ``no_cache=True`` bypasses the result cache in both directions
        (no lookup, no insertion) when the pool has one.  When
        the admission queue is at ``max_queue`` the submit is rejected
        with ``slots.QueueFull`` carrying a ``retry_after`` hint — the
        backpressure contract.  A ``rid`` colliding with a previously
        issued one raises ``ValueError`` (it would clobber the live or
        finished request's progress).  Thread-safe: a front end may call
        this while a dispatch is in flight; the request is admitted
        during the flight and packed into the next dispatch."""
        deadline = None
        if deadline_s is not None:
            deadline = time.monotonic() + float(deadline_s)
        shards = int(shards)
        if not 1 <= shards <= len(self.pool):
            raise ValueError(
                f"shards={shards} does not fit the pool "
                f"({len(self.pool)} slot(s)); a sharded request needs "
                "shards slots, all from this pool")
        if heuristic_only and shards > 1:
            raise ValueError(
                "heuristic_only=True runs no exact rungs; sharding its "
                "(nonexistent) frontier across slots is meaningless — "
                "drop shards or heuristic_only")
        req = SolveRequest(0, g, reconstruct, start_k, mode=mode,
                           use_mmw=use_mmw, use_simplicial=use_simplicial,
                           cap=cap, speculate=max(1, int(speculate)),
                           shards=shards,
                           priority=int(priority), deadline=deadline,
                           on_event=on_event,
                           heuristics=(None if heuristics is None
                                       else max(0, int(heuristics))),
                           heuristic_only=bool(heuristic_only),
                           seed=None if seed is None else int(seed),
                           no_cache=bool(no_cache))
        kw = self._effective_kw(req)
        backend_lib.validate(kw["backend"], mode=kw["mode"],
                             schedule=kw["schedule"], use_mmw=kw["use_mmw"],
                             use_simplicial=kw["use_simplicial"],
                             m_bits=kw["m_bits"], lanes=len(self.pool),
                             shards=shards)
        if cap is not None:
            engine_lib.validate_geometry(cap, self.block)
        # content-addressed cache key (DESIGN.md §16) — computed OUTSIDE
        # the lock (canonical labeling is pure host work).  heuristic_only
        # requests are excluded: their result depends on the improver
        # round budget actually *consumed*, which is load-dependent.
        ck = cperm = None
        if self.cache is not None and not req.no_cache \
                and not req.heuristic_only and g.n > 0:
            ck, cperm = self._cache_key_for(req)
        with self._lock:
            hit = None
            if ck is not None:
                hit = self.cache.lookup(ck, need_order=req.reconstruct)
            if hit is None and self.pool.max_queue is not None and \
                    self.pool.qsize >= self.pool.max_queue:
                # the lookup above already counted a cache miss; keep the
                # telemetry reconciliation exact even though this request
                # never gets a child scope
                if ck is not None:
                    self.tracker.count(cache_misses=1)
                raise QueueFull(
                    f"admission queue full ({self.pool.qsize} queued, "
                    f"max_queue={self.pool.max_queue})",
                    retry_after=self._retry_after())
            if rid is None:
                rid = self._next_rid
            elif rid < self._next_rid:
                raise ValueError(
                    f"rid {rid} already issued (next fresh rid is "
                    f"{self._next_rid}); duplicate rids would clobber the "
                    "live or finished request")
            self._next_rid = max(self._next_rid, rid) + 1
            req.rid = rid
            req.tracker = self.tracker.child(f"req{rid}")
            req.t_submit = time.monotonic()
            self._prog[rid] = [0, max(0, g.n - 1), 0]
            if hit is not None:
                # warm hit: the request never touches the queue, a lane,
                # or the device — it is resolved right here at submit
                self._serve_cached(req, hit, cperm)
            else:
                if ck is not None:
                    req.cache_key, req.cache_perm = ck, cperm
                    req.tracker.count(cache_misses=1)
                self.pool.submit(req, priority=req.priority)
        # deliver the synthesized hit events (admitted/bounds/done) now —
        # a cached submit returns with the terminal event already sunk
        self._flush_events()
        return rid

    def _retry_after(self) -> float:
        """Backpressure hint: how long until a queue slot plausibly
        frees — the EWMA round wall-clock times the number of admission
        waves the backlog needs to drain through the lane pool."""
        per_round = self._round_s if self._round_s else 1.0
        waves = -(-(self.pool.qsize + 1) // max(1, len(self.pool)))
        return round(max(0.05, per_round * waves), 3)

    def _effective_kw(self, req: SolveRequest) -> dict:
        """Pool defaults with this request's overrides applied."""
        kw = dict(self.decide_kw)
        for f in _OVERRIDES:
            v = getattr(req, f)
            if v is not None:
                kw[f] = v
        return kw

    def _req_seed(self, req: SolveRequest) -> int:
        return self.seed if req.seed is None else req.seed

    def _req_heuristics(self, req: SolveRequest) -> int:
        """Improver-round budget for one request (request override, else
        pool default; a heuristic_only request with neither gets the
        fallback budget — it has no exact ladder to finish it)."""
        n = self.heuristics if req.heuristics is None else req.heuristics
        if req.heuristic_only and n <= 0:
            n = DEFAULT_HEURISTIC_ROUNDS
        return n

    # ------------------------------------------------------- result cache

    def _cache_cfg(self, req: SolveRequest) -> dict:
        """The *effective* solve config that determines the result bits
        for one request — the config half of the content address.  Knobs
        that provably do not change the result (shards, speculate,
        pipeline, priority, deadline: all bit-identical or discarded-
        uncounted paths, DESIGN.md §11–§13) are excluded so differently-
        scheduled resubmissions still hit.  ``seed`` and the heuristics
        budget are always included: ``plan_block`` threads the seed into
        the greedy clique/bound heuristics even at ``heuristics=0``, so
        two seeds can legitimately produce different ``per_k`` surfaces.
        ``reconstruct`` is deliberately *not* keyed — the cache upgrades
        entries toward the order-ful result instead (``lookup`` with
        ``need_order`` misses on order-less entries)."""
        cfg = dict(self._effective_kw(req))
        cfg["cap"] = req.cap if req.cap is not None else self.cap
        cfg["cap_max"] = self.cap_max
        cfg["budget_bytes"] = self.budget_bytes
        cfg["use_preprocess"] = self.use_preprocess
        cfg.update(self.plan_kw)
        cfg["start_k"] = req.start_k
        cfg["heuristics"] = self._req_heuristics(req)
        cfg["seed"] = self._req_seed(req)
        return cfg

    def _cache_key_for(self, req: SolveRequest) -> tuple:
        """(digest, canonical perm) for one request.  ``mode="bloom"``
        results are Monte-Carlo *label-dependent* (the filter hashes
        state bitsets), so bloom keys address the as-submitted adjacency
        (identity perm) — only bit-identical resubmissions hit; every
        exact-dedup mode keys the canonical form, so any isomorphic
        relabeling hits."""
        cfg = self._cache_cfg(req)
        return canon.cache_key(req.g, cfg,
                               canonical=(cfg["mode"] != "bloom"))

    def _serve_cached(self, req: SolveRequest, res, perm) -> None:
        """Resolve one request from a cache hit, at submit time, under
        the scheduler lock.  The synthesized event stream (``admitted``
        flagged ``cached``, one ``bounds``, terminal ``done``) satisfies
        every invariant of the live stream — same shape, same monotone
        clamps, strictly increasing ``seq`` — so sinks cannot tell a hit
        from an instant solve except by the flag.  The stored order is
        canonical-space; it is translated back through the *hitting*
        submission's perm, so a relabeled duplicate receives an order
        valid for its own labels."""
        rid = req.rid
        if res.order is not None:
            if req.reconstruct:
                inv = [0] * len(perm)
                for v, c in enumerate(perm):
                    inv[c] = v
                res = dataclasses.replace(
                    res, order=[inv[c] for c in res.order])
            else:
                # a non-reconstruct submission must see the same surface
                # as its own uncached solve: no order
                res = dataclasses.replace(res, order=None)
        self._emit(req, {"event": "admitted", "name": req.g.name,
                         "round": self.rounds + 1, "cached": True})
        req.round_admitted = self.rounds
        req.tracker.timing("admission_s", time.monotonic() - req.t_submit)
        req.tracker.count(cache_hits=1)
        prog = self._prog[rid]
        lb = max(prog[0], res.width if res.exact else res.lb)
        ub = min(prog[1], res.width)
        prog[0], prog[1] = lb, ub
        self._emit(req, {"event": "bounds", "lb": lb, "ub": ub,
                         "cached": True})
        self.done[rid] = res
        self.terminal[rid] = "done"
        self.tracker.count(reqs_done=1)
        snap = self._close_request(req)
        prog = self._prog.pop(rid)
        self._emit(req, {"event": "done", "width": res.width,
                         "exact": res.exact, "lb": lb, "ub": res.width,
                         "expanded": res.expanded, "rounds": self.rounds,
                         "cached": True, "metrics": snap},
                   prog=prog)
        if self.verbose:
            print(f"[twserve] req {rid} ({req.g.name}): cache hit, "
                  f"width={res.width} exact={res.exact}", flush=True)

    def cache_stats(self) -> dict:
        """Result-cache counters (``enabled: False`` when the pool runs
        without one); the front end's ``cache_stats`` wire op returns
        exactly this dict."""
        if self.cache is None:
            return {"enabled": False}
        return dict(self.cache.stats(), enabled=True)

    def _group_key(self, req: SolveRequest) -> tuple:
        """Requests share a vmapped program iff this key matches: the
        static decide config plus the cap setting (explicit caps pin the
        jit signature; ``None`` caps share the planned ratchet)."""
        kw = self._effective_kw(req)
        return tuple(sorted(kw.items())) + (("cap", req.cap),)

    def _start(self, req: SolveRequest):
        """Admission: build the request's deepening state (preprocess +
        bounds + first block plan — host-only work, safe to overlap with
        an in-flight dispatch).  Returns None when the request does not
        take a lane: trivial instance (decided at admission), deadline
        already expired (anytime-resolved), or admission failure
        (``error`` terminal event — the failure is isolated to this
        request; the queue keeps admitting)."""
        try:
            self._emit(req, {"event": "admitted", "name": req.g.name,
                             "round": self.rounds + 1})
            req.round_admitted = self.rounds
            if req.tracker is not None and req.t_submit:
                req.tracker.timing("admission_s",
                                   time.monotonic() - req.t_submit)
            if req.deadline is not None and \
                    time.monotonic() >= req.deadline:
                # expired while queued: resolve with what is known now
                # (nothing ran, so the trivial 0..n-1 bounds clamped by
                # any prior stream state)
                prog = self._prog.get(req.rid) or [0, max(0, req.g.n - 1),
                                                   0]
                res = solver_lib.SolveResult(prog[1], False, prog[0],
                                             prog[1], 0, 0.0, None, {})
                self._resolve_timeout(req, res)
                return None
            if req.heuristic_only:
                # bounds-only serving: no preprocess, no block plans, no
                # exact rungs — just the improver lanes (DESIGN.md §15)
                inst = bounds_engine.HeuristicState(
                    req.g, solver_lib, seed=self._req_seed(req),
                    max_rounds=self._req_heuristics(req),
                    tracker=req.tracker)
            else:
                inst = batch.InstanceState(
                    req.g, solver_lib, use_preprocess=self.use_preprocess,
                    plan_kw=dict(start_k=req.start_k,
                                 seed=self._req_seed(req), **self.plan_kw),
                    reconstruct=req.reconstruct,
                    recon_kw=self._recon_kw(req), tracker=req.tracker)
        except Exception as e:    # noqa: BLE001 — per-request isolation
            self._fail(req, e)
            return None
        if inst.result is not None:
            self._finish(req, inst)
            return None
        self._emit(req, dict(self._bounds_event(req, inst),
                             event="bounds"))
        return (req, inst)

    def _recon_kw(self, req: SolveRequest) -> dict:
        return dict(cap=req.cap if req.cap is not None else self.cap,
                    cap_max=self.cap_max, **self._effective_kw(req))

    def _close_request(self, req: SolveRequest) -> Optional[dict]:
        """Terminal telemetry: stamp the rounds-per-request gauge, take
        the request child scope's final snapshot (retained in
        ``req_metrics`` and attached to the terminal event), then detach
        the child — its counts stay in the pool totals (write-through),
        so a drained pool's request snapshots still sum to the pool
        scope.  Returns None when the request never got a child scope
        (e.g. a hand-built ``SolveRequest`` fed straight to the pool)."""
        self._heur_rounds.pop(req.rid, None)
        tr = req.tracker
        if tr is None or isinstance(tr, telemetry.NullTracker):
            return None
        tr.gauge("rounds", max(0, self.rounds - req.round_admitted))
        if req.t_submit:
            # submit -> terminal latency: what an open-loop load driver
            # reads its percentiles from (benchmarks/serve_load.py)
            tr.timing("request_s", time.monotonic() - req.t_submit)
        snap = tr.snapshot()
        self.req_metrics[req.rid] = snap
        self.tracker.drop_child(f"req{req.rid}")
        return snap

    def _finish(self, req: SolveRequest, inst: batch.InstanceState):
        r = inst.result
        self.done[req.rid] = r
        self.terminal[req.rid] = "done"
        self.tracker.count(reqs_done=1)
        # the ONE cache-insertion point (DESIGN.md §16): only a clean
        # ``done`` populates the cache — cancel, deadline and error take
        # different terminal paths and never reach here.  ``cache_key``
        # was stamped at submit iff this request is cacheable.
        if self.cache is not None and req.cache_key is not None:
            store = r
            if r.order is not None and req.cache_perm:
                # store the order in canonical label space, so the entry
                # serves every isomorphic relabeling of this graph
                store = dataclasses.replace(
                    r, order=[req.cache_perm[v] for v in r.order])
            evicted = self.cache.insert(req.cache_key, store)
            self.tracker.count(cache_insertions=1)
            if evicted:
                self.tracker.count(cache_evictions=evicted)
        snap = self._close_request(req)
        prog = self._prog.pop(req.rid, [0, max(0, req.g.n - 1), 0])
        lb = max(prog[0], r.width if r.exact else r.lb)
        self._emit(req, {"event": "done", "width": r.width,
                         "exact": r.exact, "lb": lb, "ub": r.width,
                         "expanded": r.expanded, "rounds": self.rounds,
                         "metrics": snap},
                   prog=prog)
        if self.verbose:
            print(f"[twserve] req {req.rid} ({req.g.name}): width={r.width}"
                  f" exact={r.exact} expanded={r.expanded}", flush=True)

    def _fail(self, req: SolveRequest, err: Exception):
        """Admission failed for this request alone: record the error,
        emit the ``error`` terminal event, keep the pool serving."""
        msg = f"{type(err).__name__}: {err}"
        self.errors[req.rid] = msg
        self.terminal[req.rid] = "error"
        self.tracker.count(reqs_error=1)
        snap = self._close_request(req)
        prog = self._prog.pop(req.rid, [0, 0, 0])
        self._emit(req, {"event": "error", "error": msg, "metrics": snap},
                   prog=prog)
        if self.verbose:
            print(f"[twserve] req {req.rid} ({getattr(req.g, 'name', '?')})"
                  f" failed at admission: {msg}", flush=True)

    def _resolve_timeout(self, req: SolveRequest, res):
        """Terminal path for deadline expiry: the anytime result (monotone
        best-so-far lb/ub, ``exact=False``) plus a ``done`` event flagged
        ``timed_out`` — a timed-out request returns bounds, not nothing."""
        self.done[req.rid] = res
        self.terminal[req.rid] = "timeout"
        self.tracker.count(reqs_timeout=1)
        snap = self._close_request(req)
        prog = self._prog.pop(req.rid, [res.lb, res.ub, 0])
        self._emit(req, {"event": "done", "width": res.width,
                         "exact": False, "timed_out": True, "lb": res.lb,
                         "ub": res.ub, "expanded": res.expanded,
                         "rounds": self.rounds, "metrics": snap},
                   prog=prog)
        if self.verbose:
            print(f"[twserve] req {req.rid} ({req.g.name}): deadline "
                  f"expired, anytime lb={res.lb} ub={res.ub}", flush=True)

    # ------------------------------------------------------ traffic shaping

    def cancel(self, rid: int) -> bool:
        """Abandon one request: a queued rid is dropped from the queue, a
        running rid frees its lane immediately (mid-ladder) and any
        in-flight verdicts for it are discarded uncounted at the next
        ``sync``.  Emits the terminal ``cancelled`` event (carrying the
        last streamed lb/ub).  Returns True when something was cancelled;
        False for unknown or already-terminal rids (idempotent)."""
        with self._lock:
            ok = False
            if rid not in self.terminal:
                req = self.pool.discard(lambda r: r.rid == rid)
                if req is None:
                    for i, (r, _inst) in self.pool.active():
                        if r.rid == rid:
                            req = r
                            self.pool.release(i)     # the lane frees NOW
                            self._cursor.pop(rid, None)
                            self._discard.add(rid)   # in-flight verdicts
                            break
                if req is not None:
                    self.terminal[rid] = "cancelled"
                    self.tracker.count(reqs_cancelled=1)
                    snap = self._close_request(req)
                    prog = self._prog.pop(rid, [0, 0, 0])
                    self._emit(req, {"event": "cancelled", "lb": prog[0],
                                     "ub": prog[1], "rounds": self.rounds,
                                     "metrics": snap},
                               prog=prog)
                    ok = True
                    if self.verbose:
                        print(f"[twserve] req {rid} cancelled", flush=True)
        self._flush_events()
        return ok

    def _expire_deadlines(self):
        """Deadline sweep (under the lock, at sync time): preempt every
        lane whose request ran past its deadline — resolve it with the
        anytime bounds, free the lane, and mark any still-in-flight rungs
        for uncounted discard."""
        now = time.monotonic()
        for i, (req, inst) in self.pool.active():
            if req.deadline is None or now < req.deadline:
                continue
            b = self._bounds_event(req, inst)
            self._resolve_timeout(
                req, inst.anytime_result(lb=b["lb"], ub=b["ub"]))
            self.pool.release(i)
            self._cursor.pop(req.rid, None)
            self._discard.add(req.rid)

    # ------------------------------------------------------------ streaming

    def _emit(self, req: SolveRequest, ev: dict,
              prog: Optional[list] = None):
        """Buffer one event for the request's callback.  The ``seq``
        stamp is taken under the scheduler lock (ordering guarantees);
        delivery happens in ``_flush_events`` *after* the lock is
        released, so a slow or blocking sink never stalls dispatch."""
        if req.on_event is None:
            return
        if prog is None:
            prog = self._prog.get(req.rid)
        seq = 0
        if prog is not None:
            prog[2] += 1
            seq = prog[2]
        self._pending.append((req.on_event, req.rid, dict(ev, rid=req.rid,
                                                          seq=seq)))

    def _flush_events(self):
        """Deliver buffered events outside the scheduler lock.  The
        delivery lock (reentrant) serializes concurrent flushers so the
        global emission order is preserved; a raising sink is isolated
        (warn + drop), never failing the solve."""
        if not self._pending:
            return
        with self._deliver_lock:
            with self._lock:
                pending, self._pending = self._pending, []
            for cb, rid, ev in pending:
                try:
                    cb(ev)
                except Exception as e:   # noqa: BLE001 — sink isolation
                    warnings.warn(f"twserve event sink for rid {rid} "
                                  f"raised {e!r}; event dropped",
                                  stacklevel=2)

    def _bounds_event(self, req: SolveRequest, inst) -> dict:
        """Running instance-level (lb, ub) — ``InstanceState.bounds``
        clamped monotone against the previously streamed pair."""
        lb, ub = inst.bounds()
        prog = self._prog.get(req.rid)
        if prog is not None:
            lb = max(lb, prog[0])
            ub = min(ub, prog[1])
            prog[0], prog[1] = lb, ub
        return {"lb": lb, "ub": ub}

    def status(self, rid: int) -> dict:
        """Queued / running / terminal snapshot for one request
        (thread-safe; the front end's ``status`` endpoint).  Terminal
        states: ``done`` (with ``timed_out: true`` when a deadline
        preempted it), ``cancelled``, ``error``."""
        with self._lock:
            t = self.terminal.get(rid)
            if t == "cancelled":
                return {"state": "cancelled"}
            if t == "error":
                return {"state": "error",
                        "error": self.errors.get(rid, "admission failed")}
            if rid in self.done:
                r = self.done[rid]
                st = {"state": "done", "width": r.width, "exact": r.exact,
                      "lb": r.lb, "ub": r.ub, "expanded": r.expanded}
                if t == "timeout":
                    st["timed_out"] = True
                return st
            for _i, (req, inst) in self.pool.active():
                if req.rid == rid:
                    return dict(self._bounds_event(req, inst),
                                state="running")
            if any(req.rid == rid for req in self.pool.queued()):
                return {"state": "queued"}
            return {"state": "unknown"}

    def metrics(self, rid: Optional[int] = None) -> dict:
        """Scoped telemetry snapshot (thread-safe): the pool scope's
        totals plus per-request snapshots — live and queued requests
        snapshotted in place, finished ones from the snapshot retained
        at their terminal event.  With ``rid`` only that request is
        included (empty ``requests`` for unknown rids).  Because request
        child scopes write through to the pool scope, the rung-level
        counters of the ``requests`` snapshots sum exactly into
        ``pool["counters"]``; the front end's ``metrics`` wire op
        returns exactly this dict."""
        with self._lock:
            requests = dict(self.req_metrics)
            live = list(self.pool.queued()) + \
                [req for _i, (req, _inst) in self.pool.active()]
            for req in live:
                tr = req.tracker
                if tr is not None and \
                        not isinstance(tr, telemetry.NullTracker):
                    requests[req.rid] = tr.snapshot()
            if rid is not None:
                requests = {rid: requests[rid]} if rid in requests else {}
            return {"pool": self.tracker.snapshot(children=False),
                    "rounds": self.rounds, "queued": self.pool.qsize,
                    "idle_syncs": self.idle_syncs,
                    "covered_syncs": self.covered_syncs,
                    "requests": requests}

    # ----------------------------------------------------------- the engine

    def launch(self) -> bool:
        """Admit, pack every occupied lane's next rung(s), and enqueue
        the dispatches **without waiting for their verdicts** (JAX async
        dispatch; the handles are held in flight).  With ``pipeline > 1``
        a lane's next rungs are its *projected* ladder steps (the
        pipeline cursor): the rungs after the ones already in flight for
        its current block — launched before the previous round syncs, so
        the device never drains.  Returns False when nothing was packed
        (idle pool, or every ladder fully in flight)."""
        with self._lock:
            if len(self._rounds) >= self.pipeline:
                raise RuntimeError(
                    f"launch() with {len(self._rounds)} round(s) in "
                    f"flight (pipeline depth {self.pipeline}); sync() "
                    "first")
            self.pool.admit(self._start)
            # low-priority improver lanes ride along with the exact rungs:
            # one batched dispatch covers every request with budget left
            heur = self._pack_improvers()
            members = []          # (slot, req, inst, run, [ks to launch])
            for i, (req, inst) in self.pool.active():
                run = inst.run
                if run is None:
                    continue      # heuristic_only: improver lanes only
                cur = self._cursor.get(req.rid)
                # a heuristic lb jump may have moved run.k past the
                # cursor: rungs below run.k are already refuted, never
                # re-launch them
                k0 = max(cur[1], run.k) \
                    if (cur is not None and cur[0] is run) else run.k
                # slot-proportional speculation: a width-S request holds
                # S slots, so it is entitled to S concurrent rung
                # dispatches per round — its ladder climbs S rungs per
                # round (each rung an S-way sharded dispatch), which is
                # what lets a sharded heavy request finish in fewer
                # scheduler rounds (overshoot past the winning rung is
                # discarded uncounted, same as explicit speculation)
                win = max(req.speculate, req.shards)
                hi = min(k0 + win, run.plan.ub)
                if k0 >= hi:
                    continue      # whole remaining ladder already flying
                members.append((i, req, inst, run, list(range(k0, hi))))
                self._cursor[req.rid] = (run, hi)
            if not members and not heur:
                launched = False
            else:
                launched = True
                self.rounds += 1
                if members:
                    n_round = max(run.plan.g.n
                                  for _i, _r, _s, run, _ks in members)
                    self._n_pad = max(self._n_pad, _round32(n_round))
                L = len(self.pool)

                groups: Dict[tuple, tuple] = {}
                sharded = []    # one (i, req, inst, run, kk, name) per rung
                for i, req, inst, run, ks in members:
                    if req.shards > 1:
                        # scale-out request: each rung is its own sharded
                        # dispatch (frontier split req.shards ways), not a
                        # lane of the shared vmapped group
                        for kk in ks:
                            sharded.append((i, req, inst, run, kk,
                                            run.plan.g.name))
                            self._emit(req, {"event": "rung_started",
                                             "block": run.plan.g.name,
                                             "k": kk, "round": self.rounds})
                        continue
                    lanes, metas = groups.setdefault(self._group_key(req),
                                                     ([], []))
                    for kk in ks:
                        lanes.append(batch.Lane(run.plan.graph_at(kk), kk,
                                                tuple(run.plan.clique)))
                        metas.append((i, req, inst, run, kk,
                                      run.plan.g.name))
                        self._emit(req, {"event": "rung_started",
                                         "block": run.plan.g.name,
                                         "k": kk, "round": self.rounds})
                # every dispatch resident before any sync — including the
                # pipelined rounds still in flight — splits the budget
                n_dispatch = sum(len(hs) for _no, hs, _t in self._rounds)
                n_dispatch += sum(-(-len(lanes) // L)
                                  for lanes, _m in groups.values())
                n_dispatch += len(sharded)

                handles = []
                for key, (lanes, metas) in groups.items():
                    kw = dict(key)
                    cap = kw.pop("cap")
                    if cap is None:
                        cap = self.cap
                    if cap is None:
                        cap = self._plan_group_cap(key, lanes, n_dispatch)
                    # chunk a speculation-widened group into pool-sized
                    # dispatches (lane axis padded to the full pool so
                    # the steady state reuses one compiled program)
                    for lo in range(0, len(lanes), L):
                        # a shared vmapped dispatch serves many requests,
                        # so its dispatch/host-sync counts are pool-level
                        # (the per-rung expanded counts are attributed to
                        # requests at feed time, via InstanceState)
                        handle = batch.decide_lanes_async(
                            lanes[lo:lo + L], cap=cap, n_pad=self._n_pad,
                            lane_pad=L, tracker=self.tracker, **kw)
                        handles.append((handle, metas[lo:lo + L]))
                for meta in sharded:
                    i, req, inst, run, kk, name = meta
                    kw = self._effective_kw(req)
                    cap = req.cap if req.cap is not None else self.cap
                    if cap is None:
                        key = ("shard", req.shards) + self._group_key(req)
                        cap = self._plan_group_cap(
                            key,
                            [batch.Lane(run.plan.graph_at(kk), kk,
                                        tuple(run.plan.clique))],
                            n_dispatch, width=req.shards)
                    # a sharded dispatch runs one request's rung alone, so
                    # its dispatch count and donation/occupancy stats are
                    # attributable — they land in the request's child
                    # scope and roll up to the pool totals
                    handle = shard_lib.decide_sharded_async(
                        run.plan.graph_at(kk), kk, tuple(run.plan.clique),
                        shards=req.shards, cap=cap, n_pad=self._n_pad,
                        donate_ratio=self.donate_ratio,
                        tracker=req.tracker or self.tracker, **kw)
                    # one-element metas: the handle finalizes to a single
                    # LaneResult, so sync()'s zip feeds it like any lane
                    handles.append((handle, [meta]))
                if heur:
                    # ONE vmapped dispatch improves every budgeted
                    # request's ub (seeded randomized min-degree sweep);
                    # the matching lb contraction runs host-side at apply
                    # time.  Metas are tagged "heur" so sync() routes
                    # them through _apply_improvement, not feed
                    handle = bounds_engine.ub_orders_async(
                        [g for _i, _r, _s, _run, g, _sd in heur],
                        [sd for _i, _r, _s, _run, _g, sd in heur],
                        tracker=self.tracker)
                    handles.append((handle,
                                    [("heur", i, req, inst, run, sd)
                                     for i, req, inst, run, _g, sd
                                     in heur]))
                self._rounds.append((self.rounds, handles,
                                     time.monotonic()))
        self._flush_events()
        return launched

    def _plan_group_cap(self, key: tuple, lanes: list,
                        n_dispatch: int = 1,
                        width: Optional[int] = None) -> int:
        """plan_capacity for one config group, ratcheted per group key
        (compile stability) and re-clamped whenever the budget share
        shrinks — because the padded word count grew, or because the
        step launches several concurrent dispatches (``n_dispatch``)
        that split ``budget_bytes`` between them.  ``width`` is the
        dispatch's resident lane count — the full pool for a shared
        vmapped group (default), ``req.shards`` for a sharded dispatch
        whose per-shard buffers are what the plan sizes."""
        if width is None:
            width = len(self.pool)
        budget = self.budget_bytes
        if budget is not None:
            budget = int(budget) // max(1, n_dispatch)
        w = bitset.n_words(self._n_pad)
        cap = max(batch.plan_capacity(
            lane.g.n, w, lanes=width, block=self.block,
            cap_max=self.cap_max, budget_bytes=budget)
            for lane in lanes)
        cap = max(self._cap_pad.get(key, 0), cap)
        if budget is not None:
            # the budget outranks the compile-stability ratchet: a cap
            # ratcheted under a smaller word count (or a
            # fewer-dispatches step) must shrink, or the resident pools
            # would exceed the bytes the knob promises to bound
            afford = int(budget) // (width * 4 * max(1, w))
            cap = min(cap, max(32, batch._pow2_floor(afford)))
        self._cap_pad[key] = cap
        return cap

    def _pack_improvers(self) -> list:
        """Collect this round's anytime-improver lanes (under the lock):
        every active request with improver budget left and an open
        lb < ub gap contributes its *current* graph — the in-flight
        block for an exact request (block-local bounds compose through
        ``InstanceState.bounds``), the whole graph for heuristic_only.
        Returns ``(slot, req, inst, run, graph, seed)`` tuples; the seed
        is derived from the request seed and the round index, so the
        improver stream is deterministic per request."""
        out = []
        for i, (req, inst) in self.pool.active():
            budget = self._req_heuristics(req)
            done = self._heur_rounds.get(req.rid, 0)
            if done >= budget:
                continue
            lb, ub = inst.bounds()
            if lb >= ub:
                continue
            run = inst.run
            target = run.plan.g if run is not None else inst.g
            seed = bounds_engine._round_seed(self._req_seed(req), done)
            self._heur_rounds[req.rid] = done + 1
            out.append((i, req, inst, run, target, seed))
        return out

    def _apply_improvement(self, i: int, req: SolveRequest, inst,
                           run, seed: int, width: int, order: list):
        """Sync-side half of one improver round (under the lock): pair
        the dispatched ub sweep with a host lb contraction, clamp both
        into the request's state (``improve_bounds`` — monotone tighten
        only), emit a ``bounds`` event if either side moved, and resolve
        the request if the bounds closed its remaining ladder.  Stale
        results (the block advanced, the request went terminal) are
        dropped — improvements for a graph no longer being solved prove
        nothing about the current block."""
        rid = req.rid
        if rid in self._discard or rid in self.terminal or \
                inst.result is not None or inst.run is not run:
            return
        target = run.plan.g if run is not None else inst.g
        lb_new = bounds_engine.contraction_lb(target, seed)
        prog = self._prog.get(rid)
        before = (prog[0], prog[1]) if prog is not None else None
        info = inst.improve_bounds(lb=lb_new, ub=width, ub_order=order)
        counts = {}
        if info["ub_improved"]:
            counts["heur_ub_improvements"] = 1
        if info["lb_improved"]:
            counts["heur_lb_improvements"] = 1
        if info["rungs_skipped"]:
            counts["exact_rungs_skipped"] = info["rungs_skipped"]
        if counts:
            (req.tracker or self.tracker).count(**counts)
        b = self._bounds_event(req, inst)
        if before is None or (b["lb"], b["ub"]) != before:
            self._emit(req, dict(b, event="bounds", round=self.rounds))
        if req.heuristic_only:
            inst.step_done()     # budget accounting lives in the state
        if inst.result is not None:
            self._finish(req, inst)
            self.pool.release(i)
            self._cursor.pop(rid, None)

    def poll_admissions(self) -> None:
        """Overlap bookkeeping: admit and plan newly arrived requests
        into free slots while the launched dispatches are still in
        flight.  Touches host state only (queue, slots, preprocessing/
        bounds of the new requests) — never the in-flight device buffers
        (DESIGN.md §11's overlap invariant); the admitted requests join
        the next ``launch()``."""
        with self._lock:
            self.pool.admit(self._start)
        self._flush_events()

    def sync(self) -> bool:
        """Block for the *oldest* in-flight round's verdicts, feed them
        through each request's ``InstanceState`` in rung order, emit
        ``rung_decided`` events, recycle finished slots, and run the
        deadline sweep.  Verdicts for a cancelled rid, or for a rung of
        a block that already decided (pipelining/speculation overshoot),
        are discarded uncounted — the sequential ladder never ran them.
        The device wait runs outside the scheduler lock so submissions,
        ``status`` and ``cancel`` calls keep landing mid-flight.
        Returns False when nothing was in flight."""
        with self._lock:
            if not self._rounds:
                return False
            no, parts, t_launch = self._rounds.pop(0)
        for handle, metas in parts:
            results = handle.result()          # device wait — no lock held
            with self._lock:
                if metas and metas[0][0] == "heur":
                    # improver lanes: apply, don't feed (bounds can move
                    # and rungs can be skipped, but no rung is counted)
                    for (_t, i, req, inst, run, seed), (w, order) in \
                            zip(metas, results):
                        self._apply_improvement(i, req, inst, run, seed,
                                                w, order)
                    continue
                for (i, req, inst, run, k, name), res in zip(metas,
                                                             results):
                    if req.rid in self._discard or inst.run is not run \
                            or k != run.k:
                        # cancelled, deadline-preempted, the block
                        # decided on an earlier rung, or a heuristic lb
                        # jump skipped past this rung: the (tightened)
                        # sequential ladder never ran it — discard
                        # uncounted (speculation semantics, §8)
                        continue
                    inst.feed(k, res)
                    self._emit(req, dict(
                        self._bounds_event(req, inst),
                        event="rung_decided", block=name, k=k,
                        round=no, feasible=res.feasible,
                        inexact=res.inexact, expanded=res.expanded))
                    if inst.result is not None:
                        self._finish(req, inst)
                        self.pool.release(i)
                        self._cursor.pop(req.rid, None)
        with self._lock:
            self._expire_deadlines()
            dt = time.monotonic() - t_launch
            self._round_s = dt if self._round_s is None else \
                0.7 * self._round_s + 0.3 * dt
            self.tracker.timing("round_s", dt)
            if self._rounds:
                self.covered_syncs += 1    # the device already has work
            else:
                self.idle_syncs += 1       # host-sync gap: device idles
                self._discard.clear()      # nothing in flight references
        self._flush_events()
        return True

    def step(self) -> bool:
        """One overlapped scheduler step: launch the next round's shared
        dispatches, run admission/planning for new arrivals while the
        device works, then — once the pipeline is full (or nothing new
        launched) — sync the oldest round's verdicts and recycle slots.
        With ``pipeline=1`` this is exactly launch → poll → sync; deeper
        pipelines keep ``pipeline`` rounds in flight so the device stays
        busy across each host sync."""
        launched = False
        if len(self._rounds) < self.pipeline:
            launched = self.launch()
        self.poll_admissions()
        if self._rounds and (len(self._rounds) >= self.pipeline
                             or not launched):
            self.sync()
            return True
        return launched

    def recover(self) -> None:
        """Cleanup after a raised ``step()`` — a persistent driver must
        keep driving.  Discards every in-flight round *and resets the
        pipeline cursors*: a failed ``sync`` already lost its round's
        verdicts, so feeding any younger pipelined round (or launching
        from a cursor past the lost rungs) would leave a gap in the
        deepening ladder and break parity.  The next ``launch()``
        re-packs each lane from its unchanged host state
        (``InstanceState`` only advances in ``feed``, so nothing is lost
        or double-counted — the discarded rungs simply re-run)."""
        with self._lock:
            for _no, handles, _t in self._rounds:
                for handle, metas in handles:
                    if handle is not None:
                        handle.discard()
                    if metas and metas[0][0] == "heur":
                        # un-spend the discarded improver rounds, or a
                        # heuristic_only request whose budget was burned
                        # by a failed round could never terminate
                        for _t_, _i, req, _inst, _run, _sd in metas:
                            n = self._heur_rounds.get(req.rid, 0)
                            if n > 0:
                                self._heur_rounds[req.rid] = n - 1
            self._rounds = []
            self._cursor.clear()
        self._flush_events()

    def run(self, max_rounds: int = 1_000_000) -> Dict[int, object]:
        """Drain the queue (and the pipeline); returns
        {rid: solver.SolveResult} for completed and deadline-resolved
        requests (cancelled/errored rids carry no result — see
        ``terminal``/``errors``)."""
        rounds = 0
        while (self.pool.busy or self.in_flight) and rounds < max_rounds:
            if not self.step():
                break
            rounds += 1
        self._flush_events()
        return self.done

    @property
    def in_flight(self) -> bool:
        """Is a launched dispatch awaiting ``sync()``?"""
        return bool(self._rounds)

    @property
    def inflight_dispatches(self) -> int:
        """Dispatches currently resident on device across the pipeline."""
        return sum(len(handles) for _no, handles, _t in self._rounds)

    def pool_bytes(self) -> int:
        """Resident frontier-pool footprint of the largest dispatch issued
        so far (lanes x cap x W uint32 rows — ``frontier.frontier_bytes``)."""
        cap = self.cap
        if cap is None:
            cap = max(self._cap_pad.values(), default=0) or \
                batch.plan_capacity(self._n_pad, block=self.block,
                                    cap_max=self.cap_max)
        return frontier_lib.frontier_bytes(cap, bitset.n_words(self._n_pad),
                                           lanes=len(self.pool))
