"""Treewidth solve service: asynchronous continuous batching of requests.

The paper keeps the GPU busy by batching many independent wavefront
expansions per dispatch; this module applies the same principle one level
up, at the *request* level, and keeps the host busy too.  A fixed pool of
L lanes (``repro.serve.slots.SlotPool`` — the admission core shared with
the LM scheduler) runs continuous batching over concurrent ``solve``
requests:

  * each admitted request holds one lane with its current iterative-
    deepening rung — the ``(adj, allowed, k)`` of its current
    preprocessed block at its current k;
  * every scheduler step packs all occupied lanes into shared multi-lane
    dispatches (``batch.decide_lanes_async``, DESIGN.md §8/§11): the
    vmapped ``decide_loop`` runs every rung concurrently, a finished
    lane's masked early-exit freezing its carry while the others step;
  * the dispatch is **launched without blocking** (JAX async dispatch:
    the device arrays are held in an ``engine.DispatchHandle``, the host
    sync is deferred).  While the device works, the scheduler runs
    admission and planning for newly arrived requests — they take free
    slots immediately and are packed into the *next* dispatch instead of
    waiting for an idle pool (DESIGN.md §11's overlap pipeline);
  * when the verdicts are synced, each lane's result is fed to its
    request's ``batch.InstanceState`` (the same per-rung accounting
    ``solve``/``solve_many`` use, so results are bit-identical to
    sequential ``solver.solve`` per request) and the slot is immediately
    recycled — to the request's next rung, its next block, or the next
    queued request.

**Per-request knobs.**  Each ``submit`` may override the pool's dedup
``mode``, the pruning flags (``use_mmw``/``use_simplicial``), pin an
explicit frontier ``cap``, or claim a larger lane share (``speculate`` —
that many consecutive deepening rungs per dispatch, smallest feasible
wins, accounting identical to the sequential ladder).  Requests whose
effective configs match share one vmapped program; incompatible configs
fall back to sub-pool dispatches within the same step (one dispatch per
config group).  An override the backend cannot run raises
``BackendCapabilityError`` from that ``submit`` alone — the pool and its
other requests are unaffected.

**Streaming.**  ``submit(..., on_event=cb)`` streams anytime progress in
the spirit of Tamaki's heuristic-computation work (PAPERS.md): per-rung
``rung_started``/``rung_decided`` events carrying running instance-level
``lb``/``ub`` (lb never decreases, ub never increases; they meet at the
width when the result is exact) and the ``per_k`` delta, then one final
``done``.  Per request, ``seq`` is strictly increasing, a block's
``rung_decided`` events arrive in increasing k, and ``done`` is last —
see DESIGN.md §11 for the ordering/monotonicity guarantees.

Fairness is structural: admission is FIFO, and every in-flight request
advances exactly one rung (or its ``speculate`` share) per step.

Memory: per-lane frontier buffers are sized by ``batch.plan_capacity``
(``cap=None``); ``budget_bytes`` bounds the step's whole resident
footprint — when config groups or speculation make one step launch
several concurrent dispatches, the budget is split across them (explicit
per-request ``cap``s are user-pinned and bypass it) — and compiled-
program churn is bounded by ratcheting the padded vertex count, the
planned cap (per config group) and the lane axis — a steady-state
service hits one compiled program per live config group.  See DESIGN.md
§10 (service + memory planning) and §11 (async pipeline, grouping,
event guarantees, parity argument).

Runnable example (blocking drain; see ``repro.launch.twserved`` for the
persistent process and ``repro.serve.client`` for its client)::

    from repro.core import graph
    from repro.serve.twscheduler import TwScheduler

    events = []
    sched = TwScheduler(lanes=4, block=32)
    sched.submit(graph.petersen(), on_event=events.append)
    sched.submit(graph.myciel(3), use_mmw=True)    # per-request knob
    results = sched.run()                          # {rid: SolveResult}
    assert events[-1]["event"] == "done"
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import backend as backend_lib
from repro.core import batch, bitset, bloom
from repro.core import engine as engine_lib
from repro.core import frontier as frontier_lib
from repro.core import solver as solver_lib
from repro.core.graph import Graph

from .slots import SlotPool


@dataclasses.dataclass
class SolveRequest:
    """One user query: compute tw(g), optionally with a certified order.

    Fields beyond ``rid``/``g`` are the per-request knobs (``None`` means
    "inherit the pool default"): ``mode`` picks the dedup (``"sort"`` /
    ``"bloom"``), ``use_mmw``/``use_simplicial`` the pruning,
    ``cap`` pins an explicit frontier buffer, and ``speculate`` the lane
    share (that many consecutive deepening rungs per dispatch).
    ``on_event`` receives the streaming event dicts (module docstring).

        req = SolveRequest(0, graph.petersen(), mode="bloom", speculate=2)
    """
    rid: int
    g: Graph
    reconstruct: bool = False
    start_k: Optional[int] = None
    mode: Optional[str] = None
    use_mmw: Optional[bool] = None
    use_simplicial: Optional[bool] = None
    cap: Optional[int] = None
    speculate: int = 1
    on_event: Optional[Callable[[dict], None]] = None


# the per-request overridable knobs (subset of decide_kw keys)
_OVERRIDES = ("mode", "use_mmw", "use_simplicial")


def _round32(n: int) -> int:
    """Word-align the padded vertex count: keeps W stable (bloom parity
    for sub-word instances) and bounds jit signatures."""
    return max(32, -(-n // 32) * 32)


class TwScheduler:
    """Asynchronous continuous-batching scheduler over solve requests.

    Constructor knobs mirror ``solver.solve`` and set the pool defaults;
    each ``submit`` may override the per-request subset (class docstring).
    ``cap=None`` (default) auto-sizes each dispatch's per-lane frontier
    buffer via ``batch.plan_capacity``; ``budget_bytes`` (int or
    ``"auto"``) bounds the whole L-lane pool.  Results per request are
    bit-identical to ``solver.solve(g, ...)`` with the same knobs (see
    DESIGN.md §10/§11 for the two padded-lane caveats inherited from §8).

    Two driving styles:

    * blocking drain — ``run()`` (or repeated ``step()``), as in the
      module example;
    * overlapped — ``launch()`` (admit + enqueue dispatches, returns
      immediately), then host-side work / ``poll_admissions()`` while the
      device flies, then ``sync()`` for the verdicts.  ``step()`` is
      exactly ``launch(); poll_admissions(); sync()``.

    All public methods take an internal lock, so a persistent front end
    (``repro.launch.twserved``) may ``submit``/``status`` from server
    threads while one driver thread steps the pool; the device wait in
    ``sync()`` runs outside the lock, which is what lets submissions
    land *mid-flight*.
    """

    def __init__(self, *, lanes: int = batch.DEFAULT_MAX_LANES,
                 cap: Optional[int] = None, block: int = 1 << 11,
                 mode: str = "sort", use_mmw: bool = False,
                 m_bits: int = 1 << 24, k_hashes: int = bloom.DEFAULT_K,
                 schedule: Optional[str] = None, backend: str = "jax",
                 use_simplicial: bool = False, use_clique: bool = True,
                 use_paths: bool = True, use_preprocess: bool = True,
                 cap_max: int = batch.DEFAULT_CAP, budget_bytes=None,
                 verbose: bool = False):
        if schedule is None:
            schedule = "doubling" if backend == "pallas" else "while"
        backend_lib.validate(backend, mode=mode, schedule=schedule,
                             use_mmw=use_mmw, use_simplicial=use_simplicial,
                             m_bits=m_bits, lanes=int(lanes))
        if budget_bytes == "auto":
            budget_bytes = backend_lib.device_memory_budget()
        self.pool = SlotPool(int(lanes))
        self.cap = cap
        self.cap_max = cap_max
        self.budget_bytes = budget_bytes
        self.block = block
        self.verbose = verbose
        self.decide_kw = dict(block=block, mode=mode, use_mmw=use_mmw,
                              m_bits=m_bits, k_hashes=k_hashes,
                              schedule=schedule, backend=backend,
                              use_simplicial=use_simplicial)
        self.plan_kw = dict(use_clique=use_clique, use_paths=use_paths)
        self.use_preprocess = use_preprocess
        self.done: Dict[int, object] = {}       # rid -> solver.SolveResult
        self.rounds = 0                          # scheduler steps launched
        self._next_rid = 0
        self._lock = threading.RLock()
        self._inflight: List[Tuple[object, list]] = []  # (handle, metas)
        # streaming progress per live rid: [lb, ub, seq] (monotone clamps)
        self._prog: Dict[int, list] = {}
        # monotone ratchets: padded n (word-aligned, shared) and, per
        # config group, the planned cap — each bump compiles one new
        # program, steady state reuses it
        self._n_pad = 32
        self._cap_pad: Dict[tuple, int] = {}

    # ------------------------------------------------------------ admission

    def submit(self, g: Graph, *, reconstruct: bool = False,
               start_k: Optional[int] = None,
               rid: Optional[int] = None,
               mode: Optional[str] = None,
               use_mmw: Optional[bool] = None,
               use_simplicial: Optional[bool] = None,
               cap: Optional[int] = None,
               speculate: int = 1,
               on_event: Optional[Callable[[dict], None]] = None) -> int:
        """Queue one solve request; returns its request id.

        The keyword subset after ``rid`` is the per-request override
        surface (``SolveRequest``).  An override the pool's backend
        cannot run raises ``BackendCapabilityError`` (an invalid explicit
        ``cap`` raises ``ValueError``) *here*, for this request only —
        the pool keeps serving.  Thread-safe: a front end may call this
        while a dispatch is in flight; the request is admitted during
        the flight and packed into the next dispatch."""
        req = SolveRequest(0, g, reconstruct, start_k, mode=mode,
                           use_mmw=use_mmw, use_simplicial=use_simplicial,
                           cap=cap, speculate=max(1, int(speculate)),
                           on_event=on_event)
        kw = self._effective_kw(req)
        backend_lib.validate(kw["backend"], mode=kw["mode"],
                             schedule=kw["schedule"], use_mmw=kw["use_mmw"],
                             use_simplicial=kw["use_simplicial"],
                             m_bits=kw["m_bits"], lanes=len(self.pool))
        if cap is not None:
            engine_lib.validate_geometry(cap, self.block)
        with self._lock:
            if rid is None:
                rid = self._next_rid
            self._next_rid = max(self._next_rid, rid) + 1
            req.rid = rid
            self._prog[rid] = [0, max(0, g.n - 1), 0]
            self.pool.submit(req)
        return rid

    def _effective_kw(self, req: SolveRequest) -> dict:
        """Pool defaults with this request's overrides applied."""
        kw = dict(self.decide_kw)
        for f in _OVERRIDES:
            v = getattr(req, f)
            if v is not None:
                kw[f] = v
        return kw

    def _group_key(self, req: SolveRequest) -> tuple:
        """Requests share a vmapped program iff this key matches: the
        static decide config plus the cap setting (explicit caps pin the
        jit signature; ``None`` caps share the planned ratchet)."""
        kw = self._effective_kw(req)
        return tuple(sorted(kw.items())) + (("cap", req.cap),)

    def _start(self, req: SolveRequest):
        """Admission: build the request's deepening state (preprocess +
        bounds + first block plan — host-only work, safe to overlap with
        an in-flight dispatch).  Returns None when the instance decides
        at admission (trivial graph, lb == ub) — the slot is then
        recycled to the next queued request at once."""
        recon_kw = dict(cap=req.cap if req.cap is not None else self.cap,
                        cap_max=self.cap_max, **self._effective_kw(req))
        inst = batch.InstanceState(
            req.g, solver_lib, use_preprocess=self.use_preprocess,
            plan_kw=dict(start_k=req.start_k, **self.plan_kw),
            reconstruct=req.reconstruct, recon_kw=recon_kw)
        self._emit(req, {"event": "admitted", "name": req.g.name,
                         "round": self.rounds + 1})
        if inst.result is not None:
            self._finish(req, inst)
            return None
        self._emit(req, dict(self._bounds_event(req, inst),
                             event="bounds"))
        return (req, inst)

    def _finish(self, req: SolveRequest, inst: batch.InstanceState):
        r = inst.result
        self.done[req.rid] = r
        prog = self._prog.pop(req.rid, [0, max(0, req.g.n - 1), 0])
        lb = max(prog[0], r.width if r.exact else r.lb)
        self._emit(req, {"event": "done", "width": r.width,
                         "exact": r.exact, "lb": lb, "ub": r.width,
                         "expanded": r.expanded, "rounds": self.rounds},
                   prog=prog)
        if self.verbose:
            print(f"[twserve] req {req.rid} ({req.g.name}): width={r.width}"
                  f" exact={r.exact} expanded={r.expanded}", flush=True)

    # ------------------------------------------------------------ streaming

    def _emit(self, req: SolveRequest, ev: dict, prog: Optional[list] = None):
        """Deliver one event to the request's callback (never raises —
        a broken sink must not take down the pool)."""
        if req.on_event is None:
            return
        if prog is None:
            prog = self._prog.get(req.rid)
        seq = 0
        if prog is not None:
            prog[2] += 1
            seq = prog[2]
        ev = dict(ev, rid=req.rid, seq=seq)
        try:
            req.on_event(ev)
        except Exception as e:           # noqa: BLE001 — sink isolation
            warnings.warn(f"twserve event sink for rid {req.rid} raised "
                          f"{e!r}; event dropped", stacklevel=2)

    def _bounds_event(self, req: SolveRequest, inst) -> dict:
        """Running instance-level (lb, ub), clamped monotone against the
        previously streamed pair.

        lb sources (each a true lower bound on tw(g)): the preprocess
        bound, the fold of finished blocks (their exact widths), the
        current block's plan.lb, and its refuted rungs (k0..k-1
        infeasible ⇒ tw ≥ k — only when k0 was not forced above the
        genuine bound and no state was dropped).  ub sources (each a true
        upper bound per part; the instance ub is their max): finished
        blocks' widths (folded), the current block's heuristic plan.ub,
        and n-1 for blocks not yet planned."""
        lb = inst.pre.lb if inst.pre is not None else 0
        ub_parts = [0]
        if inst.fold is not None:
            lb = max(lb, inst.fold.lbs)
            if inst.fold.exact:
                lb = max(lb, inst.fold.width)
            ub_parts.append(inst.fold.width)
        run = inst.run
        if run is not None:
            lb = max(lb, run.plan.lb)
            if not run.plan.forced and not run.any_inexact:
                lb = max(lb, run.k)
            ub_parts.append(run.plan.ub)
        ub_parts.extend(p.n - 1 for p in inst.parts[inst.bi:])
        ub = max(ub_parts)
        prog = self._prog.get(req.rid)
        if prog is not None:
            lb = max(lb, prog[0])
            ub = min(ub, prog[1])
            prog[0], prog[1] = lb, ub
        return {"lb": lb, "ub": ub}

    def status(self, rid: int) -> dict:
        """Queued / running / done snapshot for one request (thread-safe;
        the front end's ``status`` endpoint)."""
        with self._lock:
            if rid in self.done:
                r = self.done[rid]
                return {"state": "done", "width": r.width, "exact": r.exact,
                        "lb": r.lb, "ub": r.ub, "expanded": r.expanded}
            for _i, (req, inst) in self.pool.active():
                if req.rid == rid:
                    return dict(self._bounds_event(req, inst),
                                state="running")
            if any(req.rid == rid for req in self.pool.queue):
                return {"state": "queued"}
            return {"state": "unknown"}

    # ----------------------------------------------------------- the engine

    def launch(self) -> bool:
        """Admit, pack every occupied lane's current rung(s), and enqueue
        the dispatches **without waiting for their verdicts** (JAX async
        dispatch; the handles are held in flight).  Returns False when
        the pool is idle (nothing launched)."""
        with self._lock:
            if self._inflight:
                raise RuntimeError("launch() with a dispatch in flight; "
                                   "sync() first")
            self.pool.admit(self._start)
            active = self.pool.active()
            if not active:
                return False
            self.rounds += 1

            groups: Dict[tuple, list] = {}
            for i, (req, inst) in active:
                groups.setdefault(self._group_key(req), []).append(
                    (i, req, inst))
            n_round = max(inst.run.plan.g.n for _i, (_r, inst) in active)
            self._n_pad = max(self._n_pad, _round32(n_round))
            L = len(self.pool)

            packed = []
            for key, members in groups.items():
                lanes, metas = [], []
                for i, req, inst in members:
                    run = inst.run
                    for kk in range(run.k, min(run.k + req.speculate,
                                               run.plan.ub)):
                        lanes.append(batch.Lane(run.plan.graph_at(kk), kk,
                                                tuple(run.plan.clique)))
                        metas.append((i, req, inst, kk, run.plan.g.name))
                        self._emit(req, {"event": "rung_started",
                                         "block": run.plan.g.name, "k": kk,
                                         "round": self.rounds})
                packed.append((key, lanes, metas))
            # all of the step's dispatches are resident on device at once
            # (they launch before any sync), so a pool budget must be
            # split across them, not granted per dispatch
            n_dispatch = sum(-(-len(lanes) // L) for _k, lanes, _m in packed)

            for key, lanes, metas in packed:
                kw = dict(key)
                cap = kw.pop("cap")
                if cap is None:
                    cap = self.cap
                if cap is None:
                    cap = self._plan_group_cap(key, lanes, n_dispatch)
                # chunk a speculation-widened group into pool-sized
                # dispatches (lane axis padded to the full pool so the
                # steady state reuses one compiled program per group)
                for lo in range(0, len(lanes), L):
                    handle = batch.decide_lanes_async(
                        lanes[lo:lo + L], cap=cap, n_pad=self._n_pad,
                        lane_pad=L, **kw)
                    self._inflight.append((handle, metas[lo:lo + L]))
            return True

    def _plan_group_cap(self, key: tuple, lanes: list,
                        n_dispatch: int = 1) -> int:
        """plan_capacity for one config group, ratcheted per group key
        (compile stability) and re-clamped whenever the budget share
        shrinks — because the padded word count grew, or because the
        step launches several concurrent dispatches (``n_dispatch``)
        that split ``budget_bytes`` between them."""
        budget = self.budget_bytes
        if budget is not None:
            budget = int(budget) // max(1, n_dispatch)
        w = bitset.n_words(self._n_pad)
        cap = max(batch.plan_capacity(
            lane.g.n, w, lanes=len(self.pool), block=self.block,
            cap_max=self.cap_max, budget_bytes=budget)
            for lane in lanes)
        cap = max(self._cap_pad.get(key, 0), cap)
        if budget is not None:
            # the budget outranks the compile-stability ratchet: a cap
            # ratcheted under a smaller word count (or a
            # fewer-dispatches step) must shrink, or the resident pools
            # would exceed the bytes the knob promises to bound
            afford = int(budget) // (len(self.pool) * 4 * max(1, w))
            cap = min(cap, max(32, batch._pow2_floor(afford)))
        self._cap_pad[key] = cap
        return cap

    def poll_admissions(self) -> None:
        """Overlap bookkeeping: admit and plan newly arrived requests
        into free slots while the launched dispatches are still in
        flight.  Touches host state only (queue, slots, preprocessing/
        bounds of the new requests) — never the in-flight device buffers
        (DESIGN.md §11's overlap invariant); the admitted requests join
        the next ``launch()``."""
        with self._lock:
            self.pool.admit(self._start)

    def sync(self) -> None:
        """Block for the in-flight verdicts (the only host syncs of the
        step), feed them through each request's ``InstanceState`` in rung
        order, emit ``rung_decided`` events, and recycle finished slots.
        The device wait runs outside the scheduler lock so submissions
        and ``status`` calls keep landing mid-flight."""
        inflight, finished = self._inflight, set()
        self._inflight = []
        for handle, metas in inflight:
            results = handle.result()          # device wait — no lock held
            with self._lock:
                for (i, req, inst, k, name), res in zip(metas, results):
                    if req.rid in finished:
                        continue   # block decided on an earlier rung this
                        # round: the sequential ladder never ran this one —
                        # discard it uncounted (speculation semantics, §8)
                    cont = inst.feed(k, res)
                    self._emit(req, dict(
                        self._bounds_event(req, inst),
                        event="rung_decided", block=name, k=k,
                        round=self.rounds, feasible=res.feasible,
                        inexact=res.inexact, expanded=res.expanded))
                    if not cont:
                        finished.add(req.rid)
                    if inst.result is not None:
                        self._finish(req, inst)
                        self.pool.release(i)

    def step(self) -> bool:
        """One overlapped scheduler step: launch the shared dispatches,
        run admission/planning for new arrivals while the device works,
        then sync the verdicts and recycle slots."""
        if not self.launch():
            return False
        self.poll_admissions()
        self.sync()
        return True

    def recover(self) -> None:
        """Best-effort cleanup after a raised ``step()`` — a persistent
        driver must keep driving.  Tries to sync whatever did launch
        (their verdicts are still valid and feed normally); if even that
        fails, drops the in-flight handles so the next ``launch()`` can
        proceed (the affected rungs re-pack from unchanged host state —
        ``InstanceState`` only advances in ``feed``, so nothing is lost
        or double-counted)."""
        try:
            self.sync()
        except Exception:                     # noqa: BLE001 — last resort
            with self._lock:
                self._inflight = []

    def run(self, max_rounds: int = 1_000_000) -> Dict[int, object]:
        """Drain the queue; returns {rid: solver.SolveResult}."""
        rounds = 0
        while self.pool.busy and rounds < max_rounds:
            if not self.step():
                break
            rounds += 1
        return self.done

    @property
    def in_flight(self) -> bool:
        """Is a launched dispatch awaiting ``sync()``?"""
        return bool(self._inflight)

    def pool_bytes(self) -> int:
        """Resident frontier-pool footprint of the largest dispatch issued
        so far (lanes x cap x W uint32 rows — ``frontier.frontier_bytes``)."""
        cap = self.cap
        if cap is None:
            cap = max(self._cap_pad.values(), default=0) or \
                batch.plan_capacity(self._n_pad, block=self.block,
                                    cap_max=self.cap_max)
        return frontier_lib.frontier_bytes(cap, bitset.n_words(self._n_pad),
                                           lanes=len(self.pool))
