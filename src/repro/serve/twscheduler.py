"""Treewidth solve service: continuous batching of solve requests.

The paper keeps the GPU busy by batching many independent wavefront
expansions per dispatch; this module applies the same principle one level
up, at the *request* level.  A fixed pool of L lanes
(``repro.serve.slots.SlotPool`` — the admission core shared with the LM
scheduler) runs continuous batching over concurrent ``solve`` requests:

  * each admitted request holds one lane with its current iterative-
    deepening rung — the ``(adj, allowed, k)`` of its current
    preprocessed block at its current k;
  * every scheduler step packs all occupied lanes into ONE shared
    multi-lane dispatch (``batch.decide_lanes``, DESIGN.md §8): the
    vmapped ``decide_loop`` runs every rung concurrently, a finished
    lane's masked early-exit freezing its carry while the others step;
  * when the dispatch returns, each lane's verdict is fed to its
    request's ``batch.InstanceState`` (the same per-rung accounting
    ``solve``/``solve_many`` use, so results are bit-identical to
    sequential ``solver.solve`` per request) and the slot is immediately
    recycled — to the request's next rung, its next block, or the next
    queued request.

Fairness is structural: admission is FIFO, and every in-flight request
advances exactly one rung per dispatch (round-robin by construction —
a hard instance cannot starve the cheap ones behind it, it just keeps
its one lane while they stream through the remaining L-1).

Memory: the per-lane frontier buffers are sized by
``batch.plan_capacity`` (``cap=None``), so a pool full of small blocks
does not pay L x 2^17 rows; ``budget_bytes`` bounds the whole pool.
Compiled-program churn is bounded by ratcheting the padded vertex count
(word-aligned), the planned cap, and the lane axis (always padded to the
full pool with trivial lanes) — a steady-state service hits one compiled
program.  See DESIGN.md §10 for the architecture and the parity caveats
(bloom-mode requests padded into a larger word count than their solo run
draw a different Monte-Carlo false-positive set; MMW sees padding rows).

    sched = TwScheduler(lanes=8)
    sched.submit(graph.queen(5))
    sched.submit(graph.myciel(4), reconstruct=True)
    results = sched.run()          # {rid: solver.SolveResult}
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import backend as backend_lib
from repro.core import batch, bitset, bloom
from repro.core import frontier as frontier_lib
from repro.core import solver as solver_lib
from repro.core.graph import Graph

from .slots import SlotPool


@dataclasses.dataclass
class SolveRequest:
    """One user query: compute tw(g), optionally with a certified order."""
    rid: int
    g: Graph
    reconstruct: bool = False
    start_k: Optional[int] = None


def _round32(n: int) -> int:
    """Word-align the padded vertex count: keeps W stable (bloom parity
    for sub-word instances) and bounds jit signatures."""
    return max(32, -(-n // 32) * 32)


class TwScheduler:
    """Continuous-batching scheduler over treewidth solve requests.

    Solver knobs mirror ``solver.solve`` and apply to every request in
    the pool (one shared dispatch = one static config).  ``cap=None``
    (default) auto-sizes each dispatch's per-lane frontier buffer via
    ``batch.plan_capacity``; ``budget_bytes`` (int or ``"auto"``) bounds
    the whole L-lane pool.  Results per request are bit-identical to
    ``solver.solve(g, ...)`` with the same knobs (see DESIGN.md §10 for
    the two padded-lane caveats inherited from §8).
    """

    def __init__(self, *, lanes: int = batch.DEFAULT_MAX_LANES,
                 cap: Optional[int] = None, block: int = 1 << 11,
                 mode: str = "sort", use_mmw: bool = False,
                 m_bits: int = 1 << 24, k_hashes: int = bloom.DEFAULT_K,
                 schedule: Optional[str] = None, backend: str = "jax",
                 use_simplicial: bool = False, use_clique: bool = True,
                 use_paths: bool = True, use_preprocess: bool = True,
                 cap_max: int = batch.DEFAULT_CAP, budget_bytes=None,
                 verbose: bool = False):
        if schedule is None:
            schedule = "doubling" if backend == "pallas" else "while"
        backend_lib.validate(backend, mode=mode, schedule=schedule,
                             use_mmw=use_mmw, use_simplicial=use_simplicial,
                             m_bits=m_bits, lanes=int(lanes))
        if budget_bytes == "auto":
            budget_bytes = backend_lib.device_memory_budget()
        self.pool = SlotPool(int(lanes))
        self.cap = cap
        self.cap_max = cap_max
        self.budget_bytes = budget_bytes
        self.block = block
        self.verbose = verbose
        self.decide_kw = dict(block=block, mode=mode, use_mmw=use_mmw,
                              m_bits=m_bits, k_hashes=k_hashes,
                              schedule=schedule, backend=backend,
                              use_simplicial=use_simplicial)
        self.plan_kw = dict(use_clique=use_clique, use_paths=use_paths)
        self.use_preprocess = use_preprocess
        self.recon_kw = dict(cap=cap, cap_max=cap_max, **self.decide_kw)
        self.done: Dict[int, object] = {}       # rid -> solver.SolveResult
        self.rounds = 0                          # shared dispatches issued
        self._next_rid = 0
        # monotone ratchets: padded n (word-aligned), planned cap — each
        # bump compiles one new program, steady state reuses it
        self._n_pad = 32
        self._cap_pad = 0

    # ------------------------------------------------------------ admission

    def submit(self, g: Graph, *, reconstruct: bool = False,
               start_k: Optional[int] = None,
               rid: Optional[int] = None) -> int:
        """Queue one solve request; returns its request id."""
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.pool.submit(SolveRequest(rid, g, reconstruct, start_k))
        return rid

    def _start(self, req: SolveRequest):
        """Admission: build the request's deepening state.  Returns None
        when the instance decides at admission (trivial graph, lb == ub)
        — the slot is then recycled to the next queued request at once."""
        inst = batch.InstanceState(
            req.g, solver_lib, use_preprocess=self.use_preprocess,
            plan_kw=dict(start_k=req.start_k, **self.plan_kw),
            reconstruct=req.reconstruct, recon_kw=self.recon_kw)
        if inst.result is not None:
            self._finish(req, inst)
            return None
        return (req, inst)

    def _finish(self, req: SolveRequest, inst: batch.InstanceState):
        self.done[req.rid] = inst.result
        if self.verbose:
            r = inst.result
            print(f"[twserve] req {req.rid} ({req.g.name}): width={r.width}"
                  f" exact={r.exact} expanded={r.expanded}", flush=True)

    # ----------------------------------------------------------- the engine

    def step(self) -> bool:
        """One shared dispatch: admit, pack every occupied lane's current
        rung, decide them all at once, recycle finished slots."""
        self.pool.admit(self._start)
        active = self.pool.active()
        if not active:
            return False

        lanes, metas = [], []
        for i, (req, inst) in active:
            run = inst.run
            lanes.append(batch.Lane(run.plan.graph_at(run.k), run.k,
                                    tuple(run.plan.clique)))
            metas.append((i, req, inst, run.k))
        self._n_pad = max(self._n_pad,
                          _round32(max(lane.g.n for lane in lanes)))
        cap = self.cap
        if cap is None:
            w = bitset.n_words(self._n_pad)
            cap = max(batch.plan_capacity(
                lane.g.n, w, lanes=len(self.pool), block=self.block,
                cap_max=self.cap_max, budget_bytes=self.budget_bytes)
                for lane in lanes)
            cap = max(self._cap_pad, cap)
            if self.budget_bytes is not None:
                # the budget outranks the compile-stability ratchet: a cap
                # ratcheted under a smaller word count must shrink when a
                # wider instance grows W, or the pool would exceed the
                # bytes the knob promises to bound
                afford = int(self.budget_bytes) // \
                    (len(self.pool) * 4 * max(1, w))
                cap = min(cap, max(32, batch._pow2_floor(afford)))
            self._cap_pad = cap

        results = batch.decide_lanes(
            lanes, cap=cap, n_pad=self._n_pad, lane_pad=len(self.pool),
            **self.decide_kw)
        self.rounds += 1

        for (i, req, inst, k), res in zip(metas, results):
            inst.feed(k, res)          # may finish block(s) / the instance
            if inst.result is not None:
                self._finish(req, inst)
                self.pool.release(i)
        return True

    def run(self, max_rounds: int = 1_000_000) -> Dict[int, object]:
        """Drain the queue; returns {rid: solver.SolveResult}."""
        rounds = 0
        while self.pool.busy and rounds < max_rounds:
            if not self.step():
                break
            rounds += 1
        return self.done

    def pool_bytes(self) -> int:
        """Resident frontier-pool footprint of the largest dispatch issued
        so far (lanes x cap x W uint32 rows — ``frontier.frontier_bytes``)."""
        cap = self.cap if self.cap is not None else \
            (self._cap_pad or batch.plan_capacity(
                self._n_pad, block=self.block, cap_max=self.cap_max))
        return frontier_lib.frontier_bytes(cap, bitset.n_words(self._n_pad),
                                           lanes=len(self.pool))
