"""Recurrent blocks: Mamba selective SSM, xLSTM mLSTM / sLSTM.

Training paths are *chunk-parallel*:
  * mamba  — `associative_scan` inside fixed-size chunks, `lax.scan` carrying
    the (d_inner, d_state) state across chunks (memory O(C * d_inner * ds));
  * mLSTM  — chunkwise stabilized gated linear attention (flash-linear-
    attention schedule): intra-chunk C x C attention + inter-chunk matrix
    state (hd x hd) carry, with running log-max stabilizers (the xLSTM
    exponential-gate stabilization);
  * sLSTM  — inherently sequential (recurrent h->gates dependency): a plain
    `lax.scan` over time.  This is an architectural property, not an
    implementation shortcut (xLSTM paper §2.3).

Decode paths are O(1)-state recurrent steps — which is exactly why these
architectures run the `long_500k` shape that dense attention cannot.

Every training path is validated against a step-by-step sequential
reference in tests/test_ssm.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .params import Param
from . import layers

F32 = jnp.float32


# =====================================================================
# Mamba selective SSM
# =====================================================================

def mamba_dims(cfg):
    di = int(cfg.ssm.expand * cfg.d_model)
    dtr = cfg.ssm.dt_rank or max(1, -(-cfg.d_model // 16))
    return di, dtr, cfg.ssm.d_state, cfg.ssm.conv_kernel


def mamba_spec(cfg, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    di, dtr, ds, kc = mamba_dims(cfg)
    return {
        "in_proj": Param((d, 2 * di), ("embed", "mlp")),
        "conv_w": Param((kc, di), (None, "mlp"), "normal", 0.5),
        "conv_b": Param((di,), ("mlp",), "zeros"),
        "x_proj": Param((di, dtr + 2 * ds), ("mlp", None)),
        "dt_proj": Param((dtr, di), (None, "mlp")),
        "dt_bias": Param((di,), ("mlp",), "zeros"),
        "a_log": Param((di, ds), ("mlp", None), "ones"),
        "d_skip": Param((di,), ("mlp",), "ones"),
        "out_proj": Param((di, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv: x (B,S,di), w (K,di).  state (B,K-1,di) holds
    the trailing inputs of the previous segment (for decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, j:j + x.shape[1], :] * w[j] for j in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return out + b, new_state


def _mamba_scan_chunked(a, u, h0, chunk: int):
    """h_t = a_t * h_{t-1} + u_t ; a,u (B,S,di,ds); h0 (B,di,ds)."""
    b, s, di, ds = a.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        # identity steps: decay 1, input 0 — state passes through unchanged
        a = jnp.concatenate([a, jnp.ones((b, pad, di, ds), a.dtype)], axis=1)
        u = jnp.concatenate([u, jnp.zeros((b, pad, di, ds), u.dtype)], axis=1)
    s_pad = s + pad
    nc = s_pad // c
    ac = jnp.moveaxis(a.reshape(b, nc, c, di, ds), 1, 0)
    uc = jnp.moveaxis(u.reshape(b, nc, c, di, ds), 1, 0)
    del s_pad

    def assoc(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    def chunk_body(h, au):
        a_k, u_k = au                            # (B,C,di,ds)
        acum, ucum = jax.lax.associative_scan(assoc, (a_k, u_k), axis=1)
        h_t = acum * h[:, None] + ucum           # (B,C,di,ds)
        return h_t[:, -1], h_t

    h_end, hs = jax.lax.scan(chunk_body, h0, (ac, uc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s + pad, di, ds)[:, :s]
    # identity padding keeps the carried state exact
    return hs, h_end


def _mamba_scan_fused(dt, x1, bmat, cmat, a_mat, h0, chunk: int):
    """Chunked selective scan with the (B,S,di,ds)-sized decay/input/state
    tensors materialised only per chunk (beyond-paper §Perf iteration: the
    full-sequence (B,S,di,ds) buffers dominated hymba's HBM roofline term).

    dt, x1 (B,S,di) f32; bmat, cmat (B,S,ds) f32; a_mat (di,ds).
    Returns (y (B,S,di), h_end (B,di,ds))."""
    b, s, di = dt.shape
    ds = bmat.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zdt = jnp.zeros((b, pad, di), dt.dtype)
        dt = jnp.concatenate([dt, zdt], axis=1)          # dt=0 -> decay=1
        x1 = jnp.concatenate([x1, zdt], axis=1)
        zb = jnp.zeros((b, pad, ds), bmat.dtype)
        bmat = jnp.concatenate([bmat, zb], axis=1)
        cmat = jnp.concatenate([cmat, zb], axis=1)
    nc = (s + pad) // c

    def chunks(t):
        return jnp.moveaxis(t.reshape(b, nc, c, t.shape[-1]), 1, 0)

    def assoc(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        dt_k, x1_k, b_k, c_k = xs                        # (B,C,...)
        decay = jnp.exp(dt_k[..., None] * a_mat[None, None])
        u = (dt_k * x1_k)[..., None] * b_k[:, :, None, :]
        acum, ucum = jax.lax.associative_scan(assoc, (decay, u), axis=1)
        h_t = acum * h[:, None] + ucum                   # (B,C,di,ds)
        y_k = jnp.sum(h_t * c_k[:, :, None, :], axis=-1)
        return h_t[:, -1], y_k

    h_end, ys = jax.lax.scan(
        body, h0, (chunks(dt), chunks(x1), chunks(bmat), chunks(cmat)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s + pad, di)[:, :s]
    return y, h_end


def mamba_block(p, x, cfg, state: Optional[Tuple] = None,
                return_state: bool = False):
    """x (B,S,d) -> (B,S,d).  state = (h (B,di,ds), conv (B,K-1,di))."""
    di, dtr, ds, kc = mamba_dims(cfg)
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x1, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[1] if state is not None else None
    x1, new_conv = _causal_conv(x1, p["conv_w"], p["conv_b"], conv_state)
    x1 = jax.nn.silu(x1)

    dbc = jnp.einsum("bsi,ie->bse", x1, p["x_proj"])
    dt_r = dbc[..., :dtr]
    bmat = dbc[..., dtr:dtr + ds].astype(F32)
    cmat = dbc[..., dtr + ds:].astype(F32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(F32)
    a_mat = -jnp.exp(p["a_log"].astype(F32))                 # (di, ds)

    h0 = state[0].astype(F32) if state is not None else \
        jnp.zeros((b, di, ds), F32)
    y, h_end = _mamba_scan_fused(dt, x1.astype(F32), bmat, cmat, a_mat, h0,
                                 cfg.ssm.chunk)
    y = y + p["d_skip"].astype(F32) * x1.astype(F32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        return out, (h_end.astype(F32), new_conv)
    return out


def mamba_decode(p, x, cfg, state):
    """Single-token step: x (B,1,d); state (h, conv)."""
    return mamba_block(p, x, cfg, state=state, return_state=True)


def mamba_ref(p, x, cfg):
    """Sequential oracle (python loop over time)."""
    di, dtr, ds, kc = mamba_dims(cfg)
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x1, z = jnp.split(xz, 2, axis=-1)
    x1, _ = _causal_conv(x1, p["conv_w"], p["conv_b"])
    x1 = jax.nn.silu(x1)
    dbc = jnp.einsum("bsi,ie->bse", x1, p["x_proj"])
    dt_r, bmat, cmat = (dbc[..., :dtr], dbc[..., dtr:dtr + ds].astype(F32),
                        dbc[..., dtr + ds:].astype(F32))
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(F32)
    a_mat = -jnp.exp(p["a_log"].astype(F32))
    h = jnp.zeros((b, di, ds), F32)
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t, :, None] * a_mat[None])
        h = decay * h + (dt[:, t] * x1[:, t].astype(F32))[..., None] \
            * bmat[:, t, None, :]
        ys.append(jnp.sum(h * cmat[:, t, None, :], axis=-1))
    y = jnp.stack(ys, axis=1) + p["d_skip"].astype(F32) * x1.astype(F32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


# =====================================================================
# mLSTM (xLSTM matrix memory) — chunkwise gated linear attention
# =====================================================================

def mlstm_dims(cfg):
    di = int(cfg.ssm.expand * cfg.d_model) if cfg.ssm else cfg.d_model
    h = cfg.n_heads
    return di, h, di // h


def mlstm_spec(cfg) -> dict:
    d = cfg.d_model
    di, h, hd = mlstm_dims(cfg)
    return {
        "up": Param((d, 2 * di), ("embed", "mlp")),
        "wq": Param((di, h, hd), ("mlp", "heads", None)),
        "wk": Param((di, h, hd), ("mlp", "heads", None)),
        "wv": Param((di, h, hd), ("mlp", "heads", None)),
        "wi": Param((di, h), ("mlp", "heads"), "small"),
        "wf": Param((di, h), ("mlp", "heads"), "small"),
        "norm": layers.rmsnorm_spec(hd),
        "down": Param((di, d), ("mlp", "embed")),
    }


def _mlstm_chunk(q, k, v, log_f, log_i, carry, hd):
    """One chunk of stabilized gated linear attention.

    q,k,v (B,H,C,hd); log_f/log_i (B,H,C); carry = (Cst (B,H,hd,hd),
    nst (B,H,hd), mst (B,H)).  Returns (h (B,H,C,hd), new carry).
    """
    cst, nst, mst = carry
    c = q.shape[2]
    f_cum = jnp.cumsum(log_f, axis=-1)                       # F_t
    # intra-chunk log weights b[t,s] = F_t - F_s + log_i_s  (s <= t)
    bmat = f_cum[..., :, None] - f_cum[..., None, :] + log_i[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    bmat = jnp.where(tri, bmat, -jnp.inf)
    m_intra = jnp.max(bmat, axis=-1)                         # (B,H,C)
    m_cross = mst[..., None] + f_cum                         # (B,H,C)
    m_t = jnp.maximum(m_intra, m_cross)

    w_intra = jnp.exp(bmat - m_t[..., None])                 # (B,H,C,C)
    scale = hd ** -0.5
    scores = jnp.einsum("bhtx,bhsx->bhts", q * scale, k) * w_intra
    h_intra = jnp.einsum("bhts,bhsx->bhtx", scores, v)
    n_intra = jnp.einsum("bhts,bhsx->bhtx", w_intra, k)      # Σ w k_s

    w_cross = jnp.exp(m_cross - m_t)                         # (B,H,C)
    h_cross = jnp.einsum("bhtx,bhxy->bhty", q * scale, cst) * w_cross[..., None]
    n_cross = nst[:, :, None, :] * w_cross[..., None]

    h_num = h_intra + h_cross
    n_vec = n_intra + n_cross                                # (B,H,C,hd)
    denom = jnp.abs(jnp.einsum("bhtx,bhtx->bht", q * scale, n_vec))
    denom = jnp.maximum(denom, jnp.exp(-m_t))
    h = h_num / denom[..., None]

    # ---- carry update to end of chunk
    f_end = f_cum[..., -1]                                   # (B,H)
    m_end_intra = jnp.max(f_end[..., None] - f_cum + log_i, axis=-1)
    m_new = jnp.maximum(mst + f_end, m_end_intra)
    w_state = jnp.exp(mst + f_end - m_new)
    w_toks = jnp.exp(f_end[..., None] - f_cum + log_i - m_new[..., None])
    cst_new = cst * w_state[..., None, None] + jnp.einsum(
        "bhsx,bhsy,bhs->bhxy", k, v, w_toks)
    nst_new = nst * w_state[..., None] + jnp.einsum(
        "bhsx,bhs->bhx", k, w_toks)
    return h, (cst_new, nst_new, m_new)


def mlstm_inner(q, k, v, log_f, log_i, chunk: int, carry=None):
    """q,k,v (B,S,H,hd) -> h (B,S,H,hd) with chunkwise scan."""
    b, s0, h, hd = q.shape
    c = min(chunk, s0)
    pad = (-s0) % c
    if pad:
        # identity steps: f = 1 (log 0), i -> 0 (log -inf) leave state intact
        zq = jnp.zeros((b, pad, h, hd), q.dtype)
        q = jnp.concatenate([q, zq], axis=1)
        k = jnp.concatenate([k, zq.astype(k.dtype)], axis=1)
        v = jnp.concatenate([v, zq.astype(v.dtype)], axis=1)
        log_f = jnp.concatenate(
            [log_f, jnp.zeros((b, pad, h), log_f.dtype)], axis=1)
        log_i = jnp.concatenate(
            [log_i, jnp.full((b, pad, h), -1e30, log_i.dtype)], axis=1)
    s = s0 + pad
    nc = s // c

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(b, nc, c, h, hd).transpose(0, 1, 3, 2, 4), 1, 0)

    def gates_to_chunks(x):
        return jnp.moveaxis(x.reshape(b, nc, c, h).transpose(0, 1, 3, 2), 1, 0)

    qc, kc, vc = to_chunks(q.astype(F32)), to_chunks(k.astype(F32)), \
        to_chunks(v.astype(F32))
    fc, ic = gates_to_chunks(log_f.astype(F32)), gates_to_chunks(
        log_i.astype(F32))
    if carry is None:
        carry = (jnp.zeros((b, h, hd, hd), F32), jnp.zeros((b, h, hd), F32),
                 jnp.full((b, h), -1e30, F32))

    def body(cr, args):
        qk, kk, vk, fk, ik = args
        hk, cr = _mlstm_chunk(qk, kk, vk, fk, ik, cr, hd)
        return cr, hk

    carry, hs = jax.lax.scan(body, carry, (qc, kc, vc, fc, ic))
    hs = jnp.moveaxis(hs, 0, 1)                              # (B,nc,H,C,hd)
    hs = hs.transpose(0, 1, 3, 2, 4).reshape(b, s, h, hd)[:, :s0]
    return hs, carry


def mlstm_block(p, x, cfg, state=None, return_state: bool = False):
    """x (B,S,d) -> (B,S,d)."""
    di, h, hd = mlstm_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xi, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bsi,ihx->bshx", xi, p["wq"])
    k = jnp.einsum("bsi,ihx->bshx", xi, p["wk"])
    v = jnp.einsum("bsi,ihx->bshx", xi, p["wv"])
    log_i = jnp.einsum("bsi,ih->bsh", xi, p["wi"]).astype(F32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", xi, p["wf"]).astype(F32))
    hs, carry = mlstm_inner(q, k, v, log_f, log_i,
                            cfg.ssm.chunk if cfg.ssm else 64, carry=state)
    hs = layers.rmsnorm(p["norm"], hs.astype(x.dtype), cfg.norm_eps)
    y = hs.reshape(x.shape[0], x.shape[1], di) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["down"])
    if return_state:
        return out, carry
    return out


def mlstm_ref_inner(q, k, v, log_f, log_i):
    """Sequential oracle of the stabilized mLSTM recurrence."""
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    cst = jnp.zeros((b, h, hd, hd), F32)
    nst = jnp.zeros((b, h, hd), F32)
    mst = jnp.full((b, h), -1e30, F32)
    outs = []
    for t in range(s):
        lf, li = log_f[:, t].astype(F32), log_i[:, t].astype(F32)
        m_new = jnp.maximum(lf + mst, li)
        fw = jnp.exp(lf + mst - m_new)
        iw = jnp.exp(li - m_new)
        kt, vt, qt = k[:, t].astype(F32), v[:, t].astype(F32), \
            q[:, t].astype(F32) * scale
        cst = cst * fw[..., None, None] + iw[..., None, None] * \
            jnp.einsum("bhx,bhy->bhxy", kt, vt)
        nst = nst * fw[..., None] + iw[..., None] * kt
        mst = m_new
        num = jnp.einsum("bhx,bhxy->bhy", qt, cst)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhx,bhx->bh", qt, nst)),
                          jnp.exp(-mst))
        outs.append(num / den[..., None])
    return jnp.stack(outs, axis=1)


def mlstm_decode(p, x, cfg, state):
    """Single-token mLSTM step (recurrent form)."""
    return mlstm_block(p, x, cfg, state=state, return_state=True)


# =====================================================================
# sLSTM — sequential scalar-memory LSTM with exponential gating
# =====================================================================

def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "wx": Param((d, h, 4, hd), ("embed", "heads", None, None)),
        "r": Param((h, hd, 4, hd), ("heads", None, None, None), "small"),
        "b": Param((h, 4, hd), ("heads", None, None), "zeros"),
        "norm": layers.rmsnorm_spec(d),
        "down": Param((d, d), ("embed", "embed")),
    }


def _slstm_step(p, xt, state, eps):
    """xt (B,H,4,hd) pre-projected; state = (c, n, h, m) each (B,H,hd)."""
    c, n, hprev, m = state
    rec = jnp.einsum("bhx,hxgy->bhgy", hprev, p["r"].astype(F32))
    g = xt.astype(F32) + rec + p["b"].astype(F32)
    i_t, f_t, z_t, o_t = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    m_new = jnp.maximum(f_t + m, i_t)
    i = jnp.exp(i_t - m_new)
    f = jnp.exp(f_t + m - m_new)
    c_new = f * c + i * jnp.tanh(z_t)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, eps)
    return (c_new, n_new, h_new, m_new)


def slstm_block(p, x, cfg, state=None, return_state: bool = False):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xp = jnp.einsum("bsd,dhgy->bshgy", x, p["wx"])
    if state is None:
        z = jnp.zeros((b, h, hd), F32)
        state = (z, z, z, jnp.full((b, h, hd), -1e30, F32))

    def step(st, xt):
        st = _slstm_step(p, xt, st, 1e-6)
        return st, st[2]

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xp, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    hs = layers.rmsnorm(p["norm"], hs, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", hs, p["down"])
    if return_state:
        return out, state
    return out


def slstm_decode(p, x, cfg, state):
    return slstm_block(p, x, cfg, state=state, return_state=True)
