"""LM losses and public model API."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer
from .params import abstract_params, init_params, count_params


def causal_lm_loss(logits, targets, cfg, mask=None, z_loss: float = 1e-4):
    """Next-token cross entropy with padded-vocab masking + z-loss.

    logits (B, S, Vpad); targets (B, S) — already shifted by the data
    pipeline (targets[t] is the token after inputs[t]).
    """
    v = cfg.vocab
    logits = logits.astype(jnp.float32)
    # mask padded vocab entries out of the softmax
    vpad = logits.shape[-1]
    if vpad > v:
        neg = jnp.full((vpad - v,), -1e30, jnp.float32)
        logits = logits.at[..., v:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    mask = mask.astype(jnp.float32)
    total = jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return total, {"nll": jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)}


class Model:
    """Thin functional wrapper binding a config to spec/init/apply."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.spec = transformer.lm_spec(cfg)

    def init(self, key, dtype=None):
        return init_params(self.spec, key,
                           dtype or jnp.dtype(self.cfg.param_dtype))

    def abstract(self, dtype=None):
        return abstract_params(self.spec,
                               dtype or jnp.dtype(self.cfg.param_dtype))

    def n_params(self) -> int:
        return count_params(self.spec)

    def apply(self, params, tokens, **kw):
        return transformer.forward(params, self.cfg, tokens, **kw)

    def init_cache(self, batch: int, cache_len: int):
        return transformer.init_cache(self.cfg, batch, cache_len)
