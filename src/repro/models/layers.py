"""Shared layers: norms, embeddings, RoPE, MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import Param


# ------------------------------------------------------------------- norms

def rmsnorm_spec(d: int) -> dict:
    return {"scale": Param((d,), (None,), "ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int) -> dict:
    return {"scale": Param((d,), (None,), "ones"),
            "bias": Param((d,), (None,), "zeros")}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# -------------------------------------------------------------- embeddings

def embedding_spec(vocab: int, d: int) -> dict:
    return {"table": Param((vocab, d), ("vocab", "embed"), "embed")}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    """Project to (padded) vocab logits."""
    return jnp.einsum("...d,vd->...v", x, p["table"])


def output_head_spec(d: int, vocab: int) -> dict:
    return {"proj": Param((d, vocab), ("embed", "vocab"), "normal")}


def output_head(p, x):
    return jnp.einsum("...d,dv->...v", x, p["proj"])


def positional_embedding_spec(max_len: int, d: int) -> dict:
    return {"pos": Param((max_len, d), (None, "embed"), "embed")}


def sinusoidal_positions(length: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * 2 * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# -------------------------------------------------------------------- RoPE

def rope_angles(positions, hd: int, theta: float):
    """positions (...,) -> cos/sin (..., hd/2)."""
    dim = jnp.arange(hd // 2, dtype=jnp.float32)
    inv = theta ** (-2.0 * dim / hd)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- MLP

def swiglu_spec(d: int, f: int) -> dict:
    return {
        "wi_gate": Param((d, f), ("embed", "mlp")),
        "wi_up": Param((d, f), ("embed", "mlp")),
        "wo": Param((f, d), ("mlp", "embed")),
    }


def swiglu(p, x):
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, p["wi_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["wo"])


def gelu_mlp_spec(d: int, f: int) -> dict:
    return {
        "wi": Param((d, f), ("embed", "mlp")),
        "bi": Param((f,), ("mlp",), "zeros"),
        "wo": Param((f, d), ("mlp", "embed")),
        "bo": Param((d,), (None,), "zeros"),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi"]) + p["bi"])
    return jnp.einsum("...f,fd->...d", h, p["wo"]) + p["bo"]
