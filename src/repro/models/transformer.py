"""Model assembly: decoder-only LM, encoder-decoder (whisper), VLM fusion.

Layers are grouped into a repeating *unit* (``cfg.block_pattern``) whose
parameters are stacked along a leading "layers" axis and executed with
``lax.scan`` — compile time and HLO size are O(unit), not O(depth), which is
what makes 62-layer/48-layer configs lowerable for 512-device meshes in
reasonable time.

Sub-block kinds:
  attn   — GQA self-attention (sliding window if cfg.sliding_window)
  cross  — cross-attention to encoder memory (whisper decoder)
  mlp    — SwiGLU           gmlp — GELU MLP (whisper)
  moe    — routed experts   mamba/mlstm/slstm — recurrent blocks
  hymba  — parallel attn + mamba heads on the same normed input, mean-fused
           (arXiv:2411.13676)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import layers, moe as moe_lib, ssm as ssm_lib
from .params import Param, stack_spec, map_spec


# ------------------------------------------------------------- block specs

def sub_block_spec(kind: str, cfg) -> dict:
    d = cfg.d_model
    spec = {"norm": layers.rmsnorm_spec(d)}
    if kind == "attn":
        spec["attn"] = attn_lib.attention_spec(cfg)
    elif kind == "cross":
        spec["attn"] = attn_lib.attention_spec(cfg, cross=True)
    elif kind == "mlp":
        spec["mlp"] = layers.swiglu_spec(d, cfg.d_ff)
    elif kind == "gmlp":
        spec["mlp"] = layers.gelu_mlp_spec(d, cfg.d_ff)
    elif kind == "moe":
        spec["moe"] = moe_lib.moe_spec(cfg)
    elif kind == "mamba":
        spec["mamba"] = ssm_lib.mamba_spec(cfg)
    elif kind == "mlstm":
        spec["mlstm"] = ssm_lib.mlstm_spec(cfg)
    elif kind == "slstm":
        spec["slstm"] = ssm_lib.slstm_spec(cfg)
    elif kind == "hymba":
        spec["attn"] = attn_lib.attention_spec(cfg)
        spec["mamba"] = ssm_lib.mamba_spec(cfg)
    else:
        raise ValueError(kind)
    return spec


def unit_spec(cfg, decoder: bool) -> dict:
    out = {}
    for i, group in enumerate(cfg.block_pattern):
        g = {}
        for kind in group:
            g[kind] = sub_block_spec(kind, cfg)
        if decoder and cfg.cross_attention:
            g["cross"] = sub_block_spec("cross", cfg)
        out[f"layer{i}"] = g
    return out


def lm_spec(cfg) -> dict:
    spec = {
        "embed": layers.embedding_spec(cfg.padded_vocab, cfg.d_model),
        "final_norm": layers.rmsnorm_spec(cfg.d_model),
        "layers": stack_spec(unit_spec(cfg, decoder=True), cfg.n_reps),
    }
    if not cfg.tie_embeddings:
        spec["head"] = layers.output_head_spec(cfg.d_model, cfg.padded_vocab)
    if cfg.encoder_layers:
        enc_cfg = cfg
        spec["encoder"] = {
            "layers": stack_spec(
                {"layer0": {"attn": sub_block_spec("attn", enc_cfg),
                            "gmlp": sub_block_spec("gmlp", enc_cfg)}},
                cfg.encoder_layers),
            "final_norm": layers.rmsnorm_spec(cfg.d_model),
        }
    if cfg.frontend == "vision":
        spec["vision_adapter"] = {
            "proj": Param((cfg.d_model, cfg.d_model), ("embed", "embed"))}
    if cfg.frontend == "audio":
        spec["audio_adapter"] = {
            "proj": Param((cfg.d_model, cfg.d_model), ("embed", "embed"))}
    return spec


# ------------------------------------------------------------ cache specs

def sub_block_cache(kind: str, cfg, batch: int, cache_len: int):
    """Zero cache entry for one sub-block (decode mode)."""
    hd, kv = cfg.hd, cfg.n_kv
    f32 = jnp.float32
    if kind in ("attn", "hymba"):
        win = cfg.sliding_window
        clen = min(cache_len, win) if win else cache_len
        entry = {"k": jnp.zeros((batch, clen, kv, hd), _dt(cfg)),
                 "v": jnp.zeros((batch, clen, kv, hd), _dt(cfg))}
        if kind == "hymba":
            di, _, ds, kc = ssm_lib.mamba_dims(cfg)
            entry.update(h=jnp.zeros((batch, di, ds), f32),
                         conv=jnp.zeros((batch, kc - 1, di), _dt(cfg)))
        return entry
    if kind == "mamba":
        di, _, ds, kc = ssm_lib.mamba_dims(cfg)
        return {"h": jnp.zeros((batch, di, ds), f32),
                "conv": jnp.zeros((batch, kc - 1, di), _dt(cfg))}
    if kind == "mlstm":
        di, h, hd2 = ssm_lib.mlstm_dims(cfg)
        return {"c": jnp.zeros((batch, h, hd2, hd2), f32),
                "n": jnp.zeros((batch, h, hd2), f32),
                "m": jnp.full((batch, h), -1e30, f32)}
    if kind == "slstm":
        h = cfg.n_heads
        hd2 = cfg.d_model // h
        z = jnp.zeros((batch, h, hd2), f32)
        return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, hd2), -1e30, f32)}
    if kind == "cross":
        # memory k/v filled at prefill from the encoder output
        return {"k": jnp.zeros((batch, cfg.encoder_len, cfg.n_heads, hd), _dt(cfg)),
                "v": jnp.zeros((batch, cfg.encoder_len, cfg.n_heads, hd), _dt(cfg))}
    if kind in ("mlp", "gmlp", "moe"):
        return {}
    raise ValueError(kind)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def init_cache(cfg, batch: int, cache_len: int):
    """Stacked (n_reps, ...) cache pytree matching the scan layout."""
    unit = {}
    for i, group in enumerate(cfg.block_pattern):
        g = {kind: sub_block_cache(kind, cfg, batch, cache_len)
             for kind in group}
        if cfg.cross_attention:
            g["cross"] = sub_block_cache("cross", cfg, batch, cache_len)
        unit[f"layer{i}"] = g
    reps = cfg.n_reps
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), unit)


# -------------------------------------------------------------- sub-blocks

def apply_sub(kind: str, p, x, cfg, *, positions, mode: str, cache=None,
              pos=None, memory=None):
    """One residual sub-block on pre-normed input.  Returns
    (delta, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("mlp",):
        return layers.swiglu(p["mlp"], x), cache, aux
    if kind == "gmlp":
        return layers.gelu_mlp(p["mlp"], x), cache, aux
    if kind == "moe":
        y, aux = moe_lib.moe_block(p["moe"], x, cfg)
        return y, cache, aux

    if kind in ("attn", "hymba"):
        ap = p["attn"]
        win = cfg.sliding_window
        if mode == "decode":
            q, k_new, v_new = attn_lib.project_qkv(
                ap, cfg, x, x, pos[:, None], pos[:, None])
            if win:
                kc, vc = attn_lib.update_window_cache(
                    cache["k"], cache["v"], k_new, v_new, pos)
                ctx = attn_lib.decode_window_attention(q, kc, vc, pos, win)
            else:
                kc, vc = attn_lib.update_cache(
                    cache["k"], cache["v"], k_new, v_new, pos)
                ctx = attn_lib.decode_attention(q, kc, vc, pos, window=win)
            new_cache = dict(cache, k=kc, v=vc)
        else:
            q, k, v = attn_lib.project_qkv(ap, cfg, x, x, positions, positions)
            s = x.shape[1]
            if s <= 2 * cfg.attn_chunk:
                ctx = attn_lib.full_attention(q, k, v, causal=True, window=win)
            else:
                ctx = attn_lib.chunked_attention(
                    q, k, v, causal=True, chunk=cfg.attn_chunk, window=win)
            new_cache = cache
            if mode == "prefill" and cache is not None:
                clen = cache["k"].shape[1]
                if win:
                    # keep the trailing window in ring order
                    m = min(s, clen)
                    idx = (jnp.arange(s - m, s)) % clen
                    kc = cache["k"].at[:, idx].set(k[:, -m:])
                    vc = cache["v"].at[:, idx].set(v[:, -m:])
                else:
                    kc = jax.lax.dynamic_update_slice(
                        cache["k"], k, (0, 0, 0, 0))
                    vc = jax.lax.dynamic_update_slice(
                        cache["v"], v, (0, 0, 0, 0))
                new_cache = dict(cache, k=kc, v=vc)
        y_attn = attn_lib.output_proj(ap, ctx)
        if kind == "attn":
            return y_attn, new_cache, aux

        # hymba: parallel mamba head on the same normed input, mean fusion
        if mode == "decode":
            y_m, (h_new, conv_new) = ssm_lib.mamba_decode(
                p["mamba"], x, cfg, (cache["h"], cache["conv"]))
            new_cache = dict(new_cache, h=h_new, conv=conv_new)
        elif mode == "prefill" and cache is not None:
            y_m, (h_new, conv_new) = ssm_lib.mamba_block(
                p["mamba"], x, cfg, return_state=True)
            new_cache = dict(new_cache, h=h_new, conv=conv_new)
        else:
            y_m = ssm_lib.mamba_block(p["mamba"], x, cfg)
        return (y_attn + y_m) * 0.5, new_cache, aux

    if kind == "cross":
        ap = p["attn"]
        if mode == "decode":
            q = jnp.einsum("bsd,dhx->bshx", x, ap["wq"])
            ctx = attn_lib.decode_attention(
                q, cache["k"], cache["v"],
                jnp.full((x.shape[0],), cache["k"].shape[1] - 1, jnp.int32))
            new_cache = cache
        else:
            q = jnp.einsum("bsd,dhx->bshx", x, ap["wq"])
            k = jnp.einsum("bsd,dkx->bskx", memory, ap["wk"])
            v = jnp.einsum("bsd,dkx->bskx", memory, ap["wv"])
            ctx = attn_lib.full_attention(q, k, v, causal=False)
            new_cache = dict(cache, k=k, v=v) if cache is not None else cache
        return attn_lib.output_proj(ap, ctx), new_cache, aux

    if kind == "mamba":
        if mode == "decode":
            y, (h, conv) = ssm_lib.mamba_decode(
                p["mamba"], x, cfg, (cache["h"], cache["conv"]))
            return y, dict(cache, h=h, conv=conv), aux
        if mode == "prefill" and cache is not None:
            y, (h, conv) = ssm_lib.mamba_block(p["mamba"], x, cfg,
                                               return_state=True)
            return y, dict(cache, h=h, conv=conv), aux
        return ssm_lib.mamba_block(p["mamba"], x, cfg), cache, aux

    if kind == "mlstm":
        st = (cache["c"], cache["n"], cache["m"]) if cache else None
        if mode == "decode" or (mode == "prefill" and cache is not None):
            y, (c, n, m) = ssm_lib.mlstm_block(p["mlstm"], x, cfg, state=st
                                               if mode == "decode" else None,
                                               return_state=True)
            return y, dict(cache, c=c, n=n, m=m), aux
        return ssm_lib.mlstm_block(p["mlstm"], x, cfg), cache, aux

    if kind == "slstm":
        st = (cache["c"], cache["n"], cache["h"], cache["m"]) if cache else None
        if mode == "decode" or (mode == "prefill" and cache is not None):
            y, (c, n, h, m) = ssm_lib.slstm_block(
                p["slstm"], x, cfg,
                state=st if mode == "decode" else None, return_state=True)
            return y, dict(cache, c=c, n=n, h=h, m=m), aux
        return ssm_lib.slstm_block(p["slstm"], x, cfg), cache, aux

    raise ValueError(kind)


# ------------------------------------------------------------------ units

def _constrain_dp(x, cfg):
    """Pin the residual stream's batch dim to the DP mesh axes (§Perf lever:
    stops GSPMD from dropping batch sharding inside the layer scan, which
    otherwise degenerates into activation-sized partial-sum all-reduces).

    No-op outside an ambient-mesh context, when the batch does not divide
    the DP axes, or for single-token (decode) tensors — the optimized sweep
    showed decode layouts are already fine and forced reshards only add
    wire bytes (EXPERIMENTS.md §Perf, optimized full sweep)."""
    if not cfg.constrain_acts:
        return x
    if x.ndim >= 2 and x.shape[1] == 1:          # decode step
        return x
    try:
        from repro.utils import compat
        mesh = compat.get_abstract_mesh()
        names = getattr(mesh, "axis_names", ()) or ()
        dp = tuple(a for a in ("pod", "data") if a in names)
        if not dp:
            return x
        dpn = 1
        for a in dp:
            dpn *= mesh.shape[a]
        if dpn <= 1 or x.shape[0] % dpn:
            return x
        from jax.sharding import PartitionSpec as P
        spec = P(dp, *([None] * (x.ndim - 1)))
        if isinstance(mesh, jax.sharding.Mesh):
            # old jax: no ambient-mesh context — bind the mesh explicitly
            spec = jax.sharding.NamedSharding(mesh, spec)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:       # noqa: BLE001 — constraint is best-effort
        return x


def apply_unit(up, x, cfg, *, positions, mode, cache=None, pos=None,
               memory=None, decoder=True):
    aux = jnp.float32(0.0)
    new_cache = {} if cache is not None else None
    for i, group in enumerate(cfg.block_pattern):
        lname = f"layer{i}"
        lp = up[lname]
        lcache = cache[lname] if cache is not None else None
        lnew = {}
        kinds = list(group)
        if decoder and cfg.cross_attention:
            # interleave cross-attention after self-attention
            out_kinds = []
            for kd in kinds:
                out_kinds.append(kd)
                if kd == "attn":
                    out_kinds.append("cross")
            kinds = out_kinds
        for kind in kinds:
            bp = lp[kind]
            x = _constrain_dp(x, cfg)
            h = layers.rmsnorm(bp["norm"], x, cfg.norm_eps)
            delta, kc, a = apply_sub(
                kind, bp, h, cfg, positions=positions, mode=mode,
                cache=(lcache.get(kind) if lcache is not None else None),
                pos=pos, memory=memory)
            x = x + delta
            aux = aux + a
            if new_cache is not None:
                lnew[kind] = kc if kc is not None else {}
        if new_cache is not None:
            new_cache[lname] = lnew
    return x, new_cache, aux


def apply_stack(stacked_params, x, cfg, *, positions, mode, cache=None,
                pos=None, memory=None, decoder=True, remat=None):
    """Scan the repeating unit over the stacked 'layers' axis."""
    remat = remat if remat is not None else cfg.remat

    def body(carry, scanned):
        xc, aux = carry
        up, uc = scanned
        xn, nc, a = apply_unit(up, xc, cfg, positions=positions, mode=mode,
                               cache=uc, pos=pos, memory=memory,
                               decoder=decoder)
        return (xn, aux + a), nc

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)

    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (stacked_params, cache))
    return x, new_cache, aux


# ------------------------------------------------------------------ models

def encode(params, cfg, enc_embeds):
    """Whisper-style encoder over precomputed frame embeddings (B, L, d)."""
    d = cfg.d_model
    pos_emb = layers.sinusoidal_positions(enc_embeds.shape[1], d,
                                          enc_embeds.dtype)
    x = enc_embeds + pos_emb[None]
    if "audio_adapter" in params:
        x = jnp.einsum("bld,de->ble", x, params["audio_adapter"]["proj"])
    enc_cfg_pattern = (("attn", "gmlp"),)
    ecfg = cfg.replace(block_pattern=enc_cfg_pattern, cross_attention=False,
                       sliding_window=None, n_layers=cfg.encoder_layers)

    def body(xc, up):
        # encoder attention is bidirectional: reuse apply_unit w/ full attn
        for i, group in enumerate((("attn", "gmlp"),)):
            lp = up[f"layer{i}"]
            for kind in group:
                bp = lp[kind]
                h = layers.rmsnorm(bp["norm"], xc, cfg.norm_eps)
                if kind == "attn":
                    q, k, v = attn_lib.project_qkv(
                        bp["attn"], ecfg, h, h,
                        jnp.arange(h.shape[1]), jnp.arange(h.shape[1]),
                        rope=False)
                    ctx = attn_lib.full_attention(q, k, v, causal=False)
                    xc = xc + attn_lib.output_proj(bp["attn"], ctx)
                else:
                    xc = xc + layers.gelu_mlp(bp["mlp"], h)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return layers.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg, tokens, *, mode: str = "train", cache=None,
            pos=None, prefix_embeds=None, enc_embeds=None, remat=None):
    """Top-level forward.

    tokens (B, S) int32; prefix_embeds (B, P, d) for VLM; enc_embeds
    (B, L, d) for audio.  Returns (logits, new_cache, aux_loss).
    """
    x = layers.embed(params["embed"], tokens).astype(_dt(cfg))
    offset = 0
    if prefix_embeds is not None:
        pe = jnp.einsum("bpd,de->bpe", prefix_embeds.astype(_dt(cfg)),
                        params["vision_adapter"]["proj"])
        x = jnp.concatenate([pe, x], axis=1)
        offset = prefix_embeds.shape[1]
    memory = None
    if cfg.encoder_layers and enc_embeds is not None:
        memory = encode(params, cfg, enc_embeds.astype(_dt(cfg)))

    if mode == "decode":
        positions = None
    else:
        positions = jnp.arange(x.shape[1])[None, :]

    x, new_cache, aux = apply_stack(
        params["layers"], x, cfg, positions=positions, mode=mode,
        cache=cache, pos=pos, memory=memory, remat=remat)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.output_head(params["head"], x)
    if offset:
        logits = logits[:, offset:]
    return logits, new_cache, aux
