from .lm import Model, causal_lm_loss
