"""Minimal parameter/spec system (no flax dependency).

A model is described by a *spec tree*: nested dicts whose leaves are
``Param(shape, logical_axes, init, dtype)``.  From the same spec we derive:

  * concrete initialisation (PRNG)              — tests / real training
  * abstract ShapeDtypeStructs                  — dry-run lowering
  * NamedShardings via sharding.rules           — pjit in/out shardings

Logical axis names used across the zoo:
  "embed"   — d_model dim            (FSDP -> data axis by default)
  "heads"   — attention head dim     (TP -> model axis)
  "kv"      — kv head dim
  "mlp"     — feed-forward hidden    (TP -> model axis)
  "vocab"   — (padded) vocabulary    (TP -> model axis)
  "expert"  — MoE expert dim         (EP -> model axis)
  "layers"  — stacked repeat dim     (never sharded)
  None      — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"         # normal | zeros | ones | embed | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param(x) -> bool:
    return isinstance(x, Param)


def map_spec(fn: Callable, spec):
    """Map fn over Param leaves of a nested dict tree."""
    if is_param(spec):
        return fn(spec)
    if isinstance(spec, dict):
        return {k: map_spec(fn, v) for k, v in spec.items()}
    raise TypeError(type(spec))


def init_params(spec, key: jax.Array, dtype=jnp.float32):
    """Concrete init. Deterministic per-leaf keys derived from tree paths."""
    leaves = []

    def collect(path, s):
        if is_param(s):
            leaves.append((path, s))
        else:
            for k in sorted(s):
                collect(path + (k,), s[k])

    collect((), spec)
    keys = jax.random.split(key, max(1, len(leaves)))
    out: dict = {}
    for (path, p), k in zip(leaves, keys):
        if p.init == "zeros":
            val = jnp.zeros(p.shape, dtype)
        elif p.init == "ones":
            val = jnp.ones(p.shape, dtype)
        else:
            fan_in = p.shape[0] if len(p.shape) > 1 else max(p.shape[0], 1)
            std = p.scale / math.sqrt(fan_in)
            if p.init == "embed":
                std = p.scale * 0.02
            elif p.init == "small":
                std = p.scale * 0.006
            val = (jax.random.normal(k, p.shape, jnp.float32) * std).astype(dtype)
        node = out
        for seg in path[:-1]:
            node = node.setdefault(seg, {})
        node[path[-1]] = val
    return out


def abstract_params(spec, dtype=jnp.float32):
    return map_spec(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec)


def spec_axes(spec):
    return map_spec(lambda p: p.axes, spec)


def count_params(spec) -> int:
    total = [0]
    map_spec(lambda p: total.__setitem__(0, total[0] + int(np.prod(p.shape))),
             spec)
    return total[0]


def stack_spec(spec, reps: int):
    """Prepend a 'layers' axis to every leaf (for scan-over-layers)."""
    return map_spec(
        lambda p: Param((reps,) + p.shape, ("layers",) + p.axes,
                        p.init, p.scale), spec)
