"""Attention: GQA + qk-norm + RoPE + sliding window + cross + KV-cache decode.

Training / prefill attention is **doubly-chunked with an online softmax**
(flash-attention schedule expressed in pure JAX): an outer ``lax.scan`` over
query chunks and an inner scan over key/value chunks, fp32 accumulators.
This bounds activation memory at O(Cq*Ck) per block instead of O(S^2) —
required for the 32k-prefill shapes to fit HBM.

GQA is computed with grouped einsums (no materialised head repetition):
q is viewed as (B, S, K, G, hd) with H = K*G.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .params import Param
from . import layers

NEG = -1e30


def attention_spec(cfg, cross: bool = False) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    if cross:
        k = h                     # whisper cross-attention is MHA
    spec = {
        "wq": Param((d, h, hd), ("embed", "heads", None)),
        "wk": Param((d, k, hd), ("embed", "kv", None)),
        "wv": Param((d, k, hd), ("embed", "kv", None)),
        "wo": Param((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = layers.rmsnorm_spec(hd)
        spec["k_norm"] = layers.rmsnorm_spec(hd)
    return spec


def project_qkv(p, cfg, xq, xkv, positions_q, positions_kv, rope: bool = True):
    """Returns q (B,Sq,H,hd), k/v (B,Skv,K,hd), rope+qk-norm applied."""
    q = jnp.einsum("bsd,dhx->bshx", xq, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", xkv, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", xkv, p["wv"])
    if "q_norm" in p:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        cos_q, sin_q = layers.rope_angles(positions_q, cfg.hd, cfg.rope_theta)
        cos_k, sin_k = layers.rope_angles(positions_kv, cfg.hd, cfg.rope_theta)
        q = layers.apply_rope(q, cos_q, sin_q)
        k = layers.apply_rope(k, cos_k, sin_k)
    return q, k, v


def output_proj(p, ctx):
    """ctx (B, S, H, hd) -> (B, S, d)."""
    return jnp.einsum("bshx,hxd->bsd", ctx, p["wo"])


# ----------------------------------------------------- chunked online softmax

def chunked_attention(q, k, v, *, causal: bool, chunk: int,
                      window: Optional[int] = None,
                      q_offset=0, k_offset=0):
    """q (B,Sq,H,hd), k/v (B,Skv,K,hd) -> (B,Sq,H,hd).

    Double-chunked flash schedule; all-mask blocks still execute (static
    trip counts — see EXPERIMENTS.md §Perf for the triangular-skip variant).
    """
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    cq = min(chunk, sq)
    ck = min(chunk, skv)
    assert sq % cq == 0 and skv % ck == 0, (sq, cq, skv, ck)
    nq, nk = sq // cq, skv // ck
    scale = hd ** -0.5

    qc = q.reshape(b, nq, cq, kh, g, hd).astype(jnp.float32) * scale
    kc = k.reshape(b, nk, ck, kh, hd)
    vc = v.reshape(b, nk, ck, kh, hd)

    def q_block(_, qi_and_block):
        qi, qb = qi_and_block                       # qb (B,cq,K,G,hd)
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def kv_block(carry, kj_and_kv):
            m, l, acc = carry
            kj, kb, vb = kj_and_kv
            kpos = k_offset + kj * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgx,bckx->bqkgc", qb,
                           kb.astype(jnp.float32))
            mask = jnp.ones((cq, ck), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, :, None, None, :], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckx->bqkgx", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((b, cq, kh, g), NEG, jnp.float32),
                jnp.zeros((b, cq, kh, g), jnp.float32),
                jnp.zeros((b, cq, kh, g, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init,
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out

    _, blocks = jax.lax.scan(
        q_block, None, (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    # blocks (nq, B, cq, K, G, hd) -> (B, S, H, hd)
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, kh, g, hd)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                   q_offset=0, k_offset=0):
    """Reference unchunked attention (short sequences / encoder / tests)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = hd ** -0.5
    qg = q.reshape(b, sq, kh, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqkgx,bckx->bqkgc", qg, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(sq)
    kpos = k_offset + jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckx->bqkgx", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ------------------------------------------------------------------- decode

def decode_attention(q, k_cache, v_cache, pos, *,
                     window: Optional[int] = None):
    """Single-token decode: q (B,1,H,hd); cache (B,Smax,K,hd); pos (B,).

    Attends to cache positions <= pos (per slot), optional sliding window.
    """
    b, _, h, hd = q.shape
    smax, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = hd ** -0.5
    qg = q.reshape(b, kh, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgx,bckx->bkgc", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(smax)
    mask = kpos[None, :] <= pos[:, None]
    if window is not None:
        mask &= (pos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckx->bkgx", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def update_cache(k_cache, v_cache, k_new, v_new, pos):
    """Write k/v_new (B,1,K,hd) at per-slot positions pos (B,)."""
    def write(cache, new, p):
        return jax.lax.dynamic_update_slice(cache, new, (p, 0, 0))
    k_cache = jax.vmap(write)(k_cache, k_new, pos)
    v_cache = jax.vmap(write)(v_cache, v_new, pos)
    return k_cache, v_cache


def update_window_cache(k_cache, v_cache, k_new, v_new, pos):
    """Ring-buffer write for sliding-window caches: slot = pos % window."""
    win = k_cache.shape[1]
    return update_cache(k_cache, v_cache, k_new, v_new, pos % win)


def decode_window_attention(q, k_cache, v_cache, pos, window: int):
    """Decode against a ring-buffer cache of size ``window``."""
    b, _, h, hd = q.shape
    win, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = hd ** -0.5
    qg = q.reshape(b, kh, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgx,bckx->bkgc", qg, k_cache.astype(jnp.float32))
    slot = jnp.arange(win)
    # slot holds absolute position: p_abs = pos - ((pos - slot) mod win)
    age = (pos[:, None] - slot[None, :]) % win
    p_abs = pos[:, None] - age
    mask = (p_abs >= 0) & (p_abs <= pos[:, None])
    s = jnp.where(mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckx->bkgx", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
