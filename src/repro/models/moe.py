"""Mixture-of-Experts with capacity-factor dispatch (Switch/GShard style).

Dispatch is sort-based rather than the dense (T, E, C) one-hot einsum: token
choices are sorted by expert id, ranked within their expert group, and
scattered into per-expert capacity buffers — O(T * d) memory instead of
O(T * E * C).  This reuses the exact bucket-building pattern of the
treewidth solver's ownership routing (core/distributed.py) — the same
"route by key, fixed per-destination capacity, drop overflow" machinery the
paper's Bloom filter was replaced with.

Experts are sharded over the "expert" logical axis (-> model mesh axis);
tokens stay data-sharded, and GSPMD inserts the dispatch all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import Param


def moe_spec(cfg) -> dict:
    d, m = cfg.d_model, cfg.moe
    spec = {
        "router": Param((d, m.n_experts), ("embed", None), "small"),
        "wi_gate": Param((m.n_experts, d, m.d_ff_expert),
                         ("expert", "embed", "mlp")),
        "wi_up": Param((m.n_experts, d, m.d_ff_expert),
                       ("expert", "embed", "mlp")),
        "wo": Param((m.n_experts, m.d_ff_expert, d),
                    ("expert", "mlp", "embed")),
    }
    if m.shared_expert:
        spec["shared"] = {
            "wi_gate": Param((d, m.d_ff_expert), ("embed", "mlp")),
            "wi_up": Param((d, m.d_ff_expert), ("embed", "mlp")),
            "wo": Param((m.d_ff_expert, d), ("mlp", "embed")),
        }
    return spec


def _capacity(tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)       # round up to 8


def moe_block(p, x, cfg):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    cap = _capacity(t, cfg)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)           # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- load-balance + router-z auxiliary losses (Switch Transformer)
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, m.n_experts), axis=1), axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    zloss = m.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux_loss = aux + zloss

    # ---- sort-based capacity dispatch
    flat_e = top_e.reshape(-1)                              # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), m.top_k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    counts = jnp.bincount(flat_e, length=m.n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * m.top_k) - starts[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, m.n_experts * cap)

    buf = jnp.zeros((m.n_experts * cap, d), dtype=x.dtype)
    buf = buf.at[slot].set(xt[tok_sorted], mode="drop")
    buf = buf.reshape(m.n_experts, cap, d)

    # ---- expert FFN (E sharded over the model axis)
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["wo"])
    eo = eo.reshape(m.n_experts * cap, d)

    # ---- combine (weighted scatter-add back to token order)
    y = jnp.zeros((t, d), dtype=jnp.float32)
    contrib = eo[jnp.minimum(slot, m.n_experts * cap - 1)].astype(jnp.float32)
    contrib = contrib * (w_sorted * keep)[:, None]
    y = y.at[tok_sorted].add(contrib, mode="drop")

    if m.shared_expert:
        sp = p["shared"]
        sg = jnp.einsum("td,df->tf", xt, sp["wi_gate"])
        su = jnp.einsum("td,df->tf", xt, sp["wi_up"])
        y = y + jnp.einsum("tf,fd->td",
                           jax.nn.silu(sg) * su, sp["wo"]).astype(jnp.float32)

    return y.reshape(b, s, d).astype(x.dtype), aux_loss


def moe_ref(p, x, cfg):
    """Dense reference (every token through every expert) for tests."""
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    g = jnp.einsum("td,edf->etf", xt, p["wi_gate"])
    u = jnp.einsum("td,edf->etf", xt, p["wi_up"])
    eo = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, p["wo"])  # (E,T,d)
    w_full = jnp.zeros_like(probs)
    for j in range(m.top_k):
        w_full = w_full.at[jnp.arange(xt.shape[0]), top_e[:, j]].add(
            top_w[:, j])
    y = jnp.einsum("te,etd->td", w_full, eo.astype(jnp.float32))
    if m.shared_expert:
        sp = p["shared"]
        sg = jnp.einsum("td,df->tf", xt, sp["wi_gate"])
        su = jnp.einsum("td,df->tf", xt, sp["wi_up"])
        y = y + jnp.einsum("tf,fd->td",
                           jax.nn.silu(sg) * su, sp["wo"]).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype)
