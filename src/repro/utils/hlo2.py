"""Loop-aware HLO collective accounting.

XLA's plain-text HLO lists a ``while`` body once, but a scan-over-layers
body executes ``known_trip_count`` times — collectives inside it (e.g.
per-layer tensor-parallel all-reduces) must be scaled by the trip count for
the roofline's collective term to be honest.

The optimized module conveniently annotates every loop:
  while(...), condition=%c, body=%b, ...
      backend_config={"known_trip_count":{"n":"28"}, ...}
so accounting is: bytes(comp) = direct collective bytes
                               + sum over while ops: trips * bytes(body).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_WIRE_FACTOR = {
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(pred|[sufbc]\w*?\d+)\[([\d,]*)\]")
_HDR = re.compile(r"^(?:ENTRY )?(%[\w.\-]+) \(", re.M)
_COLL = re.compile(
    r"= (\([^)]*\)|\S+) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_WHILE = re.compile(
    r"while\(%[\w.\-]+\), condition=%[\w.\-]+, body=(%[\w.\-]+)"
    r".*?backend_config=(\{.*?\})(?:\n|$)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def split_computations(text: str) -> dict:
    comps = {}
    matches = list(_HDR.finditer(text))
    for i, m in enumerate(matches):
        end = matches[i + 1].start() if i + 1 < len(matches) else len(text)
        comps[m.group(1)] = text[m.start():end]
    return comps


def _trips(backend_config: str) -> int:
    try:
        return int(json.loads(backend_config)
                   .get("known_trip_count", {}).get("n", 1))
    except (json.JSONDecodeError, TypeError, ValueError):
        return 1


def collective_bytes_scaled(text: str) -> dict:
    comps = split_computations(text)
    entry_m = re.search(r"^ENTRY (%[\w.\-]+)", text, re.M)
    memo: dict = {}

    def acc(name: str, depth: int = 0) -> dict:
        if name in memo:
            return memo[name]
        body = comps.get(name)
        if body is None or depth > 16:
            return {}
        out: dict = defaultdict(float)
        for m in _COLL.finditer(body):
            out[m.group(2)] += _shape_bytes(m.group(1))
        for m in _WHILE.finditer(body):
            sub = acc(m.group(1), depth + 1)
            t = _trips(m.group(2))
            for k, v in sub.items():
                out[k] += v * t
        memo[name] = dict(out)
        return memo[name]

    total: dict = defaultdict(float)
    if entry_m:
        for k, v in acc(entry_m.group(1)).items():
            total[k] += v
    stats = dict(total)
    stats["total_bytes"] = sum(total.values())
    stats["wire_bytes"] = sum(
        v * _WIRE_FACTOR.get(k, 1.0) for k, v in total.items())
    return stats


def while_summary(text: str):
    """[(body, trips)] for reporting."""
    return [(m.group(1), _trips(m.group(2)))
            for m in _WHILE.finditer(text)]
