"""Version compatibility shims for the jax API surface we depend on.

The repo targets the newer ambient-mesh API (``jax.sharding.set_mesh`` /
``get_abstract_mesh`` / top-level ``jax.shard_map``); the pinned toolchain
(jax 0.4.37) predates all three.  Every call site goes through this module
so the drift is handled in exactly one place:

* ``set_mesh`` / ``get_abstract_mesh`` — on old jax the ambient mesh is a
  module-level global here.  Callers must treat the result as *maybe None*
  and guard on ``getattr(mesh, "axis_names", None)`` (they already do: the
  ambient mesh is a best-effort sharding hint everywhere it is read).
* ``shard_map`` — maps the new ``axis_names={...}`` (manual axes) kwarg to
  the old ``auto=frozenset(...)`` complement form.
* ``cost_analysis_dict`` — ``Compiled.cost_analysis()`` returned a
  one-dict-per-computation *list* on old jax, a flat dict on new.
"""
from __future__ import annotations

from typing import Optional

import jax

_AMBIENT_MESH: Optional["jax.sharding.Mesh"] = None


def set_mesh(mesh) -> None:
    """Install ``mesh`` as the ambient mesh (process-wide, no context)."""
    global _AMBIENT_MESH
    if hasattr(jax.sharding, "set_mesh"):
        jax.sharding.set_mesh(mesh)
        return
    _AMBIENT_MESH = mesh


def get_abstract_mesh():
    """The ambient (abstract) mesh, or None when none is installed.

    On old jax this returns the *concrete* Mesh passed to ``set_mesh``;
    concrete meshes expose the same ``axis_names`` / ``shape`` surface the
    callers consume, and ``NamedSharding`` accepts them directly.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    return _AMBIENT_MESH


def shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` with partial-manual axes on both API generations."""
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {"check_rep": False}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def cost_analysis_dict(compiled) -> dict:
    """Flat {metric: value} cost analysis for a ``Compiled`` object."""
    cost = compiled.cost_analysis()
    if isinstance(cost, dict):
        return cost
    if not cost:                     # old jax: list of per-computation dicts
        return {}
    out: dict = {}
    for entry in cost:
        for k, v in entry.items():
            try:
                out[k] = out.get(k, 0.0) + float(v)
            except (TypeError, ValueError):
                out.setdefault(k, v)
    return out
