"""HLO text analysis: collective-byte accounting for the roofline.

``cost_analysis`` reports FLOPs and bytes but not collective traffic; we
parse the (post-SPMD, per-device) HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their operand
bytes, weighted by the algorithmic wire factor of each collective.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# bytes-on-wire multiplier per element byte (ring algorithms, large N limit)
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(pred|[sufbc]\w*?\d+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device collective bytes by op kind.

    Returns {kind: bytes} plus 'wire_bytes' (wire-factor weighted total)
    and 'total_bytes' (unweighted).
    """
    out = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        result_shape, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(result_shape)
    stats = dict(out)
    stats["total_bytes"] = sum(out.values())
    stats["wire_bytes"] = sum(
        v * _WIRE_FACTOR.get(k, 1.0) for k, v in out.items())
    return stats


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo_text))
