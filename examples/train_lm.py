"""Train a small qwen3-family model on synthetic data for a few hundred
steps with checkpointing (CPU-runnable end-to-end training driver).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys

from repro.launch import train

steps = "300"
if "--steps" in sys.argv:
    steps = sys.argv[sys.argv.index("--steps") + 1]

train.main([
    "--arch", "qwen3-0.6b", "--reduced",
    "--steps", steps, "--batch", "8", "--seq", "128",
    "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_train_lm",
    "--ckpt-every", "100", "--log-every", "20",
])
