"""Quickstart: compute the treewidth of a graph with the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import graph, solver

# build a graph (generators, DIMACS files, or edge lists)
g = graph.queen(5)                       # 5x5 queen graph, tw = 18
print(f"graph {g.name}: {g.n} vertices, {g.n_edges} edges")

# solve: iterative-deepening wavefront DP (paper Listing 1) with exact
# sort-based dedup.  reconstruct=True returns a certified elimination
# order — it composes with the default preprocessing (safe-separator
# blocks are reconstructed individually and stitched back through the
# preprocess vertex maps)
res = solver.solve(g, cap=1 << 16, block=1 << 10, reconstruct=True)
print(f"treewidth = {res.width} (exact={res.exact})")
print(f"explored {res.expanded} states in {res.time_sec:.2f}s")

# the elimination order is a checkable certificate
width = solver.order_width(g, res.order)
print(f"certificate: replaying the order gives width {width}")
assert width == res.width

# speculative deepening: decide several widths per dispatch through the
# multi-lane engine (same results, fewer dispatches — see core/batch.py;
# batch.solve_many batches across whole instance suites the same way)
res_lanes = solver.solve(g, cap=1 << 16, block=1 << 10, lanes=4)
assert res_lanes.width == res.width

# paper-faithful Bloom-filter dedup (Monte Carlo) for comparison
res_bloom = solver.solve(g, cap=1 << 16, block=1 << 10, mode="bloom",
                         m_bits=1 << 22)
print(f"bloom mode: treewidth = {res_bloom.width} "
      f"(expanded {res_bloom.expanded})")
