"""Serve a small model with continuous batching (more requests than slots).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve

serve.main([
    "--arch", "qwen3-0.6b", "--reduced",
    "--requests", "12", "--slots", "4",
    "--prompt-len", "16", "--max-new", "24", "--cache-len", "128",
])
