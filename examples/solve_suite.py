"""End-to-end driver (the paper's workload): solve a benchmark suite and
print a Table-1 style report.

    PYTHONPATH=src python examples/solve_suite.py [--full] [--batch [LANES]]

``--batch`` solves the whole suite through the multi-lane engine
(``repro.core.batch.solve_many``): instead of one dispatch per
(instance, k), every scheduler round packs the current deepening rung of
every unfinished instance into shared multi-lane dispatches.  Same
widths/exactness, far fewer dispatches — the report prints both counters.
"""
import sys
import time

from repro.core import batch, engine, graph, solver

SUITE = [("myciel3", 5), ("petersen", 4), ("queen5_5", 18),
         ("queen6_6", 25), ("myciel4", 10), ("desargues", 6)]
if "--full" in sys.argv:
    SUITE += [("mcgee", 7), ("dyck", 7), ("queen7_7", 35)]


def _batch_lanes(argv):
    """0 = sequential; --batch alone = default lanes; --batch N = N."""
    if "--batch" not in argv:
        return 0
    i = argv.index("--batch")
    if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
        return batch.DEFAULT_MAX_LANES
    try:
        lanes = int(argv[i + 1])
    except ValueError:
        sys.exit(f"--batch expects a lane count, got {argv[i + 1]!r}")
    if lanes < 1:
        sys.exit(f"--batch expects a lane count >= 1, got {lanes}")
    return lanes


def main(argv):
    lanes = _batch_lanes(argv)
    kw = dict(cap=1 << 18, block=1 << 10)
    names = [key for key, _ in SUITE]
    gs = [graph.REGISTRY[key]() for key in names]

    print(f"{'name':<12} {'|V|':>4} {'tw':>4} {'exact':>6} "
          f"{'time(s)':>8} {'Exp':>10}")
    engine.reset_counters()
    t0 = time.time()
    if lanes:
        results = batch.solve_many(gs, lanes=lanes, **kw)
        times = [None] * len(gs)       # lanes overlap; per-instance wall
        total_t = time.time() - t0     # time is the suite wall-clock
    else:
        results, times = [], []
        for g in gs:
            t1 = time.time()
            results.append(solver.solve(g, **kw))
            times.append(time.time() - t1)
        total_t = time.time() - t0
    counters = dict(engine.COUNTERS)

    total_exp = 0
    for (key, want), g, res, dt in zip(SUITE, gs, results, times):
        total_exp += res.expanded
        flag = "" if res.width == want else f"  (expected {want}!)"
        tcol = f"{dt:>8.2f}" if dt is not None else f"{'—':>8}"
        print(f"{key:<12} {g.n:>4} {res.width:>4} {str(res.exact):>6} "
              f"{tcol} {res.expanded:>10}{flag}")
    mode = f"solve_many lanes={lanes}" if lanes else "sequential"
    print(f"\ntotal ({mode}): {total_t:.1f}s, {total_exp} states "
          f"({total_exp / max(total_t, 1e-9):.0f} states/s), "
          f"{counters['dispatches']} dispatches, "
          f"{counters['host_syncs']} host syncs")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
